"""minloc_packed (core/sharded.py): tie-breaking and index-bit-packing
bounds — previously covered only by one multi-device smoke pass in
test_integration.py.

The packed variant rides on two invariants this file pins down directly:

1. non-negative f32 distances (INF included) compare identically to their
   IEEE-754 bit patterns viewed as u32 — so one u32 min over the packed
   pairs is the distance min;
2. any valid vertex index (int32, so <= 2^31 - 1 even at the largest
   addressable n) fits a u32 below the 0xFFFFFFFF tie-break sentinel, so
   the second u32 min picks the smallest index among equal distances.

The P=1 shard_map roundtrips run on the single real CPU device; the
cross-device tie-break cases force 4 host devices in a subprocess like the
other multi-device tests.
"""
import functools
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core._compat import make_mesh, shard_map
from repro.core.sharded import minloc_allgather, minloc_packed, minloc_pmin

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

I32_MAX = np.iinfo(np.int32).max


def test_f32_bit_pattern_order_matches_float_order():
    """Invariant 1, at the bit level: sorting non-negative f32 (with INF
    and the largest finite float) by u32 bit pattern equals sorting by
    value — the property the one-collective pack relies on."""
    rng = np.random.default_rng(0)
    d = np.concatenate([
        rng.uniform(0, 1e30, 500).astype(np.float32),
        np.float32([0.0, np.inf, np.finfo(np.float32).max,
                    np.finfo(np.float32).tiny, 1e-38, 3.0, 3.0]),
    ])
    bits = d.view(np.uint32)
    assert (d[np.argsort(bits, kind="stable")]
            == d[np.argsort(d, kind="stable")]).all()


def test_index_packing_bounds_at_large_n():
    """Invariant 2: the largest int32 vertex id survives the u32 round
    trip and still loses to the 0xFFFFFFFF sentinel."""
    idx = jnp.int32(I32_MAX)
    as_u32 = idx.astype(jnp.uint32)
    assert int(as_u32) == I32_MAX
    assert int(as_u32) < 0xFFFFFFFF
    assert int(as_u32.astype(jnp.int32)) == I32_MAX


def _run_minloc_p1(fn, d, idx):
    mesh = make_mesh((1,), ("data",))

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False)
    def run(d, i):
        best, bi = fn(d[0], i[0], "data")
        return best[None], bi[None]

    best, bi = run(jnp.float32([d]), jnp.int32([idx]))
    return float(best[0]), int(bi[0])


@pytest.mark.parametrize("fn", [minloc_allgather, minloc_pmin, minloc_packed])
@pytest.mark.parametrize("d,idx", [
    (0.0, 0),
    (3.5, 7),
    (1e-38, I32_MAX),                  # tiny dist, largest packable index
    (np.float32(np.finfo(np.float32).max), I32_MAX),
    (np.inf, I32_MAX),                 # unreachable-candidate sentinel path
])
def test_minloc_p1_roundtrip_exact(fn, d, idx):
    """P=1 collective roundtrip: the packed bitcasts must return the exact
    distance bits and index, including +inf and extreme magnitudes."""
    best, bi = _run_minloc_p1(fn, d, idx)
    ref = np.float32(d)
    assert (np.isinf(best) and np.isinf(ref)) or np.float32(best) == ref
    assert bi == idx


_MULTIDEV_CODE = """
import functools
import numpy as np
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core._compat import make_mesh, shard_map
from repro.core.sharded import minloc_allgather, minloc_packed, minloc_pmin

I32_MAX = np.iinfo(np.int32).max
mesh = make_mesh((4,), ("data",))

def reference(ds, idxs):
    best = np.min(ds)
    cand = [i for d, i in zip(ds, idxs) if d == best]
    return best, min(cand)

CASES = [
    # exact cross-device distance ties -> smallest index must win
    ([5.0, 5.0, 5.0, 7.0], [9, 3, I32_MAX, 1]),
    ([5.0, 5.0, 5.0, 5.0], [I32_MAX, I32_MAX - 1, 4, 4]),
    # large-n regime: all indices above 2^30, near the packing ceiling
    ([2.0, 2.0, 3.0, 2.0], [I32_MAX, I32_MAX - 7, 2**30, I32_MAX - 7]),
    # INF candidates (unreachable) must lose to any finite distance
    ([float("inf"), 8.0, float("inf"), 8.0], [0, I32_MAX, 1, 5]),
    # everything unreachable: agree on distance INF + the index tie-break
    ([float("inf")] * 4, [I32_MAX, 7, I32_MAX, 9]),
    # denormal-vs-zero ordering survives the bitcast
    ([0.0, float(np.finfo(np.float32).tiny), 1.0, 0.0], [8, 0, 1, 2]),
]

for fn in (minloc_allgather, minloc_pmin, minloc_packed):
    @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P(), P()), check_vma=False)
    def run(d, i):
        best, bi = fn(d[0], i[0], "data")
        return best[None], bi[None]

    for ds, idxs in CASES:
        best, bi = run(jnp.float32(ds), jnp.int32(idxs))
        rb, ri = reference(np.float32(ds), idxs)
        got = (float(best[0]), int(bi[0]))
        ok = (np.isinf(got[0]) and np.isinf(rb)) or got[0] == rb
        assert ok and got[1] == ri, (fn.__name__, ds, idxs, got, (rb, ri))
print("MINLOC_TIEBREAK_OK")
"""


@pytest.mark.slow
def test_minloc_tiebreak_multidevice_all_variants_match_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    r = subprocess.run([sys.executable, "-c", _MULTIDEV_CODE],
                       capture_output=True, text=True, env=env, timeout=600)
    assert "MINLOC_TIEBREAK_OK" in r.stdout, r.stdout + r.stderr
