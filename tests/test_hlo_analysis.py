"""HLO analyzer: loop weighting, dot-FLOP accounting, collective payloads."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.launch import hlo_analysis as H


def _stats(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return H.weighted_stats(c.as_text())


def test_scan_weighted_equals_unrolled():
    d = 128
    W = jax.ShapeDtypeStruct((d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((8, d), jnp.float32)

    def scanned(w, x):
        out, _ = lax.scan(lambda c, _: (c @ w, None), x, None, length=8)
        return out

    def unrolled(w, x):
        for _ in range(8):
            x = x @ w
        return x

    s1, s2 = _stats(scanned, W, x), _stats(unrolled, W, x)
    assert s1.dot_flops == s2.dot_flops == 8 * 2 * 8 * d * d


def test_single_dot_flops():
    a = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 8), jnp.float32)
    s = _stats(lambda a, b: a @ b, a, b)
    assert s.dot_flops == 2 * 16 * 32 * 8


def test_nested_scans_multiply():
    d = 64
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)

    def nested(x):
        def inner(c, _):
            return c @ c, None

        def outer(c, _):
            c2, _ = lax.scan(inner, c, None, length=3)
            return c2, None

        out, _ = lax.scan(outer, x, None, length=5)
        return out

    s = _stats(nested, x)
    assert s.dot_flops == 5 * 3 * 2 * d * d * d


def test_elementwise_vector_flops():
    x = jax.ShapeDtypeStruct((100,), jnp.float32)
    s = _stats(lambda x: jnp.tanh(x) + x, x)
    assert s.vector_flops >= 200           # tanh + add, 100 elements each


def test_shape_bytes_parser():
    assert H._shape_bytes("f32[16,1024]") == 16 * 1024 * 4
    assert H._shape_bytes("bf16[4,2,8]{2,1,0}") == 64 * 2
    assert H._shape_bytes("(f32[8], s32[4])") == 32 + 16
    assert H._shape_bytes("pred[]") == 1
    assert H._shape_bytes("f32[16,1024]{1,0:T(8,128)}") == 16 * 1024 * 4


def test_op_line_parser_tuple_with_comments():
    line = ('  %while.5 = (s32[], f32[8,512]{1,0}, /*index=2*/f32[512,512]) '
            'while(%tuple), condition=%cond, body=%body, '
            'backend_config={"known_trip_count":{"n":"24"}}')
    parsed = H._parse_op_line(line)
    assert parsed is not None
    name, shape, opcode, args, attrs = parsed
    assert name == "while.5" and opcode == "while"
    assert "body" in attrs and H._TRIP.search(attrs).group(1) == "24"


def test_roofline_terms_and_dominant():
    ws = H.WeightedStats()
    ws.dot_flops = H.PEAK_FLOPS          # 1 second of MXU
    ws.traffic_bytes = H.HBM_BW * 2      # 2 seconds of HBM
    ws.collective_bytes["all-reduce"] = H.ICI_BW * 0.5
    r = H.roofline(ws, chips=4, model_flops=H.PEAK_FLOPS * 2)
    assert r.dominant == "memory"
    assert r.bound_time_s == pytest.approx(2.0)
    assert r.useful_ratio == pytest.approx(0.5)
    assert H.mfu_fraction(r, 4) == pytest.approx(
        (H.PEAK_FLOPS * 2) / (4 * H.PEAK_FLOPS * 2.0))


def test_collectives_counted_in_spmd_module():
    """A psum inside shard_map lowers to all-reduce ops we must count."""
    import functools
    from jax.sharding import PartitionSpec as P

    from repro.core._compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("data",))

    @functools.partial(shard_map, mesh=mesh, in_specs=P("data"),
                       out_specs=P())
    def f(x):
        return lax.psum(x, "data")

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 4), jnp.float32)).compile()
    ws = H.weighted_stats(c.as_text())
    assert ws.collective_count["all-reduce"] >= 1
    assert ws.collective_bytes["all-reduce"] > 0
