"""Sharded serving route: dispatch seam + vertex-partitioned serving.

Covers the serve/dispatch.py policy logic (pure, any device count), the
``multisource_csr_sharded`` union-frontier engine's bitwise parity and
its strictly-smaller edge counter (P=1 in-process), shard-aware row
keys and registry partition staging, and — on a real multi-device mesh —
the scheduler's sharded batch/p2p paths end to end.  The in-process
multi-device tests skip on one device and run in CI's ``multidevice``
job (forced 4 host devices); the subprocess tests force their own
device counts and are slow-marked, like tests/test_sharded_csr.py.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from conftest import dijkstra_oracle
from repro.core import csr as C
from repro.core._compat import make_mesh
from repro.core.api import shortest_paths
from repro.serve import (DispatchPolicy, DistanceCache, GraphRegistry,
                         MicroBatchScheduler)
from repro.serve.dispatch import serving_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
NDEV = len(jax.devices())
multidevice = pytest.mark.skipif(
    NDEV < 2, reason="needs >1 device (CI multidevice job forces 4)")


# ---------------------------------------------------------------------------
# dispatch policy (pure logic, any device count)
# ---------------------------------------------------------------------------

def test_policy_would_shard_is_pure_size_check():
    pol = DispatchPolicy(shard_threshold=100)
    if pol.nprocs > 1:
        assert pol.would_shard(100) and pol.would_shard(101)
        assert not pol.would_shard(99)
    else:                       # one device: sharding is never worth it
        assert not pol.would_shard(10**9)
    assert not pol.would_shard(10**9, dynamic=True)
    assert not DispatchPolicy(shard_threshold=None).would_shard(10**9)


def test_policy_clamps_nprocs_to_visible_devices():
    pol = DispatchPolicy(nprocs=10**6)
    assert pol.nprocs == NDEV
    assert DispatchPolicy(nprocs=1).nprocs == 1


def test_policy_single_device_choices():
    pol = DispatchPolicy(shard_threshold=None)
    cg = C.sparse_csr_graph(50, seed=0)
    for kind, engine in (("single", "frontier"),
                         ("batch", "multisource_csr"),
                         ("p2p", "frontier")):
        ch = pol.choose(cg, kind=kind)
        assert (ch.engine, ch.mesh, ch.nprocs) == (engine, None, 1)
        assert not ch.sharded
    with pytest.raises(ValueError, match="unknown kind"):
        pol.choose(cg, kind="bogus")


@multidevice
def test_policy_sharded_choices_and_cached_mesh():
    pol = DispatchPolicy(shard_threshold=100)
    big = C.sparse_csr_graph(200, seed=1)
    for kind, engine in (("single", "frontier_sharded"),
                         ("batch", "multisource_csr_sharded"),
                         ("p2p", "frontier_sharded")):
        ch = pol.choose(big, kind=kind)
        assert ch.engine == engine and ch.sharded
        assert ch.nprocs == pol.nprocs and ch.mesh is not None
    # below threshold stays single-device; the mesh is built once
    assert not pol.choose(C.sparse_csr_graph(50, seed=2)).sharded
    assert (pol.choose(big).mesh
            is serving_mesh(pol.nprocs, pol.axis))


@multidevice
def test_policy_never_shards_dynamic_graphs():
    from repro.dynamic import DynamicGraph

    pol = DispatchPolicy(shard_threshold=10)
    dg = DynamicGraph(C.sparse_csr_graph(200, seed=3))
    assert not pol.choose(dg, kind="batch").sharded
    # and a registered dynamic handle is equally pinned single-device
    reg = GraphRegistry()
    h = reg.register("d", dg)
    assert not pol.choose(h, kind="batch").sharded


# ---------------------------------------------------------------------------
# union-frontier multisource engine, P=1 in-process
# ---------------------------------------------------------------------------

def test_multisource_sharded_p1_bitwise_and_union_edges():
    """Per-source rows bitwise-equal to serial; the union-frontier edge
    counter is STRICTLY below the sum of per-source frontier counters
    whenever frontiers overlap (they always do from sweep 1 on a
    connected corpus: the counter is what gate_sharded measures)."""
    mesh = make_mesh((1,), ("data",))
    for n, m, seed in [(57, 170, 0), (500, 1500, 9)]:
        cg = C.random_csr_graph(n, m, seed=seed)
        srcs = [0, 3, 7, 11]
        res = shortest_paths(cg, srcs, engine="multisource_csr_sharded",
                             mesh=mesh)
        assert res.dist.shape == (4, n) and res.pred is None
        per_source = 0
        for i, s in enumerate(srcs):
            ref = shortest_paths(cg, s, engine="serial")
            assert np.array_equal(res.dist[i], ref.dist), (n, s)
            oracle = dijkstra_oracle(cg, s)
            fin = np.isfinite(oracle)
            assert np.allclose(res.dist[i][fin], oracle[fin], rtol=1e-5)
            per_source += shortest_paths(cg, s,
                                         engine="frontier").edges_relaxed
        assert 0 < res.edges_relaxed < per_source, (n, res.edges_relaxed,
                                                    per_source)


def test_multisource_sharded_p1_matches_multisource_csr():
    mesh = make_mesh((1,), ("data",))
    cg = C.sparse_csr_graph(300, seed=4)
    srcs = [5, 5, 12]                     # duplicate sources are fine
    sh = shortest_paths(cg, srcs, engine="multisource_csr_sharded",
                        mesh=mesh)
    sd = shortest_paths(cg, srcs, engine="multisource_csr")
    assert np.array_equal(sh.dist, sd.dist)
    assert np.array_equal(sh.sources, sd.sources)


def test_frontier_sharded_accepts_target_as_full_solve():
    """target= on frontier_sharded runs the full fixpoint (no early
    exit): identical bytes to the untargeted solve, pred included."""
    mesh = make_mesh((1,), ("data",))
    cg = C.sparse_csr_graph(200, seed=5)
    t = shortest_paths(cg, 7, engine="frontier_sharded", mesh=mesh,
                       target=20)
    full = shortest_paths(cg, 7, engine="frontier_sharded", mesh=mesh)
    assert np.array_equal(t.dist, full.dist)
    assert t.pred is not None and np.array_equal(t.pred, full.pred)


# ---------------------------------------------------------------------------
# registry staging + shard-aware keys
# ---------------------------------------------------------------------------

def test_row_key_carries_owner_shard():
    reg = GraphRegistry()
    h = reg.register("g", C.sparse_csr_graph(100, seed=6))   # loc_n = 25
    assert h.row_key(3) == ("g", 3)
    assert h.row_key(3, shards=4) == ("g", 0, 3)
    assert h.row_key(25, shards=4) == ("g", 1, 25)
    assert h.row_key(99, shards=4) == ("g", 3, 99)
    assert h.owner_shard(50, 4) == 2


def test_registry_partition_staging_memoized_and_accounted():
    reg = GraphRegistry()
    h = reg.register("g", C.sparse_csr_graph(64, seed=7))
    base = reg.bytes_in_use
    parts = h.partition(2)
    assert parts is h.partition(2)               # memoized per nprocs
    assert reg.bytes_in_use >= base + parts.nbytes
    ops = h.partition_ops(2)
    assert ops is h.partition_ops(2)
    assert reg.bytes_in_use > base + parts.nbytes  # device arrays counted
    # a different arity restages (policy change, not the serving path)
    assert h.partition(4).nprocs == 4
    assert h.partition_ops(4) is not ops


def test_registry_partition_refuses_dynamic_graphs():
    from repro.dynamic import DynamicGraph

    reg = GraphRegistry()
    h = reg.register("d", DynamicGraph(C.sparse_csr_graph(32, seed=8)))
    with pytest.raises(ValueError, match="dynamic"):
        h.partition(2)


# ---------------------------------------------------------------------------
# scheduler sharded routing, in-process multi-device
# ---------------------------------------------------------------------------

@multidevice
def test_scheduler_sharded_batch_and_p2p_bitwise():
    pol = DispatchPolicy(shard_threshold=500)
    reg, cache = GraphRegistry(), DistanceCache(64)
    sched = MicroBatchScheduler(reg, cache, max_batch=8, dispatch=pol)
    cg = C.sparse_csr_graph(2000, seed=3)
    reg.register("big", cg)
    reg.register("small", C.sparse_csr_graph(100, seed=4))

    for s in (5, 9, 5, 700, 1999):
        sched.submit("big", s)
    sched.submit("small", 3)
    answers = sched.drain()
    assert sched.sharded_batches == 1 and sched.sharded_sources == 4
    assert sched.engine_batches == 2          # small went single-device
    for a in answers:
        if a.query.graph == "big":
            ref = shortest_paths(cg, a.query.source, engine="serial")
            assert np.array_equal(a.value, ref.dist), a.query.source
    # rows cached under (name, owner_shard, source) keys
    keys = cache.keys_for("big")
    assert keys and all(len(k) == 3 for k in keys)
    h = reg.get("big")
    assert all(k[1] == h.owner_shard(k[2], pol.nprocs) for k in keys)
    assert all(len(k) == 2 for k in cache.keys_for("small"))

    # sharded p2p: full fixpoint, bitwise, and (unlike the single-device
    # target= path) the complete row lands in the cache
    sched.submit("big", 42, 77)
    a = sched.drain()[0]
    ref = shortest_paths(cg, 42, engine="serial")
    assert np.float32(a.value) == ref.dist[77]
    assert sched.sharded_p2p == 1 and sched.sharded_edges > 0
    row = cache.peek(h.row_key(42, shards=pol.nprocs))
    assert row is not None and np.array_equal(row, ref.dist)
    sched.submit("big", 42, 99)               # repeat hits the cache
    assert sched.drain()[0].via == "cache"


@multidevice
def test_sharded_evicted_graph_race_fails_typed_while_live_serves():
    """The submit -> evict -> tick race on the SHARDED route: the evicted
    graph's queries answer ``graph_gone`` while another shard-routed
    graph drained in the same tick still serves bitwise-exact."""
    pol = DispatchPolicy(shard_threshold=500)
    reg, cache = GraphRegistry(), DistanceCache(64)
    sched = MicroBatchScheduler(reg, cache, max_batch=8, dispatch=pol)
    ga = C.sparse_csr_graph(1200, seed=21)
    gb = C.sparse_csr_graph(1200, seed=22)
    reg.register("ga", ga)
    reg.register("gb", gb)
    sched.submit("ga", 11)
    sched.submit("ga", 40, 900)
    sched.submit("gb", 17)
    reg.evict("ga")
    by_qid = {a.query.source: a for a in sched.tick()}
    for s in (11, 40):
        assert by_qid[s].status == "graph_gone" and not by_qid[s].ok
    live = by_qid[17]
    assert live.status == "ok" and live.exact
    assert np.array_equal(live.value,
                          shortest_paths(gb, 17, engine="serial").dist)
    assert sched.sharded_batches == 1             # gb really went sharded
    assert not cache.keys_for("ga")               # eviction purged rows


@multidevice
def test_scheduler_sharded_occupancy_and_bucket_padding():
    pol = DispatchPolicy(shard_threshold=100)
    reg, cache = GraphRegistry(), DistanceCache(64)
    sched = MicroBatchScheduler(reg, cache, max_batch=8, dispatch=pol)
    reg.register("g", C.sparse_csr_graph(400, seed=9))
    for s in (1, 2, 3):                       # 3 distinct -> bucket 4
        sched.submit("g", s)
    sched.tick()
    assert sched.sharded_batches == 1
    assert sched.mean_occupancy == pytest.approx(3 / 4)


# ---------------------------------------------------------------------------
# multi-device end-to-end (subprocesses force their own device counts)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sssp_serve_driver_sharded_replay_verifies():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)     # the driver forces its own count
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.sssp_serve", "--smoke",
         "--devices", "4", "--shard-threshold", "128"],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "sharded route: 4 devices" in r.stdout
    assert r.stdout.count("verified bitwise vs serial") == 3
    # at least one scenario actually took the sharded engines
    assert " batches + " in r.stdout


@pytest.mark.slow
def test_auto_engine_routes_sharded_multidevice():
    code = """
import numpy as np
from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.serve import DispatchPolicy, set_default_policy

set_default_policy(DispatchPolicy(shard_threshold=500))
cg = C.sparse_csr_graph(2000, seed=11)
res = shortest_paths(cg, 3, engine="auto")
assert res.engine == "frontier_sharded", res.engine
ref = shortest_paths(cg, 3, engine="serial")
assert np.array_equal(res.dist, ref.dist)
resb = shortest_paths(cg, [3, 7], engine="auto")
assert resb.engine == "multisource_csr_sharded", resb.engine
assert np.array_equal(resb.dist[0], ref.dist)
small = C.sparse_csr_graph(100, seed=12)
assert shortest_paths(small, 0, engine="auto").engine == "frontier"
print("AUTO_SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True, env=env, timeout=900)
    assert "AUTO_SHARDED_OK" in r.stdout, r.stdout + r.stderr
