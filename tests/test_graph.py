"""graph.py container edge cases: duplicate-edge resolution, padded_size
boundaries, and the invariant that padding vertices never affect results."""
import numpy as np
import pytest

from conftest import dijkstra_oracle, finite_close
from repro.core import graph as G
from repro.core.api import shortest_paths


# ---------------------------------------------------------------------------
# duplicate-edge min-weight resolution in from_edge_list
# ---------------------------------------------------------------------------

def test_duplicate_edges_undirected_min_across_orientations():
    """(u,v) and (v,u) duplicates with conflicting weights resolve to one
    symmetric minimum."""
    edges = np.array([[0, 1], [1, 0], [0, 1]])
    w = np.array([5.0, 2.0, 7.0])
    g = G.from_edge_list(3, edges, w)
    assert g.adj[0, 1] == 2.0 and g.adj[1, 0] == 2.0


def test_duplicate_edges_directed_kept_per_orientation():
    edges = np.array([[0, 1], [0, 1], [1, 0]])
    w = np.array([5.0, 2.0, 9.0])
    g = G.from_edge_list(3, edges, w, directed=True)
    assert g.adj[0, 1] == 2.0
    assert g.adj[1, 0] == 9.0


def test_duplicate_edges_csr_matches_dense():
    rng = np.random.default_rng(0)
    edges = rng.integers(0, 20, size=(200, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]
    w = rng.uniform(1, 50, size=len(edges))
    for directed in (False, True):
        dense = G.from_edge_list(20, edges, w, directed=directed)
        sparse = G.csr_from_edge_list(20, edges, w, directed=directed)
        assert np.array_equal(sparse.to_dense().adj, dense.adj)


# ---------------------------------------------------------------------------
# padded_size boundaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,multiple,expect", [
    (2, 3, 3),        # multiple > n -> padded size is the multiple
    (1, 8, 8),
    (12, 4, 12),      # exact multiple -> unchanged
    (4, 4, 4),
    (5, 1, 5),        # multiple == 1 is a no-op
    (13, 4, 16),
    (999, 1000, 1000),
])
def test_padded_size_boundaries(n, multiple, expect):
    assert G.padded_size(n, multiple) == expect


def test_padded_noop_returns_same_object():
    g = G.random_graph(12, 24, seed=0)
    assert g.padded(4) is g


def test_padded_keeps_true_n_and_edge_count():
    g = G.random_graph(10, 30, seed=1)
    gp = g.padded(8)
    assert gp.adj.shape == (16, 16)
    assert gp.n == g.n
    assert gp.num_edges == g.num_edges


# ---------------------------------------------------------------------------
# padding vertices never affect results
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["serial", "bellman", "bellman_csr"])
@pytest.mark.parametrize("multiple", [3, 7, 32])
def test_padding_inert_across_engines(engine, multiple):
    g = G.random_graph(20, 60, seed=multiple)
    gp = g.padded(multiple)
    pn = gp.adj.shape[0]
    ref = dijkstra_oracle(g, 0)
    res = shortest_paths(G.Graph(adj=gp.adj, n=pn), 0, engine=engine)
    assert finite_close(ref, res.dist[: g.n])
    # padding vertices are unreachable from real ones...
    assert not np.isfinite(res.dist[g.n:]).any()
    # ...and a source *in* the padding reaches only itself.
    res = shortest_paths(G.Graph(adj=gp.adj, n=pn), pn - 1, engine=engine)
    assert res.dist[pn - 1] == 0.0
    assert not np.isfinite(np.delete(res.dist, pn - 1)).any()
