"""MoE dispatch semantics: routing correctness against a per-token dense
reference, capacity dropping, expert padding, load-balance aux."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, make_smoke
from repro.models.moe import _capacity, _padded_experts, init_moe, moe


def _cfg(**kw):
    base = make_smoke(get_config("qwen2-moe-a2.7b"))
    return dataclasses.replace(base, **kw)


def _dense_reference(p, x, cfg):
    """Per-token loop: run every token through its top-k experts directly."""
    B, S, d = x.shape
    E = cfg.num_experts
    xt = np.asarray(x.reshape(-1, d), np.float32)
    logits = xt @ np.asarray(p["router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    k = cfg.moe_top_k
    out = np.zeros_like(xt)
    wi_g = np.asarray(p["wi_gate"], np.float32)
    wi_u = np.asarray(p["wi_up"], np.float32)
    wo = np.asarray(p["wo"], np.float32)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:k]
        w = probs[t][top]
        w = w / w.sum()
        for e, wt in zip(top, w):
            g = xt[t] @ wi_g[e]
            u = xt[t] @ wi_u[e]
            h = (g / (1 + np.exp(-g))) * u
            out[t] += wt * (h @ wo[e])
    if "shared" in p:
        g = xt @ np.asarray(p["shared"]["wi_gate"], np.float32)
        u = xt @ np.asarray(p["shared"]["wi_up"], np.float32)
        h = (g / (1 + np.exp(-g))) * u
        out += h @ np.asarray(p["shared"]["wo"], np.float32)
    return out.reshape(B, S, d)


def test_moe_matches_dense_reference_no_drops():
    cfg = _cfg(capacity_factor=float(64), expert_pad_to=0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = moe(p, x, cfg)
    ref = _dense_reference(p, x, cfg)
    assert np.allclose(np.asarray(out, np.float32), ref, atol=2e-3), \
        np.abs(np.asarray(out, np.float32) - ref).max()


def test_capacity_drops_tokens():
    """With capacity_factor near zero almost everything drops -> output is
    (nearly) only the shared-expert path."""
    cfg = _cfg(capacity_factor=1e-6, num_shared_experts=0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out, _ = moe(p, x, cfg)
    # capacity floor is 8 per expert: most tokens dropped, tiny norm
    full_cfg = _cfg(capacity_factor=float(64), num_shared_experts=0)
    full, _ = moe(p, x, full_cfg)
    assert (np.linalg.norm(np.asarray(out))
            < 0.8 * np.linalg.norm(np.asarray(full)))


def test_padded_experts_receive_no_tokens():
    cfg = _cfg(expert_pad_to=16)      # smoke has 8 real experts
    assert _padded_experts(cfg) == 16
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out_pad, _ = moe(p, x, cfg)
    # unpadded config with the same real-expert weights must agree
    cfg0 = _cfg(expert_pad_to=0, capacity_factor=cfg.capacity_factor)
    p0 = {k: (v if k in ("router", "shared")
              else v[:cfg.num_experts]) for k, v in p.items()}
    out0, _ = moe(p0, x, cfg0)
    assert np.allclose(np.asarray(out_pad), np.asarray(out0), atol=2e-3)


def test_aux_loss_balanced_vs_skewed():
    cfg = _cfg(router_aux_weight=1.0, capacity_factor=4.0)
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    _, aux_rand = moe(p, x, cfg)
    # force all tokens to one expert by biasing the router
    p_skew = dict(p)
    router = np.asarray(p["router"]).copy()
    router[:, 0] += 100.0
    p_skew["router"] = jnp.asarray(router)
    _, aux_skew = moe(p_skew, x, cfg)
    assert float(aux_skew) > float(aux_rand)


def test_capacity_rounding():
    cfg = _cfg(capacity_factor=1.25)
    c = _capacity(1024, cfg)
    assert c % 8 == 0 and c >= 8
