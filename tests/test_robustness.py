"""Serving fault tolerance: typed failures, deadlines, degradation, chaos.

The robustness layer's contract (README.md §Robustness): a bad query or a
lost graph fails ITS caller/answer with a typed status — never the tick
serving everyone else; deadline pressure sheds or degrades rather than
queueing without bound; a capped solve surfaces ``not_converged`` instead
of serving non-fixpoint labels; injected faults (serve/faults.py) are
deterministic, so every chaos replay is reproducible byte for byte.  The
bitwise-exactness invariant of tests/test_serve.py binds exactly the
answers that still claim ``exact=True``.
"""
import numpy as np
import pytest

from repro.core import csr as C
from repro.core._compat import make_mesh
from repro.core.api import shortest_paths
from repro.dynamic import DynamicGraph
from repro.serve import (DistanceCache, FaultPlan, GraphRegistry,
                         MicroBatchScheduler, QueryRejected,
                         SchedulerStalled)


def _stack(cg, *, name="g", landmarks=0, **kw):
    registry = GraphRegistry()
    cache = DistanceCache(capacity=kw.pop("cache_rows", 64))
    sched = MicroBatchScheduler(registry, cache, max_batch=8, **kw)
    if cg is not None:
        registry.register(name, cg, landmarks=landmarks)
    return registry, cache, sched


def _serial(g, s):
    return shortest_paths(g, s, engine="serial").dist


# ---------------------------------------------------------------------------
# eager submit validation
# ---------------------------------------------------------------------------

def test_submit_validation_rejects_malformed_queries_eagerly():
    cg = C.random_csr_graph(50, 150, seed=0)
    _, _, sched = _stack(cg)
    bad = [
        dict(graph=3, source=0),                  # graph name not a str
        dict(graph="g", source=True),             # bool is not a vertex
        dict(graph="g", source=1.5),              # non-integral source
        dict(graph="g", source=-1),               # negative source
        dict(graph="g", source=50),               # >= n for registered g
        dict(graph="g", source=0, target=-2),     # negative target
        dict(graph="g", source=0, target=99),     # >= n target
    ]
    for kw in bad:
        with pytest.raises(QueryRejected):
            sched.submit(**kw)
    with pytest.raises(QueryRejected):
        sched.submit("g", 0, deadline=float("nan"))
    assert sched.pending == 0                     # nothing was admitted
    assert sched.stats()["submissions_rejected"] == len(bad) + 1
    # the rejection failed only its caller: the scheduler still serves
    sched.submit("g", 3)
    (a,) = sched.drain()
    assert a.ok and a.exact and np.array_equal(a.value, _serial(cg, 3))


def test_submit_unregistered_graph_is_answered_graph_gone_at_tick():
    # an unknown name is NOT an eager rejection (it may be registered
    # before the tick); unresolved, it fails as a typed answer instead
    _, _, sched = _stack(None)
    q = sched.submit("ghost", 2)
    (a,) = sched.tick()
    assert a.query is q and a.status == "graph_gone"
    assert not a.ok and not a.exact and a.value is None


# ---------------------------------------------------------------------------
# evicted-graph race (single device; the sharded twin lives in
# tests/test_serve_sharded.py)
# ---------------------------------------------------------------------------

def test_evicted_graph_race_fails_typed_while_live_graph_serves():
    g0 = C.random_csr_graph(120, 360, seed=1)
    g1 = C.random_csr_graph(120, 360, seed=2)
    registry, _, sched = _stack(g0, name="g0")
    registry.register("g1", g1)
    sched.submit("g0", 5)                         # admitted while g0 lives
    sched.submit("g1", 7)
    registry.evict("g0")                          # race: evicted pre-tick
    answers = {a.query.graph: a for a in sched.tick()}
    assert answers["g0"].status == "graph_gone" and not answers["g0"].ok
    assert answers["g1"].status == "ok" and answers["g1"].exact
    assert np.array_equal(answers["g1"].value, _serial(g1, 7))
    assert registry.evict("g0") is None           # idempotent


# ---------------------------------------------------------------------------
# deadlines, bounded queue, shedding
# ---------------------------------------------------------------------------

def test_expired_query_answered_deadline_exceeded_before_solving():
    cg = C.random_csr_graph(60, 180, seed=3)
    _, _, sched = _stack(cg)
    sched.submit("g", 4, arrival=0.0, deadline=1.0)
    sched.submit("g", 9, arrival=0.0)             # no deadline: must serve
    by_src = {a.query.source: a for a in sched.tick(now=2.0)}
    assert by_src[4].status == "deadline_exceeded" and by_src[4].value is None
    assert by_src[9].ok and np.array_equal(by_src[9].value, _serial(cg, 9))
    assert sched.stats()["deadline_expired"] == 1


def test_bounded_queue_rejects_p2p_and_sheds_for_full_rows():
    cg = C.random_csr_graph(60, 180, seed=4)
    _, _, sched = _stack(cg, max_queue=2)
    sched.submit("g", 1, 2)
    sched.submit("g", 3, 4)
    # saturated + p2p newcomer: rejected at the submit boundary
    with pytest.raises(QueryRejected):
        sched.submit("g", 5, 6)
    # saturated + full-row newcomer: the NEWEST queued p2p (cheapest to
    # recompute — a bounded early-exit solve, never cached) is shed for it
    q = sched.submit("g", 7)
    assert sched.pending == 2
    answers = sched.drain()
    shed = [a for a in answers if a.status == "rejected"]
    assert len(shed) == 1 and shed[0].query.source == 3
    served = {a.query.source: a for a in answers if a.ok}
    assert set(served) == {1, 7} and served[7].query is q
    st = sched.stats()
    assert st["shed"] == 1 and st["submissions_rejected"] == 1


# ---------------------------------------------------------------------------
# graceful degradation under deadline pressure
# ---------------------------------------------------------------------------

def test_p2p_degrades_to_landmark_bracket_under_pressure():
    cg = C.sparse_csr_graph(200, seed=5)
    registry, _, sched = _stack(cg, landmarks=4, degrade_margin=0.5)
    ids = set(int(i) for i in registry.get("g").landmarks_ready().ids)
    src = next(v for v in range(cg.n) if v not in ids)
    tgt = next(v for v in range(cg.n - 1, -1, -1)
               if v not in ids and v != src)      # neither endpoint exact
    sched.submit("g", src, tgt, deadline=1.0)
    (a,) = sched.drain(now=0.8)                   # 0.2s left <= margin
    assert a.via == "degraded" and a.status == "ok" and not a.exact
    lb, ub = a.bounds
    true = float(_serial(cg, src)[tgt])
    assert lb <= true <= ub and a.value == ub     # ub is a real path
    assert sched.stats()["degraded_p2p"] == 1


def test_full_row_degrades_to_stale_version_under_pressure():
    cg = C.random_csr_graph(100, 300, seed=6)
    dyn = DynamicGraph(cg, overlay_capacity=16)
    registry, cache, sched = _stack(dyn, degrade_margin=0.5, repair_rows=0)
    sched.submit("g", 8)
    (fresh,) = sched.drain()
    v0_row = np.asarray(fresh.value).copy()
    # bump a TIGHT edge of row 8 (one the row's shortest paths use), so
    # the row is genuinely affected; repair_rows=0 means it cannot be
    # repaired, so the degrade-enabled scheduler retains it as STALE
    us = np.asarray(dyn.base.indices)
    vs = np.asarray(dyn.base.dst_ids())
    u, v = next(
        (int(a), int(b)) for a, b in zip(us, vs)
        if np.isfinite(v0_row[a])
        and np.float32(v0_row[a] + dyn.weight_of(a, b)) == v0_row[b])
    registry.mutate("g", [("update", u, v,
                           float(dyn.weight_of(u, v)) + 50.0)])
    assert sched.rows_staled >= 1
    sched.submit("g", 8, deadline=1.0)
    (a,) = sched.drain(now=0.9)
    assert a.via == "degraded" and a.status == "ok" and not a.exact
    assert np.array_equal(a.value, v0_row)        # the versioned stale row
    assert sched.stats()["degraded_batch"] == 1
    # without pressure the same query re-solves exactly at the new version
    sched.submit("g", 8)
    (b,) = sched.drain()
    assert b.exact and np.array_equal(b.value, _serial(dyn.snapshot(), 8))


# ---------------------------------------------------------------------------
# retries, backoff, typed solve failures
# ---------------------------------------------------------------------------

def test_transient_fault_is_retried_to_a_bitwise_exact_answer():
    cg = C.random_csr_graph(80, 240, seed=7)
    plan = FaultPlan(seed=1, rates={"solve": 1.0}, max_per_site=1)
    _, _, sched = _stack(cg, faults=plan, retry_budget=2)
    sched.submit("g", 6)
    (a,) = sched.drain()
    assert a.ok and a.exact and np.array_equal(a.value, _serial(cg, 6))
    st = sched.stats()
    assert st["solve_exceptions"] == 1 and st["retries"] == 1
    assert plan.counts()["solve"] == 1


def test_persistent_fault_exhausts_retry_budget_to_solve_failed():
    cg = C.random_csr_graph(80, 240, seed=8)
    plan = FaultPlan(seed=2, rates={"solve": 1.0})    # never recovers
    _, _, sched = _stack(cg, faults=plan, retry_budget=2)
    sched.submit("g", 6)
    answers = sched.drain()                       # guard must NOT trip:
    (a,) = answers                                # backoff ticks progress
    assert a.status == "solve_failed" and not a.ok and a.value is None
    assert a.query.attempts == 3                  # 1 try + 2 retries
    assert sched.stats()["retries"] == 2


def test_clip_fault_surfaces_not_converged_and_caches_nothing():
    cg = C.sparse_csr_graph(150, seed=9)          # diameter >> 1 sweep
    plan = FaultPlan(seed=3, rates={"clip": 1.0}, clip_sweeps=1)
    _, cache, sched = _stack(cg, faults=plan)
    sched.submit("g", 0)
    sched.submit("g", 0, 140)
    answers = sched.drain()
    assert len(answers) == 2
    assert all(a.status == "not_converged" and not a.ok for a in answers)
    assert len(cache) == 0                        # capped labels never enter
    assert sched.stats()["not_converged"] == 2


def test_poisoned_mutation_batch_rolls_back_atomically():
    cg = C.random_csr_graph(90, 270, seed=10)
    dyn = DynamicGraph(cg, overlay_capacity=16)
    plan = FaultPlan(seed=4, rates={"mutate": 1.0}, max_per_site=1)
    registry, _, sched = _stack(dyn, faults=plan)
    u, v = int(dyn.base.indices[0]), int(dyn.base.dst_ids()[0])
    w0 = float(dyn.weight_of(u, v))
    sched.submit_mutation("g", "update", u, v, w0 + 5.0)
    acks = sched.tick()
    assert len(acks) == 1 and acks[0].status == "rejected"
    assert dyn.version == 0 and float(dyn.weight_of(u, v)) == w0
    # the graph is untouched: a fresh query is exact against the base
    sched.submit("g", 12)
    (a,) = sched.drain()
    assert a.exact and np.array_equal(a.value, _serial(cg, 12))


# ---------------------------------------------------------------------------
# drain progress guard
# ---------------------------------------------------------------------------

def test_drain_raises_stalled_instead_of_spinning_forever():
    cg = C.random_csr_graph(40, 120, seed=11)
    _, _, sched = _stack(cg)
    # simulate the requeue-path regression the guard exists for: a solve
    # that silently answers nobody (no exception, no retry, no answer)
    sched._solve_batch = lambda handle, queries: []
    sched.submit("g", 2)
    with pytest.raises(SchedulerStalled):
        sched.drain()


# ---------------------------------------------------------------------------
# chaos determinism
# ---------------------------------------------------------------------------

def test_fault_plan_schedule_is_a_pure_function_of_seed():
    mk = lambda: FaultPlan(seed=42, rates={"solve": 0.5, "clip": 0.3},
                           max_per_site=3)
    a, b = mk(), mk()
    fires = [(s, a.roll(s), b.roll(s))
             for s in ("solve", "clip", "solve", "evict") * 20]
    assert all(x == y for _, x, y in fires)
    assert a.counts() == b.counts()
    assert a.counts()["solve"] <= 3               # cap respected
    assert a.probes["solve"] == b.probes["solve"] == 40


def test_chaos_replay_statuses_are_deterministic():
    def once():
        cg = C.random_csr_graph(70, 210, seed=12)
        plan = FaultPlan(seed=9,
                         rates={"solve": 0.4, "clip": 0.4}, max_per_site=2)
        _, _, sched = _stack(cg, faults=plan, retry_budget=1)
        for s in (3, 9, 3, 40, 41, 42):
            sched.submit("g", s)
        sched.submit("g", 5, 60)
        return [(a.query.qid, a.status, a.exact) for a in sched.drain()]

    assert once() == once()


# ---------------------------------------------------------------------------
# solver guardrails: max_sweeps= and the converged flag
# ---------------------------------------------------------------------------

def _path_graph(n):
    import repro.core.graph as G
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    return G.csr_from_edge_list(n, edges, np.ones(n - 1))


@pytest.mark.parametrize("engine", ["bellman_csr", "frontier",
                                    "multisource_csr"])
def test_max_sweeps_cap_reports_not_converged(engine):
    cg = _path_graph(12)                          # needs ~11 sweeps from 0
    src = [0] if engine == "multisource_csr" else 0
    capped = shortest_paths(cg, src, engine=engine, max_sweeps=2)
    assert capped.converged is False and capped.sweeps == 2
    free = shortest_paths(cg, src, engine=engine)
    assert free.converged is True
    dist = free.dist[0] if engine == "multisource_csr" else free.dist
    assert np.array_equal(dist, np.arange(12, dtype=np.float32))


@pytest.mark.parametrize("engine", ["bellman_csr_sharded",
                                    "frontier_sharded",
                                    "multisource_csr_sharded"])
def test_sharded_max_sweeps_cap_reports_not_converged(engine):
    mesh = make_mesh((1,), ("data",))
    cg = _path_graph(16)
    src = [0] if engine == "multisource_csr_sharded" else 0
    capped = shortest_paths(cg, src, engine=engine, mesh=mesh,
                            max_sweeps=2)
    assert capped.converged is False
    free = shortest_paths(cg, src, engine=engine, mesh=mesh)
    assert free.converged is True
    dist = free.dist[0] if engine == "multisource_csr_sharded" else free.dist
    assert np.array_equal(dist, np.arange(16, dtype=np.float32))
