"""SSSP engine correctness: the paper's three implementations (+ batched
variant) against an independent numpy Dijkstra oracle, plus property-based
invariants (hypothesis) on random graphs.

``hypothesis`` is optional: without it the property tests are skipped but
everything else still collects and runs (the tier-1 gate)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax
import jax.numpy as jnp

from conftest import finite_close
from repro.core import graph as G
from repro.core.api import shortest_paths
from repro.core.bellman import sssp_bellman
from repro.core.serial import dijkstra_serial, dijkstra_serial_np
from repro.core.multisource import sssp_multisource


# ---------------------------------------------------------------------------
# oracle agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["serial", "bellman", "bellman_kernel",
                                    "multisource"])
@pytest.mark.parametrize("n,m", [(10, 30), (10, 45), (100, 300), (100, 4950),
                                 (257, 1000)])
def test_engine_matches_oracle(engine, n, m):
    g = G.random_graph(n, m, seed=n + m)
    ref, _ = dijkstra_serial_np(g.adj, 0)
    res = shortest_paths(g, np.array([0]) if engine == "multisource" else 0,
                         engine=engine)
    got = res.dist[0] if res.dist.ndim == 2 else res.dist
    assert finite_close(ref, got)


def test_directed_graph():
    # the paper's -w flag: directed adjacency is asymmetric
    g = G.random_graph(60, 240, seed=7, directed=True)
    assert not np.allclose(g.adj, g.adj.T)
    ref, _ = dijkstra_serial_np(g.adj, 3)
    res = shortest_paths(g, 3, engine="bellman")
    assert finite_close(ref, res.dist)


def test_disconnected_graph_inf():
    g = G.random_graph(50, 60, seed=1, connected=False)
    ref, _ = dijkstra_serial_np(g.adj, 0)
    res = shortest_paths(g, 0, engine="bellman")
    assert finite_close(ref, res.dist)
    # if the oracle found unreachable vertices, we must agree they are inf
    assert np.array_equal(np.isfinite(ref), np.isfinite(res.dist))


def test_multisource_matches_per_source_runs():
    g = G.random_graph(80, 400, seed=3)
    srcs = np.array([0, 17, 42, 63], np.int32)
    res = shortest_paths(g, srcs, engine="multisource")
    for i, s in enumerate(srcs):
        ref, _ = dijkstra_serial_np(g.adj, int(s))
        assert finite_close(ref, res.dist[i])


def test_pred_tree_valid():
    g = G.random_graph(90, 350, seed=11)
    for engine in ("serial", "bellman"):
        res = shortest_paths(g, 0, engine=engine)
        d, p = res.dist, res.pred
        for v in range(g.n):
            if v == 0 or not np.isfinite(d[v]):
                continue
            u = p[v]
            assert u >= 0
            assert np.isclose(d[v], d[u] + g.adj[u, v], rtol=1e-5)


def test_bellman_sweep_count_bounded_by_diameter():
    # path graph: hop diameter n-1 -> n-1 sweeps + 1 to detect fixpoint
    n = 12
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    g = G.from_edge_list(n, edges, np.ones(n - 1))
    res = shortest_paths(g, 0, engine="bellman")
    assert res.sweeps <= n
    assert finite_close(res.dist, np.arange(n, dtype=float))


def test_frontier_variant_matches():
    g = G.random_graph(70, 280, seed=5)
    d0, _, _ = sssp_bellman(jnp.asarray(g.adj), jnp.int32(0))
    d1, _, _ = sssp_bellman(jnp.asarray(g.adj), jnp.int32(0),
                            use_frontier=True)
    assert finite_close(np.asarray(d0), np.asarray(d1))


# ---------------------------------------------------------------------------
# the paper's padding step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,p,expect", [
    (4, 3, 6),      # the paper's worked example: 4 nodes, 3 procs -> 6
    (2, 3, 3),      # procs > n -> padded_n = procs
    (12, 4, 12),    # already divisible
    (13, 4, 16),
])
def test_padded_size_paper_logic(n, p, expect):
    assert G.padded_size(n, p) == expect


def test_padding_preserves_distances():
    g = G.random_graph(10, 30, seed=2)
    gp = g.padded(4)
    assert gp.adj.shape == (12, 12)
    ref, _ = dijkstra_serial_np(g.adj, 0)
    res = shortest_paths(G.Graph(adj=gp.adj, n=12), 0, engine="bellman")
    assert finite_close(ref, res.dist[:10])
    # padding vertices unreachable
    assert not np.isfinite(res.dist[10:]).any()


def test_duplicate_edges_keep_minimum():
    edges = np.array([[0, 1], [0, 1], [1, 2]])
    w = np.array([5.0, 2.0, 1.0])
    g = G.from_edge_list(3, edges, w)
    assert g.adj[0, 1] == 2.0 and g.adj[1, 0] == 2.0


# ---------------------------------------------------------------------------
# property-based invariants (skipped when hypothesis is not installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @st.composite
    def graphs(draw):
        n = draw(st.integers(3, 40))
        m = draw(st.integers(0, 3 * n))
        seed = draw(st.integers(0, 2**31 - 1))
        directed = draw(st.booleans())
        return G.random_graph(n, m, seed=seed, directed=directed,
                              connected=draw(st.booleans()))

    @settings(max_examples=25, deadline=None)
    @given(graphs(), st.integers(0, 10**6))
    def test_property_engines_agree(g, s):
        src = s % g.n
        ref, _ = dijkstra_serial_np(g.adj, src)
        for engine in ("serial", "bellman", "bellman_csr"):
            res = shortest_paths(g, src, engine=engine)
            assert finite_close(ref, res.dist), engine

    @settings(max_examples=25, deadline=None)
    @given(graphs(), st.integers(0, 10**6))
    def test_property_triangle_inequality_fixpoint(g, s):
        """At the fixpoint, no edge can relax: d[v] <= d[u] + w(u,v)."""
        src = s % g.n
        res = shortest_paths(g, src, engine="bellman")
        d = np.where(np.isfinite(res.dist), res.dist, 1e30)
        via = d[:, None] + np.where(np.isfinite(g.adj), g.adj, 1e30)
        assert (d[None, :] <= via.min(0) + 1e-3).all()
        assert d[src] == 0.0

    @settings(max_examples=15, deadline=None)
    @given(graphs())
    def test_property_monotone_in_edges(g):
        """Adding an edge can only shorten distances."""
        ref = shortest_paths(g, 0, engine="bellman").dist
        adj2 = g.adj.copy()
        adj2[0, g.n - 1] = adj2[g.n - 1, 0] = 0.5
        got = shortest_paths(G.Graph(adj=adj2, n=g.n), 0,
                             engine="bellman").dist
        r = np.where(np.isfinite(ref), ref, 1e30)
        q = np.where(np.isfinite(got), got, 1e30)
        assert (q <= r + 1e-3).all()
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_invariants():
        """Placeholder so the skip is visible in reports."""
