"""Vertex-partitioned sharded CSR engines (core/sharded_csr.py).

Covers: the CsrPartition view's invariants (arc-set roundtrip, ascending
local segment ids, inert sentinel padding, out-CSR window consistency),
the ~1/P per-device memory claim, P=1 in-process parity (bitwise vs
serial, pred vs bellman_csr, edges_relaxed vs the single-device frontier
engine), and — via subprocesses with forced host device counts, like the
other multi-device tests — bitwise parity with serial on the Table II
sparse corpus through n=10000 for P in {2, 4, 8}.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from conftest import dijkstra_oracle
from repro.core import csr as C
from repro.core._compat import make_mesh
from repro.core.api import shortest_paths

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


# ---------------------------------------------------------------------------
# partition view
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nprocs", [1, 2, 3, 8])
def test_partition_roundtrips_arc_set(nprocs):
    cg = C.random_csr_graph(57, 170, seed=11)
    parts = cg.partitioned(nprocs)
    assert parts.n_pad == parts.loc_n * nprocs and parts.n_pad >= cg.n
    got = set()
    for p in range(nprocs):
        real = np.isfinite(parts.in_w[p])
        # ascending local dst (segment-min precondition), incl. padding
        assert (np.diff(parts.in_dst_loc[p]) >= 0).all()
        for s, dl, w in zip(parts.in_src[p][real],
                            parts.in_dst_loc[p][real],
                            parts.in_w[p][real]):
            got.add((int(s), int(dl) + p * parts.loc_n, float(w)))
        # out view holds the same arcs behind the per-source windows
        out = set()
        for u in range(parts.n_pad + 1):
            lo, hi = parts.out_indptr[p, u], parts.out_indptr[p, u + 1]
            for e in range(lo, hi):
                out.add((int(u), int(parts.out_dst_loc[p, e]) + p * parts.loc_n,
                         float(parts.out_w[p, e])))
        assert out == {a for a in got
                       if a[1] // parts.loc_n == p}
    want = {(int(u), int(v), float(w)) for u, v, w in
            zip(cg.indices, cg.dst_ids(), cg.weights)}
    assert got == want


def test_partition_sentinel_row_is_empty():
    cg = C.sparse_csr_graph(40, seed=2)
    parts = cg.partitioned(4)
    # the frontier engines index row n_pad for dead compaction slots
    assert (parts.out_indptr[:, parts.n_pad + 1]
            == parts.out_indptr[:, parts.n_pad]).all()


def test_partition_per_device_memory_is_1_over_p():
    """Per-device edge arrays ~1/P of the single-device staged equivalent
    (csr_operands' src/dst/w 12 B/arc + frontier_operands' out dst/w
    8 B/arc = 20 B/arc); the out_indptr index stays O(n) per device."""
    cg = C.sparse_csr_graph(10000, seed=7)
    single = 20 * cg.nnz
    for P in (2, 4, 8):
        parts = cg.partitioned(P)
        assert parts.per_device_edge_bytes <= 1.3 * single / P, (
            P, parts.per_device_edge_bytes, single)
        assert parts.per_device_index_bytes <= 4 * (parts.n_pad + 2)


def test_partition_rejects_bad_nprocs():
    with pytest.raises(ValueError):
        C.sparse_csr_graph(10, seed=0).partitioned(0)


# ---------------------------------------------------------------------------
# engines, P=1 in-process (the real multi-device runs are subprocesses)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["bellman_csr_sharded", "frontier_sharded"])
def test_sharded_csr_engines_p1_match_oracle_and_serial(engine):
    mesh = make_mesh((1,), ("data",))
    for n, m, directed, seed in [(57, 170, False, 0), (103, 300, True, 3),
                                 (500, 1500, False, 9)]:
        cg = C.random_csr_graph(n, m, seed=seed, directed=directed)
        res = shortest_paths(cg, 4, engine=engine, mesh=mesh)
        ref = shortest_paths(cg, 4, engine="serial")
        assert np.array_equal(res.dist, ref.dist), (engine, n, directed)
        oracle = dijkstra_oracle(cg, 4)
        fin = np.isfinite(oracle)
        assert np.allclose(res.dist[fin], oracle[fin], rtol=1e-5)
        assert (np.isfinite(res.dist) == fin).all()
        # same deterministic lowest-u pred tie-break as the CSR family
        bp = shortest_paths(cg, 4, engine="bellman_csr").pred
        assert np.array_equal(res.pred, bp)


def test_frontier_sharded_p1_edge_counter_matches_single_device():
    """Same work, partitioned: each arc has exactly one owner, so the psum
    of per-owner counters equals the single-device frontier counter."""
    mesh = make_mesh((1,), ("data",))
    cg = C.sparse_csr_graph(500, seed=5)
    sh = shortest_paths(cg, 0, engine="frontier_sharded", mesh=mesh)
    sd = shortest_paths(cg, 0, engine="frontier")
    assert sh.edges_relaxed == sd.edges_relaxed
    assert sh.sweeps == sd.sweeps


def test_sharded_csr_single_vertex_and_edgeless():
    mesh = make_mesh((1,), ("data",))
    cg = C.csr_from_edge_list(1, np.zeros((0, 2)), np.zeros((0,)))
    for engine in ("bellman_csr_sharded", "frontier_sharded"):
        res = shortest_paths(cg, 0, engine=engine, mesh=mesh)
        assert res.dist.shape == (1,) and res.dist[0] == 0.0
    cg = C.csr_from_edge_list(5, np.zeros((0, 2)), np.zeros((0,)))
    res = shortest_paths(cg, 2, engine="frontier_sharded", mesh=mesh)
    assert res.dist[2] == 0.0 and np.isinf(np.delete(res.dist, 2)).all()


def test_sharded_csr_engines_need_mesh():
    cg = C.sparse_csr_graph(10, seed=0)
    with pytest.raises(ValueError, match="needs a mesh"):
        shortest_paths(cg, 0, engine="bellman_csr_sharded")


# ---------------------------------------------------------------------------
# multi-device bitwise parity (Table II corpus through n=10000)
# ---------------------------------------------------------------------------

_MULTIDEV_CODE = """
import numpy as np
from repro.core import csr as C
from repro.core._compat import make_mesh
from repro.core.api import shortest_paths

P = {procs}
mesh = make_mesh((P,), ("data",))
for n in (103, 1000, 10000):
    cg = C.sparse_csr_graph(n, seed=n)          # Table II shape: m = 3n
    ref = shortest_paths(cg, 0, engine="serial")
    fr = shortest_paths(cg, 0, engine="frontier")
    for engine in ("bellman_csr_sharded", "frontier_sharded"):
        res = shortest_paths(cg, 0, engine=engine, mesh=mesh)
        assert res.dist.shape == ref.dist.shape
        assert np.array_equal(res.dist, ref.dist), (engine, n)
        assert np.array_equal(res.pred, fr.pred), (engine, n)
    assert res.edges_relaxed == fr.edges_relaxed, n   # frontier_sharded
print("SHARDED_CSR_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("procs", [2, 4, 8])
def test_sharded_csr_bitwise_vs_serial_multidevice(procs):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={procs}"
    r = subprocess.run(
        [sys.executable, "-c", _MULTIDEV_CODE.format(procs=procs)],
        capture_output=True, text=True, env=env, timeout=900)
    assert "SHARDED_CSR_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_sssp_run_driver_sharded_csr_procs():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.sssp_run",
         "--engine", "frontier_sharded", "--procs", "4",
         "--nodes", "2000", "--edges", "6000", "--verify", "--repeats", "1"],
        capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr
    assert "verify: OK" in r.stdout
