"""Subprocess integration tests: multi-device SSSP, failure-injection
restart determinism, serving driver, DDP compression trainer.

These spawn fresh Python processes so each can force its own XLA host
device count (the in-process suite stays on the single real device)."""
import json
import os
import re
import shutil
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def _run(code=None, module=None, args=(), devices=1, env=None, timeout=600):
    e = dict(os.environ)
    e["PYTHONPATH"] = SRC + os.pathsep + e.get("PYTHONPATH", "")
    if devices > 1:
        e["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    e.update(env or {})
    cmd = [sys.executable]
    if code is not None:
        cmd += ["-c", code]
    else:
        cmd += ["-m", module, *args]
    return subprocess.run(cmd, capture_output=True, text=True, env=e,
                          timeout=timeout)


@pytest.mark.slow
def test_sharded_engines_multidevice_match_oracle():
    code = """
import jax, numpy as np, jax.numpy as jnp
from repro.core import graph as G
from repro.core.api import shortest_paths
from repro.core.serial import dijkstra_serial_np
from repro.core._compat import make_mesh
mesh = make_mesh((8,), ("data",))
g = G.random_graph(103, 400, seed=5)
ref, _ = dijkstra_serial_np(g.adj, 4)
for engine in ("dijkstra_sharded", "bellman_sharded"):
    res = shortest_paths(g, 4, engine=engine, mesh=mesh)
    ok = np.allclose(np.where(np.isfinite(ref), ref, 1e30),
                     np.where(np.isfinite(res.dist), res.dist, 1e30), rtol=1e-5)
    assert ok, engine
res = shortest_paths(g, np.array([4, 9]), engine="multisource", mesh=mesh)
ok = np.allclose(np.where(np.isfinite(ref), ref, 1e30),
                 np.where(np.isfinite(res.dist[0]), res.dist[0], 1e30), rtol=1e-5)
assert ok
print("MULTIDEVICE_OK")
"""
    r = _run(code=code, devices=8)
    assert "MULTIDEVICE_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_minloc_variants_agree_multidevice():
    code = """
import jax, numpy as np, jax.numpy as jnp
from repro.core import graph as G
from repro.core.sharded import dijkstra_sharded
from repro.core.serial import dijkstra_serial_np
from repro.core._compat import make_mesh
mesh = make_mesh((8,), ("data",))
g = G.random_graph(96, 380, seed=8).padded(8)
ref, _ = dijkstra_serial_np(g.adj, 0)
for impl in ("allgather", "pmin", "packed"):
    d, p = dijkstra_sharded(jnp.asarray(g.adj), 0, mesh, n_true=96, minloc=impl)
    d = np.asarray(d)[:96]
    assert np.allclose(np.where(np.isfinite(ref[:96]), ref[:96], 1e30),
                       np.where(np.isfinite(d), d, 1e30), rtol=1e-5), impl
print("MINLOC_OK")
"""
    r = _run(code=code, devices=8)
    assert "MINLOC_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_failure_injection_restart_is_bit_identical(tmp_path):
    """Train 20 steps clean; train with a crash at step 12 + restart; the
    post-restart losses must match the uninterrupted run exactly."""
    ck1, ck2 = str(tmp_path / "a"), str(tmp_path / "b")
    env = {"REPRO_EMIT_LOSSES": "1"}
    base = ["--arch", "qwen1.5-0.5b", "--smoke", "--steps", "20",
            "--batch", "4", "--seq", "32", "--ckpt-every", "5",
            "--log-every", "100"]
    r0 = _run(module="repro.launch.train", args=base + ["--ckpt-dir", ck1],
              env=env)
    assert r0.returncode == 0, r0.stderr
    clean = json.loads(re.search(r"LOSSES (\[.*\])", r0.stdout).group(1))

    r1 = _run(module="repro.launch.train",
              args=base + ["--ckpt-dir", ck2, "--simulate-failure-at", "12"],
              env=env)
    assert r1.returncode != 0 and "simulated node failure" in r1.stderr

    r2 = _run(module="repro.launch.train", args=base + ["--ckpt-dir", ck2],
              env=env)
    assert r2.returncode == 0, r2.stderr
    assert "restored step 10" in r2.stdout
    resumed = json.loads(re.search(r"LOSSES (\[.*\])", r2.stdout).group(1))
    # steps 10..19 of the clean run == the resumed run
    np.testing.assert_allclose(clean[10:], resumed, rtol=1e-6)


@pytest.mark.slow
def test_ddp_compressed_trainer_multidevice():
    code = """
import jax, jax.numpy as jnp
from repro.configs import get_config, make_smoke
from repro.train.state import init_train_state
from repro.train.step import make_ddp_train_step
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train import compression as comp
cfg = make_smoke(get_config("qwen1.5-0.5b"))
opt = OptConfig(lr=1e-3, warmup_steps=1, total_steps=30)
from repro.core._compat import make_mesh
mesh = make_mesh((4,), ("data",))
key = jax.random.PRNGKey(0)
st = init_train_state(key, cfg, opt)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
ddp = jax.jit(make_ddp_train_step(cfg, opt, mesh, compress=True))
p, o, e = st.params, init_opt_state(st.params, opt), comp.init_error_state(st.params)
losses = []
for _ in range(6):
    p, o, e, loss = ddp(p, o, e, batch)
    losses.append(float(loss))
assert losses[-1] < losses[0], losses
print("DDP_OK", losses[0], losses[-1])
"""
    r = _run(code=code, devices=4)
    assert "DDP_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_serve_driver_runs():
    r = _run(module="repro.launch.serve",
             args=["--arch", "mamba2-130m", "--smoke", "--requests", "4",
                   "--batch", "2", "--prompt-len", "16", "--gen", "4"])
    assert r.returncode == 0, r.stderr
    assert "tok/s" in r.stdout


@pytest.mark.slow
def test_sssp_run_driver_scaling_procs():
    r = _run(module="repro.launch.sssp_run",
             args=["--engine", "dijkstra_sharded", "--procs", "4",
                   "--nodes", "200", "--edges", "600", "--verify",
                   "--repeats", "1"])
    assert r.returncode == 0, r.stderr
    assert "verify: OK" in r.stdout


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint on 1 device, restore on an 8-device mesh (reshard-on-load)."""
    ck = str(tmp_path / "ck")
    r1 = _run(module="repro.launch.train",
              args=["--arch", "mamba2-130m", "--smoke", "--steps", "6",
                    "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                    "--ckpt-every", "3"])
    assert r1.returncode == 0, r1.stderr
    r2 = _run(module="repro.launch.train",
              args=["--arch", "mamba2-130m", "--smoke", "--steps", "8",
                    "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                    "--ckpt-every", "4", "--data-axis", "8"],
              devices=8)
    assert r2.returncode == 0, r2.stderr
    assert "restored step 6" in r2.stdout


@pytest.mark.slow
def test_moe_ep_shard_map_matches_gspmd():
    """The explicit expert-parallel shard_map MoE must produce the same
    outputs as the GSPMD grouped path (same routing, same capacity
    semantics) on a real (data=2, model=2) mesh."""
    code = """
import dataclasses, jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config, make_smoke
from repro.models.moe import init_moe, moe
cfg = dataclasses.replace(make_smoke(get_config("qwen2-moe-a2.7b")),
                          expert_pad_to=8)
from repro.core._compat import make_mesh, set_mesh
mesh = make_mesh((2, 2), ("data", "model"))
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
with set_mesh(mesh):
    cfg_g = dataclasses.replace(cfg, moe_impl="gspmd")
    cfg_e = dataclasses.replace(cfg, moe_impl="ep")
    out_g, aux_g = jax.jit(lambda p, x: moe(p, x, cfg_g))(p, x)
    out_e, aux_e = jax.jit(lambda p, x: moe(p, x, cfg_e))(p, x)
err = np.abs(np.asarray(out_g, np.float32) - np.asarray(out_e, np.float32)).max()
aerr = abs(float(aux_g) - float(aux_e))
assert err < 2e-3, err
assert aerr < 1e-4, (float(aux_g), float(aux_e))
print("EP_OK", err, aerr)
"""
    r = _run(code=code, devices=4)
    assert "EP_OK" in r.stdout, r.stdout + r.stderr
