"""Frontier-compacted engine + batched multi-source CSR correctness.

Pins down the PR's perf claims as testable invariants: the frontier
engines agree bitwise with every other engine (same f32 path-sum minima),
the edges-relaxed counter proves the O(frontier out-degree) sweeps do
strictly less work than bellman_csr's O(m) sweeps where frontiers are
narrow, and the batched CSR engine equals S independent solves.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import dijkstra_oracle, finite_close
from repro.core import csr as C
from repro.core import graph as G
from repro.core.api import recover_pred, shortest_paths
from repro.core.bellman_csr import csr_operands, sssp_multisource_csr
from repro.core.frontier import (frontier_operands, make_flat_sweep_fn,
                                 sssp_frontier)
from repro.kernels.frontier_relax import (frontier_cand_block,
                                          frontier_cand_ref,
                                          frontier_relax_ref)

FRONTIER = ("frontier", "frontier_kernel")


def _skewed_hub(n=120, spokes=100):
    """Heavy-tailed out-degree: vertex 0 fans out to ``spokes`` vertices
    (the shape where padded-ELL widths blow up and frontier compaction
    must still relax every window correctly)."""
    hub = np.stack([np.zeros(spokes, np.int64),
                    np.arange(1, spokes + 1)], 1)
    path = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    edges = np.concatenate([hub, path])
    return G.csr_from_edge_list(n, edges,
                                np.arange(1.0, len(edges) + 1.0))


def _cases():
    return [
        pytest.param(G.random_graph(50, 1225, seed=1), id="dense50"),
        pytest.param(G.random_graph(100, 300, seed=2), id="sparse100"),
        pytest.param(G.random_graph(60, 240, seed=3, directed=True),
                     id="directed60"),
        pytest.param(G.random_graph(50, 60, seed=4, connected=False),
                     id="disconnected50"),
        pytest.param(_skewed_hub(), id="skewed-hub"),
        pytest.param(G.from_edge_list(1, np.zeros((0, 2), np.int64),
                                      np.zeros(0)), id="single-vertex"),
    ]


# ---------------------------------------------------------------------------
# frontier engines vs the independent heap oracle (+ bitwise vs bellman_csr)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", FRONTIER)
@pytest.mark.parametrize("g", _cases())
def test_frontier_matches_oracle(engine, g):
    ref = dijkstra_oracle(g, 0)
    res = shortest_paths(g, 0, engine=engine)
    assert finite_close(ref, res.dist)
    assert np.array_equal(np.isfinite(ref), np.isfinite(res.dist))
    # same candidate minima as the whole-graph sweep: bitwise equality
    base = shortest_paths(g, 0, engine="bellman_csr")
    assert np.array_equal(base.dist, res.dist)


@pytest.mark.parametrize("n,m", [(100, 300), (1000, 3000)])
def test_frontier_bitwise_matches_serial_paper_corpus(n, m):
    g = G.paper_graph(n, m, seed=n + m)
    ref = shortest_paths(g, 0, engine="serial").dist
    for engine in FRONTIER:
        got = shortest_paths(g, 0, engine=engine).dist
        assert np.array_equal(ref, got), engine


@pytest.mark.parametrize("delta", [5.0, 30.0, 1000.0])
def test_frontier_delta_schedule_same_fixpoint(delta):
    g = G.random_graph(120, 480, seed=9)
    base = shortest_paths(g, 0, engine="frontier")
    res = shortest_paths(g, 0, engine="frontier", delta=delta)
    assert np.array_equal(base.dist, res.dist)
    assert np.array_equal(base.pred, res.pred)


def test_frontier_small_chunk_multi_step_inner_loop():
    """chunk=8 forces many inner edge-slot steps per sweep; result must be
    bitwise identical to the single-chunk default."""
    cg = C.random_csr_graph(80, 320, seed=13)
    ops = frontier_operands(cg)
    d_ref, p_ref, s_ref, e_ref, c_ref = sssp_frontier(ops, jnp.int32(0),
                                                      n=cg.n)
    d, p, s, e, c = sssp_frontier(ops, jnp.int32(0), n=cg.n, chunk=8)
    assert np.array_equal(np.asarray(d_ref), np.asarray(d))
    assert np.array_equal(np.asarray(p_ref), np.asarray(p))
    assert (int(s_ref), int(e_ref)) == (int(s), int(e))
    assert bool(c_ref) and bool(c)


def test_frontier_pred_tree_valid_and_matches_csr():
    g = G.random_graph(90, 350, seed=11)
    base = shortest_paths(g, 0, engine="bellman_csr")
    for engine in FRONTIER:
        res = shortest_paths(g, 0, engine=engine)
        # identical fixpoint + identical recovery -> identical tree
        assert np.array_equal(base.pred, res.pred), engine


# ---------------------------------------------------------------------------
# the perf claim, as an invariant: sweeps touch only the frontier's edges
# ---------------------------------------------------------------------------

def test_frontier_relaxes_fewer_edges_than_bellman_csr_on_path():
    """Path graph: bellman_csr relaxes all 2(n-1) arcs for each of ~n
    sweeps; the frontier engine's active set is one vertex per sweep, so
    its total must be strictly (and asymptotically) smaller."""
    n = 64
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    cg = G.csr_from_edge_list(n, edges, np.ones(n - 1))
    rf = shortest_paths(cg, 0, engine="frontier")
    rb = shortest_paths(cg, 0, engine="bellman_csr")
    assert rb.edges_relaxed == rb.sweeps * cg.nnz
    assert rf.edges_relaxed < rb.edges_relaxed
    # one frontier vertex per sweep, <= 2 arcs each (undirected path)
    assert rf.edges_relaxed <= 2 * n


def test_frontier_edges_counter_exact_on_star():
    """Star from the hub: sweep 1 relaxes the hub's out-degree, sweep 2
    relaxes the leaves' back-arcs, then one empty-improvement sweep."""
    n = 9
    edges = np.stack([np.zeros(n - 1, np.int64), np.arange(1, n)], 1)
    cg = G.csr_from_edge_list(n, edges, np.ones(n - 1))
    res = shortest_paths(cg, 0, engine="frontier")
    assert res.edges_relaxed == (n - 1) + (n - 1)
    assert res.sweeps == 2


# ---------------------------------------------------------------------------
# batched multi-source CSR
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g", _cases())
def test_multisource_csr_rows_match_oracle(g):
    n = g.n if hasattr(g, "n") else g.shape[0]
    srcs = np.unique(np.array([0, n // 2, n - 1], np.int32))
    res = shortest_paths(g, srcs, engine="multisource_csr")
    assert res.dist.shape == (len(srcs), n)
    assert res.pred is None
    for i, s in enumerate(srcs):
        assert finite_close(dijkstra_oracle(g, int(s)), res.dist[i]), s


def test_multisource_csr_bitwise_matches_single_source_and_dense_batch():
    g = G.random_graph(80, 400, seed=3)
    srcs = np.array([0, 17, 42, 63], np.int32)
    res = shortest_paths(g, srcs, engine="multisource_csr")
    dense = shortest_paths(g, srcs, engine="multisource")
    assert np.array_equal(res.dist, dense.dist)
    for i, s in enumerate(srcs):
        single = shortest_paths(g, int(s), engine="bellman_csr")
        assert np.array_equal(single.dist, res.dist[i]), s


def test_multisource_csr_accepts_csr_input_no_densify(monkeypatch):
    cg = C.random_csr_graph(500, 1500, seed=8)
    monkeypatch.setattr(
        C.CsrGraph, "to_dense",
        lambda self: pytest.fail("multisource_csr densified the graph"),
    )
    res = shortest_paths(cg, np.array([0, 250], np.int32),
                         engine="multisource_csr")
    assert np.isfinite(res.dist).all()


# ---------------------------------------------------------------------------
# recover_pred (satellite: SsspResult.pred is None for multisource)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["multisource", "multisource_csr"])
def test_recover_pred_builds_valid_trees(engine):
    g = G.random_graph(90, 350, seed=11)
    srcs = np.array([0, 30, 60], np.int32)
    res = shortest_paths(g, srcs, engine=engine)
    assert res.pred is None
    arg = g.to_csr() if engine == "multisource_csr" else g
    P = recover_pred(res, arg)
    assert P.shape == res.dist.shape
    for i, s in enumerate(srcs):
        d, p = res.dist[i], P[i]
        assert p[s] == -1
        for v in range(g.n):
            if v == s or not np.isfinite(d[v]):
                continue
            u = p[v]
            assert u >= 0 and u != v
            assert np.isclose(d[v], d[u] + g.adj[u, v], rtol=1e-5)
        # same helper as the single-source engines -> identical tree
        eng = "bellman_csr" if engine == "multisource_csr" else "bellman"
        assert np.array_equal(
            P[i], shortest_paths(g, int(s), engine=eng).pred)


def test_recover_pred_passthrough_and_source_inference():
    g = G.random_graph(40, 120, seed=6)
    res = shortest_paths(g, 0, engine="bellman_csr")
    assert recover_pred(res, g.to_csr()) is res.pred
    # sources stripped -> inferred from the zero entry of each row
    ms = shortest_paths(g, np.array([7], np.int32), engine="multisource")
    ms.sources = None
    P = recover_pred(ms, g)
    assert np.array_equal(
        P[0], shortest_paths(g, 7, engine="bellman").pred)


# ---------------------------------------------------------------------------
# out-CSR container views + the Pallas candidate kernel vs its oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("directed", [False, True])
def test_out_csr_is_the_transpose(directed):
    cg = C.random_csr_graph(60, 240, seed=21, directed=directed)
    indptr, out_dst, out_w = cg.out_csr()
    assert indptr[-1] == cg.nnz
    adj = cg.to_dense().adj
    for u in range(cg.n):
        dsts = out_dst[indptr[u]:indptr[u + 1]]
        ws = out_w[indptr[u]:indptr[u + 1]]
        assert np.all(np.diff(dsts) > 0)            # sorted, no dup arcs
        for v, w in zip(dsts, ws):
            assert adj[u, v] == w
        assert len(dsts) == np.isfinite(np.delete(adj[u], u)).sum()


def test_out_ell_padding_is_inert():
    cg = _skewed_hub()
    idx, w = cg.out_ell()
    indptr, _, _ = cg.out_csr()
    deg = np.diff(indptr)
    assert idx.shape[1] >= deg.max() and idx.shape[1] % 8 == 0
    for u in range(cg.n):
        assert np.all(np.isfinite(w[u, :deg[u]]))
        assert np.all(np.isinf(w[u, deg[u]:]))
        assert np.all(idx[u, deg[u]:] == 0)


@pytest.mark.parametrize("n,F", [(64, 16), (100, 100), (137, 40)])
def test_kernel_cand_bitwise_matches_ref(n, F):
    cg = C.random_csr_graph(n, 4 * n, seed=n)
    ell_idx, ell_w = cg.out_ell()
    rng = np.random.default_rng(n)
    d = rng.uniform(0, 50, n).astype(np.float32)
    d[rng.uniform(size=n) < 0.3] = np.inf
    fids = np.concatenate([rng.permutation(n)[:F - F // 4],
                           np.full(F // 4, n)]).astype(np.int32)  # sentinels
    dist = jnp.asarray(d)
    w_rows = jnp.asarray(ell_w)[jnp.minimum(jnp.asarray(fids), n - 1)]
    ref = frontier_cand_ref(dist, jnp.asarray(fids), w_rows)
    out = frontier_cand_block(dist, jnp.asarray(fids), w_rows,
                              interpret=True)
    assert np.array_equal(np.asarray(ref), np.asarray(out))


def test_kernel_sweep_bitwise_matches_flat_sweep():
    """Full-sweep agreement: the kernel ELL path and the flat-CSR path
    scatter-min the same candidate multiset."""
    from repro.kernels.frontier_relax.ops import make_frontier_sweep_fn

    cg = C.random_csr_graph(90, 360, seed=33)
    ops = frontier_operands(cg, with_ell=True)
    for src in (0, 45):
        a = sssp_frontier(ops, jnp.int32(src), n=cg.n)
        b = sssp_frontier(ops, jnp.int32(src), n=cg.n,
                          sweep_fn=make_frontier_sweep_fn(block_f=32,
                                                          interpret=True))
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y))


def test_frontier_relax_ref_matches_engine_first_sweep():
    """The uncompacted oracle sweep equals one engine sweep from the
    source frontier."""
    cg = C.random_csr_graph(70, 280, seed=5)
    ops = frontier_operands(cg, with_ell=True)
    n = cg.n
    dist0 = jnp.full((n,), jnp.inf).at[0].set(0.0)
    active = dist0 < jnp.inf
    want = frontier_relax_ref(dist0, active, ops["out_ell_idx"],
                              ops["out_ell_w"])
    d1, _, _, _, _ = sssp_frontier(ops, jnp.int32(0), n=n, max_sweeps=1)
    assert np.array_equal(np.asarray(want), np.asarray(d1))
