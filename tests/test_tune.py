"""Self-tuning subsystem contracts (repro/tune + its dispatch seam).

Pins what ISSUE 10 introduced:

- determinism: fitting twice from the same records and seed yields
  byte-identical serialized models (lstsq + seeded bootstrap only);
- conservative fallback: outside the calibrated support the TunedPolicy
  defers to the hard-coded thresholds (``via="threshold"``), inside it
  routes by predicted wall (``via="model"``) — and selection never
  changes answers (bitwise-equal to serial either way);
- statics plumbing: the Δ / chunk / batch-cap a policy returns on the
  ``EngineChoice`` actually reach the scheduler's solves — admission is
  throttled to the cap and ``sssp_frontier`` receives the statics;
- replay gate: a clean log replays green, a perturbed (slowed) log
  fails, out-of-support and unfitted records are skipped with reasons,
  backend mismatches are refused, and the gate is one-sided by default;
- policy seam: ``set_default_policy`` returns the previous policy and
  ``policy_override`` restores it (exception path included);
- v2 cost records: the shim auto-stamps backend/device_kind and the
  validator accepts both v1- and v2-shaped records;
- calibration: a micro sweep through the real api shim produces valid
  records a model fits from end to end.
"""
import json

import numpy as np
import pytest

from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.obs import CostLog, set_cost_log
from repro.obs.validate import validate_cost_records
from repro.serve import DistanceCache, GraphRegistry, MicroBatchScheduler
from repro.serve.dispatch import (DispatchPolicy, EngineChoice,
                                  default_policy, policy_override,
                                  set_default_policy)
from repro.tune import TunedPolicy, fit_model, graph_features, replay_records
from repro.tune.model import CostModel


# ---------------------------------------------------------------------------
# synthetic calibration records: noiseless power laws the fit recovers
# exactly, with delta_stepping the cheapest engine by construction
# ---------------------------------------------------------------------------

def _rec(engine, n, m, wall_ms, *, batch=1, nprocs=1, delta=0.0,
         corpus="sparse", hops=10.0, skew=2.0, converged=True,
         delta_kind=None):
    r = {"engine": engine, "graph": "t", "n": n, "m": m, "batch": batch,
         "nprocs": nprocs, "delta": delta, "sweeps": 3,
         "edges_relaxed": m, "wall_ms": wall_ms, "converged": converged,
         "corpus": corpus, "hops": hops, "skew": skew,
         "backend": "cpu", "device_kind": "cpu"}
    if delta_kind:
        r["delta_kind"] = delta_kind
    return r


def _synthetic_records():
    """Grid n in {256..2048}, m = 3n: frontier ~ n/100 ms, bellman ~
    n/50 ms, delta_stepping ~ n/1000 ms with two Δ candidates per point
    (Δ=8 measured better than Δ=4)."""
    recs = []
    for n in (256, 512, 1024, 2048):
        m = 3 * n
        recs.append(_rec("frontier", n, m, n / 100.0))
        recs.append(_rec("bellman_csr", n, m, n / 50.0))
        recs.append(_rec("delta_stepping", n, m, n / 500.0, delta=4.0,
                         delta_kind="auto"))
        recs.append(_rec("delta_stepping", n, m, n / 1000.0, delta=8.0,
                         delta_kind="alt"))
        for b in (2, 4):
            recs.append(_rec("multisource_csr", n, m, b * n / 150.0,
                             batch=b))
    return recs


@pytest.fixture()
def model():
    return fit_model(_synthetic_records(), seed=0)


# ---------------------------------------------------------------------------
# model fitting
# ---------------------------------------------------------------------------

def test_fit_deterministic_under_fixed_seed():
    recs = _synthetic_records()
    a = fit_model(recs, seed=0, meta={"k": 1})
    b = fit_model(list(recs), seed=0, meta={"k": 1})
    assert a.to_json() == b.to_json()
    # serialization roundtrip is also exact
    assert CostModel.from_json(a.to_json()).to_json() == a.to_json()


def test_fit_recovers_power_law_and_statics(model):
    # noiseless data -> near-zero residual and accurate interpolation
    fit = model.fit_for("frontier", 1)
    assert fit is not None and fit.rms_log_err < 1e-6
    pred = model.predict("frontier", n=1024, m=3072)
    assert pred == pytest.approx(1024 / 100.0, rel=1e-3)
    # delta fits collapse to the per-point best static and remember it
    assert model.predict("delta_stepping", n=1024, m=3072) \
        == pytest.approx(1024 / 1000.0, rel=1e-3)
    assert model.best_delta("delta_stepping", n=1024, m=3072) == 8.0
    # best_batch is the per-source argmin at the nearest point
    assert model.best_batch(n=1024, m=3072) in (2, 4)


def test_best_delta_keeps_auto_width_within_noise():
    # the alt Δ "wins" by 5% — inside DELTA_WIN_MARGIN, so the graph-
    # derived auto width is kept; a one-off noisy calibration point must
    # not permanently bias the static
    recs = []
    for n in (256, 512, 1024):
        m = 3 * n
        recs.append(_rec("delta_stepping", n, m, 10.0, delta=4.0,
                         delta_kind="auto"))
        recs.append(_rec("delta_stepping", n, m, 9.5, delta=8.0,
                         delta_kind="alt"))
    mdl = fit_model(recs, seed=0)
    assert mdl.best_delta("delta_stepping", n=512, m=1536) == 4.0


def test_fit_skips_thin_groups_and_bad_records():
    recs = [_rec("frontier", 256, 768, 1.0),
            _rec("frontier", 512, 1536, 2.0),  # only 2 points: skipped
            _rec("weird", 256, 768, 1.0, converged=False),
            _rec("weird", 256, 768, 0.0)]      # zero wall: dropped
    m = fit_model(recs, seed=0)
    assert m.fit_for("frontier", 1) is None
    assert m.fit_for("weird", 1) is None
    assert m.meta["dropped_records"] == 2
    assert any(s.startswith("frontier@P1") for s in m.meta["skipped_groups"])


# ---------------------------------------------------------------------------
# TunedPolicy selection + fallback
# ---------------------------------------------------------------------------

def test_tuned_policy_routes_by_model_inside_support(model):
    cg = C.random_csr_graph(1024, 3072, seed=7)
    pol = TunedPolicy(model, nprocs=1)
    base = DispatchPolicy(nprocs=1).choose(cg, kind="single")
    choice = pol.choose(cg, kind="single")
    assert base.engine == "frontier" and base.via == "threshold"
    assert choice.engine == "delta_stepping" and choice.via == "model"
    assert choice.delta == 8.0          # measured-best static rides along
    assert pol.model_routed == 1 and pol.fallback_routed == 0
    # selection never changes answers
    with policy_override(pol):
        tuned = shortest_paths(cg, 0, engine="auto")
    serial = shortest_paths(cg, 0, engine="serial")
    assert np.array_equal(np.asarray(tuned.dist), np.asarray(serial.dist))


def test_tuned_policy_falls_back_outside_support(model):
    pol = TunedPolicy(model, nprocs=1)
    tiny = C.random_csr_graph(50, 150, seed=3)      # n << support/margin
    choice = pol.choose(tiny, kind="single")
    assert choice.via == "threshold"
    assert choice.engine == "frontier"              # the hard-coded rule
    assert pol.fallback_routed == 1 and pol.model_routed == 0
    # unfitted pair (no sharded fits in the synthetic model): an n large
    # enough to shard falls back too, never guesses
    pol4 = TunedPolicy(model, nprocs=1)
    huge = C.random_csr_graph(8192, 24576, seed=4)  # above support * 2
    assert pol4.choose(huge, kind="single").via == "threshold"


def test_tuned_policy_dynamic_graph_falls_back(model):
    from repro.dynamic.overlay import DynamicGraph

    dyn = DynamicGraph(C.random_csr_graph(1024, 3072, seed=9))
    pol = TunedPolicy(model, nprocs=1)
    assert pol.choose(dyn, kind="single").via == "threshold"


# ---------------------------------------------------------------------------
# statics plumbing through the scheduler
# ---------------------------------------------------------------------------

class _StaticsPolicy(DispatchPolicy):
    """Threshold policy that pins statics, standing in for a model."""

    def batch_cap(self, g):
        return 2

    def choose(self, g, *, kind="single"):
        base = super().choose(g, kind=kind)
        if kind == "p2p" and base.nprocs == 1:
            return EngineChoice(base.engine, None, base.axis, 1,
                                delta=7.5, chunk=128, via="model")
        return base


def _stack(cg, policy, *, max_batch=8):
    registry = GraphRegistry()
    cache = DistanceCache(capacity=64)
    sched = MicroBatchScheduler(registry, cache, max_batch=max_batch,
                                dispatch=policy)
    registry.register("g", cg)
    return sched


def test_scheduler_admission_respects_policy_batch_cap():
    cg = C.random_csr_graph(256, 768, seed=5)
    sched = _stack(cg, _StaticsPolicy(nprocs=1))
    for s in (3, 9, 17, 33, 57):
        sched.submit("g", s)
    first = sched.tick()
    assert len(first) == 2              # cap=2 < max_batch=8 throttles
    rest = []
    for _ in range(3):
        rest += sched.tick()
    assert len(first) + len(rest) == 5  # requeued queries drain
    ref = shortest_paths(cg, 3, engine="serial").dist
    got = next(a for a in first + rest if a.query.source == 3)
    assert np.array_equal(np.asarray(got.value), np.asarray(ref))


def test_scheduler_p2p_uses_choice_statics(monkeypatch):
    import repro.serve.scheduler as sched_mod

    seen = {}
    real = sched_mod.sssp_frontier

    def spy(*args, **kwargs):
        seen.update(kwargs)
        return real(*args, **kwargs)

    monkeypatch.setattr(sched_mod, "sssp_frontier", spy)
    cg = C.random_csr_graph(256, 768, seed=5)
    sched = _stack(cg, _StaticsPolicy(nprocs=1))
    sched.submit("g", 3, 77)
    (ans,) = sched.tick()
    assert seen.get("delta") == 7.5 and seen.get("chunk") == 128
    ref = shortest_paths(cg, 3, engine="serial").dist[77]
    assert np.float32(ans.value) == np.float32(ref)


# ---------------------------------------------------------------------------
# replay gate
# ---------------------------------------------------------------------------

def test_replay_clean_log_passes(model):
    recs = _synthetic_records()
    rep = replay_records(recs, model, tol=1.5)
    assert rep["pass"] and rep["replayed"] > 0 and not rep["failures"]


def test_replay_fails_on_perturbed_log(model):
    recs = _synthetic_records()
    slow = [dict(r, wall_ms=r["wall_ms"] * 10) for r in recs]
    rep = replay_records(slow, model, tol=3.0)
    assert not rep["pass"]
    assert any(k.startswith("frontier@P1") for k in rep["failures"])


def test_replay_one_sided_by_default(model):
    fast = [dict(r, wall_ms=r["wall_ms"] / 10)
            for r in _synthetic_records()]
    assert replay_records(fast, model, tol=3.0)["pass"]
    assert not replay_records(fast, model, tol=3.0, two_sided=True)["pass"]


def test_replay_skips_uncovered_records_with_reasons(model):
    recs = [_rec("frontier", 10 ** 6, 3 * 10 ** 6, 1.0),   # out of support
            _rec("repair", 512, 1536, 1.0),                # unfitted
            _rec("frontier", 512, 1536, 1.0, converged=False)]
    rep = replay_records(recs, model, tol=3.0)
    assert rep["replayed"] == 0 and not rep["pass"]
    assert rep["skipped"]["out_of_support:frontier@P1"] == 1
    assert rep["skipped"]["unfitted:repair@P1"] == 1
    assert rep["skipped"]["not_converged"] == 1


def test_replay_refuses_backend_mismatch(model):
    recs = [dict(r, backend="tpu") for r in _synthetic_records()]
    rep = replay_records(recs, model, tol=3.0, expect_backend="cpu")
    assert rep["backend_mismatch"] == len(recs) and not rep["pass"]


# ---------------------------------------------------------------------------
# policy seam + v2 records + features
# ---------------------------------------------------------------------------

def test_set_default_policy_returns_previous_and_override_restores():
    p1, p2 = DispatchPolicy(nprocs=1), DispatchPolicy(nprocs=1)
    prev0 = set_default_policy(p1)
    try:
        assert default_policy() is p1
        with policy_override(p2) as installed:
            assert installed is p2 and default_policy() is p2
        assert default_policy() is p1
        with pytest.raises(RuntimeError):
            with policy_override(p2):
                assert default_policy() is p2
                raise RuntimeError("boom")
        assert default_policy() is p1           # restored on exception
        assert set_default_policy(None) is p1   # returns the previous
    finally:
        set_default_policy(prev0)


def test_cost_records_v2_backend_stamped_and_v1_still_valid():
    cg = C.random_csr_graph(64, 192, seed=1)
    log = CostLog()
    prev = set_cost_log(log)
    try:
        shortest_paths(cg, 0, engine="frontier")
    finally:
        set_cost_log(prev)
    rows = [r.to_dict() for r in log.records]
    assert rows and rows[0]["backend"] and rows[0]["device_kind"]
    assert validate_cost_records(rows) == []
    v1 = [{k: v for k, v in r.items()
           if k not in ("backend", "device_kind")} for r in rows]
    assert validate_cost_records(v1) == []      # v1 shape still accepted
    bad = [dict(rows[0], backend=123)]
    assert validate_cost_records(bad) != []


def test_graph_features_memoized_and_sane():
    cg = C.random_csr_graph(256, 768, seed=11)
    f1 = graph_features(cg)
    assert f1["n"] == 256 and f1["m"] == cg.nnz
    assert f1["hops"] >= 1 and f1["skew"] >= 1.0
    assert graph_features(cg) is f1             # memoized on the graph


def test_micro_calibration_sweep_fits_end_to_end():
    from repro.tune.calibrate import sweep

    records = sweep((("sparse", 64, 192),), repeats=1, devices=1,
                    smoke=True, batches=(2,), verbose=False)
    assert records and validate_cost_records(records) == []
    assert all(r["corpus"] == "sparse" and r["hops"] >= 1 for r in records)
    m = fit_model(records, min_records=1)
    assert m.engines()                          # something fitted
    for eng, p in m.engines():
        assert m.predict(eng, n=64, m=192, nprocs=p) > 0
