"""Serving subsystem correctness: every served answer is oracle-exact.

The serving layer (repro/serve) composes batching, deduplication, caching,
and landmark pruning — each a chance to serve a wrong byte.  These tests
pin the invariant the whole subsystem is built around: whatever path an
answer takes (cache hit, landmark row, bucket-padded multisource batch,
target early-exit frontier solve), it is bitwise-equal to a fresh
``serial`` engine solve of the same query.  Plus the machinery itself:
registry byte-budget LRU eviction (with cache purge), scheduler dedup and
bucket padding, cache LRU counters, landmark-bound admissibility
(property-tested when hypothesis is installed), and the ``target=``
early-exit contract of core/frontier.py.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from conftest import dijkstra_oracle
from repro.core import csr as C
from repro.core import graph as G
from repro.core.api import shortest_paths
from repro.core.frontier import frontier_operands, sssp_frontier
from repro.serve import (DistanceCache, GraphRegistry, LatencyRecorder,
                         MicroBatchScheduler, build_landmarks, make_trace)
from repro.serve.landmarks import sample_landmark_ids
from repro.serve.workload import zipf_vertices


def _stack(cg, *, budget=None, cache_rows=256, max_batch=8, landmarks=0,
           name="g"):
    registry = GraphRegistry(byte_budget=budget)
    cache = DistanceCache(capacity=cache_rows)
    sched = MicroBatchScheduler(registry, cache, max_batch=max_batch)
    registry.register(name, cg, landmarks=landmarks)
    return registry, cache, sched


def _serial_rows(cg, sources):
    return {s: shortest_paths(cg, s, engine="serial").dist
            for s in set(sources)}


def _assert_exact(answers, rows_by_graph):
    """Every Answer bitwise-equal to the serial row of its query."""
    for a in answers:
        q = a.query
        ref = rows_by_graph[q.graph][q.source]
        if q.target is None:
            assert np.array_equal(a.value, ref), (q, a.via)
        else:
            got, want = np.float32(a.value), ref[q.target]
            assert got == want or (np.isinf(got) and np.isinf(want)), \
                (q, a.via, got, want)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lru_eviction_by_byte_budget():
    graphs = [C.random_csr_graph(200, 600, seed=i) for i in range(3)]
    one = graphs[0].nbytes
    registry = GraphRegistry(byte_budget=int(2.5 * one))
    evicted = []
    registry.add_evict_hook(evicted.append)
    for i, cg in enumerate(graphs):
        registry.register(f"g{i}", cg)
    # third registration blows the 2.5-graph budget: g0 (LRU) must go
    assert evicted == ["g0"]
    assert registry.names == ("g1", "g2")
    assert registry.stats()["evicted"] == 1
    with pytest.raises(KeyError):
        registry.get("g0")
    # touching g1 makes g2 the LRU victim of the next admission
    registry.get("g1")
    registry.register("g3", C.random_csr_graph(200, 600, seed=9))
    assert "g1" in registry and "g2" not in registry


def test_registry_staged_bytes_are_accounted():
    cg = C.random_csr_graph(100, 300, seed=0)
    registry = GraphRegistry()
    h = registry.register("g", cg)
    base = registry.bytes_in_use
    h.csr_ops()
    staged = registry.bytes_in_use
    assert staged > base          # device arrays now counted
    h.frontier_ops()
    assert registry.bytes_in_use > staged
    # frontier_ops shares csr_ops' arrays: the increment is the out-CSR
    # views only, not a second copy of src/dst/w
    shared = sum(int(a.nbytes) for a in h.csr_ops().values())
    assert registry.bytes_in_use - base < 2 * shared + cg.n * 8


def test_registry_single_graph_over_budget_is_admitted():
    cg = C.random_csr_graph(300, 900, seed=1)
    registry = GraphRegistry(byte_budget=10)      # absurdly small
    registry.register("g", cg)
    assert "g" in registry and registry.stats()["over_budget"]


def test_registry_eviction_purges_cache_rows():
    g0, g1 = (C.random_csr_graph(150, 450, seed=i) for i in (0, 1))
    registry, cache, sched = _stack(g0, budget=int(1.5 * g0.nbytes),
                                    name="g0")
    sched.submit("g0", 3)
    sched.drain()
    assert cache.peek(("g0", 3)) is not None
    registry.register("g1", g1)                   # evicts g0
    assert cache.peek(("g0", 3)) is None          # purged with its graph
    # queries against the evicted graph get error answers; queries for
    # live graphs drained in the same tick are still served
    sched.submit("g0", 4)
    sched.submit("g1", 2)
    answers = sched.tick()
    by_graph = {a.query.graph: a for a in answers}
    assert by_graph["g0"].via == "error" and by_graph["g0"].value is None
    assert by_graph["g0"].status == "graph_gone" and not by_graph["g0"].ok
    assert by_graph["g1"].status == "ok" and by_graph["g1"].exact
    assert np.array_equal(
        by_graph["g1"].value,
        shortest_paths(g1, 2, engine="serial").dist)


def test_registry_reregister_same_name_purges_stale_rows():
    g_old = C.random_csr_graph(150, 450, seed=0)
    g_new = C.random_csr_graph(150, 450, seed=5)
    registry, cache, sched = _stack(g_old)
    sched.submit("g", 7)
    sched.drain()
    registry.register("g", g_new)                 # same name, new graph
    sched.submit("g", 7)
    (ans,) = sched.drain()
    ref = shortest_paths(g_new, 7, engine="serial").dist
    assert np.array_equal(ans.value, ref)         # not the stale g_old row


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def test_cache_lru_counters_and_eviction():
    cache = DistanceCache(capacity=2)
    r = {k: np.full(4, float(k)) for k in range(3)}
    cache.put(("g", 0), r[0])
    cache.put(("g", 1), r[1])
    assert cache.get(("g", 0)) is r[0]            # 0 now MRU
    cache.put(("g", 2), r[2])                     # evicts 1 (LRU)
    assert cache.get(("g", 1)) is None
    assert cache.get(("g", 2)) is r[2]
    assert (cache.hits, cache.misses, cache.evictions) == (2, 1, 1)
    assert cache.stats()["hit_rate"] == pytest.approx(2 / 3, abs=1e-3)


def test_cache_capacity_zero_disables():
    cache = DistanceCache(capacity=0)
    cache.put(("g", 0), np.zeros(4))
    assert cache.get(("g", 0)) is None and len(cache) == 0


def test_cache_purge_graph_is_selective():
    cache = DistanceCache(capacity=8)
    cache.put(("a", 0), np.zeros(2))
    cache.put(("a", 1), np.zeros(2))
    cache.put(("b", 0), np.ones(2))
    assert cache.purge_graph("a") == 2
    assert cache.peek(("b", 0)) is not None and len(cache) == 1


def test_cache_put_freezes_rows_against_caller_mutation():
    cache = DistanceCache(capacity=4)
    # borrowed buffer (a view): copied before freezing, so the caller's
    # backing store stays writable and post-put edits never reach the
    # cached bytes
    backing = np.arange(6, dtype=np.float32)
    view = backing[:4]
    assert not view.flags.owndata
    cache.put(("g", 0), view)
    backing[:] = -1.0                    # the regression: mutate after put
    assert np.array_equal(cache.get(("g", 0)),
                          np.arange(4, dtype=np.float32))
    # owned buffer: frozen in place — the repair-in-place aliasing class
    # becomes an immediate error instead of corrupted served bytes
    row = np.ones(4, dtype=np.float32)
    cache.put(("g", 1), row)
    with pytest.raises(ValueError):
        row[0] = 99.0
    assert np.array_equal(cache.get(("g", 1)), np.ones(4))


def test_cache_rejects_non_tuple_keys():
    # keys_for/purge_graph index k[0] on every key: a str key would make
    # purge_graph("g") crash or over-purge, so put refuses it outright
    cache = DistanceCache(capacity=4)
    with pytest.raises(TypeError, match="tuple"):
        cache.put("g", np.zeros(2))
    cache.put(("g", 0), np.zeros(2))
    cache.put(("g", 1, 0), np.ones(2))   # versioned/sharded arities coexist
    assert sorted(cache.keys_for("g")) == [("g", 0), ("g", 1, 0)]
    assert cache.purge_graph("g") == 2 and len(cache) == 0


# ---------------------------------------------------------------------------
# scheduler: dedup, bucketing, exactness per path
# ---------------------------------------------------------------------------

def test_scheduler_dedup_one_solve_for_repeat_sources():
    cg = C.random_csr_graph(120, 360, seed=2)
    _, _, sched = _stack(cg)
    for _ in range(10):
        sched.submit("g", 5)
    for t in (1, 2, 3):
        sched.submit("g", 5, t)
    answers = sched.tick()
    assert len(answers) == 13
    assert sched.engine_batches == 1              # ONE solve served all 13
    assert sched.engine_sources == 1
    assert sched.dedup_saved == 12
    _assert_exact(answers, {"g": _serial_rows(cg, [5])})


def test_scheduler_bucket_padding_hits_powers_of_two():
    cg = C.random_csr_graph(100, 300, seed=3)
    _, _, sched = _stack(cg, max_batch=8)
    for s in (1, 2, 3):                           # 3 distinct -> bucket 4
        sched.submit("g", s)
    sched.tick()
    assert sched.mean_occupancy == pytest.approx(3 / 4)
    assert sched._bucket(1) == 1 and sched._bucket(3) == 4
    assert sched._bucket(8) == 8 and sched._bucket(100) == 8  # clamped


def test_scheduler_overflow_requeues_beyond_max_batch():
    cg = C.random_csr_graph(60, 180, seed=4)
    _, _, sched = _stack(cg, max_batch=4)
    for s in range(10):
        sched.submit("g", s)
    first = sched.tick()
    assert len(first) == 4 and sched.pending == 6
    rest = sched.drain()
    assert len(rest) == 6
    rows = _serial_rows(cg, range(10))
    _assert_exact(first + rest, {"g": rows})


def test_scheduler_admission_split_and_requeue_order():
    """The set-based source admission (O(B) per tick instead of O(B^2))
    must keep the take/defer split and requeue order byte-identical:
    repeats of admitted sources ride along, overflow sources defer in
    FIFO order ahead of newer arrivals."""
    cg = C.random_csr_graph(60, 180, seed=6)
    _, _, sched = _stack(cg, max_batch=2)
    qs = [sched.submit("g", s) for s in (7, 8, 9, 7, 10)]
    first = sched.tick()
    # sources 7, 8 admitted; the repeat 7 rides along; 9, 10 deferred
    assert [a.query.qid for a in first] == [qs[0].qid, qs[1].qid, qs[3].qid]
    assert sched.engine_batches == 1 and sched.engine_sources == 2
    assert [q.qid for q in sched._queue] == [qs[2].qid, qs[4].qid]
    later = sched.submit("g", 11)        # newer arrival waits its turn
    second = sched.tick()
    assert [a.query.qid for a in second] == [qs[2].qid, qs[4].qid]
    third = sched.tick()
    assert [a.query.qid for a in third] == [later.qid]
    rows = _serial_rows(cg, [7, 8, 9, 10, 11])
    _assert_exact(first + second + third, {"g": rows})


def test_scheduler_multigraph_overflow_fair_requeue():
    """Two graphs overflowing max_batch in ONE tick: both graphs'
    deferred queries are requeued ahead of newer arrivals, each graph's
    in original FIFO order (the tick() contract across graphs)."""
    ga, gb = (C.random_csr_graph(50, 150, seed=i) for i in (7, 8))
    registry, cache, sched = _stack(ga, max_batch=2, name="a")
    registry.register("b", gb)
    for s in range(4):                   # a0 b0 a1 b1 ... interleaved
        sched.submit("a", s)
        sched.submit("b", s)
    first = sched.tick()
    assert len(first) == 4               # 2 sources admitted per graph
    assert [(q.graph, q.source) for q in sched._queue] == [
        ("a", 2), ("a", 3), ("b", 2), ("b", 3)]
    newer = sched.submit("a", 4)         # arrives after the overflow
    second = sched.tick()
    # both graphs' deferred queries are served before the newer arrival
    assert {(a.query.graph, a.query.source) for a in second} == {
        ("a", 2), ("a", 3), ("b", 2), ("b", 3)}
    assert [q.qid for q in sched._queue] == [newer.qid]
    third = sched.tick()
    assert [a.query.qid for a in third] == [newer.qid]
    rows = {"a": _serial_rows(ga, [0, 1, 2, 3, 4]),
            "b": _serial_rows(gb, range(4))}
    _assert_exact(first + second + third, rows)


def test_scheduler_cache_hits_skip_engine():
    cg = C.random_csr_graph(80, 240, seed=5)
    _, cache, sched = _stack(cg)
    sched.submit("g", 11)
    sched.drain()
    batches = sched.engine_batches
    sched.submit("g", 11)                         # same source again
    sched.submit("g", 11, 40)                     # and a p2p off the row
    answers = sched.drain()
    assert sched.engine_batches == batches        # no new solve
    assert all(a.via == "cache" for a in answers)
    _assert_exact(answers, {"g": _serial_rows(cg, [11])})


def test_scheduler_target_solo_path_exact_and_uncached():
    cg = C.random_csr_graph(150, 450, seed=6)
    _, cache, sched = _stack(cg, landmarks=4)
    ids = set(sched.registry.get("g").landmarks.ids.tolist())
    s = next(v for v in range(150) if v not in ids)
    sched.submit("g", s, (s + 37) % 150)
    (ans,) = sched.drain()
    assert ans.via == "target" and sched.target_solves == 1
    # a target= solve is partial: its row must NOT have been cached
    assert cache.peek(("g", s)) is None
    _assert_exact([ans], {"g": _serial_rows(cg, [s])})


def test_scheduler_landmark_row_answers_are_engine_rows():
    cg = C.random_csr_graph(90, 270, seed=7)
    _, _, sched = _stack(cg, landmarks=6)
    lm = int(sched.registry.get("g").landmarks.ids[0])
    sched.submit("g", lm)                         # sssp at a landmark
    sched.submit("g", lm, (lm + 1) % 90)          # p2p sourced at one
    answers = sched.drain()
    assert all(a.via == "landmark" for a in answers)
    assert sched.engine_batches == 0
    _assert_exact(answers, {"g": _serial_rows(cg, [lm])})


def test_scheduler_landmark_disconnection_answer():
    # two components; landmark in the big one proves inf to the island
    edges = np.stack([np.arange(49), np.arange(1, 50)], 1)
    cg = G.csr_from_edge_list(52, edges, np.ones(49) * 2.0)
    registry, _, sched = _stack(cg, landmarks=0)
    handle = registry.get("g")
    handle.landmarks = build_landmarks(cg, 8, seed=0)
    src = int(next(i for i in range(50)
                   if np.isfinite(handle.landmarks.D[:, i]).any()
                   and i not in set(handle.landmarks.ids.tolist())))
    sched.submit("g", src, 51)                    # 50..51 is the island
    (ans,) = sched.drain()
    assert ans.via == "landmark" and np.isinf(ans.value)
    ref = shortest_paths(cg, src, engine="serial").dist
    assert np.isinf(ref[51])


# ---------------------------------------------------------------------------
# trace replay end-to-end (the zipf satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scenario", ["uniform", "zipf", "p2p"])
def test_trace_replay_bitwise_exact(scenario):
    g0 = C.random_csr_graph(130, 390, seed=8)
    g1 = C.random_csr_graph(90, 270, seed=9)
    registry, cache, sched = _stack(g0, landmarks=5, max_batch=4, name="g0")
    registry.register("g1", g1, landmarks=5)
    events = make_trace(scenario, [("g0", 130), ("g1", 90)],
                        num_queries=50, rate=1e4, seed=10)
    rec = LatencyRecorder()
    for e in events:
        sched.submit(e.graph, e.source, e.target, arrival=e.arrival)
    answers = sched.drain()
    for a in answers:
        rec.observe(a, now=1.0)
    assert len(answers) == 50
    rows = {"g0": _serial_rows(g0, [a.query.source for a in answers
                                    if a.query.graph == "g0"]),
            "g1": _serial_rows(g1, [a.query.source for a in answers
                                    if a.query.graph == "g1"])}
    _assert_exact(answers, rows)
    assert rec.summary()["queries"] == 50
    if scenario == "zipf":
        # the skew must actually produce engine savings via dedup/cache
        served_free = (sched.dedup_saved
                       + sched.answered_via["cache"]
                       + sched.answered_via["landmark"])
        assert served_free > 0


def test_zipf_trace_is_skewed_and_deterministic():
    rng = np.random.default_rng(0)
    v = zipf_vertices(rng, 1000, 5000, 1.1)
    _, counts = np.unique(v, return_counts=True)
    assert counts.max() > 5 * np.median(counts)   # heavy head
    t1 = make_trace("zipf", [("g", 50)], num_queries=20, rate=10, seed=3)
    t2 = make_trace("zipf", [("g", 50)], num_queries=20, rate=10, seed=3)
    assert t1 == t2
    # hot_seed pins the hot set across different event seeds
    a = make_trace("zipf", [("g", 200)], num_queries=300, rate=10,
                   seed=1, hot_seed=42)
    b = make_trace("zipf", [("g", 200)], num_queries=300, rate=10,
                   seed=2, hot_seed=42)
    hot_a = {e.source for e in a}
    hot_b = {e.source for e in b}
    assert len(hot_a & hot_b) > 0


# ---------------------------------------------------------------------------
# landmarks: admissibility
# ---------------------------------------------------------------------------

def test_landmark_bounds_admissible_seeded():
    for seed in range(5):
        cg = C.random_csr_graph(80, 200, seed=seed)
        ls = build_landmarks(cg, 6, seed=seed)
        rng = np.random.default_rng(seed)
        for _ in range(20):
            s, t = int(rng.integers(80)), int(rng.integers(80))
            d = dijkstra_oracle(cg, s)[t]
            lb, ub = ls.lower_bound(s, t), ls.upper_bound(s, t)
            if np.isinf(d):
                assert np.isinf(lb) or lb == 0.0 or np.isfinite(lb)
                assert np.isinf(ub)
            else:
                assert lb <= d * (1 + 1e-5) + 1e-5
                assert ub >= d * (1 - 1e-5) - 1e-5
            assert ls.conservative_lb(s, t) <= max(lb, 0.0)


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), k=st.integers(1, 8),
           st_pair=st.tuples(st.integers(0, 59), st.integers(0, 59)))
    def test_landmark_lower_bound_admissible_property(seed, k, st_pair):
        cg = C.random_csr_graph(60, 180, seed=seed % 97)
        ls = build_landmarks(cg, k, seed=seed)
        s, t = st_pair
        d = dijkstra_oracle(cg, s)[t]
        lb = ls.lower_bound(s, t)
        if np.isfinite(d):
            # admissible up to f32 rounding of the engine rows
            assert lb <= d * (1 + 1e-5) + 1e-5
            assert ls.conservative_lb(s, t) <= d * (1 + 1e-6) + 1e-5
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_landmark_lower_bound_admissible_property():
        pass


def test_landmark_refuses_directed_graphs():
    cg = C.random_csr_graph(40, 120, seed=0, directed=True)
    with pytest.raises(ValueError, match="directed"):
        build_landmarks(cg, 3)


def test_sample_landmark_ids_distinct_and_bounded():
    ids = sample_landmark_ids(50, 50, seed=1)
    assert sorted(ids.tolist()) == list(range(50))
    with pytest.raises(ValueError):
        sample_landmark_ids(10, 11)


# ---------------------------------------------------------------------------
# target= early exit (core/frontier.py + api threading)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,seed", [(60, 180, 0), (200, 600, 1),
                                      (150, 300, 2)])
def test_target_early_exit_bitwise_vs_full_solve(n, m, seed):
    cg = C.random_csr_graph(n, m, seed=seed)
    full = shortest_paths(cg, 0, engine="frontier")
    rng = np.random.default_rng(seed)
    for t in {0, n - 1, *rng.integers(0, n, 5).tolist()}:
        part = shortest_paths(cg, 0, engine="frontier", target=int(t))
        assert part.dist[t] == full.dist[t]
        assert part.sweeps <= full.sweeps
        assert part.edges_relaxed <= full.edges_relaxed


def test_target_early_exit_with_admissible_lb_is_exact_and_cheaper():
    cg = C.random_csr_graph(300, 900, seed=3)
    ls = build_landmarks(cg, 8, seed=3)
    full = shortest_paths(cg, 7, engine="frontier")
    for t in (50, 150, 299):
        lb = ls.conservative_lb(7, t)
        part = shortest_paths(cg, 7, engine="frontier", target=t,
                              target_lb=lb)
        assert part.dist[t] == full.dist[t]
        assert part.edges_relaxed <= full.edges_relaxed


def test_target_exit_settled_region_is_exact():
    # everything the early exit claims settled (dist < dist[target])
    # must equal the full fixpoint bitwise
    cg = C.random_csr_graph(120, 360, seed=4)
    full = shortest_paths(cg, 0, engine="frontier")
    part = shortest_paths(cg, 0, engine="frontier", target=60)
    settled = part.dist < part.dist[60]
    assert np.array_equal(part.dist[settled], full.dist[settled])


def test_target_unreachable_runs_to_fixpoint():
    edges = np.stack([np.arange(9), np.arange(1, 10)], 1)
    cg = G.csr_from_edge_list(12, edges, np.ones(9))  # 10..11 islanded
    res = shortest_paths(cg, 0, engine="frontier", target=11)
    assert np.isinf(res.dist[11])
    full = shortest_paths(cg, 0, engine="frontier")
    assert np.array_equal(res.dist, full.dist)


def test_target_rejected_for_non_frontier_engines():
    cg = C.random_csr_graph(30, 90, seed=5)
    with pytest.raises(ValueError, match="frontier"):
        shortest_paths(cg, 0, engine="bellman_csr", target=3)


def test_target_with_delta_schedule_exact():
    cg = C.random_csr_graph(150, 450, seed=6)
    full = shortest_paths(cg, 2, engine="frontier")
    part = shortest_paths(cg, 2, engine="frontier", target=99, delta=25.0)
    assert part.dist[99] == full.dist[99]


def test_raw_sssp_frontier_target_counts_reduced_work():
    cg = C.random_csr_graph(400, 1200, seed=7)
    ops = frontier_operands(cg)
    d_full, _, s_full, e_full, _ = sssp_frontier(ops, jnp.int32(0), n=cg.n)
    # a target adjacent to the source should settle in very few sweeps
    nbr = int(np.asarray(ops["out_dst"])[int(ops["out_indptr"][0])])
    d, _, s, e, _ = sssp_frontier(ops, jnp.int32(0), n=cg.n,
                                  target=jnp.int32(nbr))
    assert d[nbr] == d_full[nbr]
    assert int(s) <= int(s_full) and int(e) <= int(e_full)
