"""Sparse CSR subsystem correctness: containers, engines, and the Pallas
ELL kernel against an independent heap-Dijkstra oracle (conftest.py).

The paper's §V names the dense adjacency matrix as its memory/perf ceiling;
this suite pins down that the CSR path (a) agrees with every dense engine,
(b) agrees bitwise with the serial engine on the paper corpus (min-plus is
exact in f32), and (c) never allocates an O(n²) array.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import dijkstra_oracle, finite_close
from repro.core import csr as C
from repro.core import graph as G
from repro.core.api import CSR_ENGINES, shortest_paths
from repro.core.bellman_csr import csr_operands, sssp_bellman_csr
from repro.kernels.csr_relax import (csr_relax_sweep, ell_relax_ref,
                                     segment_relax_ref)

ALL_LOCAL_ENGINES = ("serial", "bellman", "bellman_kernel",
                     "bellman_csr", "bellman_csr_kernel")


def _cases():
    return [
        pytest.param(G.random_graph(50, 1225, seed=1), id="dense50"),
        pytest.param(G.random_graph(100, 300, seed=2), id="sparse100"),
        pytest.param(G.random_graph(60, 240, seed=3, directed=True),
                     id="directed60"),
        pytest.param(G.random_graph(50, 60, seed=4, connected=False),
                     id="disconnected50"),
        pytest.param(G.from_edge_list(1, np.zeros((0, 2), np.int64),
                                      np.zeros(0)), id="single-vertex"),
    ]


# ---------------------------------------------------------------------------
# every engine agrees with the independent oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ALL_LOCAL_ENGINES)
@pytest.mark.parametrize("g", _cases())
def test_every_engine_matches_oracle(engine, g):
    ref = dijkstra_oracle(g, 0)
    res = shortest_paths(g, 0, engine=engine)
    assert finite_close(ref, res.dist)
    assert np.array_equal(np.isfinite(ref), np.isfinite(res.dist))


@pytest.mark.parametrize("engine", CSR_ENGINES)
@pytest.mark.parametrize("g", _cases())
def test_csr_engines_accept_csr_input(engine, g):
    """CsrGraph in -> same answer as the dense Graph path, no densify."""
    cg = g.to_csr()
    ref = dijkstra_oracle(cg, 0)
    res = shortest_paths(cg, 0, engine=engine)
    assert finite_close(ref, res.dist)


def test_dense_engine_densifies_csr_input():
    g = G.random_graph(40, 120, seed=9)
    res = shortest_paths(g.to_csr(), 0, engine="bellman")
    assert finite_close(dijkstra_oracle(g, 0), res.dist)


# ---------------------------------------------------------------------------
# container round-trips
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("directed", [False, True])
@pytest.mark.parametrize("n,m", [(1, 0), (10, 30), (97, 400), (128, 128)])
def test_to_csr_roundtrip_exact(n, m, directed):
    g = G.random_graph(n, m, seed=n + m, directed=directed,
                       connected=m > 0)
    cg = g.to_csr()
    assert cg.n == n and cg.directed == directed
    assert np.array_equal(cg.to_dense().adj, g.adj)


def test_csr_from_edge_list_matches_dense_semantics():
    """Same edge list (with duplicates + both orientations) -> same matrix."""
    edges = np.array([[0, 1], [1, 0], [0, 1], [2, 3], [3, 2], [1, 2]])
    w = np.array([5.0, 2.0, 7.0, 1.0, 4.0, 3.0])
    for directed in (False, True):
        g = G.from_edge_list(5, edges, w, directed=directed)
        cg = G.csr_from_edge_list(5, edges, w, directed=directed)
        assert np.array_equal(cg.to_dense().adj, g.adj), directed


def test_random_csr_graph_identical_to_dense_generator():
    """Shared RNG stream: same seed -> the same graph, either container."""
    cg = C.random_csr_graph(200, 600, seed=5)
    g = G.random_graph(200, 600, seed=5)
    assert np.array_equal(cg.to_dense().adj, g.adj)
    assert cg.num_edges == g.num_edges


def test_ell_padding_is_inert():
    cg = C.random_csr_graph(30, 90, seed=6)
    idx, w = cg.ell()
    assert idx.shape == w.shape and idx.shape[1] % 8 == 0
    deg = np.diff(cg.indptr)
    for v in range(cg.n):
        assert np.all(np.isfinite(w[v, :deg[v]]))
        assert np.all(np.isinf(w[v, deg[v]:]))      # sentinel slots
        assert np.all(idx[v, deg[v]:] == 0)
    # sentinels never change the sweep result vs the flat segment view
    dist = jnp.asarray(np.random.default_rng(0).uniform(0, 50, cg.n),
                       jnp.float32)
    ops = csr_operands(cg, with_ell=True)
    a = ell_relax_ref(dist, ops["ell_idx"], ops["ell_w"])
    b = segment_relax_ref(dist, ops["src"], ops["dst"], ops["w"])
    assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Pallas ELL kernel vs oracles (bitwise, interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [16, 100, 137, 256, 300])
def test_kernel_sweep_bitwise_matches_ref(n):
    cg = C.random_csr_graph(n, 4 * n, seed=n)
    ops = csr_operands(cg, with_ell=True)
    rng = np.random.default_rng(n)
    d = rng.uniform(0, 50, n).astype(np.float32)
    d[rng.uniform(size=n) < 0.3] = np.inf
    dist = jnp.asarray(d)
    ref = ell_relax_ref(dist, ops["ell_idx"], ops["ell_w"])
    out = csr_relax_sweep(dist, ops["ell_idx"], ops["ell_w"], interpret=True)
    assert np.array_equal(np.asarray(ref), np.asarray(out))


def test_kernel_wide_ell_rows_bitwise():
    """ELL width above 128 (a hub vertex): the auto block_k divisor path
    must stay bitwise-exact without force-padding the width."""
    n = 150
    hub_edges = np.stack([np.arange(1, 141), np.zeros(140, np.int64)], 1)
    edges = np.concatenate([hub_edges,
                            np.stack([np.arange(n - 1),
                                      np.arange(1, n)], 1)])
    cg = G.csr_from_edge_list(n, edges,
                              np.arange(1.0, len(edges) + 1), directed=True)
    ops = csr_operands(cg, with_ell=True)
    assert ops["ell_idx"].shape[1] > 128
    dist = jnp.asarray(np.random.default_rng(0).uniform(0, 50, n),
                       jnp.float32)
    ref = ell_relax_ref(dist, ops["ell_idx"], ops["ell_w"])
    out = csr_relax_sweep(dist, ops["ell_idx"], ops["ell_w"], interpret=True)
    assert np.array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("block_v,block_k", [(64, 8), (128, 16), (256, None)])
def test_kernel_block_shapes(block_v, block_k):
    n = 192
    cg = C.random_csr_graph(n, 6 * n, seed=block_v)
    ops = csr_operands(cg, with_ell=True)
    dist = jnp.asarray(np.random.default_rng(1).uniform(0, 50, n),
                       jnp.float32)
    ref = ell_relax_ref(dist, ops["ell_idx"], ops["ell_w"])
    out = csr_relax_sweep(dist, ops["ell_idx"], ops["ell_w"],
                          block_v=block_v, block_k=block_k, interpret=True)
    assert np.array_equal(np.asarray(ref), np.asarray(out))


# ---------------------------------------------------------------------------
# engine behaviors
# ---------------------------------------------------------------------------

def test_csr_engine_has_no_dead_frontier_flag():
    """The old ``use_frontier`` parameter was computed-but-dead (defaulted
    off, never wired through the api); frontier relaxation now lives in
    core/frontier.py as a real engine (test_frontier.py), and the flag is
    gone for good."""
    import inspect

    sig = inspect.signature(sssp_bellman_csr.__wrapped__)
    assert "use_frontier" not in sig.parameters
    cg = C.random_csr_graph(70, 280, seed=5)
    ops = csr_operands(cg)
    d0, _, _, _ = sssp_bellman_csr(ops, jnp.int32(0), n=cg.n)
    d1 = shortest_paths(cg, 0, engine="frontier").dist
    assert np.array_equal(np.asarray(d0), np.asarray(d1))


def test_csr_sweep_count_bounded_by_diameter():
    n = 12
    edges = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    cg = G.csr_from_edge_list(n, edges, np.ones(n - 1))
    res = shortest_paths(cg, 0, engine="bellman_csr")
    assert res.sweeps <= n
    assert finite_close(res.dist, np.arange(n, dtype=float))


def test_out_of_range_edges_fail_fast():
    """Both containers reject invalid vertex ids instead of silently
    aliasing them onto valid arcs (dst*n+src packing would otherwise)."""
    w = np.array([1.0])
    for bad in (np.array([[7, 2]]), np.array([[-1, 2]])):
        with pytest.raises(IndexError):
            G.from_edge_list(5, bad, w)       # negative would silently wrap
        with pytest.raises(IndexError):
            G.csr_from_edge_list(5, bad, w)   # packing would silently alias


def test_pred_never_self_loop_and_engines_agree():
    """The fixpoint argmin must not pick the diagonal tie (pred[v] == v
    breaks path reconstruction); dense and CSR recovery use the same
    lowest-u tie-break, so the trees match exactly."""
    edges = np.array([[0, 5], [5, 1], [0, 2], [2, 3], [3, 4]])
    g = G.from_edge_list(6, edges, np.ones(len(edges)))
    preds = {}
    for engine in ("bellman", "bellman_kernel", "bellman_csr",
                   "bellman_csr_kernel"):
        p = shortest_paths(g, 0, engine=engine).pred
        assert all(p[v] != v for v in range(g.n)), engine
        preds[engine] = p
    assert np.array_equal(preds["bellman"], preds["bellman_csr"])
    # and on a random graph too
    g = G.random_graph(80, 240, seed=17)
    for engine in ("bellman", "bellman_csr"):
        p = shortest_paths(g, 0, engine=engine).pred
        assert all(p[v] != v for v in range(g.n)), engine


def test_csr_pred_tree_valid():
    g = G.random_graph(90, 350, seed=11)
    adj = g.adj
    for engine in CSR_ENGINES:
        res = shortest_paths(g, 0, engine=engine)
        d, p = res.dist, res.pred
        for v in range(g.n):
            if v == 0 or not np.isfinite(d[v]):
                continue
            u = p[v]
            assert u >= 0
            assert np.isclose(d[v], d[u] + adj[u, v], rtol=1e-5)


# ---------------------------------------------------------------------------
# acceptance: paper corpus exact match + no O(n²) allocation
# ---------------------------------------------------------------------------

def _corpus():
    dense = [(n, m) for n, m in G.PAPER_DENSE if n <= 1000]
    sparse = [(n, m) for n, m in G.PAPER_SPARSE if n <= 10000]
    return [
        pytest.param(n, m, marks=[pytest.mark.slow] if n >= 10000 else [],
                     id=f"n{n}-m{m}")
        for n, m in dense + sparse
    ]


@pytest.mark.parametrize("n,m", _corpus())
def test_paper_corpus_csr_matches_serial_exactly(n, m):
    """min-plus over f32 path sums is exact: both engines compute the min
    over identically-ordered f32 path sums, so equality is bitwise."""
    g = G.paper_graph(n, m, seed=n + m)
    ref = shortest_paths(g, 0, engine="serial").dist
    got = shortest_paths(g, 0, engine="bellman_csr").dist
    assert np.array_equal(ref, got)


def test_csr_path_never_materializes_dense(monkeypatch):
    """Table II's n=20000 point entirely in sparse form: the engine must
    not densify (to_dense is trapped) and no container/operand array may
    be more than a small multiple of n + m."""
    n = 20000
    cg = C.sparse_csr_graph(n)          # m = 3n, the paper's corpus shape
    monkeypatch.setattr(
        C.CsrGraph, "to_dense",
        lambda self: pytest.fail("CSR path densified an O(n²) matrix"),
    )
    res = shortest_paths(cg, 0, engine="bellman_csr")
    budget = 16 * (n + cg.nnz)          # generous O(n + m), << n² = 4e8
    for name, arr in [("indptr", cg.indptr), ("indices", cg.indices),
                      ("weights", cg.weights), ("dist", res.dist),
                      ("pred", res.pred)]:
        assert arr.size <= budget, name
    idx, w = cg.ell()
    assert idx.size <= budget and w.size <= budget
    # connected generator + correctness spot-check against the heap oracle
    ref = dijkstra_oracle(cg, 0)
    assert np.isfinite(res.dist).all()
    assert finite_close(ref, res.dist)


# ---------------------------------------------------------------------------
# immutability contract: frozen arrays protect the memoized views
# ---------------------------------------------------------------------------

def test_csr_arrays_and_memoized_views_are_read_only():
    """CsrGraph's fields and every memoized derived view are frozen: an
    in-place write anywhere would silently corrupt views other callers
    already hold (serve handles pin them; dynamic overlays layer on top
    of them), so numpy must refuse it (the __post_init__ contract)."""
    cg = C.random_csr_graph(60, 180, seed=9)
    out_indptr, out_dst, out_w = cg.out_csr()
    ell_idx, ell_w = cg.ell()
    oell_idx, oell_w = cg.out_ell()
    victims = {
        "indptr": cg.indptr, "indices": cg.indices, "weights": cg.weights,
        "dst_ids": cg.dst_ids(), "out_indptr": out_indptr,
        "out_dst": out_dst, "out_w": out_w, "ell_idx": ell_idx,
        "ell_w": ell_w, "out_ell_idx": oell_idx, "out_ell_w": oell_w,
        "dense_adj": cg.to_dense().adj,
    }
    for name, arr in victims.items():
        with pytest.raises(ValueError, match="read-only"):
            arr.flat[0] = 1
    # memoized identity: repeat calls hand back the SAME frozen arrays
    assert cg.out_csr()[2] is out_w
    assert cg.ell()[1] is ell_w


def test_csr_freeze_applies_to_caller_supplied_arrays():
    """Arrays passed into the constructor are frozen too — the container
    owns them from that point on (copy first to keep a mutable handle)."""
    indptr = np.array([0, 0, 1], np.int64)
    indices = np.array([0], np.int32)
    weights = np.array([2.0], np.float32)
    C.CsrGraph(indptr=indptr, indices=indices, weights=weights, n=2)
    with pytest.raises(ValueError, match="read-only"):
        weights[0] = 5.0
