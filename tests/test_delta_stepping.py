"""Δ-stepping engine correctness + the PR's perf claims as invariants.

Pins down: both delta engines agree with the independent heap oracle and
bitwise with ``serial`` (same f32 path-sum minima) for any positive Δ;
the fused Pallas kernel matches the interpreted reference bitwise; the
light/heavy split views partition the arc set exactly; auto-Δ is
deterministic; on the gate corpora (road-like grid, skewed hub) the
bucket schedule takes strictly fewer phases than the frontier engine
takes sweeps; and the api/dispatch seams validate and route as
documented.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import dijkstra_oracle, finite_close
from repro.core import csr as C
from repro.core import graph as G
from repro.core.api import shortest_paths
from repro.core.delta_stepping import (auto_delta, delta_operands,
                                       delta_profile, make_light_pull_fn,
                                       sssp_delta_stepping)
from repro.core.frontier import frontier_operands, sssp_frontier, sweep_cap
from repro.kernels.bucket_relax import (bucket_relax_block, bucket_relax_ref,
                                        make_bucket_pull_fn)

DELTA = ("delta_stepping", "delta_stepping_kernel")


def _skewed_hub_small(n=120, spokes=100):
    hub = np.stack([np.zeros(spokes, np.int64),
                    np.arange(1, spokes + 1)], 1)
    path = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    edges = np.concatenate([hub, path])
    return G.csr_from_edge_list(n, edges,
                               np.arange(1.0, len(edges) + 1.0))


def _cases():
    return [
        pytest.param(G.random_graph(50, 1225, seed=1), id="dense50"),
        pytest.param(G.random_graph(100, 300, seed=2), id="sparse100"),
        pytest.param(G.random_graph(60, 240, seed=3, directed=True),
                     id="directed60"),
        pytest.param(G.random_graph(50, 60, seed=4, connected=False),
                     id="disconnected50"),
        pytest.param(_skewed_hub_small(), id="skewed-hub"),
        pytest.param(G.from_edge_list(1, np.zeros((0, 2), np.int64),
                                      np.zeros(0)), id="single-vertex"),
    ]


# ---------------------------------------------------------------------------
# oracle + bitwise-vs-serial, auto and explicit Δ
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", DELTA)
@pytest.mark.parametrize("g", _cases())
def test_delta_matches_oracle_and_serial(engine, g):
    ref = shortest_paths(g, 0, engine="serial")
    r = shortest_paths(g, 0, engine=engine)        # delta=None -> auto
    assert finite_close(r.dist, dijkstra_oracle(g, 0))
    assert np.array_equal(r.dist, ref.dist)
    assert np.array_equal(r.pred, ref.pred)
    assert r.converged
    assert r.edges_relaxed is not None and r.sweeps is not None


@pytest.mark.parametrize("delta", [0.5, 37.0, 1e6])
def test_delta_any_width_bitwise(delta):
    # Δ below every weight (all arcs heavy), mid-range, and above every
    # path length (single all-light bucket) — distances must not move.
    cg = C.random_csr_graph(200, 800, seed=7)
    ref = shortest_paths(cg, 0, engine="serial")
    for engine in DELTA:
        r = shortest_paths(cg, 0, engine=engine, delta=delta)
        assert np.array_equal(r.dist, ref.dist), (engine, delta)
        assert r.converged


def test_delta_degenerate_widths():
    cg = C.random_csr_graph(150, 600, seed=8)
    ref = shortest_paths(cg, 0, engine="serial")
    # Δ >= max finite distance: one bucket, pure pull-Jacobi.
    big = shortest_paths(cg, 0, engine="delta_stepping", delta=1e7)
    assert np.array_equal(big.dist, ref.dist)
    assert big.sweeps == 1
    # Δ below the minimum weight: every arc heavy, empty light ELL — the
    # schedule degrades to bucket-by-bucket heavy pushes and must still
    # terminate at the exact fixpoint.
    allh = shortest_paths(cg, 0, engine="delta_stepping", delta=0.25)
    assert np.array_equal(allh.dist, ref.dist)
    assert allh.sweeps > big.sweeps


def test_delta_zero_weight_and_equal_weight_edges():
    # zero-weight arcs are light for every Δ; all-weights-equal-to-Δ puts
    # every arc exactly on the light boundary (w <= Δ inclusive).
    n = 60
    path = np.stack([np.arange(n - 1), np.arange(1, n)], 1)
    w = np.ones(n - 1)
    w[::7] = 0.0
    cg = G.csr_from_edge_list(n, path, w)
    ref = shortest_paths(cg, 0, engine="serial")
    for engine in DELTA:
        r = shortest_paths(cg, 0, engine=engine, delta=1.0)
        assert np.array_equal(r.dist, ref.dist), engine
    eq = G.csr_from_edge_list(n, path, np.full(n - 1, 5.0))
    ref = shortest_paths(eq, 0, engine="serial")
    for engine in DELTA:
        r = shortest_paths(eq, 0, engine=engine, delta=5.0)
        assert np.array_equal(r.dist, ref.dist), engine
        assert r.converged


# ---------------------------------------------------------------------------
# the light/heavy split views
# ---------------------------------------------------------------------------

def test_split_views_partition_arcs():
    cg = C.skewed_hub_csr_graph(300, seed=5)
    delta = 120.0
    l_idx, l_w = cg.light_in_ell(delta)
    hip, h_dst, h_w = cg.heavy_out_csr(delta)
    m_light = int(np.isfinite(np.asarray(l_w)).sum())
    assert m_light + h_dst.shape[0] == cg.nnz       # exact partition
    finite = np.asarray(l_w)[np.isfinite(np.asarray(l_w))]
    assert (finite <= delta).all()
    assert (np.asarray(h_w) > delta).all()
    assert hip[-1] == h_dst.shape[0]
    # memoized: second call returns the same frozen objects
    assert cg.light_in_ell(delta)[0] is l_idx
    assert cg.heavy_out_csr(delta)[1] is h_dst
    assert not l_idx.flags.writeable and not h_w.flags.writeable


def test_auto_delta_deterministic():
    a = C.road_like_csr_graph(2500, seed=3)
    b = C.road_like_csr_graph(2500, seed=3)        # fresh object, same graph
    assert auto_delta(a) == auto_delta(b)
    prof = delta_profile(a)
    assert set(prof) == {"delta", "light_max_deg", "k_cap", "routable"}
    assert prof["routable"]                         # grids stay narrow
    assert prof["delta"] == auto_delta(a)
    # memoized on the instance
    assert delta_profile(a) is prof


# ---------------------------------------------------------------------------
# fused kernel vs interpreted reference (bitwise)
# ---------------------------------------------------------------------------

def test_bucket_relax_kernel_matches_ref():
    cg = C.skewed_hub_csr_graph(500, seed=2)
    ops = delta_operands(cg, auto_delta(cg))
    key = jax.random.PRNGKey(0)
    dist = jnp.where(jax.random.uniform(key, (cg.n,)) < 0.3,
                     jax.random.uniform(jax.random.PRNGKey(1),
                                        (cg.n,)) * 300.0,
                     jnp.inf).astype(jnp.float32)
    for hi in (0.0, 150.0, np.inf):
        nk, gk = bucket_relax_block(dist, ops["light_ell_idx"],
                                    ops["light_ell_w"], jnp.float32(hi))
        nr, gr = bucket_relax_ref(dist, ops["light_ell_idx"],
                                  ops["light_ell_w"], hi)
        assert np.array_equal(np.asarray(nk), np.asarray(nr),
                              equal_nan=True), hi
        assert bool(gk) == bool(gr), hi


def test_kernel_engine_bitwise_equals_flat():
    cg = C.road_like_csr_graph(1024, seed=6)
    d = auto_delta(cg)
    ops = delta_operands(cg, d)
    flat = sssp_delta_stepping(ops, jnp.int32(0), jnp.float32(d), n=cg.n,
                               pull_fn=make_light_pull_fn())
    kern = sssp_delta_stepping(ops, jnp.int32(0), jnp.float32(d), n=cg.n,
                               pull_fn=make_bucket_pull_fn())
    for a, b in zip(flat, kern):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# the perf claim: strictly fewer phases than frontier sweeps (gate corpora)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    pytest.param(lambda: C.road_like_csr_graph(10000, seed=1), id="road10k"),
    pytest.param(lambda: C.skewed_hub_csr_graph(10000, seed=1), id="hub10k"),
])
def test_fewer_phases_than_frontier_sweeps(make):
    cg = make()
    fops = frontier_operands(cg)
    df, _, sf, ef, cf = sssp_frontier(fops, jnp.int32(0), n=cg.n)
    d = auto_delta(cg)
    assert delta_profile(cg)["routable"]
    ops = delta_operands(cg, d)
    dd, _, ph, ed, cd = sssp_delta_stepping(ops, jnp.int32(0),
                                            jnp.float32(d), n=cg.n)
    assert bool(cf) and bool(cd)
    assert np.array_equal(np.asarray(df), np.asarray(dd))   # bitwise, 10k
    assert finite_close(np.asarray(dd), dijkstra_oracle(cg, 0))
    assert int(ph) < int(sf), (int(ph), int(sf))


# ---------------------------------------------------------------------------
# sweep_cap derivation
# ---------------------------------------------------------------------------

def test_sweep_cap_derived_bound():
    assert sweep_cap(100, None, None) == 100
    assert sweep_cap(100, 5.0, None) == 400          # legacy Δ fallback
    assert sweep_cap(100, 5.0, 7) == 7
    # derived: n + ceil(max_dist/Δ) + 1, floored at the legacy 4n
    tight = int(sweep_cap(100, 5.0, None, max_dist=50.0))
    assert tight == 400                              # floor binds
    loose = int(sweep_cap(100, 0.5, None, max_dist=1e4))
    assert loose == 100 + 20000 + 1                  # derivation binds
    # non-finite bound clamps instead of wrapping int32
    assert int(sweep_cap(100, 0.5, None, max_dist=np.inf)) >= 400


# ---------------------------------------------------------------------------
# api validation + dispatch routing
# ---------------------------------------------------------------------------

def test_api_delta_validation():
    cg = C.random_csr_graph(50, 150, seed=1)
    for bad in (0.0, -3, np.inf, np.nan, "wide"):
        with pytest.raises(ValueError):
            shortest_paths(cg, 0, engine="delta_stepping", delta=bad)
        with pytest.raises(ValueError):
            shortest_paths(cg, 0, engine="frontier", delta=bad)
    # engines that would silently ignore delta= must reject it
    for engine in ("serial", "bellman", "bellman_csr", "multisource_csr"):
        with pytest.raises(ValueError, match="delta"):
            shortest_paths(cg, 0, engine=engine, delta=1.0)
    # target= early exit is frontier-only
    with pytest.raises(ValueError, match="target"):
        shortest_paths(cg, 0, engine="delta_stepping", target=5)


def test_dispatch_routes_delta():
    from repro.serve.dispatch import DispatchPolicy

    pol = DispatchPolicy(shard_threshold=None, delta_threshold=1000)
    road = C.road_like_csr_graph(2500, seed=2)
    choice = pol.choose(road, kind="single")
    assert choice.engine == "delta_stepping" and not choice.sharded
    # batch / p2p kinds keep their engines (batched gather / target exit)
    assert pol.choose(road, kind="batch").engine == "multisource_csr"
    assert pol.choose(road, kind="p2p").engine == "frontier"
    # below the threshold, or non-CSR input: frontier as before
    small = C.random_csr_graph(100, 300, seed=3)
    assert pol.choose(small, kind="single").engine == "frontier"
    assert pol.choose(np.zeros((50, 50)), kind="single").engine == "frontier"
    # Δ routing off
    off = DispatchPolicy(shard_threshold=None, delta_threshold=None)
    assert off.choose(road, kind="single").engine == "frontier"


def test_engine_auto_delta_route_bitwise():
    from repro.serve.dispatch import DispatchPolicy, set_default_policy

    road = C.road_like_csr_graph(2500, seed=4)
    set_default_policy(DispatchPolicy(shard_threshold=None,
                                      delta_threshold=1000))
    try:
        r = shortest_paths(road, 0, engine="auto")
        assert r.engine == "delta_stepping"
    finally:
        set_default_policy(None)
    ref = shortest_paths(road, 0, engine="frontier")
    assert np.array_equal(r.dist, ref.dist)
    assert np.array_equal(r.pred, ref.pred)
