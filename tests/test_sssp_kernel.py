"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps in interpret mode.

min-plus is exact in floating point (adds + compares only), so the kernel
must agree with the oracle *bitwise* on f32; bf16 agrees bitwise too (same
adds at the same precision).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.kernels.sssp_relax import (relax_sweep, relax_sweep_multi,
                                      relax_sweep_ref, relax_sweep_multi_ref)
from repro.kernels.sssp_relax.kernel import relax_matvec, relax_matvec_frontier


def _dist(n, dtype, seed=0, inf_frac=0.3):
    rng = np.random.default_rng(seed)
    d = rng.uniform(0, 50, n).astype(np.float32)
    d[rng.uniform(size=n) < inf_frac] = np.inf
    return jnp.asarray(d, dtype)


@pytest.mark.parametrize("n", [64, 96, 100, 128, 256, 300, 500])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matvec_sweep_shapes_dtypes(n, dtype):
    g = G.random_graph(n, 4 * n, seed=n)
    adj = jnp.asarray(g.adj, dtype)
    d = _dist(n, dtype, seed=n)
    ref = relax_sweep_ref(d, adj)
    out = relax_sweep(d, adj, interpret=True, block_u=128, block_v=128)
    assert np.array_equal(np.asarray(ref, np.float32),
                          np.asarray(out, np.float32)), n


@pytest.mark.parametrize("block", [64, 128, 256])
def test_matvec_block_shapes(block):
    n = 512
    g = G.random_graph(n, 3 * n, seed=block)
    d = _dist(n, jnp.float32, seed=1)
    adj = jnp.asarray(g.adj)
    ref = relax_sweep_ref(d, adj)
    out = relax_sweep(d, adj, interpret=True, block_u=block, block_v=block)
    assert np.array_equal(np.asarray(ref), np.asarray(out))


@pytest.mark.parametrize("s", [1, 3, 8, 9])
@pytest.mark.parametrize("n", [128, 200])
def test_matmul_multisource(s, n):
    g = G.random_graph(n, 5 * n, seed=s * 100 + n)
    adj = jnp.asarray(g.adj)
    D = jnp.stack([_dist(n, jnp.float32, seed=i) for i in range(s)])
    ref = relax_sweep_multi_ref(D, adj)
    out = relax_sweep_multi(D, adj, interpret=True)
    assert np.array_equal(np.asarray(ref), np.asarray(out))


def test_frontier_kernel_masks_rows():
    n = 256
    g = G.random_graph(n, 3 * n, seed=9)
    adj = jnp.asarray(g.adj)
    d = _dist(n, jnp.float32, seed=2, inf_frac=0.0)
    frontier = jnp.asarray(np.random.default_rng(0).uniform(size=n) < 0.5)
    out = relax_matvec_frontier(d, frontier, adj, block_u=128, block_v=128,
                                interpret=True)
    masked = jnp.where(frontier, d, jnp.inf)
    ref = jnp.min(masked[:, None] + adj, axis=0)
    assert np.array_equal(np.asarray(ref), np.asarray(out))


def test_all_inf_dist():
    n = 128
    g = G.random_graph(n, 2 * n, seed=4)
    d = jnp.full((n,), jnp.inf, jnp.float32)
    out = relax_sweep(d, jnp.asarray(g.adj), interpret=True,
                      block_u=128, block_v=128)
    assert not np.isfinite(np.asarray(out)).any()


def test_identity_property():
    """relaxing a fixpoint changes nothing (idempotence at convergence)."""
    n = 200
    g = G.random_graph(n, 4 * n, seed=12)
    from repro.core.serial import dijkstra_serial_np
    ref, _ = dijkstra_serial_np(g.adj, 0)
    d = jnp.asarray(ref, jnp.float32)
    out = relax_sweep(d, jnp.asarray(g.adj), interpret=True,
                      block_u=128, block_v=128)
    assert np.allclose(np.where(np.isfinite(ref), ref, 1e30),
                       np.where(np.isfinite(out), np.asarray(out), 1e30),
                       rtol=1e-5)


def test_unaligned_padding_path():
    """n not a multiple of any block: internal INF padding must be exact."""
    n = 137
    g = G.random_graph(n, 3 * n, seed=6)
    d = _dist(n, jnp.float32, seed=3)
    ref = relax_sweep_ref(d, jnp.asarray(g.adj))
    out = relax_sweep(d, jnp.asarray(g.adj), interpret=True)
    assert np.array_equal(np.asarray(ref), np.asarray(out))
