"""Observability layer invariants (repro/obs + its serve integration).

Pins the contracts ISSUE 9 introduced:

- metrics: get-or-create series, label qualification, kind-mismatch
  errors, deterministic sorted snapshots;
- tracing: span nesting/ordering under an injected clock, Chrome-trace
  schema validity, and the disabled-mode guarantee — a NullTracer run
  produces bitwise-identical scheduler answers and records nothing;
- unification: every legacy ``stats()`` count of the cache / registry /
  scheduler equals its series in the merged ``snapshot()`` (no counter
  lost or renamed by the migration);
- determinism: two same-seed replays on fresh stacks produce identical
  metric snapshots (including under a seeded fault plan);
- jit-retrace accounting: repeat scheduler ticks after warmup, and
  repeat DynamicGraph mutate+query cycles after warmup, add ZERO new
  traces of any engine (``jit.retrace{fn=...}`` is flat);
- latency split: queue-wait vs service-time are separated and both
  percentiles reported;
- cost records: the core.api shim emits schema-valid per-solve records;
- answer chains: a traced replay's submit → tick → solve → answer chain
  reconstructs for every exact engine-served answer.
"""
import numpy as np
import pytest

from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.obs import (CostLog, MetricsRegistry, Tracer, set_cost_log,
                       set_tracer)
from repro.obs.metrics import default_registry
from repro.obs.validate import (reconstruct_answer_chains,
                                validate_chrome_trace,
                                validate_cost_records)
from repro.serve import (DistanceCache, GraphRegistry, LatencyRecorder,
                         MicroBatchScheduler, make_trace)


def _stack(cg, *, landmarks=0, name="g", **kw):
    registry = GraphRegistry()
    cache = DistanceCache(capacity=64)
    sched = MicroBatchScheduler(registry, cache, max_batch=8, **kw)
    registry.register(name, cg, landmarks=landmarks)
    return sched


# ---------------------------------------------------------------- metrics


def test_metrics_registry_series():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    c.inc()
    c.inc(2)
    assert reg.counter("hits") is c and c.value == 3
    g = reg.gauge("rows", fn=lambda: 7)
    assert g.value == 7
    h = reg.histogram("lat")
    for v in (1.0, 2.0, 9.0):
        h.observe(v)
    assert h.count == 3 and h.min == 1.0 and h.max == 9.0
    assert h.percentile(50.0) == 2.0
    # labeled series are distinct and qualify deterministically
    a = reg.counter("answered", via="batch")
    b = reg.counter("answered", via="cache")
    a.inc(5)
    b.inc(1)
    snap = reg.snapshot()
    assert snap["answered{via=batch}"] == 5
    assert snap["answered{via=cache}"] == 1
    assert snap["hits"] == 3 and snap["rows"] == 7
    assert snap["lat.count"] == 3          # histogram: count only
    assert list(snap) == sorted(snap)
    with pytest.raises(TypeError):
        reg.gauge("hits")                  # kind mismatch


def test_span_nesting_under_injected_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = Tracer(clock=clock)
    with tr.span("tick", tick=1) as sp:          # t0=1
        with tr.span("batch_solve", qids=(7,)):  # t0=2, t1=3
            pass
        sp.set(answers=1)
    # inner closed first, outer second; depths record nesting
    inner, outer = tr.spans
    assert (inner.name, outer.name) == ("batch_solve", "tick")
    assert inner.depth == 1 and outer.depth == 0
    assert (inner.t0, inner.t1) == (2.0, 3.0)
    assert (outer.t0, outer.t1) == (1.0, 4.0)
    assert outer.args == {"tick": 1, "answers": 1}
    doc = tr.to_chrome()
    assert validate_chrome_trace(doc) == []
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts)


def test_chrome_schema_rejects_malformed():
    assert validate_chrome_trace({}) == ["missing top-level traceEvents"]
    bad = {"traceEvents": [{"ph": "X", "name": "tick", "ts": 1.0,
                            "pid": 1, "tid": 1}]}       # no dur
    assert any("dur" in e for e in validate_chrome_trace(bad))
    bad = {"traceEvents": [{"ph": "?", "name": "x", "ts": 0.0,
                            "pid": 1, "tid": 1}]}
    assert any("unsupported ph" in e for e in validate_chrome_trace(bad))


# ---------------------------------------------------------------- tracing


def _replay(cg, *, seed=3, queries=24, landmarks=0):
    sched = _stack(cg, landmarks=landmarks)
    trace = make_trace("zipf", [("g", cg.n)], num_queries=queries,
                       rate=1000.0, seed=seed, hot_seed=5)
    for e in trace:
        sched.submit("g", e.source, e.target, arrival=e.arrival)
    return sched, sched.drain(0.0)


def test_disabled_tracing_is_noop_and_answers_identical():
    cg = C.random_csr_graph(96, 288, seed=1)
    _, base = _replay(cg)                       # NULL_TRACER default
    tr = Tracer()
    prev = set_tracer(tr)
    try:
        _, traced = _replay(cg)
    finally:
        set_tracer(prev)
    assert len(base) == len(traced) and len(tr.spans) > 0
    for a, b in zip(base, traced):
        assert a.query.qid == b.query.qid and a.via == b.via
        assert np.array_equal(np.asarray(a.value), np.asarray(b.value))
    # and the disabled side really recorded nothing
    _, again = _replay(cg)
    assert len(again) == len(base)


def test_answer_chains_reconstruct_from_traced_replay():
    cg = C.random_csr_graph(96, 288, seed=1)
    tr = Tracer()
    prev = set_tracer(tr)
    try:
        _replay(cg)
    finally:
        set_tracer(prev)
    doc = tr.to_chrome()
    assert validate_chrome_trace(doc) == []
    assert reconstruct_answer_chains(doc) == []
    # drop the submit instants: every exact engine answer must now fail
    doc["traceEvents"] = [e for e in doc["traceEvents"]
                          if e.get("name") != "submit"]
    errs = reconstruct_answer_chains(doc)
    assert errs and all("no submit instant" in e for e in errs)


# ------------------------------------------------------------ unification


def test_stats_unification_nothing_lost():
    cg = C.random_csr_graph(96, 288, seed=2)
    sched, answers = _replay(cg, landmarks=4)
    assert answers
    snap = sched.snapshot()
    s = sched.stats()
    for key in ("ticks", "engine_batches", "engine_sources",
                "target_solves", "dedup_saved", "rows_kept",
                "rows_repaired", "rows_invalidated", "rows_staled",
                "repair_edges", "submissions_rejected", "shed",
                "deadline_expired", "degraded_p2p", "degraded_batch",
                "solve_exceptions", "retries", "not_converged",
                "sharded_batches", "sharded_p2p", "sharded_sources",
                "sharded_edges"):
        assert snap[f"sched.{key}"] == s[key], key
    for via, count in s["answered_via"].items():
        assert snap.get(f"sched.answered{{via={via}}}", 0) == count, via
    c = s["cache"]
    assert snap["cache.hits"] == c["hits"]
    assert snap["cache.misses"] == c["misses"]
    assert snap["cache.evictions"] == c["evictions"]
    assert snap["cache.rows"] == c["rows"]
    r = s["registry"]
    assert snap["registry.graphs"] == r["graphs"]
    assert snap["registry.registered"] == r["registered"]
    assert snap["registry.evicted"] == r["evicted"]
    assert snap["registry.mutations"] == r["mutations"]
    assert snap["registry.edges_mutated"] == r["edges_mutated"]
    # legacy attribute reads still resolve (back-compat shim)
    assert sched.ticks == s["ticks"]
    assert sched.dedup_saved == s["dedup_saved"]
    assert sched.cache.hits == c["hits"]
    assert sched.registry.registered == r["registered"]


def test_snapshot_deterministic_under_seeded_replay():
    cg = C.random_csr_graph(96, 288, seed=4)
    s1, _ = _replay(cg, seed=9)
    s2, _ = _replay(cg, seed=9)
    assert s1.snapshot() == s2.snapshot()


def test_snapshot_deterministic_under_seeded_chaos():
    from repro.serve import FaultPlan

    cg = C.random_csr_graph(96, 288, seed=4)
    snaps = []
    for _ in range(2):
        plan = FaultPlan(seed=11, rates={"solve": 0.3, "clip": 0.2})
        sched = _stack(cg, faults=plan, retry_budget=2)
        trace = make_trace("zipf", [("g", cg.n)], num_queries=24,
                           rate=1000.0, seed=9, hot_seed=5)
        for e in trace:
            sched.submit("g", e.source, e.target, arrival=e.arrival)
        sched.drain(0.0)
        snaps.append(sched.snapshot())
    assert snaps[0] == snaps[1]


# ------------------------------------------------------------ jit retrace


def _total_retraces() -> int:
    return sum(s.value for s in default_registry().find("jit.retrace"))


def test_zero_retraces_across_repeat_ticks():
    cg = C.random_csr_graph(80, 240, seed=6)
    sched = _stack(cg)
    # warmup wave compiles every (engine, bucket) this workload hits
    for src in (0, 1):
        sched.submit("g", src, arrival=0.0)
    sched.drain(0.0)
    before = _total_retraces()
    for wave in range(1, 4):
        for src in (2 * wave, 2 * wave + 1):    # same shape, new sources
            sched.submit("g", src, arrival=0.0)
        sched.drain(0.0)
    assert _total_retraces() == before, (
        "repeat scheduler ticks retraced a jitted engine")


def test_zero_retraces_across_dynamic_versions():
    from repro.dynamic import DynamicGraph

    cg = C.random_csr_graph(80, 240, seed=6)
    dyn = DynamicGraph(cg, overlay_capacity=64)
    sched = _stack(dyn, name="d")
    # two warm cycles: version v solves, then a mutation commits v+1 and
    # the repair + re-solve paths compile for the overlay shape
    for warm in range(2):
        sched.submit_mutation("d", "add", 3 + warm, 60 + warm, 1.5,
                              arrival=0.0)
        sched.submit("d", warm, arrival=0.0)
        sched.drain(0.0)
    before = _total_retraces()
    v0 = dyn.version
    for wave in range(3):
        sched.submit_mutation("d", "add", 10 + wave, 50 + wave, 2.0,
                              arrival=0.0)
        sched.submit("d", 2 + wave, arrival=0.0)
        sched.drain(0.0)
    assert dyn.version > v0                     # versions really advanced
    assert _total_retraces() == before, (
        "DynamicGraph version changes retraced a jitted engine")


# ---------------------------------------------------------- latency split


def test_latency_recorder_splits_queue_and_service():
    cg = C.random_csr_graph(64, 192, seed=7)
    sched = _stack(cg)
    sched.submit("g", 0, arrival=0.0)
    sched.submit("g", 1, arrival=0.5)
    answers = sched.drain(2.0)                  # served at now=2.0
    rec = LatencyRecorder()
    for a in answers:
        assert a.service_start == 2.0
        a.done_at = 3.0
        rec.observe(a, a.done_at)
    lat = rec.summary()
    # queue = service_start - arrival (2000 and 1500 ms here); service =
    # done - service_start.  np.percentile interpolates between the two.
    assert lat["queue_p99_ms"] == pytest.approx(1995.0)
    assert lat["queue_p50_ms"] == pytest.approx(1750.0)
    assert lat["service_p50_ms"] == pytest.approx(1000.0)
    assert lat["service_p99_ms"] == pytest.approx(1000.0)
    # total latency keeps its original meaning: done - arrival
    # (3000 and 2500 ms, interpolated the same way)
    assert lat["p99_ms"] == pytest.approx(2995.0)


# ----------------------------------------------------------- cost records


def test_api_shim_emits_schema_valid_cost_records():
    cg = C.random_csr_graph(64, 192, seed=8)
    cl = CostLog()
    prev = set_cost_log(cl)
    try:
        res = shortest_paths(cg, 0, engine="frontier")
    finally:
        set_cost_log(prev)
    assert len(cl.records) == 1
    r = cl.records[0]
    assert r.engine == "frontier" and r.n == cg.n and r.m == cg.nnz
    assert r.sweeps == res.sweeps
    assert r.edges_relaxed == res.edges_relaxed
    assert r.wall_ms > 0 and r.converged
    assert validate_cost_records([r.to_dict()]) == []
    # disabled log: nothing recorded, result identical
    res2 = shortest_paths(cg, 0, engine="frontier")
    assert np.array_equal(res.dist, res2.dist)
    assert len(cl.records) == 1
