"""Dynamic-graph subsystem correctness: overlays, incremental repair, and
the serving integration.

The load-bearing invariant mirrors the serving suite's: whatever path a
distance takes through the dynamic machinery — overlay full solve,
incremental repair (insert / delete / weight increase / decrease,
including disconnection and reconnection), repaired-in-place cache row,
lazily refreshed landmark — it is **bitwise-equal to a fresh ``serial``
solve on the mutated snapshot**.  Plus the machinery itself: overlay
semantics and versioning, compaction, static-shape jit-cache stability,
pull_edge_slots against a naive reference, cone sublinearity, the
scheduler's mutation ticks with selective invalidation/repair, churn
traces, and the registry-eviction-purges-every-version interplay.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.core.bellman_csr import sssp_bellman_csr, sssp_multisource_csr
from repro.core.frontier import pull_edge_slots, sssp_frontier
from repro.dynamic import (DynamicGraph, dynamic_segment_sweep,
                           dynamic_segment_sweep_multi,
                           make_dynamic_flat_sweep_fn, repair_sssp,
                           row_affected, solve_dynamic)
from repro.serve import (DistanceCache, GraphRegistry, MicroBatchScheduler,
                         MutationEvent, TraceEvent, make_churn_trace)


def _serial(dyn_or_cg, s):
    cg = (dyn_or_cg.snapshot() if isinstance(dyn_or_cg, DynamicGraph)
          else dyn_or_cg)
    return shortest_paths(cg, s, engine="serial")


def _mixed_edits(dyn, rng, count):
    """Apply ``count`` seeded mixed edits (add/delete/update) to dyn."""
    applied = 0
    while applied < count:
        u, v = int(rng.integers(dyn.n)), int(rng.integers(dyn.n))
        if u == v:
            continue
        if dyn.has_edge(u, v):
            if rng.random() < 0.45:
                dyn.delete_edge(u, v)
            else:
                dyn.update_edge(u, v, float(rng.uniform(0.5, 100)))
        else:
            dyn.add_edge(u, v, float(rng.uniform(0.5, 100)))
        applied += 1


# ---------------------------------------------------------------------------
# overlay semantics
# ---------------------------------------------------------------------------

def test_overlay_mutation_semantics_and_snapshot():
    cg = C.random_csr_graph(80, 240, seed=0)
    dyn = DynamicGraph(cg, overlay_capacity=8)
    # independent mirror of the edge set
    u = np.asarray(cg.indices, np.int64)
    v = cg.dst_ids().astype(np.int64)
    mirror = {(int(a), int(b)): float(w)
              for a, b, w in zip(u, v, cg.weights) if a < b}

    def set_mirror(a, b, w):
        key = (min(a, b), max(a, b))
        if w is None:
            del mirror[key]
        else:
            mirror[key] = np.float32(w)

    dyn.add_edge(0, 79, 3.25);  set_mirror(0, 79, 3.25)
    some = next(iter(mirror))
    dyn.update_edge(some[1], some[0], 42.0);  set_mirror(*some, 42.0)
    gone = next(k for k in mirror if k != some)
    dyn.delete_edge(*gone);  set_mirror(*gone, None)
    batch = dyn.commit()
    assert dyn.version == 1 and len(batch) == 3
    # snapshot == independently built CSR of the mirror
    e = np.array(sorted(mirror), np.int64)
    w = np.array([mirror[tuple(k)] for k in sorted(mirror)], np.float32)
    want = C.csr_from_edge_list(80, e, w)
    snap = dyn.snapshot()
    assert np.array_equal(snap.indptr, want.indptr)
    assert np.array_equal(snap.indices, want.indices)
    assert np.array_equal(snap.weights, want.weights)
    # undirected: both arcs visible through weight_of
    assert dyn.weight_of(79, 0) == np.float32(3.25)
    assert not dyn.has_edge(*gone)


def test_overlay_rejects_invalid_mutations():
    cg = C.random_csr_graph(20, 60, seed=1)
    dyn = DynamicGraph(cg)
    live = (int(cg.indices[0]), int(cg.dst_ids()[0]))
    absent = next((a, b) for a in range(20) for b in range(a + 1, 20)
                  if not dyn.has_edge(a, b))
    with pytest.raises(ValueError, match="already present"):
        dyn.add_edge(*live, 1.0)
    with pytest.raises(ValueError, match="not present"):
        dyn.update_edge(*absent, 1.0)
    with pytest.raises(ValueError, match="not present"):
        dyn.delete_edge(*absent)
    with pytest.raises(ValueError, match="finite and > 0"):
        dyn.add_edge(*absent, 0.0)
    with pytest.raises(ValueError, match="finite and > 0"):
        dyn.update_edge(*live, -1.0)
    with pytest.raises(ValueError, match="finite and > 0"):
        dyn.add_edge(*absent, float("inf"))
    with pytest.raises(ValueError, match="self-loops"):
        dyn.delete_edge(4, 4)
    with pytest.raises(IndexError):
        dyn.add_edge(0, 20, 1.0)
    with pytest.raises(ValueError, match="unknown edit op"):
        dyn.apply(("upsert", 0, 1, 2.0))
    assert dyn.version == 0 and len(dyn.commit()) == 0   # nothing leaked


def test_overlay_commit_coalesces_cancelling_edits():
    cg = C.random_csr_graph(30, 90, seed=2)
    dyn = DynamicGraph(cg)
    live = (int(cg.indices[0]), int(cg.dst_ids()[0]))
    w0 = dyn.weight_of(*live)
    # add then delete a new edge, and update a live edge back to its
    # original weight: net nothing happened
    pair = next((a, b) for a in range(30) for b in range(a + 1, 30)
                if not dyn.has_edge(a, b))
    dyn.add_edge(*pair, 5.0)
    dyn.delete_edge(*pair)
    dyn.update_edge(*live, 77.0)
    dyn.update_edge(*live, w0)
    batch = dyn.commit()
    assert len(batch) == 0 and dyn.version == 0


def test_overlay_base_arrays_untouched_and_growth():
    cg = C.random_csr_graph(40, 120, seed=3)
    w_before = cg.weights.copy()
    # compact_threshold=None: growth (not compaction) is the point here
    dyn = DynamicGraph(cg, overlay_capacity=2, compact_threshold=None)
    rng = np.random.default_rng(0)
    added = []
    for _ in range(7):                       # forces capacity growth 2->8
        while True:
            a, b = int(rng.integers(40)), int(rng.integers(40))
            if a != b and not dyn.has_edge(a, b):
                break
        dyn.add_edge(a, b, 2.0)
        added.append((a, b))
    dyn.commit()
    # 7 undirected edges = 14 overlay arcs, grown well past capacity 2
    assert dyn.overlay_used == 14 and dyn.overlay_capacity >= 14
    assert np.array_equal(cg.weights, w_before)     # base untouched
    assert not cg.weights.flags.writeable           # and still frozen
    ref = _serial(dyn, 0)
    got = solve_dynamic(dyn, 0)
    assert np.array_equal(got.dist, ref.dist)


def test_overlay_compaction_preserves_graph_and_version():
    cg = C.random_csr_graph(60, 180, seed=4)
    dyn = DynamicGraph(cg, overlay_capacity=64, compact_threshold=4)
    rng = np.random.default_rng(1)
    before = None
    for _ in range(3):
        _mixed_edits(dyn, rng, 4)
        dyn.commit()
        if before is None:
            before = dyn.snapshot()
    assert dyn.compactions >= 1
    assert dyn.overlay_used <= 4
    v = dyn.version
    snap = dyn.snapshot()
    compacted = dyn.compact()                # explicit compact: same graph
    assert dyn.version == v
    assert np.array_equal(compacted.weights, snap.weights)
    ref = _serial(dyn, 5)
    assert np.array_equal(solve_dynamic(dyn, 5).dist, ref.dist)


# ---------------------------------------------------------------------------
# pull_edge_slots: the pull twin against a naive reference
# ---------------------------------------------------------------------------

def test_pull_edge_slots_matches_naive_reference():
    cg = C.random_csr_graph(50, 200, seed=5)
    n = cg.n
    indptr = np.concatenate([cg.indptr, cg.indptr[-1:]]).astype(np.int32)
    src, w = np.asarray(cg.indices), np.asarray(cg.weights)
    rng = np.random.default_rng(2)
    dist = rng.uniform(0, 30, n).astype(np.float32)
    dist[rng.uniform(size=n) < 0.3] = np.inf
    rows = np.flatnonzero(rng.uniform(size=n) < 0.4).astype(np.int32)
    fids = np.full(n, n, np.int32)
    fids[: rows.size] = rows
    starts = indptr[fids]
    degs = indptr[np.minimum(fids + 1, n)] - starts
    degs[fids == n] = 0
    off = np.cumsum(degs) - degs
    E = int(degs.sum())
    nd = pull_edge_slots(
        jnp.asarray(dist), jnp.asarray(fids), jnp.asarray(dist),
        jnp.asarray(starts), jnp.asarray(off), jnp.int32(E),
        jnp.asarray(src), jnp.asarray(w), chunk=16, drop_id=jnp.int32(n))
    want = dist.copy()
    for r in rows:
        lo, hi = int(cg.indptr[r]), int(cg.indptr[r + 1])
        for p in range(lo, hi):
            want[r] = min(want[r],
                          np.float32(dist[src[p]] + w[p]))
    assert np.array_equal(np.asarray(nd), want)


# ---------------------------------------------------------------------------
# repair exactness: bitwise vs serial on the mutated snapshot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,seed", [(60, 180, 0), (200, 600, 1),
                                      (150, 300, 2)])
def test_repair_chained_mixed_batches_bitwise_vs_serial(n, m, seed):
    cg = C.random_csr_graph(n, m, seed=seed)
    dyn = DynamicGraph(cg, overlay_capacity=16)
    rng = np.random.default_rng(seed)
    res = solve_dynamic(dyn, 0)
    for rnd in range(5):
        _mixed_edits(dyn, rng, 4)
        res, stats = repair_sssp(dyn, res, dyn.commit())
        ref = _serial(dyn, 0)
        assert np.array_equal(res.dist, ref.dist), rnd
        assert np.array_equal(res.pred, ref.pred), rnd


def test_repair_each_direction_and_disconnection_reconnection():
    # a path graph: every repair direction has a deterministic effect
    edges = np.stack([np.arange(11), np.arange(1, 12)], 1)
    cg = C.csr_from_edge_list(12, edges, np.full(11, 2.0, np.float32))
    dyn = DynamicGraph(cg)
    res = solve_dynamic(dyn, 0)
    # decrease
    dyn.update_edge(3, 4, 0.5)
    res, st = repair_sssp(dyn, res, dyn.commit())
    assert np.array_equal(res.dist, _serial(dyn, 0).dist) and st.cone == 0
    # increase (tree arc -> cone of everything downstream)
    dyn.update_edge(3, 4, 10.0)
    res, st = repair_sssp(dyn, res, dyn.commit())
    assert np.array_equal(res.dist, _serial(dyn, 0).dist) and st.cone == 8
    # delete: disconnects the tail
    dyn.delete_edge(5, 6)
    res, st = repair_sssp(dyn, res, dyn.commit())
    ref = _serial(dyn, 0)
    assert np.array_equal(res.dist, ref.dist)
    assert np.isinf(res.dist[6:]).all() and np.all(res.pred[6:] == -1)
    # insert: reconnects through a different vertex
    dyn.add_edge(2, 9, 1.0)
    res, st = repair_sssp(dyn, res, dyn.commit())
    ref = _serial(dyn, 0)
    assert np.array_equal(res.dist, ref.dist)
    assert np.array_equal(res.pred, ref.pred)
    assert np.isfinite(res.dist).all()


def test_repair_shortcut_when_batch_cannot_touch_row():
    cg = C.random_csr_graph(100, 300, seed=6)
    dyn = DynamicGraph(cg)
    res = solve_dynamic(dyn, 0)
    # increase a NON-tree arc: provably a no-op for this source's row
    pred = res.pred
    arc = next((int(u), int(v)) for u, v in
               zip(cg.indices, cg.dst_ids())
               if pred[v] != u and pred[u] != v)
    dyn.update_edge(arc[0], arc[1],
                    float(dyn.weight_of(*arc)) + 50.0)
    res2, st = repair_sssp(dyn, res, dyn.commit())
    assert st.shortcut and res2 is res
    ref = _serial(dyn, 0)
    assert np.array_equal(res2.dist, ref.dist)
    assert np.array_equal(res2.pred, ref.pred)


def test_repair_with_delta_schedule_bitwise():
    cg = C.random_csr_graph(150, 450, seed=7)
    dyn = DynamicGraph(cg)
    res = solve_dynamic(dyn, 3)
    rng = np.random.default_rng(3)
    _mixed_edits(dyn, rng, 6)
    res, _ = repair_sssp(dyn, res, dyn.commit(), delta=25.0)
    ref = _serial(dyn, 3)
    assert np.array_equal(res.dist, ref.dist)


def test_repair_sublinear_vs_full_resolve():
    cg = C.random_csr_graph(2000, 6000, seed=8)
    dyn = DynamicGraph(cg)
    res = solve_dynamic(dyn, 0)
    rng = np.random.default_rng(4)
    _mixed_edits(dyn, rng, 2)
    res, _ = repair_sssp(dyn, res, dyn.commit())
    full = solve_dynamic(dyn, 0)
    assert np.array_equal(res.dist, full.dist)
    assert res.edges_relaxed < full.edges_relaxed


def _dyn_corpus():
    sparse = [(n, 3 * n) for n, _ in
              [(10, 0), (100, 0), (1000, 0), (2000, 0), (10000, 0)]]
    return [pytest.param(n, m,
                         marks=[pytest.mark.slow] if n >= 10000 else [],
                         id=f"n{n}")
            for n, m in sparse]


@pytest.mark.parametrize("n,m", _dyn_corpus())
def test_repair_paper_corpus_bitwise_vs_serial(n, m):
    """The acceptance sweep: one mixed mutation batch per corpus point,
    repaired distances bitwise-equal to a fresh serial solve on the
    mutated graph (Table II sparse shape through n=10000)."""
    cg = C.random_csr_graph(n, m, seed=n)
    dyn = DynamicGraph(cg, overlay_capacity=16)
    res = solve_dynamic(dyn, 0)
    rng = np.random.default_rng(n)
    _mixed_edits(dyn, rng, min(8, max(2, n // 100)))
    res, _ = repair_sssp(dyn, res, dyn.commit())
    ref = _serial(dyn, 0)
    assert np.array_equal(res.dist, ref.dist)
    assert np.array_equal(res.pred, ref.pred)


def test_repair_jit_cache_stable_across_versions():
    from repro.dynamic.repair import sssp_repair

    if not hasattr(sssp_repair, "_cache_size"):
        pytest.skip("jit cache introspection unavailable")
    cg = C.random_csr_graph(120, 360, seed=9)
    # no auto-compaction: shape stability across versions is the point
    dyn = DynamicGraph(cg, overlay_capacity=64, compact_threshold=None)
    res = solve_dynamic(dyn, 0)
    rng = np.random.default_rng(5)
    sizes = []
    for _ in range(5):
        _mixed_edits(dyn, rng, 3)          # same pad caps every round
        out, st = repair_sssp(dyn, res, dyn.commit())
        if not st.shortcut:
            res = out
            sizes.append(sssp_repair._cache_size())
    # first non-shortcut call compiles; every later one hits the cache
    assert len(sizes) >= 2 and sizes[-1] == sizes[0]


def test_repair_requires_pred():
    cg = C.random_csr_graph(30, 90, seed=10)
    dyn = DynamicGraph(cg)
    res = solve_dynamic(dyn, 0)
    res.pred = None
    dyn.delete_edge(int(cg.indices[0]), int(cg.dst_ids()[0]))
    with pytest.raises(ValueError, match="pred"):
        repair_sssp(dyn, res, dyn.commit())


# ---------------------------------------------------------------------------
# dynamic sweeps: the unchanged core engines on overlay operands
# ---------------------------------------------------------------------------

def test_dynamic_sweeps_drive_core_engines_bitwise():
    cg = C.random_csr_graph(90, 270, seed=11)
    dyn = DynamicGraph(cg)
    rng = np.random.default_rng(6)
    _mixed_edits(dyn, rng, 10)
    dyn.commit()
    snap = dyn.snapshot()
    ops = dyn.dyn_ops()
    # bellman fixpoint with the dynamic segment sweep
    d, _, _, _ = sssp_bellman_csr(ops, jnp.int32(4), n=dyn.n,
                                  sweep_fn=dynamic_segment_sweep)
    assert np.array_equal(np.asarray(d),
                          shortest_paths(snap, 4, engine="serial").dist)
    # batched multisource with the vmapped sweep
    D, _, _ = sssp_multisource_csr(ops, jnp.asarray([0, 7, 33], jnp.int32),
                                   n=dyn.n,
                                   sweep_fn=dynamic_segment_sweep_multi)
    for i, s in enumerate((0, 7, 33)):
        assert np.array_equal(
            np.asarray(D)[i],
            shortest_paths(snap, s, engine="serial").dist)
    # frontier with the dynamic flat sweep + target early exit
    full = shortest_paths(snap, 2, engine="serial").dist
    d, _, _, _, _ = sssp_frontier(ops, jnp.int32(2), n=dyn.n,
                                  sweep_fn=make_dynamic_flat_sweep_fn(),
                                  target=jnp.int32(60))
    assert np.asarray(d)[60] == full[60]


# ---------------------------------------------------------------------------
# serve integration: mutation ticks, selective invalidation, landmarks
# ---------------------------------------------------------------------------

def _dyn_stack(n=150, seed=12, **kw):
    cg = C.random_csr_graph(n, 3 * n, seed=seed)
    dyn = DynamicGraph(cg, overlay_capacity=32)
    registry = GraphRegistry(byte_budget=kw.pop("budget", None))
    cache = DistanceCache(capacity=kw.pop("cache_rows", 64))
    sched = MicroBatchScheduler(registry, cache, max_batch=8, **kw)
    registry.register("g", dyn, landmarks=kw.pop("landmarks", 0))
    return dyn, registry, cache, sched


def test_mutate_keeps_unaffected_rows_and_repairs_affected():
    dyn, registry, cache, sched = _dyn_stack()
    handle = registry.get("g")
    for s in (3, 50, 90):
        sched.submit("g", s)
    sched.drain()
    assert len(cache) == 3
    batches_before = sched.engine_batches
    # a far-away increase on a non-tree arc of nothing: add+delete a
    # fresh edge's weight bump cannot exist -> use an isolated update:
    # bump one arc hugely; rows with slack arcs survive, tight ones repair
    u, v = int(dyn.base.indices[0]), int(dyn.base.dst_ids()[0])
    registry.mutate("g", [("update", u, v,
                           float(dyn.weight_of(u, v)) + 60.0)])
    assert sched.rows_kept + sched.rows_repaired + \
        sched.rows_invalidated == 3
    assert sched.rows_invalidated == 0          # repair capacity covers all
    # every surviving row is exact for the NEW version and keyed to it
    for s in (3, 50, 90):
        row = cache.peek(handle.row_key(s))
        assert row is not None
        assert np.array_equal(row, _serial(dyn, s).dist)
    # re-query: all served from cache, no new engine work
    for s in (3, 50, 90):
        sched.submit("g", s)
    answers = sched.drain()
    assert all(a.via == "cache" for a in answers)
    assert sched.engine_batches == batches_before


def test_mutate_invalidates_when_repair_budget_exhausted():
    dyn, registry, cache, sched = _dyn_stack(repair_rows=0)
    for s in (3, 50):
        sched.submit("g", s)
    sched.drain()
    # delete a tree arc of row 3 so it is genuinely affected
    res = _serial(dyn, 3)
    v = int(np.flatnonzero(res.pred == 3)[0])
    registry.mutate("g", [("delete", 3, v)])
    assert sched.rows_repaired == 0
    assert sched.rows_invalidated >= 1
    sched.submit("g", 3)
    (ans,) = sched.drain()
    assert ans.via == "batch"                   # re-solved, not stale
    assert np.array_equal(ans.value, _serial(dyn, 3).dist)


def test_mutation_tick_orders_before_queries():
    dyn, registry, cache, sched = _dyn_stack()
    pair = next((a, b) for a in range(dyn.n) for b in range(a + 1, dyn.n)
                if not dyn.has_edge(a, b))
    sched.submit_mutation("g", "add", pair[0], pair[1], 0.01)
    sched.submit("g", pair[0])
    ack, ans = sched.tick()
    assert ack.via == "mutate" and ack.value == 1
    assert registry.get("g").version == 1
    # the query in the SAME tick sees the post-mutation graph
    assert np.array_equal(ans.value, _serial(dyn, pair[0]).dist)


def test_mutate_batch_is_atomic_on_invalid_edit():
    dyn, registry, cache, sched = _dyn_stack()
    before = dyn.snapshot()
    pair = next((a, b) for a in range(dyn.n) for b in range(a + 1, dyn.n)
                if not dyn.has_edge(a, b))
    with pytest.raises(ValueError, match="not present"):
        registry.mutate("g", [("add", pair[0], pair[1], 1.0),
                              ("delete", pair[0], pair[1]),
                              ("delete", pair[0], pair[1])])  # invalid
    # the valid prefix must have been rolled back, not left pending
    assert dyn.version == 0 and not dyn.has_edge(*pair)
    assert len(dyn.commit()) == 0
    after = dyn.snapshot()
    assert np.array_equal(before.weights, after.weights)
    assert np.array_equal(before.indices, after.indices)


def test_mutate_static_graph_raises_and_scheduler_acks_error():
    cg = C.random_csr_graph(40, 120, seed=13)
    registry = GraphRegistry()
    sched = MicroBatchScheduler(registry, DistanceCache(8))
    registry.register("s", cg)
    with pytest.raises(ValueError, match="static"):
        registry.mutate("s", [("delete", 0, 1)])
    sched.submit_mutation("s", "add", 0, 1, 2.0)
    sched.submit_mutation("nope", "add", 0, 1, 2.0)
    acks = sched.tick()
    assert [a.via for a in acks] == ["error", "error"]
    assert sched.last_mutation_error


def test_landmarks_stale_only_when_touched_and_lazily_refreshed():
    dyn, registry, cache, sched = _dyn_stack(n=120, seed=14)
    handle = registry.get("g")
    handle.landmarks = None
    from repro.serve import build_landmarks
    handle.landmarks = build_landmarks(
        dyn, 5, csr_ops=handle.csr_ops(),
        sweep_fn=handle.multisource_sweep_fn())
    # an untouched far corner: add+delete of a *slack* arc... use a
    # weight bump on an arc slack for EVERY landmark row
    D = handle.landmarks.D
    arc = None
    for u, v, w in zip(dyn.base.indices, dyn.base.dst_ids(),
                       dyn.base.weights):
        u, v = int(u), int(v)
        if all(np.float32(D[k, u] + np.float32(w)) != D[k, v]
               and np.float32(D[k, v] + np.float32(w)) != D[k, u]
               for k in range(5)):
            arc = (u, v, float(w))
            break
    assert arc is not None
    registry.mutate("g", [("update", arc[0], arc[1], arc[2] + 5.0)])
    assert not handle.landmarks_stale            # no landmark row touched
    # now delete a tree arc of landmark 0's row: must stale + refresh
    lm = int(handle.landmarks.ids[0])
    pred = _serial(dyn, lm).pred
    v = int(np.flatnonzero(pred == lm)[0])
    registry.mutate("g", [("delete", lm, v)])
    assert handle.landmarks_stale
    refreshes = handle.landmark_refreshes
    ls = handle.landmarks_ready()                # lazy re-solve happens HERE
    assert handle.landmark_refreshes == refreshes + 1
    assert not handle.landmarks_stale
    for k in range(ls.k):
        assert np.array_equal(ls.D[k],
                              _serial(dyn, int(ls.ids[k])).dist)
    # served landmark answers stay engine rows
    sched.submit("g", int(ls.ids[0]))
    (ans,) = sched.drain()
    assert ans.via == "landmark"
    assert np.array_equal(ans.value, _serial(dyn, int(ls.ids[0])).dist)


def test_eviction_purges_every_version_of_a_mutated_graph():
    """The registry-eviction interplay: evicting a mutated (versioned)
    graph purges the cache rows of EVERY version — including rows a
    buggy reconciliation might have stranded under old versions — and
    the landmark state goes with the handle."""
    dyn, registry, cache, sched = _dyn_stack(budget=None)
    pair = next((a, b) for a in range(dyn.n) for b in range(a + 1, dyn.n)
                if not dyn.has_edge(a, b))
    sched.submit("g", 3)
    sched.drain()
    registry.mutate("g", [("add", pair[0], pair[1], 1.0)])
    sched.submit("g", 7)
    sched.drain()
    # strand an extra row under a long-gone version on purpose
    cache.put(("g", 0, 11), np.zeros(dyn.n, np.float32))
    versions = {k[1] for k in cache.keys_for("g")}
    assert len(versions) >= 2                   # multi-version state exists
    # replacing the name evicts the old handle -> every version purged
    registry.register("g", C.random_csr_graph(50, 150, seed=99))
    assert cache.keys_for("g") == []
    assert registry.stats()["evicted"] == 1


# ---------------------------------------------------------------------------
# churn traces
# ---------------------------------------------------------------------------

def test_churn_trace_deterministic_and_self_consistent():
    cg = C.random_csr_graph(100, 300, seed=15)
    a = make_churn_trace([("g", cg)], num_events=80, rate=100,
                         mutate_frac=0.3, seed=4, hot_seed=9)
    b = make_churn_trace([("g", cg)], num_events=80, rate=100,
                         mutate_frac=0.3, seed=4, hot_seed=9)
    assert a == b
    n_mut = sum(isinstance(e, MutationEvent) for e in a)
    assert 0 < n_mut < 80
    # every mutation is valid when applied in order (self-consistency)
    dyn = DynamicGraph(cg, overlay_capacity=16)
    for e in a:
        if isinstance(e, MutationEvent):
            dyn.apply((e.op, e.u, e.v) if e.w is None
                      else (e.op, e.u, e.v, e.w))
    dyn.commit()
    with pytest.raises(ValueError, match="undirected"):
        make_churn_trace(
            [("d", C.random_csr_graph(30, 90, seed=1, directed=True))],
            num_events=5, rate=10)


def test_churn_replay_end_to_end_bitwise():
    """The tentpole invariant end to end: replay a churn trace through
    registry -> scheduler -> dynamic engines -> cache repair, checking
    every answer bitwise against serial on the answer-time snapshot."""
    cg = C.random_csr_graph(120, 360, seed=16)
    dyn = DynamicGraph(cg, overlay_capacity=32, compact_threshold=24)
    registry = GraphRegistry()
    cache = DistanceCache(capacity=32)
    sched = MicroBatchScheduler(registry, cache, max_batch=4)
    registry.register("g", dyn, landmarks=4)
    events = make_churn_trace([("g", cg)], num_events=90, rate=1e4,
                              mutate_frac=0.3, seed=6, hot_seed=2)
    rows: dict = {}
    for e in events:
        if isinstance(e, MutationEvent):
            sched.submit_mutation(e.graph, e.op, e.u, e.v, e.w)
        else:
            sched.submit(e.graph, e.source, e.target)
        for a in sched.drain():
            if a.via == "mutate":
                continue
            assert a.via != "error"
            q = a.query
            key = (dyn.version, q.source)
            if key not in rows:
                rows[key] = _serial(dyn, q.source).dist
            ref = rows[key]
            if q.target is None:
                assert np.array_equal(a.value, ref), (q, a.via)
            else:
                got, want = np.float32(a.value), ref[q.target]
                assert got == want or (np.isinf(got) and np.isinf(want)), \
                    (q, a.via)
    assert registry.get("g").version > 0
    s = sched.stats()
    assert s["rows_kept"] + s["rows_repaired"] + s["rows_invalidated"] > 0


# ---------------------------------------------------------------------------
# row_affected: the keep/invalidate test is sound and not vacuous
# ---------------------------------------------------------------------------

def test_row_affected_sound_and_selective():
    cg = C.random_csr_graph(80, 240, seed=17)
    dyn = DynamicGraph(cg)
    rows = {s: _serial(dyn, s).dist for s in range(0, 80, 7)}
    rng = np.random.default_rng(7)
    kept_any = False
    for _ in range(6):
        _mixed_edits(dyn, rng, 3)
        batch = dyn.commit()
        for s, row in rows.items():
            affected = row_affected(row, batch, dyn.directed)
            new = _serial(dyn, s).dist
            if not affected:
                # claimed unaffected => must still be the exact fixpoint
                assert np.array_equal(row, new), s
                kept_any = True
            rows[s] = new
    assert kept_any                     # the test is not vacuously sound
