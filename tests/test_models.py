"""Per-arch smoke tests (reduced configs, one forward/train step, shape +
finiteness asserts) and model-semantics tests (decode==forward, sliding
window, softcap, chunked-CE equivalence, remat equivalence)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, make_smoke
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32, seed=0):
    k = jax.random.PRNGKey(seed)
    batch = {
        "tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k, (B, cfg.num_image_tokens, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        batch["encoder_frames"] = jax.random.normal(
            k, (B, S // cfg.audio_downsample, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = make_smoke(get_config(arch))
    params = T.init_params(KEY, cfg)
    batch = _batch_for(cfg)
    x, _, aux = T.forward(params, batch["tokens"], cfg,
                          image_embeds=batch.get("image_embeds"),
                          encoder_frames=batch.get("encoder_frames"))
    B, S = batch["tokens"].shape
    assert x.shape == (B, S, cfg.d_model)
    assert np.isfinite(np.asarray(x, np.float32)).all()
    loss, metrics = T.train_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = make_smoke(get_config(arch))
    params = T.init_params(KEY, cfg)
    batch = _batch_for(cfg)
    kw = {k: batch[k] for k in ("image_embeds", "encoder_frames")
          if k in batch}
    logits, caches, pos = T.prefill(params, batch["tokens"], cfg,
                                    max_len=40, **kw)
    assert logits.shape == (2, cfg.vocab_size)
    dkw = ({"image_embeds": batch["image_embeds"]}
           if "image_embeds" in batch else {})
    tok = jnp.argmax(logits, -1)[:, None]
    logits2, caches, pos2 = T.decode_step(params, tok, pos, caches, cfg, **dkw)
    assert logits2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert (np.asarray(pos2) == np.asarray(pos) + 1).all()


@pytest.mark.parametrize("arch", ["gemma3-1b", "kimi-k2-1t-a32b",
                                  "zamba2-2.7b", "seamless-m4t-medium",
                                  "llama-3.2-vision-11b", "mamba2-130m"])
def test_decode_matches_forward(arch):
    """Prefill+decode must reproduce the full-forward logits (cache
    correctness across every layer kind).  MoE capacity is raised so no
    token drops (dropping legitimately differs between batched prefill and
    single-token decode)."""
    cfg = dataclasses.replace(make_smoke(get_config(arch)),
                              capacity_factor=64.0)
    params = T.init_params(KEY, cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, seed=1)
    kw = {k: batch[k] for k in ("image_embeds", "encoder_frames")
          if k in batch}
    x, _, _ = T.forward(params, batch["tokens"], cfg, **kw)
    full = np.asarray(T.logits_from_hidden(params, x, cfg))
    half = S // 2
    logits, caches, pos = T.prefill(params, batch["tokens"][:, :half], cfg,
                                    max_len=S, cache_dtype=jnp.float32, **kw)
    errs = [np.max(np.abs(logits - full[:, half - 1]))]
    dkw = ({"image_embeds": batch["image_embeds"]}
           if "image_embeds" in batch else {})
    for t in range(half, S):
        logits, caches, pos = T.decode_step(
            params, batch["tokens"][:, t:t + 1], pos, caches, cfg, **dkw)
        errs.append(np.max(np.abs(logits - full[:, t])))
    assert max(errs) < 2e-3, errs


def test_sliding_window_restricts_attention():
    """A local layer with window w must ignore tokens older than w."""
    from repro.models.attention import attend
    B, S, H, hd = 1, 12, 2, 8
    k = jax.random.PRNGKey(2)
    q = jax.random.normal(k, (B, S, H, hd))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, H, hd))
    pos = jnp.arange(S)[None, :]
    valid = jnp.ones((B, S), bool)
    full = attend(q, kk, v, q_pos=pos, k_pos=pos, k_valid=valid,
                  causal=True, window=0)
    win = attend(q, kk, v, q_pos=pos, k_pos=pos, k_valid=valid,
                 causal=True, window=4)
    # early positions (within window) agree; late positions differ
    assert np.allclose(np.asarray(full[:, :4]), np.asarray(win[:, :4]),
                       atol=1e-5)
    assert not np.allclose(np.asarray(full[:, -1]), np.asarray(win[:, -1]))
    # window == S is exactly causal attention
    win_s = attend(q, kk, v, q_pos=pos, k_pos=pos, k_valid=valid,
                   causal=True, window=S)
    assert np.allclose(np.asarray(full), np.asarray(win_s), atol=1e-5)


def test_q_chunking_is_exact():
    from repro.models.attention import attend
    B, S, H, hd = 2, 32, 4, 16
    k = jax.random.PRNGKey(3)
    q = jax.random.normal(k, (B, S, H, hd))
    kk = jax.random.normal(jax.random.fold_in(k, 1), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(k, 2), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    valid = jnp.ones((B, S), bool)
    a = attend(q, kk, v, q_pos=pos, k_pos=pos, k_valid=valid, causal=True,
               window=0, q_chunk=0)
    b = attend(q, kk, v, q_pos=pos, k_pos=pos, k_valid=valid, causal=True,
               window=0, q_chunk=8)
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_softcap_bounds_logits():
    from repro.models.common import softcap
    x = jnp.linspace(-1000, 1000, 101)
    y = softcap(x, 30.0)
    assert float(jnp.max(jnp.abs(y))) <= 30.0
    assert np.allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))


def test_chunked_ce_matches_unchunked():
    cfg = make_smoke(get_config("qwen1.5-0.5b"))
    cfg_c = dataclasses.replace(cfg, loss_chunk=8)
    params = T.init_params(KEY, cfg)
    batch = _batch_for(cfg, B=2, S=32)
    l0, _ = T.train_loss(params, batch, cfg)
    l1, _ = T.train_loss(params, batch, cfg_c)
    assert np.isclose(float(l0), float(l1), rtol=1e-5)


def test_remat_equivalence():
    cfg_n = dataclasses.replace(make_smoke(get_config("gemma2-2b")),
                                remat="none")
    cfg_f = dataclasses.replace(cfg_n, remat="full")
    params = T.init_params(KEY, cfg_n)
    batch = _batch_for(cfg_n)
    g_n = jax.grad(lambda p: T.train_loss(p, batch, cfg_n)[0])(params)
    g_f = jax.grad(lambda p: T.train_loss(p, batch, cfg_f)[0])(params)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_n, g_f)
    assert max(jax.tree.leaves(diffs)) < 1e-4


def test_label_masking():
    cfg = make_smoke(get_config("qwen1.5-0.5b"))
    params = T.init_params(KEY, cfg)
    batch = _batch_for(cfg)
    # masking every label -> loss over the remaining none must not NaN;
    # mask half -> loss differs from unmasked
    b2 = dict(batch, labels=batch["labels"].at[:, ::2].set(-1))
    l0, _ = T.train_loss(params, batch, cfg)
    l1, _ = T.train_loss(params, b2, cfg)
    assert np.isfinite(float(l1)) and not np.isclose(float(l0), float(l1))


def test_param_count_matches_instantiated():
    for arch in ("qwen1.5-0.5b", "gemma2-2b", "mamba2-130m"):
        cfg = make_smoke(get_config(arch))
        params = T.init_params(KEY, cfg)
        actual = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
        # analytic count excludes tiny norm/gate params: within 2%
        assert abs(actual - cfg.param_count()) / actual < 0.02, arch
