"""Optimizer / train-step / compression / data / sharding-rules tests."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, make_smoke
from repro.core._compat import abstract_mesh
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.sharding import rules
from repro.train import compression as comp
from repro.train.optimizer import (OptConfig, adamw_update, clip_by_global_norm,
                                   global_norm, init_opt_state, schedule)
from repro.train.state import init_train_state
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_scalar():
    """One AdamW step on a single scalar vs hand-computed values."""
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=10**9, b1=0.9,
                    b2=0.999, eps=1e-8, weight_decay=0.0, clip_norm=1e9)
    params = {"scale": jnp.asarray(2.0)}    # 'scale' -> no weight decay
    opt = init_opt_state(params, cfg)
    grads = {"scale": jnp.asarray(0.5)}
    new_p, new_s, m = adamw_update(grads, opt, params, cfg)
    # bias-corrected first step: update = lr * g/|g| = lr (adam step=sign-ish)
    mu = 0.1 * 0.5
    nu = 0.001 * 0.25
    step = (mu / 0.1) / (np.sqrt(nu / 0.001) + 1e-8)
    assert np.isclose(float(new_p["scale"]), 2.0 - 0.1 * step, rtol=1e-5)
    assert int(new_s["count"]) == 1


def test_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_ratio=0.1)
    assert float(schedule(jnp.asarray(5), cfg)) == pytest.approx(0.5)
    assert float(schedule(jnp.asarray(10), cfg)) == pytest.approx(1.0, rel=1e-3)
    assert float(schedule(jnp.asarray(110), cfg)) == pytest.approx(0.1, rel=1e-3)


def test_clip_by_global_norm():
    g = {"a": jnp.ones((4,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    assert float(global_norm(g)) == pytest.approx(10.0)
    clipped, gn = clip_by_global_norm(g, 5.0)
    assert float(global_norm(clipped)) == pytest.approx(5.0, rel=1e-5)
    assert float(gn) == pytest.approx(10.0)


def test_weight_decay_mask():
    cfg = OptConfig(lr=1.0, warmup_steps=0, total_steps=10**9,
                    weight_decay=1.0, clip_norm=1e9)
    params = {"w": jnp.asarray(1.0), "scale": jnp.asarray(1.0)}
    opt = init_opt_state(params, cfg)
    grads = {"w": jnp.asarray(0.0), "scale": jnp.asarray(0.0)}
    new_p, _, _ = adamw_update(grads, opt, params, cfg)
    assert float(new_p["w"]) < 1.0          # decayed
    assert float(new_p["scale"]) == 1.0     # masked


def test_train_loss_decreases_and_accum_matches():
    cfg = make_smoke(get_config("qwen1.5-0.5b"))
    opt = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    state = init_train_state(KEY, cfg, opt)
    B, S = 4, 32
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    step = jax.jit(make_train_step(cfg, opt))
    losses = []
    for _ in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]

    s1 = init_train_state(KEY, cfg, opt)
    s2 = init_train_state(KEY, cfg, opt)
    s1, m1 = jax.jit(make_train_step(cfg, opt))(s1, batch)
    s2, m2 = jax.jit(make_train_step(cfg, opt, grad_accum=2))(s2, batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 5e-3


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 3, jnp.float32)
    q, s = comp.quantize_int8(x)
    err = np.abs(np.asarray(comp.dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of dequantized transmissions + final error == sum of inputs
    (error feedback never loses gradient mass)."""
    from repro.core._compat import make_mesh, shard_map
    mesh = make_mesh((1,), ("data",))
    import functools
    from jax.sharding import PartitionSpec as P

    @functools.partial(shard_map, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_vma=False)
    def one_round(g, e):
        return comp.compressed_mean(g, e, "data")

    rng = np.random.default_rng(1)
    gs = [jnp.asarray(rng.standard_normal(64), jnp.float32)
          for _ in range(5)]
    err = jnp.zeros((64,))
    sent = jnp.zeros((64,))
    for g in gs:
        ghat, err = one_round(g, err)
        sent = sent + ghat
    total_in = sum(np.asarray(g) for g in gs)
    assert np.allclose(np.asarray(sent + err), total_in, atol=1e-4)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restart_safe():
    dc = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    p1, p2 = SyntheticPipeline(dc), SyntheticPipeline(dc)
    b1, b2 = p1.batch_at(13), p2.batch_at(13)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(13)["tokens"],
                              p1.batch_at(14)["tokens"])
    # labels are next-token
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()


def test_data_per_host_sharding():
    dc = DataConfig(vocab_size=100, seq_len=8, global_batch=8, seed=0)
    h0 = SyntheticPipeline(dc, process_index=0, process_count=2)
    h1 = SyntheticPipeline(dc, process_index=1, process_count=2)
    assert h0.local_batch == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_assign_spec_divisibility_fallback():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    # divisible -> assigned
    assert rules.assign_spec((8, 16), [["dp"], ["tp"]], mesh) == P("data", "model")
    # first dim indivisible -> dropped, second still assigned
    assert rules.assign_spec((7, 16), [["dp"], ["tp"]], mesh) == P(None, "model")
    # axis used once only
    assert rules.assign_spec((8, 8), [["tp"], ["tp"]], mesh) == P("model", None)


def test_param_rules_moe_fallback():
    # production model axis is 16-way: 60 experts are indivisible
    mesh = abstract_mesh((2, 16), ("data", "model"))
    # 60 experts indivisible by 16 -> ff gets the model axis
    import jax.tree_util as jtu
    path = (jtu.DictKey("segments"), jtu.SequenceKey(0), jtu.SequenceKey(0),
            jtu.DictKey("ffn"), jtu.DictKey("wi_gate"))
    spec = rules.spec_for_param(path, (24, 60, 64, 1408), mesh)
    assert spec == P(None, None, "data", "model")
    # 64 experts divisible -> experts take the model axis
    spec = rules.spec_for_param(path, (24, 64, 64, 1408), mesh)
    assert spec == P(None, "model", "data", None)


def test_cache_spec_long_context_batch1():
    mesh = abstract_mesh((2, 4), ("data", "model"))
    # (rep, B=1, S, KV, hd): B unshardable -> S takes dp, KV takes tp
    spec = rules.cache_spec((26, 1, 1024, 4, 256), mesh)
    assert spec == P(None, None, "data", "model", None)
    # (rep, B=128, S, KV, hd): B takes dp, KV takes tp
    spec = rules.cache_spec((26, 128, 1024, 4, 256), mesh)
    assert spec == P(None, "data", None, "model", None)


def test_constrain_noop_outside_mesh():
    x = jnp.ones((4, 8, 16))
    y = rules.constrain(x, "hidden")    # no ambient mesh -> identity
    assert y is x or np.array_equal(np.asarray(x), np.asarray(y))
