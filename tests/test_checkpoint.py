"""Checkpoint: roundtrip (incl. bf16), atomic commit, async manager,
retention GC, latest-step discovery, corrupted-tmp ignored."""
import os
import shutil

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _state():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16) * 1.5},
        "opt": {"mu": jnp.zeros((3, 4)), "count": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(str(tmp_path), st, 5)
    shape = jax.eval_shape(lambda: _state())
    got, extra = restore_checkpoint(str(tmp_path), shape)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_latest_step_and_gc(tmp_path):
    st = _state()
    for s in (1, 2, 3, 4):
        save_checkpoint(str(tmp_path), st, s)
    assert latest_step(str(tmp_path)) == 4
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(st, 5, block=True)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_4", "step_5"]


def test_async_manager_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(_state(), 1)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1


def test_tmp_dirs_never_visible(tmp_path):
    # a crashed writer leaves tmp.step_N; latest_step must ignore it
    os.makedirs(tmp_path / "tmp.step_9")
    save_checkpoint(str(tmp_path), _state(), 2)
    assert latest_step(str(tmp_path)) == 2
    shape = jax.eval_shape(lambda: _state())
    _, _ = restore_checkpoint(str(tmp_path), shape)    # loads step_2


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), _state(), 1)
    bad = jax.eval_shape(
        lambda: {"params": {"w": jnp.zeros((5, 4)),
                            "b": jnp.zeros((4,), jnp.bfloat16)},
                 "opt": {"mu": jnp.zeros((3, 4)), "count": jnp.int32(0)}})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(str(tmp_path), bad)


def test_restore_with_shardings(tmp_path):
    """Reshard-on-load: restore with explicit NamedShardings."""
    from repro.core._compat import make_mesh
    from repro.sharding import rules
    mesh = make_mesh((1,), ("data",))
    st = _state()
    save_checkpoint(str(tmp_path), st, 3)
    shape = jax.eval_shape(lambda: _state())
    sh = jax.tree.map(lambda _: rules.replicated(mesh), shape,
                      is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    got, _ = restore_checkpoint(str(tmp_path), shape, shardings=sh)
    assert np.array_equal(np.asarray(got["params"]["w"]),
                          np.asarray(st["params"]["w"]))
