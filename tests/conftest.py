# NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here —
# smoke tests run on the single real CPU device.  Multi-device behavior is
# covered by subprocess tests (test_integration.py) that set
# --xla_force_host_platform_device_count in the child environment, and by
# the dry-run (launch/dryrun.py) which owns its own flag.
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def finite_close(a, b, rtol=1e-5):
    """allclose treating +inf as a big sentinel (unreachable vertices)."""
    a = np.where(np.isfinite(a), a, 1e30)
    b = np.where(np.isfinite(b), b, 1e30)
    return np.allclose(a, b, rtol=rtol)
