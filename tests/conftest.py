# NOTE: XLA_FLAGS / device-count forcing is deliberately NOT set here —
# smoke tests run on the single real CPU device.  Multi-device behavior is
# covered by subprocess tests (test_integration.py) that set
# --xla_force_host_platform_device_count in the child environment, and by
# the dry-run (launch/dryrun.py) which owns its own flag.
import heapq

import numpy as np
import pytest

# NOTE: the old ``requires_modern_jax_sharding`` gate is gone — the sharded
# engines, training substrate, and their tests all go through
# repro.core._compat now, which provides shard_map / set_mesh /
# make_mesh / abstract_mesh on both the pinned jax 0.4.37 and modern jax,
# so those 13 tests run everywhere.


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def finite_close(a, b, rtol=1e-5):
    """allclose treating +inf as a big sentinel (unreachable vertices)."""
    a = np.where(np.isfinite(a), a, 1e30)
    b = np.where(np.isfinite(b), b, 1e30)
    return np.allclose(a, b, rtol=rtol)


def _out_adjacency(g):
    """Outgoing adjacency lists from a Graph, CsrGraph, or dense ndarray."""
    if hasattr(g, "indptr"):                      # CsrGraph: rows = incoming
        out = [[] for _ in range(g.n)]
        indptr, src, w = g.indptr, g.indices, g.weights
        for v in range(g.n):
            for e in range(int(indptr[v]), int(indptr[v + 1])):
                out[int(src[e])].append((int(v), float(w[e])))
        return out
    adj = np.asarray(g.adj if hasattr(g, "adj") else g)
    n = adj.shape[0]
    out = []
    for u in range(n):
        js = np.nonzero(np.isfinite(adj[u]))[0]
        out.append([(int(j), float(adj[u, j])) for j in js if j != u])
    return out


def dijkstra_oracle(g, source):
    """Independent pure-python Dijkstra: binary heap over adjacency lists.

    Deliberately shares no code with any engine (serial.py's numpy oracle
    mirrors Alg. 1's O(n²) scan; this is the classic heap formulation), so
    an agreement between the two oracles and an engine is three independent
    derivations of the same answer.  Accepts Graph, CsrGraph, or ndarray.
    Returns float64 distances, +inf for unreachable vertices.
    """
    out = _out_adjacency(g)
    n = len(out)
    dist = np.full(n, np.inf)
    dist[source] = 0.0
    done = np.zeros(n, bool)
    heap = [(0.0, source)]
    while heap:
        d, u = heapq.heappop(heap)
        if done[u]:
            continue
        done[u] = True
        for v, w in out[u]:
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(heap, (nd, v))
    return dist
