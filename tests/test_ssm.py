"""Mamba2/SSD: chunked scan vs naive step-by-step recurrence, decode
continuation, and chunk-size invariance."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, make_smoke
from repro.models.ssm import init_ssm, ssd_chunked, ssm_decode, ssm_forward


def _naive_ssd(xh, dt, A, Bm, Cm):
    """Reference recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t ⊗ x_t."""
    Bsz, L, H, P = xh.shape
    N = Bm.shape[-1]
    h = np.zeros((Bsz, H, P, N), np.float64)
    ys = np.zeros((Bsz, L, H, P), np.float64)
    for t in range(L):
        dA = np.exp(dt[:, t] * A[None, :])                       # (B, H)
        h = h * dA[:, :, None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cm[:, t], h)
    return ys, h


@pytest.mark.parametrize("L,chunk", [(16, 4), (32, 8), (24, 24), (8, 16)])
def test_ssd_chunked_matches_recurrence(L, chunk):
    rng = np.random.default_rng(0)
    Bsz, H, P, N = 2, 3, 4, 5
    xh = rng.standard_normal((Bsz, L, H, P)).astype(np.float32)
    dt = rng.uniform(0.1, 0.9, (Bsz, L, H)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    Bm = rng.standard_normal((Bsz, L, N)).astype(np.float32)
    Cm = rng.standard_normal((Bsz, L, N)).astype(np.float32)
    ref_y, ref_h = _naive_ssd(xh, dt, A, Bm, Cm)
    if L % min(chunk, L) != 0:
        pytest.skip("chunk must divide L")
    y, h = ssd_chunked(jnp.asarray(xh), jnp.asarray(dt), jnp.asarray(A),
                       jnp.asarray(Bm), jnp.asarray(Cm), chunk)
    assert np.allclose(np.asarray(y), ref_y, atol=1e-3), \
        np.abs(np.asarray(y) - ref_y).max()
    assert np.allclose(np.asarray(h), ref_h, atol=1e-3)


def test_chunk_size_invariance():
    rng = np.random.default_rng(1)
    Bsz, L, H, P, N = 1, 32, 2, 4, 3
    xh = jnp.asarray(rng.standard_normal((Bsz, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (Bsz, L, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((Bsz, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((Bsz, L, N)), jnp.float32)
    y4, h4 = ssd_chunked(xh, dt, A, Bm, Cm, 4)
    y16, h16 = ssd_chunked(xh, dt, A, Bm, Cm, 16)
    assert np.allclose(np.asarray(y4), np.asarray(y16), atol=1e-4)
    assert np.allclose(np.asarray(h4), np.asarray(h16), atol=1e-4)


def test_forward_then_decode_continues_state():
    """ssm_forward's final state must continue exactly into ssm_decode."""
    cfg = dataclasses.replace(make_smoke(get_config("mamba2-130m")),
                              param_dtype="float32")
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    L = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (1, L + 1, cfg.d_model),
                          jnp.float32) * 0.5
    # full forward over L+1 tokens
    y_full, _ = ssm_forward(p, x, cfg)
    # forward over L, then one decode step
    y_pre, (conv_state, ssm_state) = ssm_forward(p, x[:, :L], cfg)
    y_dec, _, _ = ssm_decode(p, x[:, L:L + 1], cfg, conv_state, ssm_state)
    assert np.allclose(np.asarray(y_full[:, :L]), np.asarray(y_pre),
                       atol=1e-4)
    assert np.allclose(np.asarray(y_full[:, L]), np.asarray(y_dec[:, 0]),
                       atol=1e-3), \
        np.abs(np.asarray(y_full[:, L]) - np.asarray(y_dec[:, 0])).max()
