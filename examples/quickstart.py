"""Quickstart: the paper's three SSSP engines + a tiny LM through the
public API, in under a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import graph as G
from repro.core.api import shortest_paths
from repro.configs import get_config, make_smoke
from repro.models import transformer as T

# --- 1. SSSP: serial (Alg.1), fixpoint (Alg.3/4), Pallas kernel ----------
g = G.random_graph(500, 1500, seed=0)
print(f"graph: {g.n} vertices, {g.num_edges} edges")

for engine in ("serial", "bellman", "bellman_kernel"):
    res = shortest_paths(g, source=0, engine=engine)
    reached = int(np.isfinite(res.dist).sum())
    extra = f", {res.sweeps} sweeps" if res.sweeps is not None else ""
    print(f"  {engine:16s}: reached {reached}/{g.n}{extra}; "
          f"max dist {np.nanmax(np.where(np.isfinite(res.dist), res.dist, np.nan)):.2f}")

# --- 2. multi-source batching (beyond-paper) ------------------------------
res = shortest_paths(g, np.array([0, 7, 99]), engine="multisource")
print(f"  multisource     : dist matrix {res.dist.shape}, {res.sweeps} sweeps")

# --- 3. a model from the assigned-architecture zoo -------------------------
cfg = make_smoke(get_config("gemma3-1b"))
params = T.init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
loss, metrics = T.train_loss(params, {"tokens": tokens, "labels": tokens}, cfg)
print(f"\n{cfg.name}: one train-loss eval = {float(loss):.3f}")

logits, caches, pos = T.prefill(params, tokens, cfg, max_len=40)
tok = jnp.argmax(logits, -1)[:, None]
for _ in range(5):
    logits, caches, pos = T.decode_step(params, tok, pos, caches, cfg)
    tok = jnp.argmax(logits, -1)[:, None]
print(f"decoded 5 tokens, cache pos now {np.asarray(pos)}")
print("\nquickstart OK")
