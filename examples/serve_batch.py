"""Batched serving example: run the serving loop over a queue of requests
for any assigned architecture (smoke scale on CPU), reporting latency and
throughput — the decode path here is the exact code lowered by the
decode_32k / long_500k dry-run cells.

    PYTHONPATH=src python examples/serve_batch.py            # gemma2-2b
    PYTHONPATH=src python examples/serve_batch.py --arch zamba2-2.7b
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--arch" not in argv:
        argv = ["--arch", "gemma2-2b"] + argv
    if "--smoke" not in argv:
        argv.append("--smoke")
    main(argv)
