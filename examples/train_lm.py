"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
CPU with checkpointing, then resume once to demonstrate restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--tiny]

Uses the training driver (launch/train.py) — the same code path the
production launcher uses, minus the pod mesh.
"""
import argparse
import dataclasses
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticPipeline
from repro.train.optimizer import OptConfig
from repro.train.state import init_train_state
from repro.train.step import make_train_step
from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint


def config_100m(tiny: bool) -> ModelConfig:
    if tiny:
        return ModelConfig(
            name="demo-8m", family="dense", d_model=128, num_heads=4,
            num_kv_heads=2, head_dim=32, d_ff=512, vocab_size=2048,
            segments=(("G", 4),), param_dtype="float32", loss_chunk=0,
            remat="none")
    # ~100M params: 12L, d=640, vocab 32k
    return ModelConfig(
        name="demo-100m", family="dense", d_model=640, num_heads=10,
        num_kv_heads=5, head_dim=64, d_ff=1792, vocab_size=32_768,
        segments=(("G", 12),), param_dtype="float32", loss_chunk=0,
        remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true",
                    help="8M params (fast CI-scale run)")
    args = ap.parse_args()

    cfg = config_100m(args.tiny)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    opt = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    pipe = SyntheticPipeline(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch))

    ckdir = tempfile.mkdtemp(prefix="train_lm_ck_")
    try:
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)
        mgr = CheckpointManager(ckdir)
        half = args.steps // 2
        for i in range(half):
            state, m = step_fn(state, {k: jnp.asarray(v) for k, v in
                                       pipe.batch_at(i).items()})
            if i % 20 == 0:
                print(f"step {i:4d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e}")
        mgr.save(state, half, block=True)
        print(f"--- checkpointed at step {half}; simulating restart ---")

        from repro.train.state import train_state_shape
        state2, extra = restore_checkpoint(ckdir, train_state_shape(cfg, opt))
        for i in range(half, args.steps):
            state2, m = step_fn(state2, {k: jnp.asarray(v) for k, v in
                                         pipe.batch_at(i).items()})
            if i % 20 == 0:
                print(f"step {i:4d} loss {float(m['loss']):.4f}")
        print(f"final loss {float(m['loss']):.4f}")
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


if __name__ == "__main__":
    main()
