"""Serving subsystem demo: two registered graphs, interleaved scenarios.

Registers a road-network-ish sparse graph and a denser small-world-ish
graph in one GraphRegistry (with ALT landmarks), then interleaves all
three workload scenarios — uniform full-row queries, Zipf-skewed repeat
sources, and point-to-point pairs — against BOTH graphs through a single
MicroBatchScheduler, printing where each answer came from (cache /
landmark / batched engine / target early-exit) and the end-of-run stats.

    PYTHONPATH=src python examples/sssp_serve_demo.py
"""
import numpy as np

from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.serve import (DistanceCache, GraphRegistry, MicroBatchScheduler,
                         make_trace)


def main():
    # two graphs with different shapes: Table-II sparsity vs 8x denser
    road = C.random_csr_graph(600, 1800, seed=0)
    web = C.random_csr_graph(400, 3200, seed=1)

    registry = GraphRegistry(byte_budget=64 << 20)
    cache = DistanceCache(capacity=128)
    sched = MicroBatchScheduler(registry, cache, max_batch=8)
    registry.register("road", road, landmarks=6)
    registry.register("web", web, landmarks=6)
    print(f"registered: {registry.names}, "
          f"{registry.bytes_in_use / 1e6:.2f} MB in use")

    sizes = [("road", road.n), ("web", web.n)]
    for scen in ("uniform", "zipf", "p2p"):
        for ev in make_trace(scen, sizes, num_queries=30, rate=1e4,
                             seed=42):
            sched.submit(ev.graph, ev.source, ev.target, arrival=ev.arrival)
        answers = sched.drain()
        by_via = {}
        for a in answers:
            by_via.setdefault(a.via, 0)
            by_via[a.via] += 1
        print(f"{scen:8s}: {len(answers)} answers via {by_via}")

    # spot-check a few answers against the serial engine (the full
    # bitwise sweep lives in tests/test_serve.py and the --smoke driver)
    sched.submit("road", 17)
    (ans,) = sched.drain()
    ref = shortest_paths(road, 17, engine="serial").dist
    assert np.array_equal(ans.value, ref)
    print(f"spot-check: sssp(road, 17) via {ans.via!r} == serial row")

    sched.submit("web", 3, 250)
    (ans,) = sched.drain()
    ref = shortest_paths(web, 3, engine="serial").dist
    assert np.float32(ans.value) == ref[250]
    print(f"spot-check: dist(web, 3, 250) via {ans.via!r} == serial "
          f"({ans.value:.4f})")

    print("\nfinal stats:")
    for k, v in sched.stats().items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
