"""End-to-end SSSP pipeline reproducing the paper's workflow:

    edge list -> adjacency matrix (+ padding) -> engine -> verified output,

for every engine, with timings in the paper's §III cost envelope and a
cross-engine agreement check.

    PYTHONPATH=src python examples/sssp_pipeline.py [--nodes N] [--edges M]
"""
import argparse
import time

import numpy as np

import jax

from repro.core import graph as G
from repro.core._compat import make_mesh
from repro.core.api import (CSR_ENGINES, DELTA_ENGINES, ENGINES,
                            SHARDED_CSR_ENGINES, shortest_paths)
from repro.core.serial import dijkstra_serial_np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=800)
    ap.add_argument("--edges", type=int, default=2400)
    ap.add_argument("--source", type=int, default=0)
    args = ap.parse_args()

    # 1. edge list (the paper's input format) -> both containers
    g = G.random_graph(args.nodes, args.edges, seed=0)
    cg = g.to_csr()
    dense_bytes = g.adj.nbytes
    print(f"built adjacency matrix: {g.n}x{g.n}, {g.num_edges} edges "
          f"({dense_bytes / 1e6:.2f} MB dense)")
    print(f"built CSR container:    {cg.nnz} arcs "
          f"({cg.nbytes / 1e6:.2f} MB, {dense_bytes / cg.nbytes:.1f}x "
          "smaller — the paper's §V Table II complaint, fixed)")

    # 2. oracle
    ref, _ = dijkstra_serial_np(g.adj, args.source)

    # 3. every engine (sharded ones on a host mesh over available devices)
    n_dev = jax.device_count()
    mesh = make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
    for engine in ENGINES:
        if (engine in ("dijkstra_sharded", "bellman_sharded")
                + SHARDED_CSR_ENGINES and mesh is None):
            print(f"  {engine:18s}: skipped (single device; "
                  "run under XLA_FLAGS=--xla_force_host_platform_device_count=8)")
            continue
        src = (np.array([args.source])
               if engine in ("multisource", "multisource_csr")
               else args.source)
        # CSR-native engines get the sparse container directly — no dense
        # matrix on their path at all.  The Δ engines additionally thread
        # delta="auto": the bucket width is derived per graph from the
        # staged weight profile (core/delta_stepping.auto_delta).
        arg_g = (cg if engine in CSR_ENGINES + DELTA_ENGINES
                 + SHARDED_CSR_ENGINES or engine == "multisource_csr" else g)
        kw = {"delta": "auto"} if engine in DELTA_ENGINES else {}
        shortest_paths(arg_g, src, engine=engine, mesh=mesh, **kw)  # warm jit
        t0 = time.perf_counter()
        res = shortest_paths(arg_g, src, engine=engine, mesh=mesh, **kw)
        dt = time.perf_counter() - t0
        got = res.dist[0] if res.dist.ndim == 2 else res.dist
        ok = np.allclose(np.where(np.isfinite(ref), ref, 1e30),
                         np.where(np.isfinite(got), got, 1e30), rtol=1e-5)
        print(f"  {engine:18s}: {dt:.5f}s  verify={'OK' if ok else 'FAIL'}")
        assert ok, engine
    print("all engines agree with the oracle")


if __name__ == "__main__":
    main()
