"""Dynamic-graph demo: one graph, interleaved inserts/deletes/queries.

Walks the mutation API end to end on a single road-network-ish graph:
an initial solve, then a handful of edge inserts, weight updates, and
deletes — including disconnecting and reconnecting a region — each
committed as a mutation batch and repaired incrementally
(dynamic/repair.py), with every repaired distance row spot-checked
**bitwise** against a fresh ``serial`` solve on the mutated snapshot.
The same graph is then registered in the serving stack to show the
mutation tick + selective cache reconciliation in action.

    PYTHONPATH=src python examples/sssp_dynamic_demo.py
"""
import numpy as np

from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.dynamic import DynamicGraph, repair_sssp, solve_dynamic
from repro.serve import DistanceCache, GraphRegistry, MicroBatchScheduler

SOURCE = 0


def check(dyn, res, label):
    ref = shortest_paths(dyn.snapshot(), SOURCE, engine="serial")
    assert np.array_equal(res.dist, ref.dist), f"{label}: dist mismatch"
    assert np.array_equal(res.pred, ref.pred), f"{label}: pred mismatch"
    reach = int(np.isfinite(res.dist).sum())
    print(f"  {label:28s} == serial on snapshot "
          f"(v{dyn.version}, {reach}/{dyn.n} reachable, "
          f"{res.edges_relaxed} edges relaxed)")


def main():
    cg = C.random_csr_graph(500, 1500, seed=7)
    dyn = DynamicGraph(cg, overlay_capacity=64, compact_threshold=48)
    res = solve_dynamic(dyn, SOURCE)
    print(f"graph: n={dyn.n}, live arcs={dyn.nnz_live}, "
          f"initial solve {res.edges_relaxed} edges relaxed")
    check(dyn, res, "initial solve")

    # a batch of inserts: new shortcuts lower a few rows
    dyn.add_edge(3, 441, 0.9)
    dyn.add_edge(17, 202, 2.5)
    res, stats = repair_sssp(dyn, res, dyn.commit())
    check(dyn, res, "2 inserts")

    # weight updates in both directions (decrease seeds, increase cones)
    some = [(u, v) for (u, v) in [(3, 441), (17, 202)]]
    dyn.update_edge(*some[0], 55.0)        # increase: invalidates a cone
    dyn.update_edge(*some[1], 0.4)         # decrease: seeds a frontier
    res, stats = repair_sssp(dyn, res, dyn.commit())
    print(f"    (cone {stats.cone}, seeds {stats.seeds}, "
          f"updates {stats.updates})")
    check(dyn, res, "increase + decrease")

    # delete the source's own tree edges until part of the graph falls off
    cut = [v for v in np.nonzero(res.pred == SOURCE)[0].tolist()]
    for v in cut:
        dyn.delete_edge(SOURCE, v)
    res, stats = repair_sssp(dyn, res, dyn.commit())
    print(f"    (cut {len(cut)} tree edges at the source, "
          f"cone {stats.cone})")
    check(dyn, res, f"delete {len(cut)} tree edges")

    # reconnect with one cheap highway
    far = int(np.argmax(np.where(np.isfinite(res.dist), -1.0,
                                 np.arange(dyn.n, dtype=float))))
    if not np.isfinite(res.dist[far]):
        dyn.add_edge(SOURCE, far, 1.0)
        res, _ = repair_sssp(dyn, res, dyn.commit())
        check(dyn, res, "reconnect via new edge")

    print(f"overlay {dyn.overlay_used}/{dyn.overlay_capacity} live arcs, "
          f"{dyn.compactions} compactions so far")

    # the serving stack on the same mutable graph
    registry = GraphRegistry()
    sched = MicroBatchScheduler(registry, DistanceCache(64), max_batch=8)
    registry.register("road", dyn, landmarks=4)
    for s in (2, 9, 2, 31):
        sched.submit("road", s)
    sched.drain()
    sched.submit_mutation("road", "add", 2, 490, 1.25)
    sched.submit("road", 2)                # same tick: post-mutation answer
    (ack, ans) = sched.tick()
    assert ack.via == "mutate" and ans.query.source == 2
    ref = shortest_paths(dyn.snapshot(), 2, engine="serial").dist
    assert np.array_equal(ans.value, ref)
    s = sched.stats()
    print(f"serving: mutation tick ok (via {ans.via!r}, version "
          f"{registry.get('road').version}); cache rows kept "
          f"{s['rows_kept']}, repaired {s['rows_repaired']}, "
          f"invalidated {s['rows_invalidated']}")
    print("all repaired rows bitwise-equal to serial on the mutated graph")


if __name__ == "__main__":
    main()
