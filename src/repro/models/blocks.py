"""Per-layer blocks, keyed by layer kind (see configs.base for the legend).

Each kind implements three entry points used by the stacked/scanned
transformer driver:

    init_layer(key, kind, cfg)                     -> params pytree
    apply_layer_full(p, kind, x, positions, ...)   -> (x, cache_entry, aux)
    apply_layer_decode(p, kind, x, pos, entry, ...)-> (x, new_cache_entry)
    init_cache_entry(kind, cfg, batch, max_len)    -> zeroed cache pytree

Cache entries are pytrees with uniform shapes per kind so the driver can
stack them over scan reps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as cm
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe
from repro.models.ssm import init_ssm, ssm_decode, ssm_forward

ATTN_KINDS = "GLDE"


def _is_moe(kind: str, cfg) -> bool:
    return cfg.num_experts > 0 and kind in "GL"


def _attn_statics(kind: str, cfg):
    """(causal, window, rope_theta) for an attention layer kind."""
    causal = kind != "E"
    window = cfg.sliding_window if kind == "L" else 0
    theta = (cfg.local_rope_theta if (kind == "L" and cfg.local_rope_theta)
             else cfg.rope_theta)
    return causal, window, theta


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, kind: str, cfg):
    d = cfg.d_model
    dt = cm.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    if kind in ATTN_KINDS:
        p = {
            "ln1": cm.init_rmsnorm(d, dt),
            "attn": attn.init_attention(ks[0], cfg),
            "ln2": cm.init_rmsnorm(d, dt),
        }
        p["ffn"] = (init_moe(ks[1], cfg) if _is_moe(kind, cfg)
                    else init_mlp(ks[1], cfg))
        if cfg.use_post_norms:
            p["post_ln1"] = cm.init_rmsnorm(d, dt)
            p["post_ln2"] = cm.init_rmsnorm(d, dt)
        return p
    if kind == "C":      # cross-attention layer (VLM)
        return {
            "ln1": cm.init_rmsnorm(d, dt),
            "xattn": attn.init_attention(ks[0], cfg, cross=True),
            "ln2": cm.init_rmsnorm(d, dt),
            "ffn": init_mlp(ks[1], cfg),
            "gate_ffn": jnp.zeros((), dt),
        }
    if kind == "X":      # decoder layer: self + cross (enc-dec)
        return {
            "ln1": cm.init_rmsnorm(d, dt),
            "attn": attn.init_attention(ks[0], cfg),
            "lnx": cm.init_rmsnorm(d, dt),
            "xattn": attn.init_attention(ks[1], cfg),
            "ln2": cm.init_rmsnorm(d, dt),
            "ffn": init_mlp(ks[2], cfg),
        }
    if kind in "MS":     # mamba2 (S: + shared attn block applied after)
        return {"ln": cm.init_rmsnorm(d, dt), "ssm": init_ssm(ks[0], cfg)}
    raise ValueError(f"unknown layer kind {kind!r}")


def init_shared_block(key, cfg):
    """Zamba2's weight-shared attention+FFN block (one copy for the model)."""
    d, dt = cfg.d_model, cm.dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": cm.init_rmsnorm(d, dt),
        "attn": attn.init_attention(k1, cfg),
        "ln2": cm.init_rmsnorm(d, dt),
        "ffn": init_mlp(k2, cfg),
    }


# ---------------------------------------------------------------------------
# cache entries
# ---------------------------------------------------------------------------

def init_cache_entry(kind: str, cfg, batch: int, max_len: int, dtype):
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    kv = lambda: (jnp.zeros((batch, max_len, KV, hd), dtype),
                  jnp.zeros((batch, max_len, KV, hd), dtype))
    if kind in "GLD":
        k, v = kv()
        return {"k": k, "v": v}
    if kind == "C":
        nimg = max(cfg.num_image_tokens, 1)
        return {"ck": jnp.zeros((batch, nimg, KV, hd), dtype),
                "cv": jnp.zeros((batch, nimg, KV, hd), dtype)}
    if kind == "X":
        k, v = kv()
        T = max_len // cfg.audio_downsample
        return {"k": k, "v": v,
                "ck": jnp.zeros((batch, T, KV, hd), dtype),
                "cv": jnp.zeros((batch, T, KV, hd), dtype)}
    if kind in "MS":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        e = {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
             "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                 cfg.ssm_state), jnp.float32)}
        if kind == "S":
            k, v = kv()
            e["sk"], e["sv"] = k, v
        return e
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# full-sequence application (train / prefill)
# ---------------------------------------------------------------------------

def _sandwich(p, name, y, cfg):
    if cfg.use_post_norms:
        return cm.rmsnorm(y, p[name], cfg.norm_eps)
    return y


def _write_full_kv(entry, k, v, names=("k", "v")):
    """Fill the cache's first S positions with the prefill K/V."""
    S = k.shape[1]
    entry = dict(entry)
    entry[names[0]] = entry[names[0]].at[:, :S].set(
        k.astype(entry[names[0]].dtype))
    entry[names[1]] = entry[names[1]].at[:, :S].set(
        v.astype(entry[names[1]].dtype))
    return entry


def apply_layer_full(p, kind: str, x, positions, cfg, *,
                     ctx=None, shared=None, entry=None, q_chunk=0):
    """Returns (x, cache_entry_or_None, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ATTN_KINDS:
        causal, window, theta = _attn_statics(kind, cfg)
        h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, (k, v) = attn.self_attention(
            p["attn"], h, positions, cfg, causal=causal, window=window,
            theta=theta, q_chunk=q_chunk)
        x = x + _sandwich(p, "post_ln1", y, cfg)
        h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if _is_moe(kind, cfg):
            y, aux = moe(p["ffn"], h, cfg)
        else:
            y = mlp(p["ffn"], h, cfg)
        x = x + _sandwich(p, "post_ln2", y, cfg)
        if entry is not None and kind != "E":
            entry = _write_full_kv(entry, k, v)
        return x, entry, aux

    if kind == "C":
        img = ctx["image_embeds"]
        ck, cv = attn.cross_kv(p["xattn"], img, cfg)
        h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + attn.cross_attention(p["xattn"], h, positions, (ck, cv), cfg,
                                     q_chunk=q_chunk)
        h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
        g = jnp.tanh(p["gate_ffn"].astype(jnp.float32)).astype(x.dtype)
        x = x + g * mlp(p["ffn"], h, cfg)
        if entry is not None:
            entry = dict(entry, ck=ck, cv=cv)
        return x, entry, aux

    if kind == "X":
        enc = ctx["encoder_out"]
        h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, (k, v) = attn.self_attention(
            p["attn"], h, positions, cfg, causal=True, window=0,
            theta=cfg.rope_theta, q_chunk=q_chunk)
        x = x + y
        ck, cv = attn.cross_kv(p["xattn"], enc, cfg)
        h = cm.rmsnorm(x, p["lnx"], cfg.norm_eps)
        x = x + attn.cross_attention(p["xattn"], h, positions, (ck, cv), cfg,
                                     q_chunk=q_chunk)
        h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg)
        if entry is not None:
            entry = _write_full_kv(entry, k, v)
            entry = dict(entry, ck=ck, cv=cv)
        return x, entry, aux

    if kind in "MS":
        h = cm.rmsnorm(x, p["ln"], cfg.norm_eps)
        y, (conv_tail, state) = ssm_forward(p["ssm"], h, cfg)
        x = x + y
        new_entry = None
        if entry is not None:
            new_entry = dict(entry, conv=conv_tail, state=state)
        if kind == "S":
            h = cm.rmsnorm(x, shared["ln1"], cfg.norm_eps)
            y, (k, v) = attn.self_attention(
                shared["attn"], h, positions, cfg, causal=True, window=0,
                theta=cfg.rope_theta, q_chunk=q_chunk)
            x = x + y
            h = cm.rmsnorm(x, shared["ln2"], cfg.norm_eps)
            x = x + mlp(shared["ffn"], h, cfg)
            if entry is not None:
                new_entry = _write_full_kv(new_entry, k, v, names=("sk", "sv"))
        return x, new_entry, aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# single-token decode
# ---------------------------------------------------------------------------

def apply_layer_decode(p, kind: str, x, pos, entry, cfg, *,
                       ctx=None, shared=None):
    """x: (B, 1, d); pos: (B,).  Returns (x, new_entry)."""
    if kind in "GLD":
        _, window, theta = _attn_statics(kind, cfg)
        h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, ck, cv = attn.decode_self_attention(
            p["attn"], h, pos, entry["k"], entry["v"], cfg,
            window=window, theta=theta)
        x = x + _sandwich(p, "post_ln1", y, cfg)
        h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if _is_moe(kind, cfg):
            y, _ = moe(p["ffn"], h, cfg)
        else:
            y = mlp(p["ffn"], h, cfg)
        x = x + _sandwich(p, "post_ln2", y, cfg)
        return x, dict(entry, k=ck, v=cv)

    if kind == "C":
        h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
        x = x + attn.cross_attention(p["xattn"], h, pos[:, None],
                                     (entry["ck"], entry["cv"]), cfg)
        h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
        g = jnp.tanh(p["gate_ffn"].astype(jnp.float32)).astype(x.dtype)
        x = x + g * mlp(p["ffn"], h, cfg)
        return x, entry

    if kind == "X":
        h = cm.rmsnorm(x, p["ln1"], cfg.norm_eps)
        y, ck_, cv_ = attn.decode_self_attention(
            p["attn"], h, pos, entry["k"], entry["v"], cfg,
            window=0, theta=cfg.rope_theta)
        x = x + y
        h = cm.rmsnorm(x, p["lnx"], cfg.norm_eps)
        x = x + attn.cross_attention(p["xattn"], h, pos[:, None],
                                     (entry["ck"], entry["cv"]), cfg)
        h = cm.rmsnorm(x, p["ln2"], cfg.norm_eps)
        x = x + mlp(p["ffn"], h, cfg)
        return x, dict(entry, k=ck_, v=cv_)

    if kind in "MS":
        h = cm.rmsnorm(x, p["ln"], cfg.norm_eps)
        y, conv, state = ssm_decode(p["ssm"], h, cfg,
                                    entry["conv"], entry["state"])
        x = x + y
        new_entry = dict(entry, conv=conv, state=state)
        if kind == "S":
            h = cm.rmsnorm(x, shared["ln1"], cfg.norm_eps)
            y, sk, sv = attn.decode_self_attention(
                shared["attn"], h, pos, entry["sk"], entry["sv"], cfg,
                window=0, theta=cfg.rope_theta)
            x = x + y
            h = cm.rmsnorm(x, shared["ln2"], cfg.norm_eps)
            x = x + mlp(shared["ffn"], h, cfg)
            new_entry = dict(new_entry, sk=sk, sv=sv)
        return x, new_entry
    raise ValueError(kind)
