"""Mixture-of-Experts FFN with group-local sort-based capacity dispatch.

Design (see DESIGN.md §5): the classic GShard one-hot dispatch einsum costs
O(T·E·C·d) matmul FLOPs for what is really a gather, which would poison the
roofline's useful-FLOP ratio, and a *global* argsort over all tokens makes
the SPMD partitioner serialize routing through all-gathers.  Instead tokens
are split into G groups aligned with the data-parallel shards (GShard's
"groups", MaxText's dropping implementation): routing, stable argsort,
position-in-expert and capacity dropping are all computed *within* a group,
so under GSPMD every routing op stays shard-local:

    top-k ids -> per-group argsort -> position-in-expert
    -> (G, E, C, d) buffer scatter -> grouped expert einsums
    -> weighted scatter-add back, partial-summed over the expert axis.

All shapes are static; tokens past an expert's per-group capacity C are
dropped (scatter mode="drop"), matching capacity-factor semantics.  Expert
weights are (E, d, ff): EP shards the leading axis over "model" (kimi:
384/16) and the rules engine falls back to sharding ff when E is
indivisible (qwen2: 60).

Shared experts (DeepSeek/Qwen-MoE style) are a fused always-on SwiGLU of
width num_shared · moe_d_ff.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.mlp import init_mlp, mlp
from repro.core._compat import get_abstract_mesh, shard_map as _shard_map
from repro.sharding.rules import constrain, dp_size


def _padded_experts(cfg) -> int:
    return max(cfg.num_experts, cfg.expert_pad_to)


def init_moe(key, cfg):
    E, d, ff = _padded_experts(cfg), cfg.d_model, cfg.moe_d_ff
    dt = cm.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": cm.dense_init(ks[0], (d, cfg.num_experts), jnp.float32),
        "wi_gate": cm.dense_init(ks[1], (E, d, ff), dt, fan_in=d),
        "wi_up": cm.dense_init(ks[2], (E, d, ff), dt, fan_in=d),
        "wo": cm.dense_init(ks[3], (E, ff, d), dt, fan_in=ff),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.num_shared_experts * ff)
    return p


def _capacity(Tg: int, cfg) -> int:
    c = int(cfg.capacity_factor * Tg * cfg.moe_top_k / max(cfg.num_experts, 1))
    return max(8, -(-c // 8) * 8)  # round up to 8


def _num_groups(T: int) -> int:
    """Dispatch groups = data-parallel shards (1 off-mesh), so per-group
    routing is local to a shard."""
    g = dp_size()
    while g > 1 and T % g:
        g //= 2
    return max(g, 1)


def moe(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatches to the explicit expert-parallel shard_map implementation
    when cfg.moe_impl == "ep" and the ambient mesh has a "model" axis that
    divides the (padded) expert count; otherwise the GSPMD grouped path.
    """
    if cfg.moe_impl == "ep":
        am = _ambient_mesh()
        T_loc = (x.shape[0] * x.shape[1]) // max(dp_size(), 1)
        # decode-sized token counts (T_loc of a few) don't amortize the
        # per-layer combine psum — measured slower (EXPERIMENTS.md §Perf,
        # kimi decode_32k: 3.48s gspmd vs 5.15s ep); keep gspmd there.
        if (am is not None and "model" in am.axis_names
                and _padded_experts(cfg) % am.shape["model"] == 0
                and T_loc >= 1024):
            return moe_ep(p, x, cfg, am)
    return moe_gspmd(p, x, cfg)


def _ambient_mesh():
    return get_abstract_mesh()


def moe_gspmd(p, x, cfg):
    B, S, d = x.shape
    E, k = _padded_experts(cfg), cfg.moe_top_k
    T = B * S
    G = _num_groups(T)
    Tg = T // G
    C = _capacity(Tg, cfg)
    xt = constrain(x.reshape(G, Tg, d), "tokens_grouped")

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)          # (G, Tg, E_real)
    if E > cfg.num_experts:                          # padded (dead) experts
        probs = jnp.pad(probs, ((0, 0), (0, 0), (0, E - cfg.num_experts)))
    w, ids = jax.lax.top_k(probs, k)                           # (G, Tg, k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)                 # renormalize

    # ---- group-local sort-based dispatch -------------------------------
    flat_ids = ids.reshape(G, Tg * k)
    order = jnp.argsort(flat_ids, axis=-1, stable=True)        # (G, Tg*k)
    sorted_e = jnp.take_along_axis(flat_ids, order, axis=-1)
    counts = jax.vmap(lambda f: jnp.bincount(f, length=E))(flat_ids)
    starts = jnp.cumsum(counts, axis=-1) - counts              # (G, E)
    pos_in_e = (jnp.arange(Tg * k, dtype=jnp.int32)[None, :]
                - jnp.take_along_axis(starts, sorted_e, axis=-1))
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)     # OOB -> drop
    token_of = order // k

    def scatter_group(xg, slot_g, tok_g):
        return jnp.zeros((E * C, d), x.dtype).at[slot_g].set(
            xg[tok_g], mode="drop")

    buf = jax.vmap(scatter_group)(xt, slot, token_of)          # (G, E*C, d)
    h = constrain(buf.reshape(G, E, C, d), "moe_buffer")

    # ---- expert FFN (grouped einsum over E) ----------------------------
    gte = jnp.einsum("gecd,edf->gecf", h, p["wi_gate"],
                     preferred_element_type=jnp.float32)
    u = jnp.einsum("gecd,edf->gecf", h, p["wi_up"],
                   preferred_element_type=jnp.float32)
    act = constrain((jax.nn.silu(gte) * u).astype(x.dtype), "moe_buffer")
    y = jnp.einsum("gecf,efd->gecd", act, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    yflat = y.reshape(G, E * C, d)

    # ---- combine --------------------------------------------------------
    w_sorted = jnp.take_along_axis(w.reshape(G, Tg * k), order, axis=-1)

    def combine_group(yg, slot_g, tok_g, wg, keep_g):
        gathered = jnp.take(yg, jnp.minimum(slot_g, E * C - 1), axis=0)
        contrib = gathered * (wg * keep_g).astype(yg.dtype)[:, None]
        return jnp.zeros((Tg, d), yg.dtype).at[tok_g].add(contrib)

    out = jax.vmap(combine_group)(yflat, slot, token_of, w_sorted, keep)
    out = constrain(out, "tokens_grouped")

    # ---- aux load-balancing loss (Switch eq. 4, global) -----------------
    frac_tokens = jnp.sum(counts, axis=0).astype(jnp.float32) / (T * k)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = (cfg.num_experts * jnp.sum(frac_tokens * mean_prob)
           * cfg.router_aux_weight)

    if "shared" in p:
        out = out + mlp(p["shared"], xt, cfg)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# explicit expert-parallel implementation (shard_map over the "model" axis)
# ---------------------------------------------------------------------------
#
# Key structural fact: between TP layers the hidden states are *replicated*
# over the model axis (batch is sharded over dp only), so every model-rank
# already holds all of its dp-shard's tokens.  Expert-parallelism therefore
# needs NO dispatch all-to-all at all: each rank routes identically (same
# tokens, same router), keeps only the assignments that target its local
# expert slice, runs the expert FFN locally, scatter-adds its partial
# outputs, and one psum over the model axis completes the combine.
#
# Communication per layer: ONE all-reduce of (T_loc, d) — identical to the
# Megatron dense-MLP TP all-reduce — versus the GSPMD grouped path where
# the partitioner moves (G, E, C, d)-shaped buffers (~ k×capacity_factor
# times more bytes).  This is the §Perf hillclimb for the MoE cells.

import functools as _ft

from jax import lax as _lax
from jax.sharding import PartitionSpec as _P


def moe_ep(p, x, cfg, am):
    """x: (B, S, d) replicated over "model", batch over dp axes."""
    E, k = _padded_experts(cfg), cfg.moe_top_k
    ep_size = am.shape["model"]
    E_loc = E // ep_size
    B, S, d = x.shape
    dp_axes = tuple(a for a in ("pod", "data") if a in am.axis_names)
    x_spec = _P(dp_axes if B % max(dp_size(), 1) == 0 and dp_axes else None,
                None, None)

    @_ft.partial(
        _shard_map,
        in_specs=(x_spec, _P(), _P("model"), _P("model"), _P("model")),
        out_specs=(x_spec, _P()),
        check_vma=False,
    )
    def body(x_loc, router, wig, wiu, wog):
        Bl, Sl, _ = x_loc.shape
        T = Bl * Sl
        C = _capacity(T, cfg)
        xt = x_loc.reshape(T, d)
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        if E > cfg.num_experts:
            probs = jnp.pad(probs, ((0, 0), (0, E - cfg.num_experts)))
        w, ids = jax.lax.top_k(probs, k)                     # (T, k)
        w = w / jnp.sum(w, axis=-1, keepdims=True)

        e_base = _lax.axis_index("model") * E_loc
        lids = jnp.where((ids >= e_base) & (ids < e_base + E_loc),
                         ids - e_base, E_loc)                # E_loc = drop
        flat = lids.reshape(T * k)
        order = jnp.argsort(flat, stable=True)
        sorted_e = flat[order]
        counts = jnp.bincount(flat, length=E_loc + 1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
        keep = (pos < C) & (sorted_e < E_loc)
        slot = jnp.where(keep, sorted_e * C + pos, E_loc * C)
        token_of = order // k

        buf = jnp.zeros((E_loc * C, d), x.dtype).at[slot].set(
            xt[token_of], mode="drop")
        h = buf.reshape(E_loc, C, d)
        g = jnp.einsum("ecd,edf->ecf", h, wig,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", h, wiu,
                       preferred_element_type=jnp.float32)
        act = (jax.nn.silu(g) * u).astype(x.dtype)
        y = jnp.einsum("ecf,efd->ecd", act, wog,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        yflat = y.reshape(E_loc * C, d)

        gathered = jnp.take(yflat, jnp.minimum(slot, E_loc * C - 1), axis=0)
        w_sorted = w.reshape(T * k)[order]
        contrib = gathered * (w_sorted * keep).astype(x.dtype)[:, None]
        partial = jnp.zeros((T, d), x.dtype).at[token_of].add(contrib)
        out = _lax.psum(partial, "model")                    # the combine

        # aux: aggregate routing stats globally (over dp shards) so the
        # load-balance signal matches the GSPMD path exactly; values are
        # already identical across model ranks (same tokens + router).
        cnt = jnp.bincount(ids.reshape(-1), length=E).astype(jnp.float32)
        psum_tok = jnp.sum(probs, axis=0)
        if dp_axes:
            cnt = _lax.psum(cnt, dp_axes)
            psum_tok = _lax.psum(psum_tok, dp_axes)
        T_global = T * max(dp_size(), 1)
        frac = cnt / (T_global * k)
        mean_prob = psum_tok / T_global
        aux = (cfg.num_experts * jnp.sum(frac * mean_prob)
               * cfg.router_aux_weight)
        aux = _lax.psum(aux, "model") / ep_size
        return out.reshape(Bl, Sl, d), aux

    out, aux = body(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    if "shared" in p:
        out = out + mlp(p["shared"], x, cfg)
    return out, aux
