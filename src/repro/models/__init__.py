"""Model zoo package: transformer, attention, mlp, moe, ssm, blocks."""
