"""Shared model primitives: norms, RoPE, embeddings, init, softcap.

Pure-functional JAX (no flax): params are pytrees of jnp arrays; every module
is an ``init_*(key, cfg) -> params`` plus an ``apply``-style function.  All
matmuls run in the model dtype with f32 accumulation via
``preferred_element_type``; norms and softmax statistics are f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import constrain


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, shape, dtype, fan_in=None):
    """Truncated-normal with 1/sqrt(fan_in) scale (fan_in = shape[0] default)."""
    fi = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fi, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def matmul(x, w, *, prec=None):
    """x @ w with f32 accumulation regardless of storage dtype."""
    return jnp.einsum(
        "...d,df->...f", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(x, params, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def rmsnorm_nobias(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                         # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, hd/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg):
    return {
        "tok": dense_init(
            key, (cfg.vocab_size, cfg.d_model), dtype_of(cfg),
            fan_in=cfg.d_model,
        )
    }


def embed(tokens, params, cfg):
    x = jnp.take(params["tok"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return constrain(x, "hidden")


def unembed(x, embed_params, cfg, lm_head=None):
    w = lm_head if lm_head is not None else embed_params["tok"].T
    logits = jnp.einsum(
        "...d,dv->...v", x, w, preferred_element_type=jnp.float32
    )
    return softcap(logits, cfg.logit_softcap)
