"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks of ``ssm_chunk``; within a chunk the quadratic (dual) form runs on the
VPU/MXU, between chunks a sequential state recurrence carries (H, P, N)
states — O(L·Q) work instead of O(L²), sub-quadratic as required for the
long_500k cells.  Decode is the pure recurrence: h = dA·h + dt·B⊗x.

Layout follows the reference minimal-SSD: one fused in_proj producing
[z | x | B | C | dt], causal depthwise conv over [x|B|C], gated RMSNorm
before out_proj.  Single B/C group (ngroups=1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm


def init_ssm(key, cfg):
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * N
    dt = cm.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": cm.dense_init(ks[0], (d, 2 * di + 2 * N + H), dt, fan_in=d),
        "conv_w": cm.dense_init(ks[1], (cfg.ssm_conv, conv_dim), dt,
                                fan_in=cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((di,), dt),
        "out_proj": cm.dense_init(ks[3], (di, d), dt, fan_in=di),
    }


def _split_proj(zxbcdt, cfg):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., -H:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv1d, kernel k: y[t] = sum_j w[j]*x[t-k+1+j]."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(pad[:, j:j + xBC.shape[1], :] * w[j] for j in range(k))
    return jax.nn.silu((y + b).astype(jnp.float32)).astype(xBC.dtype)


def _segsum(x):
    """(..., Q) -> (..., Q, Q): S[i, j] = sum_{j < m <= i} x[m], -inf above diag."""
    Q = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    diff = c[..., :, None] - c[..., None, :]
    i = jnp.arange(Q)
    tri = i[:, None] >= i[None, :]
    return jnp.where(tri, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk):
    """Chunked SSD scan.

    xh: (B, L, H, P); dt: (B, L, H); A: (H,); Bm, Cm: (B, L, N).
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    Bsz, L, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    dA = dt * A[None, None, :]                                # (B, L, H) <= 0
    r = lambda t: t.reshape(Bsz, nc, Q, *t.shape[2:])
    xh, dt, dA, Bm, Cm = r(xh), r(dt), r(dA), r(Bm), r(Cm)

    dAh = jnp.moveaxis(dA, -1, 2)                             # (B, nc, H, Q)
    Lmat = jnp.exp(_segsum(dAh))                              # (B, nc, H, Q, Q)

    xdt = xh * dt[..., None]                                  # dt-weighted input
    # intra-chunk (dual quadratic) term
    scores = jnp.einsum("bcln,bcsn,bchls->bchls", Cm, Bm, Lmat,
                        preferred_element_type=jnp.float32)
    Y_diag = jnp.einsum("bchls,bcshp->bclhp", scores, xdt,
                        preferred_element_type=jnp.float32)

    # per-chunk output states
    A_cum = jnp.cumsum(dAh, axis=-1)                          # (B, nc, H, Q)
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)           # (B, nc, H, Q)
    states = jnp.einsum("bcsn,bchs,bcshp->bchpn", Bm, decay_states, xdt,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence (sequential over nc chunks)
    chunk_decay = jnp.exp(A_cum[..., -1])                     # (B, nc, H)

    def scan_fn(h, inp):
        s, dec = inp                                          # (B,H,P,N),(B,H)
        h_new = h * dec[:, :, None, None] + s
        return h_new, h                                       # emit state *before* chunk

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    final, prev_states = lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (B, nc, H, P, N)

    # inter-chunk contribution
    state_decay = jnp.exp(A_cum)                              # (B, nc, H, Q)
    Y_off = jnp.einsum("bcln,bchpn,bchl->bclhp", Cm, prev_states, state_decay,
                       preferred_element_type=jnp.float32)

    y = (Y_diag + Y_off).reshape(Bsz, L, H, P)
    return y, final


def ssm_forward(p, x, cfg, *, conv_state=None, ssm_state=None):
    """Full-sequence Mamba2 forward (train / prefill).

    x: (B, L, d).  Returns (y (B, L, d), (conv_state, ssm_state)) with the
    states at sequence end (for decode continuation).
    """
    Bsz, L, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    pet_in = x.dtype if cfg.bf16_partial_reduce else jnp.float32
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"],
                        preferred_element_type=pet_in).astype(x.dtype)
    z, xBC_pre, dt_raw = _split_proj(zxbcdt, cfg)
    xBC = _causal_conv(xBC_pre, p["conv_w"], p["conv_b"])
    xh = xBC[..., :di].reshape(Bsz, L, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, final_state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                                 Bm.astype(jnp.float32),
                                 Cm.astype(jnp.float32), cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bsz, L, di).astype(x.dtype)
    y = cm.rmsnorm_nobias(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                          p["norm"], cfg.norm_eps)
    pet = x.dtype if cfg.bf16_partial_reduce else jnp.float32
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"],
                     preferred_element_type=pet).astype(x.dtype)
    k = cfg.ssm_conv
    conv_tail = xBC_pre[:, -(k - 1):]          # pre-conv tail, for decode
    return out, (conv_tail, final_state.astype(jnp.float32))


def ssm_decode(p, x, cfg, conv_state, ssm_state):
    """One-token recurrence.  x: (B, 1, d); conv_state: (B, k-1, conv_dim);
    ssm_state: (B, H, P, N).  Returns (y, new_conv_state, new_ssm_state)."""
    Bsz, _, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bld,de->ble", x, p["in_proj"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    z, xBC_new, dt_raw = _split_proj(zxbcdt, cfg)
    # roll conv state
    window = jnp.concatenate([conv_state, xBC_new], axis=1)   # (B, k, conv)
    w, b = p["conv_w"], p["conv_b"]
    y_conv = jnp.einsum("bkc,kc->bc", window, w) + b
    xBC = jax.nn.silu(y_conv.astype(jnp.float32)).astype(x.dtype)
    xh = xBC[..., :di].reshape(Bsz, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                             # (B, H)
    h = ssm_state * dA[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), xh.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + xh.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, di).astype(x.dtype)
    y = cm.rmsnorm_nobias(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                          p["norm"], cfg.norm_eps)
    pet = x.dtype if cfg.bf16_partial_reduce else jnp.float32
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"],
                     preferred_element_type=pet).astype(x.dtype)
    return out, window[:, 1:], h
