"""Dense FFN: SwiGLU (gate ⊙ up -> down), the FFN used by every assigned arch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.sharding.rules import constrain


def init_mlp(key, cfg, d_ff=None):
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    dt = cm.dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": cm.dense_init(k1, (d, ff), dt),
        "wi_up": cm.dense_init(k2, (d, ff), dt),
        "wo": cm.dense_init(k3, (ff, d), dt, fan_in=ff),
    }


def mlp(p, x, cfg=None):
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("...d,df->...f", x, p["wi_up"],
                   preferred_element_type=jnp.float32)
    h = constrain((jax.nn.silu(g) * u).astype(x.dtype), "ffh")
    pet = (x.dtype if (cfg is not None and cfg.bf16_partial_reduce)
           else jnp.float32)
    return jnp.einsum("...f,fd->...d", h, p["wo"],
                      preferred_element_type=pet).astype(x.dtype)
