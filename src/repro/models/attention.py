"""Attention: GQA, RoPE, sliding window, softcap, QK-norm, QKV bias,
cross-attention, and a KV cache for decode.

Grouped-query attention never materializes repeated KV heads: scores are a
grouped einsum ``(B,S,KV,G,hd) x (B,T,KV,hd)``, so decode reads each cached
KV byte exactly once (the decode roofline is KV-cache traffic).

``q_chunk`` bounds training/prefill memory: the query axis is processed in
``lax.scan`` chunks so the live score tensor is (B, H, q_chunk, T) instead
of (B, H, S, T) — this is what lets prefill_32k compile inside a 16 GB HBM
budget (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as cm
from repro.sharding.rules import constrain, tp_size

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_attention(key, cfg, *, cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cm.dtype_of(cfg)
    ks = jax.random.split(key, 8)
    p = {
        "wq": cm.dense_init(ks[0], (d, H, hd), dt, fan_in=d),
        "wk": cm.dense_init(ks[1], (d, KV, hd), dt, fan_in=d),
        "wv": cm.dense_init(ks[2], (d, KV, hd), dt, fan_in=d),
        "wo": cm.dense_init(ks[3], (H, hd, d), dt, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    if cross:
        p["gate"] = jnp.zeros((), dt)   # llama3.2-vision tanh gate
    return p


# ---------------------------------------------------------------------------
# core attend
# ---------------------------------------------------------------------------

def _mask(q_pos, k_pos, k_valid, *, causal, window):
    """(B, Sq, Tk) additive mask from positions."""
    qp = q_pos[:, :, None]        # (B, Sq, 1)
    kp = k_pos[:, None, :]        # (B, 1, Tk)
    ok = k_valid[:, None, :]
    if causal:
        ok = ok & (kp <= qp)
    if window > 0:
        ok = ok & (kp > qp - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_block(q, k, v, mask, attn_softcap, scale):
    """q: (B,Sq,KV,G,hd); k/v: (B,Tk,KV,hd); mask: (B,Sq,Tk) -> (B,Sq,KV,G,hd)."""
    B, Sq, KV, G, hd = q.shape
    # §Perf hillclimb (EXPERIMENTS.md): when the KV-head count cannot shard
    # over the model axis but the full head count can (kimi 8→64 heads on a
    # 16-way axis), expand K/V to merged heads so scores shard 16-way on
    # heads — otherwise the scores rule falls back to key-axis sharding
    # whose *backward* re-gathers f32 score tensors (4.2 TB/step for kimi).
    tp = tp_size()
    if Sq > 1 and G > 1 and KV % tp != 0 and (KV * G) % tp == 0:
        H, Tk = KV * G, k.shape[1]
        kh = jnp.broadcast_to(k[:, :, :, None, :],
                              (B, Tk, KV, G, hd)).reshape(B, Tk, H, hd)
        vh = jnp.broadcast_to(v[:, :, :, None, :],
                              (B, Tk, KV, G, hd)).reshape(B, Tk, H, hd)
        qh = q.reshape(B, Sq, H, hd)
        s = jnp.einsum("bshd,bthd->bhst", qh, kh,
                       preferred_element_type=jnp.float32) * scale
        s = constrain(s, "scores_h")
        if attn_softcap > 0.0:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        s = s + mask[:, None, :, :]
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhst,bthd->bshd", p, vh,
                         preferred_element_type=jnp.float32).astype(v.dtype)
        return out.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum(
        "bskgd,btkd->bkgst", q, k, preferred_element_type=jnp.float32
    ) * scale
    s = constrain(s, "scores")
    if attn_softcap > 0.0:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    s = s + mask[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", p, v,
                      preferred_element_type=jnp.float32).astype(v.dtype)


def attend(q, k, v, *, q_pos, k_pos, k_valid, causal, window,
           attn_softcap=0.0, q_chunk=0):
    """q: (B,Sq,H,hd); k,v: (B,Tk,KV,hd).  Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd)

    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0:
        n_chunk = Sq // q_chunk
        qs = qg.reshape(B, n_chunk, q_chunk, KV, G, hd)
        qps = q_pos.reshape(B, n_chunk, q_chunk)

        # checkpoint: scores/probs for a chunk are recomputed in the
        # backward pass instead of being stacked as scan residuals —
        # (n_chunk, B, H, cq, S) f32 would dominate training memory
        # (flash-attention's memory behavior, exact same numerics).
        @jax.checkpoint
        def body(_, xs):
            qc, qpc = xs                       # (B,cq,KV,G,hd), (B,cq)
            m = _mask(qpc, k_pos, k_valid, causal=causal, window=window)
            return None, _attend_block(qc, k, v, m, attn_softcap, scale)

        _, outs = lax.scan(body, None,
                           (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(qps, 1, 0)))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, hd)
    else:
        m = _mask(q_pos, k_pos, k_valid, causal=causal, window=window)
        out = _attend_block(qg, k, v, m, attn_softcap, scale)
    return out.reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# full layers
# ---------------------------------------------------------------------------

def _project_q(p, x, cfg, positions, theta, *, rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"]
    if cfg.qk_norm:
        q = cm.rmsnorm_nobias(q, p["q_norm"], cfg.norm_eps)
    if rope:
        q = cm.apply_rope(q, positions, theta)
    return constrain(q, "heads")


def _project_kv(p, x, cfg, positions, theta, *, rope=True):
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        k = cm.rmsnorm_nobias(k, p["k_norm"], cfg.norm_eps)
    if rope:
        k = cm.apply_rope(k, positions, theta)
    return constrain(k, "heads"), constrain(v, "heads")


def _out_proj(p, ctx, cfg=None):
    pet = (ctx.dtype if (cfg is not None and cfg.bf16_partial_reduce)
           else jnp.float32)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"],
                     preferred_element_type=pet).astype(ctx.dtype)
    return constrain(out, "hidden")


def self_attention(p, x, positions, cfg, *, causal, window, theta,
                   q_chunk=0):
    """Full-sequence self-attention (train / prefill).

    Returns (out (B,S,d), (k, v)) — k/v handed back for cache fill.
    """
    q = _project_q(p, x, cfg, positions, theta)
    k, v = _project_kv(p, x, cfg, positions, theta)
    valid = jnp.ones(positions.shape, jnp.bool_)
    ctx = attend(q, k, v, q_pos=positions, k_pos=positions, k_valid=valid,
                 causal=causal, window=window,
                 attn_softcap=cfg.attn_softcap, q_chunk=q_chunk)
    return _out_proj(p, ctx, cfg), (k, v)


def decode_self_attention(p, x, pos, cache_k, cache_v, cfg, *,
                          window, theta):
    """One-token decode.  x: (B,1,d); pos: (B,) write index;
    cache_k/v: (B,S,KV,hd).  Returns (out, new_cache_k, new_cache_v)."""
    B, S = cache_k.shape[0], cache_k.shape[1]
    q = _project_q(p, x, cfg, pos[:, None], theta)
    k_new, v_new = _project_kv(p, x, cfg, pos[:, None], theta)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, pos].set(k_new[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, pos].set(v_new[:, 0].astype(cache_v.dtype))
    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=pos.dtype)[None, :], (B, S))
    valid = k_pos <= pos[:, None]
    ctx = attend(q, cache_k, cache_v, q_pos=pos[:, None], k_pos=k_pos,
                 k_valid=valid, causal=True, window=window,
                 attn_softcap=cfg.attn_softcap)
    return _out_proj(p, ctx, cfg), cache_k, cache_v


def cross_attention(p, x, positions, ctx_kv, cfg, *, q_chunk=0):
    """Cross-attention to precomputed context K/V (vision / encoder).

    ctx_kv: (k, v) each (B, T_ctx, KV, hd) — computed once via
    ``cross_kv``; no RoPE on either side (positionless context).
    Output is tanh-gated (llama3.2-vision style) when a gate param exists.
    """
    q = _project_q(p, x, cfg, positions, theta=1.0, rope=False)
    k, v = ctx_kv
    B, T = k.shape[0], k.shape[1]
    k_pos = jnp.zeros((B, T), positions.dtype)
    valid = jnp.ones((B, T), jnp.bool_)
    ctx = attend(q, k, v, q_pos=positions, k_pos=k_pos, k_valid=valid,
                 causal=False, window=0, attn_softcap=cfg.attn_softcap,
                 q_chunk=q_chunk)
    out = _out_proj(p, ctx, cfg)
    if "gate" in p:
        out = out * jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype)
    return out


def cross_kv(p, ctx_x, cfg):
    """Project context embeddings to K/V once (cached across decode steps)."""
    return _project_kv(p, ctx_x, cfg, positions=None, theta=1.0, rope=False)
