"""Stacked-layer LM driver: init / train forward / prefill / decode.

The stack is a list of segments (pattern, n_rep); parameters inside a
segment are stacked over reps and the pass is a ``lax.scan`` with the
pattern unrolled inside the body, so HLO is O(pattern length) regardless of
depth (61-layer Kimi-K2 lowers as one scanned body + one unrolled layer).

One driver covers all six assigned families:
  dense / moe        decoder-only segments (G/L/D kinds)
  ssm / hybrid       M/S kinds (+ the Zamba2 weight-shared attention block)
  vlm                C kinds cross-attending to stub image embeddings
  audio (enc-dec)    encoder_segments (E) + decoder segments (X)

Caches are nested tuples: caches[seg][pos] = entry pytree with leading
(n_rep, ...) — carried through decode scans, filled by prefill.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import blocks as B
from repro.models import common as cm
from repro.sharding.rules import constrain

REMAT_POLICIES = {
    "none": None,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "full": jax.checkpoint_policies.nothing_saveable,
}


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_segment(key, pat: str, n_rep: int, cfg):
    per_pos = []
    for i, kind in enumerate(pat):
        keys = jax.random.split(jax.random.fold_in(key, i), n_rep)
        stacked = jax.vmap(lambda k, kd=kind: B.init_layer(k, kd, cfg))(keys)
        per_pos.append(stacked)
    return tuple(per_pos)


def init_params(key, cfg) -> dict:
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": cm.init_embed(ks[0], cfg),
        "final_ln": cm.init_rmsnorm(cfg.d_model, cm.dtype_of(cfg)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(
            ks[1], (cfg.d_model, cfg.vocab_size), cm.dtype_of(cfg))
    params["segments"] = tuple(
        _init_segment(jax.random.fold_in(ks[2], i), pat, rep, cfg)
        for i, (pat, rep) in enumerate(cfg.segments)
    )
    if any("S" in pat for pat, _ in cfg.segments):
        params["shared"] = B.init_shared_block(ks[3], cfg)
    if cfg.encoder_segments:
        params["enc_segments"] = tuple(
            _init_segment(jax.random.fold_in(ks[4], i), pat, rep, cfg)
            for i, (pat, rep) in enumerate(cfg.encoder_segments)
        )
        params["enc_final_ln"] = cm.init_rmsnorm(cfg.d_model, cm.dtype_of(cfg))
    return params


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    caches = []
    for pat, n_rep in cfg.segments:
        seg = []
        for kind in pat:
            e = B.init_cache_entry(kind, cfg, batch, max_len, dtype)
            seg.append(jax.tree.map(
                lambda a: jnp.zeros((n_rep,) + a.shape, a.dtype), e))
        caches.append(tuple(seg))
    return tuple(caches)


# ---------------------------------------------------------------------------
# stack runners
# ---------------------------------------------------------------------------

def _auto_q_chunk(S: int) -> int:
    if S >= 4_096:
        return 512
    return 0


def _run_stack_full(segments_cfg, seg_params, x, positions, cfg, *,
                    ctx, shared, caches, q_chunk, remat):
    """Train (caches=None) or prefill (caches given) pass over all segments."""
    policy = REMAT_POLICIES.get(remat)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, (pat, n_rep) in enumerate(segments_cfg):
        p_seg = seg_params[si]
        c_seg = None if caches is None else caches[si]

        def body(carry, xs, pat=pat):
            x = carry
            if caches is None:
                p_slice, c_slice = xs, [None] * len(pat)
            else:
                p_slice, c_slice = xs
            entries, aux_acc = [], jnp.zeros((), jnp.float32)
            for i, kind in enumerate(pat):
                x, entry, aux = B.apply_layer_full(
                    jax.tree.map(lambda a: a, p_slice[i]), kind, x, positions,
                    cfg, ctx=ctx, shared=shared, entry=c_slice[i],
                    q_chunk=q_chunk)
                entries.append(entry)
                aux_acc = aux_acc + aux
            x = constrain(x, "hidden")
            out = (tuple(entries), aux_acc) if caches is not None else aux_acc
            return x, out

        if remat != "none":
            body = jax.checkpoint(body, policy=policy,
                                  prevent_cse=False, static_argnums=())
        xs = p_seg if caches is None else (p_seg, c_seg)
        x, outs = lax.scan(body, x, xs)
        if caches is None:
            aux_total = aux_total + jnp.sum(outs)
        else:
            entries, auxs = outs
            new_caches.append(entries)
            aux_total = aux_total + jnp.sum(auxs)
    return x, (tuple(new_caches) if caches is not None else None), aux_total


def _run_stack_decode(segments_cfg, seg_params, x, pos, caches, cfg, *,
                      ctx, shared):
    new_caches = []
    for si, (pat, n_rep) in enumerate(segments_cfg):
        p_seg, c_seg = seg_params[si], caches[si]

        def body(carry, xs, pat=pat):
            x = carry
            p_slice, c_slice = xs
            entries = []
            for i, kind in enumerate(pat):
                x, entry = B.apply_layer_decode(
                    p_slice[i], kind, x, pos, c_slice[i], cfg,
                    ctx=ctx, shared=shared)
                entries.append(entry)
            return constrain(x, "hidden"), tuple(entries)

        x, entries = lax.scan(body, x, (p_seg, c_seg))
        new_caches.append(entries)
    return x, tuple(new_caches)


def _encode(params, frames, cfg):
    """Run the encoder stack on stub frame embeddings (B, T, d)."""
    Bsz, T, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (Bsz, T))
    x, _, _ = _run_stack_full(
        cfg.encoder_segments, params["enc_segments"], frames, positions, cfg,
        ctx=None, shared=None, caches=None,
        q_chunk=_auto_q_chunk(T), remat=cfg.remat)
    return cm.rmsnorm(x, params["enc_final_ln"], cfg.norm_eps)


def _build_ctx(params, cfg, image_embeds=None, encoder_frames=None):
    ctx = {}
    if image_embeds is not None:
        ctx["image_embeds"] = image_embeds
    if encoder_frames is not None:
        ctx["encoder_out"] = _encode(params, encoder_frames, cfg)
    return ctx or None


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg, *, image_embeds=None, encoder_frames=None,
            caches=None, q_chunk=None):
    """Full forward.  Returns (hidden (B,S,d), new_caches, aux)."""
    Bsz, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (Bsz, S))
    x = cm.embed(tokens, params["embed"], cfg)
    ctx = _build_ctx(params, cfg, image_embeds, encoder_frames)
    shared = params.get("shared")
    qc = _auto_q_chunk(S) if q_chunk is None else q_chunk
    x, new_caches, aux = _run_stack_full(
        cfg.segments, params["segments"], x, positions, cfg,
        ctx=ctx, shared=shared, caches=caches, q_chunk=qc, remat=cfg.remat)
    x = cm.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    return x, new_caches, aux


def logits_from_hidden(params, x, cfg):
    return cm.unembed(x, params["embed"], cfg, params.get("lm_head"))


def lm_loss(params, x, labels, cfg):
    """Chunked cross-entropy: logits are materialized loss_chunk tokens at a
    time so the (B, S, vocab) tensor never exists (vocab 262k × 4k seq would
    be the single largest buffer in the step — see EXPERIMENTS.md §Perf)."""
    Bsz, S, d = x.shape
    chunk = cfg.loss_chunk
    valid = (labels >= 0)
    safe_labels = jnp.maximum(labels, 0)

    def ce(xc, lc, vc):
        logits = logits_from_hidden(params, xc, cfg)          # (B, c, V) f32
        logits = constrain(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - gold) * vc)

    if chunk and S > chunk and S % chunk == 0:
        nc = S // chunk
        xs = (jnp.moveaxis(x.reshape(Bsz, nc, chunk, d), 1, 0),
              jnp.moveaxis(safe_labels.reshape(Bsz, nc, chunk), 1, 0),
              jnp.moveaxis(valid.reshape(Bsz, nc, chunk), 1, 0))

        # remat: logits chunks are recomputed in the backward pass instead
        # of being saved as scan residuals (vocab-sized buffers dominate
        # otherwise — 262k vocab × 512 tokens × f32 per chunk).
        def body(tot, args):
            return tot + jax.checkpoint(ce)(*args), None

        total, _ = lax.scan(body, jnp.zeros((), jnp.float32), xs)
    else:
        total = ce(x, safe_labels, valid)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return total / denom


def train_loss(params, batch, cfg):
    """batch: dict(tokens, labels[, image_embeds, encoder_frames]).
    Returns (loss, metrics)."""
    x, _, aux = forward(
        params, batch["tokens"], cfg,
        image_embeds=batch.get("image_embeds"),
        encoder_frames=batch.get("encoder_frames"))
    loss = lm_loss(params, x, batch["labels"], cfg)
    return loss + aux, {"ce": loss, "aux": aux}


def prefill(params, tokens, cfg, *, max_len: int, image_embeds=None,
            encoder_frames=None, cache_dtype=jnp.bfloat16):
    """Fill the KV/state caches for ``tokens`` and return last-token logits.

    Returns (logits (B, vocab), caches, pos (B,))."""
    Bsz, S = tokens.shape
    caches = init_cache(cfg, Bsz, max_len, cache_dtype)
    x, caches, _ = forward(params, tokens, cfg, image_embeds=image_embeds,
                           encoder_frames=encoder_frames, caches=caches)
    logits = logits_from_hidden(params, x[:, -1:], cfg)[:, 0]
    pos = jnp.full((Bsz,), S, jnp.int32)
    return logits, caches, pos


def decode_step(params, token, pos, caches, cfg, *, image_embeds=None):
    """One serving step: token (B, 1) -> logits (B, vocab), updated caches.

    ``pos`` (B,) is the write index for this token (tokens so far).
    """
    x = cm.embed(token, params["embed"], cfg)
    ctx = {"image_embeds": image_embeds} if image_embeds is not None else None
    shared = params.get("shared")
    x, caches = _run_stack_decode(cfg.segments, params["segments"], x, pos,
                                  caches, cfg, ctx=ctx, shared=shared)
    x = cm.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = logits_from_hidden(params, x, cfg)[:, 0]
    return logits, caches, pos + 1
