"""jit'd public wrappers for the frontier_relax Pallas kernel.

``frontier_cand_block`` pads the compacted-frontier operands to the kernel
grid — sentinel ids (n) for frontier slots, INF for weight slots, both of
which produce INF candidates the scatter-min ignores — then dispatches.

``make_frontier_sweep_fn`` assembles a full frontier sweep satisfying
core/frontier.py's sweep contract: an inner ``lax.while_loop`` walks the
compacted frontier ``block_f`` rows at a time (trip count tracks the actual
frontier size), gathers each chunk's padded out-ELL windows, generates
candidates with the kernel, and scatter-mins them in XLA.  Bitwise-equal to
the flat-CSR default sweep: same candidate multiset plus INF no-ops.

On CPU (this container) ``interpret=True`` executes the kernel body in
Python; on TPU the same call lowers to Mosaic.  ``auto_interpret()`` picks
per-backend so library code stays platform-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.obs.metrics import mark_trace
from repro.kernels.common import aligned as _aligned
from repro.kernels.common import auto_interpret
from repro.kernels.common import pad_to as _pad_to
from repro.kernels.frontier_relax import kernel as K

INF = jnp.inf


@functools.partial(
    jax.jit, static_argnames=("block_f", "block_k", "interpret")
)
def frontier_cand_block(
    dist: jax.Array,
    fids: jax.Array,
    ell_w: jax.Array,
    *,
    block_f: int = 256,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Kernel-backed candidate generation for a compacted frontier chunk:
    matches ref.frontier_cand_ref bitwise.

    dist (n,), fids (F,), ell_w (F, K) -> (F, K).  Pads F up to the f-block
    (sentinel id n) and K up to the k-block (INF) internally.
    """
    if interpret is None:
        interpret = auto_interpret()
    n = dist.shape[0]
    F, Kw = ell_w.shape
    K8 = _aligned(max(Kw, 1), 8)
    if block_k is not None:
        bk = block_k
    elif K8 <= 128:
        bk = K8
    else:
        # largest 8-multiple divisor <= 128, as in csr_relax/ops.py: keeps
        # K_pad == K8 instead of force-padding to a 128 multiple.
        bk = next((d for d in range(128, 7, -8) if K8 % d == 0), 128)
    F_pad = _aligned(max(F, 1), block_f)
    K_pad = _aligned(K8, bk)
    f = _pad_to(fids, F_pad, 0, n)                   # sentinel -> INF cand
    w = _pad_to(_pad_to(ell_w, F_pad, 0, INF), K_pad, 1, INF)
    out = K.frontier_cand(
        dist, f, w, block_f=block_f, block_k=bk, interpret=interpret
    )
    return out[:F, :Kw]


@functools.lru_cache(maxsize=None)
def make_frontier_sweep_fn(*, block_f: int = 256, block_k: int | None = None,
                           interpret: bool | None = None):
    """Adapter producing the kernel-backed frontier sweep for
    core.frontier.sssp_frontier — consumes the operands' out-ELL view.

    Memoized so repeated calls return the *same* closure: ``sweep_fn`` is a
    static jit argument of the engine, and a fresh closure per call would
    retrace + recompile the whole fixpoint loop every solve.
    """

    def sweep(dist, fids, starts, off, E, fcount, ops):
        mark_trace("frontier_kernel_sweep")
        n = dist.shape[0]
        n_pad = _aligned(n, block_f)
        fpad = _pad_to(fids, n_pad, 0, jnp.int32(n))

        def cond(carry):
            _, c = carry
            return c * block_f < fcount

        def body(carry):
            nd, c = carry
            blk = lax.dynamic_slice(fpad, (c * block_f,), (block_f,))
            rows = jnp.minimum(blk, n - 1)           # sentinel -> any row;
            tgt = ops["out_ell_idx"][rows]           # its candidates are INF
            ew = ops["out_ell_w"][rows]
            cand = frontier_cand_block(
                dist, blk, ew,
                block_f=block_f, block_k=block_k, interpret=interpret,
            )
            return nd.at[tgt].min(cand), c + 1

        nd, _ = lax.while_loop(cond, body, (dist, jnp.int32(0)))
        return nd

    return sweep
