from repro.kernels.frontier_relax.ops import (frontier_cand_block,
                                              make_frontier_sweep_fn)
from repro.kernels.frontier_relax.ref import (frontier_cand_ref,
                                              frontier_relax_ref)

__all__ = [
    "frontier_cand_block",
    "make_frontier_sweep_fn",
    "frontier_cand_ref",
    "frontier_relax_ref",
]
