"""Pure-jnp oracles for the frontier_relax Pallas kernel.

Frontier relaxation is adds + mins over f32, exact like the other sweeps,
so the kernel must agree with these *bitwise* — and a full frontier sweep
assembled from the kernel must agree bitwise with the flat-CSR sweep in
core/frontier.py, since both scatter-min the same candidate multiset (the
ELL path merely adds INF no-op candidates from padding slots).
"""
from __future__ import annotations

import jax.numpy as jnp


def frontier_cand_ref(dist: jnp.ndarray, fids: jnp.ndarray,
                      ell_w: jnp.ndarray) -> jnp.ndarray:
    """Candidate block the kernel computes: (n,), (F,), (F, K) -> (F, K).

    cand[f, k] = dist[fids[f]] + ell_w[f, k], INF where fids[f] == n
    (the compaction sentinel).
    """
    n = dist.shape[0]
    df = jnp.where(fids < n, dist[jnp.minimum(fids, n - 1)], jnp.inf)
    return df[:, None] + ell_w


def frontier_relax_ref(dist: jnp.ndarray, active: jnp.ndarray,
                       out_ell_idx: jnp.ndarray,
                       out_ell_w: jnp.ndarray) -> jnp.ndarray:
    """One full frontier sweep, uncompacted: relax every out-edge of every
    active vertex against the ``dist`` snapshot.  (n,), (n,) bool, (n, K),
    (n, K) -> (n,).  Inactive rows contribute INF candidates (no-ops), so
    this is the sweep the compacted engine must reproduce bitwise.
    """
    df = jnp.where(active, dist, jnp.inf)
    cand = df[:, None] + out_ell_w                           # (n, K)
    return dist.at[out_ell_idx].min(cand)
