"""Pallas TPU kernel for frontier-compacted candidate generation.

The frontier engine (core/frontier.py) relaxes only the active vertices'
out-edges.  The streaming half of that sweep — gather each compacted
frontier vertex's distance and add it across its padded out-ELL window —
is dense, regular work over (F, K) blocks, and that is what this kernel
owns:

    cand[f, k] = dist[fids[f]] + ell_w[f, k]        (INF when fids[f] == n)

The scatter-min of ``cand`` into the destination vertices stays outside in
XLA (``.at[].min``): TPU Pallas has no scatter primitive, and XLA's native
deterministic scatter lowering is exactly the associative ``atomicMin``
replacement the other engines already rely on.  The split keeps the kernel
TPU-legal — the frontier-id gather lowers to the same Mosaic dynamic-gather
path as kernels/csr_relax's row gather — while the kernel still touches
only the compacted frontier's edge windows, never the full edge set.

Grid is (F//bf, K//bk); the dist vector rides along fully resident in VMEM
(one (1, n) block every step, as in kernels/csr_relax) and each step reads
its (1, bf) slice of frontier ids.  Sentinel ids (== n, the compaction
padding) yield INF candidates, which the scatter-min epilogue ignores.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _frontier_cand_kernel(dist_ref, fid_ref, w_ref, out_ref):
    """dist_ref: (1, n) full vector; fid_ref: (1, bf) int32 frontier ids;
    w_ref/out_ref: (bf, bk) out-ELL weight / candidate blocks."""
    d = dist_ref[...][0]                                     # (n,)
    fid = fid_ref[...][0]                                    # (bf,)
    n = d.shape[0]
    df = jnp.where(fid < n, d[jnp.minimum(fid, n - 1)], jnp.inf)
    out_ref[...] = df[:, None] + w_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_f", "block_k", "interpret")
)
def frontier_cand(
    dist: jax.Array,
    fids: jax.Array,
    ell_w: jax.Array,
    *,
    block_f: int = 256,
    block_k: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """dist[fids[f]] + ell_w[f, k] for the compacted frontier (INF past the
    sentinel).  Requires F % block_f == 0 and K % block_k == 0 (ops.py pads
    to the grid).  Returns the raw (F, K) candidate block."""
    n = dist.shape[0]
    F, K = ell_w.shape
    if block_k is None:
        block_k = K
    assert fids.shape == (F,), (fids.shape, F)
    assert F % block_f == 0 and K % block_k == 0, (F, K, block_f, block_k)
    grid = (F // block_f, K // block_k)
    out = pl.pallas_call(
        _frontier_cand_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda f, k: (0, 0)),           # full dist
            pl.BlockSpec((1, block_f), lambda f, k: (0, f)),
            pl.BlockSpec((block_f, block_k), lambda f, k: (f, k)),
        ],
        out_specs=pl.BlockSpec((block_f, block_k), lambda f, k: (f, k)),
        out_shape=jax.ShapeDtypeStruct((F, K), dist.dtype),
        interpret=interpret,
    )(dist[None, :], fids[None, :], ell_w)
    return out
