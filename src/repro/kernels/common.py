"""Helpers shared by the kernel packages: backend dispatch and INF padding
to block-aligned shapes (the paper's §III-B.2 padding trick, applied to
kernel grids instead of process counts)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def auto_interpret() -> bool:
    """Interpret the Pallas body in Python everywhere but real TPU."""
    return jax.default_backend() != "tpu"


def aligned(n: int, block: int) -> int:
    return ((n + block - 1) // block) * block


def pad_to(x: jax.Array, size: int, axis: int, fill) -> jax.Array:
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)
