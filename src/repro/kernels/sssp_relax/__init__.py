from repro.kernels.sssp_relax.ops import relax_sweep, relax_sweep_multi
from repro.kernels.sssp_relax.ref import relax_sweep_ref, relax_sweep_multi_ref

__all__ = [
    "relax_sweep",
    "relax_sweep_multi",
    "relax_sweep_ref",
    "relax_sweep_multi_ref",
]
