"""Pallas TPU kernels for blocked min-plus relaxation (paper Alg. 4, TPU-native).

The CUDA kernel gives each vertex a thread that sweeps its outgoing edges with
``atomicMin(&dist[v], dist[tid] + w)``.  TPUs have no atomics and no
free-running scalar threads; the TPU-native formulation is a *blocked min-plus
product* executed on the VPU with the adjacency matrix tiled HBM->VMEM:

    out[v]    = min_u (dist[u] + A[u, v])            (matvec,   single source)
    out[s, v] = min_u (D[s, u] + A[u, v])            (matmul,   multi source)

Grid iteration over u-blocks *replaces* atomicMin: the accumulation into the
output block is an associative min the hardware executes deterministically
(TPU grid steps over the last grid axis run sequentially on a core, so
read-modify-write of the out block across u-steps is race-free by
construction — the exact property atomicMin buys on a GPU).

Block shapes are (8k, 128k)-aligned for the VPU/VREG layout; the defaults
(256, 256) keep the three resident VMEM tiles (dist block, adj block, out
block) plus the broadcast intermediate well under 2 MiB.

Everything is validated in interpret mode on CPU against ref.py; on real TPU
the same code lowers to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# single-source: min-plus matvec
# ---------------------------------------------------------------------------

def _relax_matvec_kernel(dist_ref, adj_ref, out_ref):
    """Grid (V//bv, U//bu).  dist_ref: (1, bu); adj_ref: (bu, bv); out: (1, bv).

    The u axis is the *last* grid axis, so for a fixed v-block the u-steps run
    sequentially and accumulate with min — the TPU replacement for atomicMin.
    """
    u_step = pl.program_id(1)

    @pl.when(u_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    d = dist_ref[...][0]                                         # (bu,)
    cand = jnp.min(d[:, None] + adj_ref[...], axis=0)            # (bv,)
    out_ref[...] = jnp.minimum(out_ref[...], cand[None, :])


@functools.partial(jax.jit, static_argnames=("block_u", "block_v", "interpret"))
def relax_matvec(
    dist: jax.Array,
    adj: jax.Array,
    *,
    block_u: int = 256,
    block_v: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """min_u(dist[u] + adj[u, v]) for all v.  Requires n % block == 0.

    Returns the pure relaxation term; callers take jnp.minimum(dist, out)
    (kept outside so XLA fuses it with the surrounding while_loop body).
    """
    n = adj.shape[0]
    assert adj.shape == (n, n) and dist.shape == (n,)
    assert n % block_u == 0 and n % block_v == 0, (n, block_u, block_v)
    grid = (n // block_v, n // block_u)
    out = pl.pallas_call(
        _relax_matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_u), lambda v, u: (0, u)),   # dist u-block
            pl.BlockSpec((block_u, block_v), lambda v, u: (u, v)),
        ],
        out_specs=pl.BlockSpec((1, block_v), lambda v, u: (0, v)),
        out_shape=jax.ShapeDtypeStruct((1, n), dist.dtype),
        interpret=interpret,
    )(dist[None, :], adj)
    return out[0]


# ---------------------------------------------------------------------------
# multi-source: min-plus matmul
# ---------------------------------------------------------------------------

def _relax_matmul_kernel(D_ref, adj_ref, out_ref):
    """Grid (S//bs, V//bv, U//bu).  D: (bs, bu); adj: (bu, bv); out: (bs, bv)."""
    u_step = pl.program_id(2)

    @pl.when(u_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    # (bs, bu, 1) + (1, bu, bv) -> min over u -> (bs, bv)
    cand = jnp.min(D_ref[...][:, :, None] + adj_ref[...][None, :, :], axis=1)
    out_ref[...] = jnp.minimum(out_ref[...], cand)


@functools.partial(
    jax.jit, static_argnames=("block_s", "block_u", "block_v", "interpret")
)
def relax_matmul(
    D: jax.Array,
    adj: jax.Array,
    *,
    block_s: int = 8,
    block_u: int = 128,
    block_v: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """min_u(D[s, u] + adj[u, v]) for all (s, v).  Shapes must be aligned."""
    s, n = D.shape
    assert adj.shape == (n, n)
    assert s % block_s == 0 and n % block_u == 0 and n % block_v == 0
    grid = (s // block_s, n // block_v, n // block_u)
    return pl.pallas_call(
        _relax_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_s, block_u), lambda i, v, u: (i, u)),
            pl.BlockSpec((block_u, block_v), lambda i, v, u: (u, v)),
        ],
        out_specs=pl.BlockSpec((block_s, block_v), lambda i, v, u: (i, v)),
        out_shape=jax.ShapeDtypeStruct((s, n), D.dtype),
        interpret=interpret,
    )(D, adj)


# ---------------------------------------------------------------------------
# fused frontier variant (beyond-paper): mask non-improved rows inside the
# kernel instead of materializing a masked copy of dist in HBM.
# ---------------------------------------------------------------------------

def _relax_matvec_frontier_kernel(dist_ref, frontier_ref, adj_ref, out_ref):
    u_step = pl.program_id(1)

    @pl.when(u_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    d = jnp.where(frontier_ref[...][0], dist_ref[...][0], jnp.inf)
    cand = jnp.min(d[:, None] + adj_ref[...], axis=0)
    out_ref[...] = jnp.minimum(out_ref[...], cand[None, :])


@functools.partial(jax.jit, static_argnames=("block_u", "block_v", "interpret"))
def relax_matvec_frontier(
    dist: jax.Array,
    frontier: jax.Array,
    adj: jax.Array,
    *,
    block_u: int = 256,
    block_v: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Frontier-masked sweep: rows with frontier[u] == False contribute inf."""
    n = adj.shape[0]
    assert n % block_u == 0 and n % block_v == 0
    grid = (n // block_v, n // block_u)
    out = pl.pallas_call(
        _relax_matvec_frontier_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_u), lambda v, u: (0, u)),
            pl.BlockSpec((1, block_u), lambda v, u: (0, u)),
            pl.BlockSpec((block_u, block_v), lambda v, u: (u, v)),
        ],
        out_specs=pl.BlockSpec((1, block_v), lambda v, u: (0, v)),
        out_shape=jax.ShapeDtypeStruct((1, n), dist.dtype),
        interpret=interpret,
    )(dist[None, :], frontier[None, :], adj)
    return out[0]
