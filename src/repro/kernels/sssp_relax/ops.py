"""jit'd public wrappers for the sssp_relax Pallas kernels.

Handle INF padding to block-aligned shapes (the same trick the paper uses to
make n divisible by the process count — §III-B.2), then dispatch to the
kernel and fold the self-distance ``min(dist, ·)`` back in.

On CPU (this container) ``interpret=True`` executes the kernel body in
Python; on TPU the same call lowers to Mosaic.  ``auto_interpret()`` picks
per-backend so library code can stay platform-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.obs.metrics import mark_trace
from repro.kernels.common import aligned as _aligned
from repro.kernels.common import auto_interpret
from repro.kernels.common import pad_to as _pad_to
from repro.kernels.sssp_relax import kernel as K

INF = jnp.inf


@functools.partial(
    jax.jit, static_argnames=("block_u", "block_v", "interpret", "frontier_mode")
)
def relax_sweep(
    dist: jax.Array,
    adj: jax.Array,
    frontier: jax.Array | None = None,
    *,
    block_u: int = 256,
    block_v: int = 256,
    interpret: bool | None = None,
    frontier_mode: bool = False,
) -> jax.Array:
    """One relaxation sweep via the Pallas kernel: matches ref.relax_sweep_ref.

    dist (n,), adj (n, n) -> (n,).  Pads internally to the block grid with
    INF (padding vertices are unreachable, exactly like the paper's padded
    matrix).  If ``frontier_mode`` a boolean frontier (n,) must be passed and
    masked rows contribute nothing.
    """
    if interpret is None:
        interpret = auto_interpret()
    n = adj.shape[0]
    blk = min(block_u, block_v)
    np_ = _aligned(n, blk) if n % block_u or n % block_v else n
    bu, bv = (blk, blk) if np_ != n else (block_u, block_v)
    d = _pad_to(dist, np_, 0, INF)
    a = adj
    if np_ != n:
        a = _pad_to(_pad_to(adj, np_, 0, INF), np_, 1, INF)
    if frontier_mode:
        f = _pad_to(frontier, np_, 0, False)
        out = K.relax_matvec_frontier(
            d, f, a, block_u=bu, block_v=bv, interpret=interpret
        )
    else:
        out = K.relax_matvec(d, a, block_u=bu, block_v=bv, interpret=interpret)
    return jnp.minimum(dist, out[:n])


@functools.partial(
    jax.jit, static_argnames=("block_s", "block_u", "block_v", "interpret")
)
def relax_sweep_multi(
    D: jax.Array,
    adj: jax.Array,
    *,
    block_s: int = 8,
    block_u: int = 128,
    block_v: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched sweep: D (s, n), adj (n, n) -> (s, n).  Pads s and n."""
    if interpret is None:
        interpret = auto_interpret()
    s, n = D.shape
    sp = _aligned(s, block_s)
    blk = min(block_u, block_v)
    np_ = _aligned(n, blk) if n % block_u or n % block_v else n
    bu, bv = (blk, blk) if np_ != n else (block_u, block_v)
    Dp = _pad_to(_pad_to(D, sp, 0, INF), np_, 1, INF)
    a = adj
    if np_ != n:
        a = _pad_to(_pad_to(adj, np_, 0, INF), np_, 1, INF)
    out = K.relax_matmul(
        Dp, a, block_s=block_s, block_u=bu, block_v=bv, interpret=interpret
    )
    return jnp.minimum(D, out[:s, :n])


@functools.lru_cache(maxsize=None)
def make_sweep_fn(*, block_u: int = 256, block_v: int = 256,
                  interpret: bool | None = None):
    """Adapter producing a ``sweep_fn(dist, adj)`` for core.bellman.sssp_bellman.

    Memoized so repeated calls return the *same* closure: ``sweep_fn`` is a
    static jit argument of the engine, and a fresh closure per call would
    retrace + recompile the whole fixpoint loop every solve.
    """
    def fn(dist, adj):
        mark_trace("dense_kernel_sweep")
        return relax_sweep(
            dist, adj, block_u=block_u, block_v=block_v, interpret=interpret
        )
    return fn
