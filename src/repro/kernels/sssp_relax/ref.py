"""Pure-jnp oracles for the min-plus relaxation kernels.

These define the semantics the Pallas kernels must reproduce bit-for-bit on
finite inputs (min-plus is exact in f32: only adds and compares, no rounding
order ambiguity — min is associative and the adds are elementwise).
"""
from __future__ import annotations

import jax.numpy as jnp


def relax_sweep_ref(dist: jnp.ndarray, adj: jnp.ndarray) -> jnp.ndarray:
    """One relaxation sweep. (n,), (n, n) -> (n,).

    new[v] = min(dist[v], min_u(dist[u] + adj[u, v]))

    This is the paper's CUDA kernel (Alg. 4) as a min-plus matvec: every
    "thread" tid relaxing its row concurrently with atomicMin is, on a
    machine without atomics, an associative min-reduction over u.
    """
    return jnp.minimum(dist, jnp.min(dist[:, None] + adj, axis=0))


def relax_sweep_multi_ref(D: jnp.ndarray, adj: jnp.ndarray) -> jnp.ndarray:
    """Batched (multi-source) sweep. (s, n), (n, n) -> (s, n).

    new[s, v] = min(D[s, v], min_u(D[s, u] + adj[u, v]))

    A min-plus *matmul* — the beyond-paper batching that amortizes each
    adjacency tile load over s sources (see DESIGN.md §2).
    """
    return jnp.minimum(D, jnp.min(D[:, :, None] + adj[None, :, :], axis=1))
