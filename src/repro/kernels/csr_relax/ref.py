"""Pure-jnp oracles for the sparse (CSR/ELL) relaxation kernel.

Min-plus over an explicit edge list is exact in f32 (adds + compares only),
so the Pallas ELL kernel must agree with these *bitwise* — and both must
agree with the dense oracle (kernels/sssp_relax/ref.py) on the matching
matrix, since they enumerate the same candidate set.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_relax_ref(dist: jnp.ndarray, ell_idx: jnp.ndarray,
                  ell_w: jnp.ndarray) -> jnp.ndarray:
    """One sweep over padded-ELL rows. (n,), (n, K), (n, K) -> (n,).

    new[v] = min(dist[v], min_k dist[ell_idx[v, k]] + ell_w[v, k])

    Padding slots are (0, INF): dist[0] + INF == INF never wins.
    """
    cand = jnp.min(dist[ell_idx] + ell_w, axis=1)
    return jnp.minimum(dist, cand)


def segment_relax_ref(dist: jnp.ndarray, src_ids: jnp.ndarray,
                      dst_ids: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """One sweep as a segment-min over flat CSR arcs (the engine's O(m)
    formulation); identical candidate set as the ELL view."""
    via = dist[src_ids] + weights
    cand = jax.ops.segment_min(via, dst_ids, num_segments=dist.shape[0])
    return jnp.minimum(dist, cand)
