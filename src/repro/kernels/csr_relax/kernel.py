"""Pallas TPU kernel for padded-ELL sparse relaxation.

The dense kernel (kernels/sssp_relax) streams the whole n² matrix through
VMEM per sweep; for Table II graphs that is ~333x more data than the edges
justify.  This kernel instead tiles the **padded-ELL** edge layout
(core/csr.py): fixed-width rows of (source index, weight) pairs, so block
shapes stay static — the same role the paper's vertex padding plays for its
process grid (§III-B.2).

    out[v] = min_k ( dist[ell_idx[v, k]] + ell_w[v, k] )

Grid is (V//bv, K//bk) with K as the *last* axis: for a fixed v-block the
k-steps run sequentially on the core and accumulate with min — race-free by
construction, the same atomicMin replacement argument as the dense kernel.
The dist vector stays fully resident in VMEM (one (1, n) block every step,
n·4 bytes — fine into the millions of vertices) and rows gather from it.

Validated in interpret mode on CPU against ref.py; on real TPU the row
gather lowers to Mosaic's dynamic-gather path (one VMEM load per lane),
which is exactly the memory pattern ELL exists to keep regular.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ell_relax_kernel(dist_ref, idx_ref, w_ref, out_ref):
    """Grid (V//bv, K//bk).  dist_ref: (1, n) full vector; idx/w: (bv, bk);
    out: (1, bv), min-accumulated across the sequential k-steps."""
    k_step = pl.program_id(1)

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    d = dist_ref[...][0]                                     # (n,)
    cand = jnp.min(d[idx_ref[...]] + w_ref[...], axis=1)     # (bv,)
    out_ref[...] = jnp.minimum(out_ref[...], cand[None, :])


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_k", "interpret")
)
def ell_relax(
    dist: jax.Array,
    ell_idx: jax.Array,
    ell_w: jax.Array,
    *,
    block_v: int = 256,
    block_k: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """min_k(dist[ell_idx[v,k]] + ell_w[v,k]) for all v.  Requires
    n % block_v == 0 and K % block_k == 0 (ops.py pads to the grid).

    Returns the pure relaxation term; callers take ``jnp.minimum(dist, ·)``
    (kept outside so XLA fuses it into the surrounding while_loop body).
    """
    n = dist.shape[0]
    K = ell_idx.shape[1]
    if block_k is None:
        block_k = K
    assert ell_idx.shape == (n, K) and ell_w.shape == (n, K)
    assert n % block_v == 0 and K % block_k == 0, (n, K, block_v, block_k)
    grid = (n // block_v, K // block_k)
    out = pl.pallas_call(
        _ell_relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n), lambda v, k: (0, 0)),           # full dist
            pl.BlockSpec((block_v, block_k), lambda v, k: (v, k)),
            pl.BlockSpec((block_v, block_k), lambda v, k: (v, k)),
        ],
        out_specs=pl.BlockSpec((1, block_v), lambda v, k: (0, v)),
        out_shape=jax.ShapeDtypeStruct((1, n), dist.dtype),
        interpret=interpret,
    )(dist[None, :], ell_idx, ell_w)
    return out[0]
