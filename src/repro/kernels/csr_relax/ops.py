"""jit'd public wrappers for the csr_relax Pallas kernel.

Pad the (n, K) ELL arrays to the block grid — INF-weight slots pointing at
vertex 0 can never win a min, the same unreachable-padding argument as the
paper's padded matrix (§III-B.2) — then dispatch and fold the self-distance
``min(dist, ·)`` back in.

On CPU (this container) ``interpret=True`` executes the kernel body in
Python; on TPU the same call lowers to Mosaic.  ``auto_interpret()`` picks
per-backend so library code stays platform-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.obs.metrics import mark_trace
from repro.kernels.common import aligned as _aligned
from repro.kernels.common import auto_interpret
from repro.kernels.common import pad_to as _pad_to
from repro.kernels.csr_relax import kernel as K

INF = jnp.inf


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_k", "interpret")
)
def csr_relax_sweep(
    dist: jax.Array,
    ell_idx: jax.Array,
    ell_w: jax.Array,
    *,
    block_v: int = 256,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """One sparse relaxation sweep via the Pallas ELL kernel: matches
    ref.ell_relax_ref bitwise.

    dist (n,), ell_idx/ell_w (n, K) -> (n,).  Pads n up to the v-block and
    K up to the k-block internally; padding rows/slots are unreachable.
    """
    if interpret is None:
        interpret = auto_interpret()
    n = dist.shape[0]
    Kw = ell_idx.shape[1]
    K8 = _aligned(max(Kw, 1), 8)
    if block_k is not None:
        bk = block_k
    elif K8 <= 128:
        bk = K8
    else:
        # largest 8-multiple divisor of K8 that fits a VREG-friendly step —
        # keeps K_pad == K8 (no force-padding to a 128 multiple, which
        # could nearly double the per-sweep work for K just above 128).
        bk = next((d for d in range(128, 7, -8) if K8 % d == 0), 128)
    n_pad = _aligned(n, block_v)
    K_pad = _aligned(K8, bk)
    d = _pad_to(dist, n_pad, 0, INF)
    idx = _pad_to(_pad_to(ell_idx, n_pad, 0, 0), K_pad, 1, 0)
    w = _pad_to(_pad_to(ell_w, n_pad, 0, INF), K_pad, 1, INF)
    out = K.ell_relax(
        d, idx, w, block_v=block_v, block_k=bk, interpret=interpret
    )
    return jnp.minimum(dist, out[:n])


@functools.lru_cache(maxsize=None)
def make_csr_sweep_fn(*, block_v: int = 256, block_k: int | None = None,
                      interpret: bool | None = None):
    """Adapter producing ``sweep_fn(dist, csr_operands)`` for
    core.bellman_csr.sssp_bellman_csr — consumes the pytree's ELL view.

    Memoized so repeated calls return the *same* closure: ``sweep_fn`` is a
    static jit argument of the engine, and a fresh closure per call would
    retrace + recompile the whole fixpoint loop every solve.
    """
    def fn(dist, csr):
        mark_trace("csr_kernel_sweep")
        return csr_relax_sweep(
            dist, csr["ell_idx"], csr["ell_w"],
            block_v=block_v, block_k=block_k, interpret=interpret,
        )
    return fn
