from repro.kernels.csr_relax.ops import csr_relax_sweep, make_csr_sweep_fn
from repro.kernels.csr_relax.ref import ell_relax_ref, segment_relax_ref

__all__ = [
    "csr_relax_sweep",
    "make_csr_sweep_fn",
    "ell_relax_ref",
    "segment_relax_ref",
]
