from repro.kernels.bucket_relax.ops import (bucket_relax_block,
                                            make_bucket_pull_fn)
from repro.kernels.bucket_relax.ref import bucket_cand_ref, bucket_relax_ref

__all__ = [
    "bucket_relax_block",
    "make_bucket_pull_fn",
    "bucket_cand_ref",
    "bucket_relax_ref",
]
