"""Pure-jnp oracles for the bucket_relax Pallas kernel.

The light pull is gathers + adds + mins over f32 — exact operations — so
the kernel must agree with these *bitwise*, and the Δ-stepping engine
assembled from the kernel must agree bitwise with the flat pull in
core/delta_stepping.py (same candidate multiset; the per-block improvement
flags OR-reduce to the same global boolean).
"""
from __future__ import annotations

import jax.numpy as jnp


def bucket_cand_ref(dist: jnp.ndarray, ell_idx: jnp.ndarray,
                    ell_w: jnp.ndarray) -> jnp.ndarray:
    """Row-min candidate the kernel accumulates: (n,), (n, K), (n, K) ->
    (n,).  cand[v] = min_k(dist[ell_idx[v, k]] + ell_w[v, k]); padding
    slots (0, INF) contribute INF and never win."""
    return jnp.min(dist[ell_idx] + ell_w, axis=1)


def bucket_relax_ref(dist: jnp.ndarray, ell_idx: jnp.ndarray,
                     ell_w: jnp.ndarray, hi) -> tuple[jnp.ndarray,
                                                      jnp.ndarray]:
    """The full fused pass: ``(new_dist, go)`` with ``new = min(dist,
    cand)`` and ``go = any((new < dist) & (new < hi))`` — exactly the
    engine's inner-loop step + control bit (the pull contract of
    core/delta_stepping.make_light_pull_fn)."""
    new = jnp.minimum(dist, bucket_cand_ref(dist, ell_idx, ell_w))
    return new, jnp.any((new < dist) & (new < jnp.asarray(hi, dist.dtype)))
