"""jit'd public wrappers for the bucket_relax Pallas kernel.

``bucket_relax_block`` pads the light in-ELL operands to the kernel grid —
INF for the distance and weight slots, id 0 for index slots, none of which
can improve a label or raise a flag — then dispatches and OR-reduces the
per-block improvement flags.

``make_bucket_pull_fn`` adapts it to core/delta_stepping.py's pull
contract ``pull(dist, ops, hi) -> (new_dist, go)``; the result is
bitwise-equal to the flat ``make_light_pull_fn`` (same candidate multiset
plus INF no-ops from padding, and elementwise-exact flag comparisons), so
``delta_stepping_kernel`` solves match ``delta_stepping`` bit for bit.

On CPU (this container) ``interpret=True`` executes the kernel body in
Python; on TPU the same call lowers to Mosaic.  ``auto_interpret()`` picks
per-backend so library code stays platform-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.obs.metrics import mark_trace
from repro.kernels.bucket_relax import kernel as K
from repro.kernels.common import aligned as _aligned
from repro.kernels.common import auto_interpret
from repro.kernels.common import pad_to as _pad_to

INF = jnp.inf


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_k", "interpret")
)
def bucket_relax_block(
    dist: jax.Array,
    ell_idx: jax.Array,
    ell_w: jax.Array,
    hi: jax.Array,
    *,
    block_v: int = 256,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Kernel-backed fused light pull: matches ref.bucket_relax_ref
    bitwise.  dist (n,), ell_idx/ell_w (n, K), hi scalar ->
    (new_dist (n,), go bool).  Pads n up to the v-block (INF rows) and K
    up to the k-block ((0, INF) slots) internally.
    """
    if interpret is None:
        interpret = auto_interpret()
    n = dist.shape[0]
    Kw = ell_w.shape[1]
    K8 = _aligned(max(Kw, 1), 8)
    if block_k is not None:
        bk = block_k
    elif K8 <= 128:
        bk = K8
    else:
        # largest 8-multiple divisor <= 128, as in csr_relax/ops.py: keeps
        # K_pad == K8 instead of force-padding to a 128 multiple.
        bk = next((d for d in range(128, 7, -8) if K8 % d == 0), 128)
    V_pad = _aligned(max(n, 1), block_v)
    K_pad = _aligned(K8, bk)
    d = _pad_to(dist, V_pad, 0, INF)
    idx = _pad_to(_pad_to(ell_idx, V_pad, 0, 0), K_pad, 1, 0)
    w = _pad_to(_pad_to(ell_w, V_pad, 0, INF), K_pad, 1, INF)
    new, flags = K.bucket_relax(
        d, idx, w, hi, block_v=block_v, block_k=bk, interpret=interpret
    )
    return new[:n], jnp.any(flags > 0)


@functools.lru_cache(maxsize=None)
def make_bucket_pull_fn(*, block_v: int = 256, block_k: int | None = None,
                        interpret: bool | None = None):
    """Adapter producing the kernel-backed light pull for
    core.delta_stepping.sssp_delta_stepping — consumes the operands'
    light in-ELL view.

    Memoized so repeated calls return the *same* closure: ``pull_fn`` is a
    static jit argument of the engine, and a fresh closure per call would
    retrace + recompile the whole phase loop every solve.
    """

    def pull(dist, ops, hi):
        mark_trace("bucket_kernel_pull")
        return bucket_relax_block(
            dist, ops["light_ell_idx"], ops["light_ell_w"], hi,
            block_v=block_v, block_k=block_k, interpret=interpret,
        )

    return pull
