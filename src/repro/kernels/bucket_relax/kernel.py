"""Fused Pallas TPU kernel for the Δ-stepping light-bucket pull.

The Δ engine's inner loop (core/delta_stepping.py) runs, per pass,

    new[v] = min(dist[v], min_k(dist[light_ell_idx[v, k]] + light_ell_w[v, k]))
    go     = any((new < dist) & (new < hi))

over the padded light in-ELL.  The plain ELL kernel (kernels/csr_relax)
covers only the candidate min; this kernel fuses all three steps — gather +
row-min, the self-distance fold, and the in-bucket improvement flag that
drives the inner ``lax.while_loop`` — so one pass through VMEM produces
both the new distance block and the loop-control bit, nothing re-streamed.

Grid is (V//bv, K//bk) with K as the *last* axis: for a fixed v-block the
k-steps run sequentially on the core and accumulate with min — race-free by
construction, same as csr_relax.  The dist vector stays fully resident in
VMEM as a (1, n) block; each v-block's own distances are sliced out of it
at the final k-step (no second dist operand), the bucket limit ``hi`` rides
along as a (1, 1) block.  Per-block improvement flags are OR-reduced by the
caller — elementwise comparisons are exact, so flag-from-kernel equals
flag-from-XLA and the engine's schedule is bitwise-unchanged.

Validated in interpret mode on CPU against ref.py; on real TPU the row
gather lowers to Mosaic's dynamic-gather path, the regular-access pattern
the ELL layout exists for.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _bucket_relax_kernel(dist_ref, idx_ref, w_ref, hi_ref, out_ref,
                         flag_ref):
    """Grid (V//bv, K//bk).  dist_ref: (1, V) full vector; idx/w: (bv, bk);
    hi_ref: (1, 1); out: (1, bv) min-accumulated across the sequential
    k-steps then folded with the block's own distances at the last step;
    flag: (1, 1) int32, 1 iff any row of this v-block improved below hi."""
    k_step = pl.program_id(1)
    v_step = pl.program_id(0)
    k_last = pl.num_programs(1) - 1

    @pl.when(k_step == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, jnp.inf)

    d = dist_ref[...][0]                                     # (V,)
    cand = jnp.min(d[idx_ref[...]] + w_ref[...], axis=1)     # (bv,)
    out_ref[...] = jnp.minimum(out_ref[...], cand[None, :])

    @pl.when(k_step == k_last)
    def _finish():
        bv = out_ref.shape[1]
        old = lax.dynamic_slice(d, (v_step * bv,), (bv,))
        new = jnp.minimum(old, out_ref[...][0])
        out_ref[...] = new[None, :]
        imp = (new < old) & (new < hi_ref[0, 0])
        flag_ref[...] = jnp.any(imp).astype(jnp.int32).reshape(1, 1)


@functools.partial(
    jax.jit, static_argnames=("block_v", "block_k", "interpret")
)
def bucket_relax(
    dist: jax.Array,
    ell_idx: jax.Array,
    ell_w: jax.Array,
    hi: jax.Array,
    *,
    block_v: int = 256,
    block_k: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """One fused light-bucket pull pass.  Requires V % block_v == 0 and
    K % block_k == 0 (ops.py pads to the grid; padded rows carry INF
    distances and (0, INF) ELL slots, so they neither improve nor flag).

    dist (V,), ell_idx (V, K), ell_w (V, K), hi f32 scalar ->
    (new_dist (V,), flags (V // block_v,) int32).
    """
    V = dist.shape[0]
    K = ell_idx.shape[1]
    if block_k is None:
        block_k = K
    assert ell_idx.shape == (V, K) and ell_w.shape == (V, K)
    assert V % block_v == 0 and K % block_k == 0, (V, K, block_v, block_k)
    grid = (V // block_v, K // block_k)
    out, flags = pl.pallas_call(
        _bucket_relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, V), lambda v, k: (0, 0)),           # full dist
            pl.BlockSpec((block_v, block_k), lambda v, k: (v, k)),
            pl.BlockSpec((block_v, block_k), lambda v, k: (v, k)),
            pl.BlockSpec((1, 1), lambda v, k: (0, 0)),           # hi
        ],
        out_specs=[
            pl.BlockSpec((1, block_v), lambda v, k: (0, v)),
            pl.BlockSpec((1, 1), lambda v, k: (0, v)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, V), dist.dtype),
            jax.ShapeDtypeStruct((1, grid[0]), jnp.int32),
        ],
        interpret=interpret,
    )(dist[None, :], ell_idx, ell_w,
      jnp.asarray(hi, dist.dtype).reshape(1, 1))
    return out[0], flags[0]
