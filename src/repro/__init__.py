"""JAX/Pallas reproduction + extension of "High-Performance
Parallelization of Dijkstra's Algorithm Using MPI and CUDA".

Subpackages: ``core`` (SSSP engines + graph containers), ``kernels``
(Pallas relax kernels), ``serve`` (query-serving subsystem), ``launch``
(drivers), plus the training-substrate packages (``configs``, ``models``,
``sharding``, ``train``, ``data``, ``checkpoint``).
"""
