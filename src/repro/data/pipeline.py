"""Deterministic, restart-safe synthetic data pipeline.

Every batch is a pure function of (seed, step): after a preemption or
elastic reshape the pipeline resumes from the checkpointed step index with
bit-identical data — no iterator state to persist.  Per-host sharding
slices the global batch by (process_index, process_count), so each host
materializes only its shard (the pattern a real multi-host loader uses).

Tokens are Zipf-ish categorical draws (uniform over a vocab-sized range
biased toward low ids) — enough structure for loss to move while staying
dependency-free.  Labels are next-token targets with the final position
masked (-1).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # modality stubs (assignment: frontends provide precomputed embeddings)
    image_tokens: int = 0
    frame_len: int = 0
    d_model: int = 0


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig, *, process_index: int = 0,
                 process_count: int = 1):
        assert cfg.global_batch % process_count == 0
        self.cfg = cfg
        self.process_index = process_index
        self.process_count = process_count
        self.local_batch = cfg.global_batch // process_count

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed, step, self.process_index))

    def batch_at(self, step: int) -> dict:
        """The batch for ``step`` (host-local shard)."""
        cfg = self.cfg
        rng = self._rng(step)
        # zipf-biased ids, clipped into vocab
        raw = rng.zipf(1.3, size=(self.local_batch, cfg.seq_len + 1))
        tokens_full = np.minimum(raw - 1, cfg.vocab_size - 1).astype(np.int32)
        tokens = tokens_full[:, :-1]
        labels = tokens_full[:, 1:].copy()
        labels[:, -1] = -1
        out = {"tokens": tokens, "labels": labels}
        if cfg.image_tokens:
            out["image_embeds"] = rng.standard_normal(
                (self.local_batch, cfg.image_tokens, cfg.d_model)
            ).astype(np.float32) * 0.02
        if cfg.frame_len:
            out["encoder_frames"] = rng.standard_normal(
                (self.local_batch, cfg.frame_len, cfg.d_model)
            ).astype(np.float32) * 0.02
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def pipeline_for(cfg_model, shape, *, seed: int = 0,
                 process_index: int = 0, process_count: int = 1):
    """Pipeline matching a (ModelConfig, ShapeConfig) cell."""
    dc = DataConfig(
        vocab_size=cfg_model.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        image_tokens=cfg_model.num_image_tokens,
        frame_len=(shape.seq_len // cfg_model.audio_downsample
                   if cfg_model.encoder_segments else 0),
        d_model=(cfg_model.d_model
                 if (cfg_model.num_image_tokens or cfg_model.encoder_segments)
                 else 0),
    )
    return SyntheticPipeline(dc, process_index=process_index,
                             process_count=process_count)
