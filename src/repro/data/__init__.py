"""Data pipeline package (see pipeline.py)."""
