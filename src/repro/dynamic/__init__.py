"""Dynamic-graph subsystem: mutable CSR overlays + incremental SSSP repair.

``DynamicGraph`` (overlay.py) is a versioned mutable view over a frozen
``CsrGraph`` — insertion overlay, weight updates, deletion tombstones,
threshold-triggered compaction — whose staged operands keep static
shapes across versions so solves hit the jit cache.  repair.py turns an
existing fixpoint into the mutated graph's fixpoint incrementally
(decrease seeds + invalidated-cone rebuild), bitwise-equal to a cold
solve, and provides the dynamic sweeps the serve layer threads through
the unchanged core engines.  See README.md §Dynamic graphs.
"""
from repro.dynamic.overlay import DynamicGraph, EdgeDelta, MutationBatch
from repro.dynamic.repair import (RepairStats, dynamic_segment_sweep,
                                  dynamic_segment_sweep_multi,
                                  make_dynamic_flat_sweep_fn,
                                  predecessors_from_dist_dynamic,
                                  repair_sssp, row_affected, solve_dynamic,
                                  sssp_frontier_dynamic, sssp_repair)

__all__ = [
    "DynamicGraph",
    "EdgeDelta",
    "MutationBatch",
    "RepairStats",
    "dynamic_segment_sweep",
    "dynamic_segment_sweep_multi",
    "make_dynamic_flat_sweep_fn",
    "predecessors_from_dist_dynamic",
    "repair_sssp",
    "row_affected",
    "solve_dynamic",
    "sssp_frontier_dynamic",
    "sssp_repair",
]
