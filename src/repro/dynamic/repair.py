"""Incremental SSSP repair over a mutated graph — sublinear re-solves.

arXiv:1505.05033's workload observation (repeated queries over
slowly-changing graphs) makes the full re-solve after every edge change
the wrong default: most mutations perturb a tiny cone of the distance
field.  This module repairs an existing fixpoint instead, in two
directions matched to :class:`~repro.dynamic.overlay.EdgeDelta`'s sign
(INF encodes "absent", so inserts/deletes are just extreme
decreases/increases):

* **decrease / insert** — a smaller ``w_new`` can only lower labels.
  Seed: apply ``dist[u] + w_new`` at each modified arc's head; every
  head that improved becomes the initial frontier and the standard
  frontier push propagates the improvement (core/frontier.py's
  machinery verbatim, Δ-bucket schedule included).

* **increase / delete** — labels can only rise, so the stale region must
  be found and rebuilt.  The **invalidated cone** is the pred-tree
  descendant set of the heads whose TREE arc was hit: if a vertex's old
  tree path survives unweakened its label is still a valid path length,
  so only tree descendants of hit arcs can be stale (the contrapositive
  of "label changed ⟹ every old shortest path crossed a hit arc, in
  particular the tree path").  The cone is computed by pointer-doubling
  over ``pred`` — O(n log n) vertex work, zero edge relaxations — then
  reset to +inf and **re-derived from its boundary** with one pull over
  the cone's incoming windows (``pull_edge_slots``, O(cone in-degree)):
  non-cone sources carry live labels, cone sources carry INF, so exactly
  the boundary support lands.  The improved cone vertices seed the same
  frontier push.

Both directions compose in one call (a mixed batch applies the cone
reset first, then the decrease seeds, then one shared push), and the
result is **bitwise-equal to a fresh full solve on the mutated graph**:
the warm start is pointwise >= the new fixpoint with every finite label
a real path length, so the relax loop lands on the identical min over
identical f32 path sums (see ``frontier_fixpoint``'s warm-start
contract), and the pred tree is re-recovered from (dist, graph) exactly
as a fresh solve would.

``edges_relaxed`` counts base-arc relax slots (the pull's cone
in-degree + the push sweeps' frontier out-degrees) — directly comparable
with a full ``frontier``/``sssp_frontier_dynamic`` re-solve's counter,
which is what benchmarks/dynamic_bench.py gates on (overlay slots are
bounded by the static overlay capacity and excluded from both sides).

The module also provides the **dynamic sweeps** that let the unchanged
core fixpoint engines (bellman_csr / multisource_csr / frontier) run
directly on :meth:`DynamicGraph.dyn_ops` operands: each sweep is the
corresponding static sweep plus a scatter-min over the padded overlay
slots (inert pads aim INF at the drop id).  serve/scheduler.py threads
them through its batch and target paths so a mutated graph serves
queries without ever rebuilding a container.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.api import SsspResult
from repro.core.bellman_csr import segment_relax_sweep
from repro.core.frontier import (frontier_fixpoint, make_flat_sweep_fn,
                                 pull_edge_slots, sweep_cap)
from repro.dynamic.overlay import DynamicGraph, MutationBatch
from repro.obs.metrics import mark_trace

INF = jnp.inf


# ---------------------------------------------------------------------------
# dynamic sweeps: static machinery + overlay scatter-min
# ---------------------------------------------------------------------------

def dynamic_segment_sweep(dist: jax.Array, ops: dict) -> jax.Array:
    """O(m + C) relax sweep on dynamic operands: the base segment-min
    (tombstoned arcs carry INF and never win) plus a scatter-min over the
    overlay slots (free slots aim an INF candidate at the drop id n).
    Drop-in ``sweep_fn`` for ``sssp_bellman_csr``."""
    nd = segment_relax_sweep(dist, ops)
    cand = dist[ops["ov_src"]] + ops["ov_w"]
    return nd.at[ops["ov_dst"]].min(cand, mode="drop")


def dynamic_segment_sweep_multi(D: jax.Array, ops: dict) -> jax.Array:
    """Batched (S, n) twin of :func:`dynamic_segment_sweep` — drop-in
    ``sweep_fn`` for ``sssp_multisource_csr`` (the scheduler's coalesced
    batch path on dynamic handles)."""
    return jax.vmap(lambda d: dynamic_segment_sweep(d, ops))(D)


@functools.lru_cache(maxsize=None)
def make_dynamic_flat_sweep_fn(chunk: int = 1024) -> Callable:
    """Frontier sweep on dynamic operands: the flat-CSR chunked relax over
    the effective out-weights, plus the overlay arcs whose source is in
    the active frontier.  Memoized so the closure identity is a stable
    jit static (same contract as ``make_flat_sweep_fn``)."""
    base = make_flat_sweep_fn(chunk)

    def sweep(dist, fids, starts, off, E, fcount, ops):
        mark_trace("dynamic_flat_sweep")
        nd = base(dist, fids, starts, off, E, fcount, ops)
        n = dist.shape[0]
        # sentinel ids n land in the scratch slot and are sliced away
        active = jnp.zeros((n + 1,), bool).at[fids].set(True)[:n]
        cand = jnp.where(active[ops["ov_src"]],
                         dist[ops["ov_src"]] + ops["ov_w"], INF)
        return nd.at[ops["ov_dst"]].min(cand, mode="drop")

    return sweep


def predecessors_from_dist_dynamic(dist: jax.Array, ops: dict,
                                   source) -> jax.Array:
    """Pred recovery at the fixpoint over base + overlay arcs — the same
    lowest-attaining-source tie-break as ``predecessors_from_dist_csr``,
    so the tree is bitwise what a fresh solve on the compacted snapshot
    would recover.  Same strictly-positive-weights validity caveat."""
    n = dist.shape[0]
    via_b = dist[ops["src"]] + ops["w"]
    best = jax.ops.segment_min(
        via_b, ops["dst"], num_segments=n, indices_are_sorted=True)
    via_o = dist[ops["ov_src"]] + ops["ov_w"]
    best = best.at[ops["ov_dst"]].min(via_o, mode="drop")
    attains_b = via_b <= best[ops["dst"]]
    u_cand = jnp.where(attains_b, ops["src"].astype(jnp.int32), jnp.int32(n))
    u_best = jax.ops.segment_min(
        u_cand, ops["dst"], num_segments=n, indices_are_sorted=True)
    best_o = best[jnp.clip(ops["ov_dst"], 0, n - 1)]   # pads clamped, dropped
    attains_o = via_o <= best_o
    u_cand_o = jnp.where(attains_o, ops["ov_src"].astype(jnp.int32),
                         jnp.int32(n))
    u_best = u_best.at[ops["ov_dst"]].min(u_cand_o, mode="drop")
    reached = jnp.isfinite(dist) & (u_best < n)
    pred = jnp.where(reached, u_best, -1)
    return pred.at[source].set(-1)


# ---------------------------------------------------------------------------
# full solves on dynamic operands
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("n", "chunk", "max_sweeps", "delta")
)
def sssp_frontier_dynamic(
    ops: dict,
    source: jax.Array,
    *,
    n: int,
    chunk: int = 1024,
    max_sweeps: int | None = None,
    delta: float | None = None,
):
    """Cold frontier solve on dynamic operands (the repair benchmark's
    fair "full re-solve" baseline, and the initial solve the first repair
    chains from).  Returns ``(dist, pred, sweeps, edges_relaxed,
    converged)`` with pred recovered over base + overlay arcs."""
    mark_trace("frontier_dynamic")
    sweep = make_dynamic_flat_sweep_fn(chunk)
    cap = sweep_cap(n, delta, max_sweeps)
    dist0 = jnp.full((n,), INF, ops["out_w"].dtype).at[source].set(0.0)
    dist, sweeps, edges, conv = frontier_fixpoint(
        ops, dist0, dist0 < INF, n=n, sweep=sweep, cap=cap, delta=delta)
    pred = predecessors_from_dist_dynamic(dist, ops, source)
    return dist, pred, sweeps, edges, conv


def solve_dynamic(dyn: DynamicGraph, source: int, *,
                  delta: float | None = None,
                  chunk: int = 1024) -> SsspResult:
    """Full frontier solve of the CURRENT version of ``dyn`` — no
    container rebuild, exact fixpoint of :meth:`DynamicGraph.snapshot`."""
    d, p, s, e, c = sssp_frontier_dynamic(
        dyn.dyn_ops(), jnp.int32(source), n=dyn.n, chunk=chunk, delta=delta)
    return SsspResult(np.asarray(d), np.asarray(p), int(s),
                      "frontier_dynamic", edges_relaxed=int(e),
                      sources=np.asarray([int(source)], np.int32),
                      converged=bool(c))


# ---------------------------------------------------------------------------
# the repair engine
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("n", "chunk", "max_sweeps", "delta")
)
def sssp_repair(
    ops: dict,
    dist_old: jax.Array,
    pred_old: jax.Array,
    source: jax.Array,
    seed_heads: jax.Array,
    upd_src: jax.Array,
    upd_dst: jax.Array,
    upd_w: jax.Array,
    *,
    n: int,
    chunk: int = 1024,
    max_sweeps: int | None = None,
    delta: float | None = None,
):
    """Repair ``(dist_old, pred_old)`` — a fixpoint of the PREVIOUS
    version — into the fixpoint of the operands' current version.

    seed_heads: (S,) int32, heads of increased/deleted TREE arcs
        (``pred_old[head] == tail``), padded with n (dropped);
    upd_src/upd_dst/upd_w: (U,) decreased/inserted arcs ``(u, v, w_new)``,
        padded with ``(0, n, INF)`` (dropped/inert).

    S and U are baked into the array shapes, so padding them to fixed
    buckets keeps every repair on one compiled executable across
    versions.  Returns ``(dist, pred, sweeps, edges_relaxed, cone,
    converged)``; dist/pred are bitwise-equal to a cold solve on the
    mutated graph (module docstring), ``cone`` is the invalidated-cone
    population and ``converged`` the guardrail flag (False iff
    ``max_sweeps=`` capped the re-push before its fixpoint).
    """
    mark_trace("sssp_repair")
    idx = jnp.arange(n, dtype=jnp.int32)
    # --- invalidated cone: pred-tree descendants of the seed heads, by
    # pointer doubling (after k rounds aff[v] sees ancestors within 2^k).
    aff = jnp.zeros((n,), bool).at[seed_heads].set(True, mode="drop")
    anc = jnp.where(pred_old >= 0, pred_old, idx).astype(jnp.int32)
    rounds = max(1, math.ceil(math.log2(max(n, 2))))

    def doubling(_, carry):
        a, an = carry
        return a | a[an], an[an]

    aff, _ = lax.fori_loop(0, rounds, doubling, (aff, anc))
    aff = aff & (idx != source) & jnp.isfinite(dist_old)
    cone = jnp.sum(aff)
    dist1 = jnp.where(aff, INF, dist_old)
    # --- decrease/insert seeds: one scatter-min at the modified heads.
    cand = dist1[upd_src] + upd_w
    dist2 = dist1.at[upd_dst].min(cand, mode="drop")
    # --- pull the cone's boundary support: every arc entering the cone,
    # compacted windows over the incoming CSR; cone sources carry INF so
    # only live (boundary) labels contribute.
    fids = jnp.nonzero(aff, size=n, fill_value=n)[0].astype(jnp.int32)
    starts = ops["in_indptr"][fids]
    degs = ops["in_indptr"][fids + 1] - starts
    csum = jnp.cumsum(degs)
    E0, off = csum[-1], csum - degs
    dist3 = pull_edge_slots(
        dist2, fids, dist2, starts, off, E0, ops["src"], ops["w"],
        chunk=chunk, drop_id=jnp.int32(n))
    ov_d = ops["ov_dst"]
    into_cone = aff[jnp.clip(ov_d, 0, n - 1)] & (ov_d < n)
    cand_o = jnp.where(into_cone, dist2[ops["ov_src"]] + ops["ov_w"], INF)
    dist3 = dist3.at[ov_d].min(cand_o, mode="drop")
    # --- one shared push from everything that moved below its reset.
    pending0 = dist3 < dist1
    cap = sweep_cap(n, delta, max_sweeps)
    dist, sweeps, edges, conv = frontier_fixpoint(
        ops, dist3, pending0, n=n, sweep=make_dynamic_flat_sweep_fn(chunk),
        cap=cap, delta=delta, edges0=E0)
    pred = predecessors_from_dist_dynamic(dist, ops, source)
    return dist, pred, sweeps, edges, cone, conv


@dataclasses.dataclass(frozen=True)
class RepairStats:
    """Work accounting of one repair call (result fields aside)."""

    cone: int            # invalidated-cone population (0 for pure decreases)
    seeds: int           # increase/delete tree-arc heads submitted
    updates: int         # decrease/insert arc candidates submitted
    shortcut: bool       # batch provably couldn't change this source's row


def _pad_cap(count: int, minimum: int = 8) -> int:
    """Power-of-two padding bucket, so repeat repairs with different batch
    sizes land on a handful of compiled shapes (the scheduler's source-
    bucket trick applied to mutation batches)."""
    b = minimum
    while b < count:
        b *= 2
    return b


def repair_sssp(
    dyn: DynamicGraph,
    prev: SsspResult,
    batch: MutationBatch,
    *,
    chunk: int = 1024,
    delta: float | None = None,
) -> "tuple[SsspResult, RepairStats]":
    """Host wrapper: expand ``batch``'s edge deltas into per-arc repair
    seeds against ``prev`` (solved on the pre-batch version), run
    :func:`sssp_repair` on ``dyn``'s current operands, and wrap the
    result.  ``prev`` must carry dist AND pred for ``prev.sources``'s
    single source (any engine's result works — pred trees only differ in
    ties, and any tight tree yields a sound cone).

    When no delta can touch this source's row — no decrease improves it
    and no increase hits a tree arc — the old result is provably still
    exact and is returned as-is (``stats.shortcut``), the O(1) fast path
    the serve layer's selective invalidation shares.
    """
    if prev.pred is None:
        raise ValueError("repair needs prev.pred (the cone walks the "
                         "predecessor tree); recover it first")
    dist_old = np.asarray(prev.dist, np.float32)
    pred_old = np.asarray(prev.pred, np.int32)
    if dist_old.ndim != 1:
        raise ValueError("repair_sssp repairs one source row at a time")
    source = (int(prev.sources[0]) if prev.sources is not None
              else int(np.argmin(dist_old)))
    seeds: list[int] = []
    upds: list[tuple] = []
    for r in batch.records:
        arcs = ((r.u, r.v),) if dyn.directed else ((r.u, r.v), (r.v, r.u))
        for a, b in arcs:
            if r.w_new > r.w_old or (np.isinf(r.w_new)
                                     and not np.isinf(r.w_old)):
                if pred_old[b] == a:       # only tree arcs invalidate
                    seeds.append(b)
            elif r.w_new < r.w_old or (np.isinf(r.w_old)
                                       and not np.isinf(r.w_new)):
                upds.append((a, b, np.float32(r.w_new)))
    if not seeds and not upds:
        return prev, RepairStats(cone=0, seeds=0, updates=0, shortcut=True)
    S, U = _pad_cap(len(seeds)), _pad_cap(len(upds))
    seed_arr = np.full(S, dyn.n, np.int32)
    seed_arr[: len(seeds)] = seeds
    us = np.zeros(U, np.int32)
    ud = np.full(U, dyn.n, np.int32)
    uw = np.full(U, np.inf, np.float32)
    for i, (a, b, w) in enumerate(upds):
        us[i], ud[i], uw[i] = a, b, w
    d, p, s, e, cone, conv = sssp_repair(
        dyn.dyn_ops(), jnp.asarray(dist_old), jnp.asarray(pred_old),
        jnp.int32(source), jnp.asarray(seed_arr), jnp.asarray(us),
        jnp.asarray(ud), jnp.asarray(uw),
        n=dyn.n, chunk=chunk, delta=delta)
    res = SsspResult(np.asarray(d), np.asarray(p), int(s), "repair",
                     edges_relaxed=int(e),
                     sources=np.asarray([source], np.int32),
                     converged=bool(conv))
    return res, RepairStats(cone=int(cone), seeds=len(seeds),
                            updates=len(upds), shortcut=False)


def row_affected(dist_row: np.ndarray, batch: MutationBatch,
                 directed: bool = False) -> bool:
    """Conservative host-side test: can ``batch`` change this solved
    row at all?  A decrease matters iff it improves some head
    (``dist[u] + w_new < dist[v]`` in f32, the engines' own arithmetic);
    an increase matters iff the old arc was tight (``dist[u] + w_old ==
    dist[v]``) — a slack arc never attains the min, so raising it cannot
    move any label.  False means the row is still the exact fixpoint of
    the mutated graph (serve/registry.py keeps such rows across the
    version bump instead of invalidating them)."""
    d = np.asarray(dist_row, np.float32)
    for r in batch.records:
        arcs = ((r.u, r.v),) if directed else ((r.u, r.v), (r.v, r.u))
        for a, b in arcs:
            if np.isfinite(r.w_new) and (r.w_new < r.w_old
                                         or np.isinf(r.w_old)):
                if np.float32(d[a] + np.float32(r.w_new)) < d[b]:
                    return True
            if np.isfinite(r.w_old) and (r.w_new > r.w_old
                                         or np.isinf(r.w_new)):
                if np.isfinite(d[a]) and (
                        np.float32(d[a] + np.float32(r.w_old)) == d[b]):
                    return True
    return False
