"""Mutable CSR overlays — a versioned dynamic view over a frozen base.

Every engine in the repo assumes a frozen graph: ``CsrGraph`` is an
immutable container (its arrays are read-only and its memoized
out/ELL/partitioned views depend on that — see CsrGraph.__post_init__),
so under the "heavy traffic over slowly-changing graphs" regime of
arXiv:1505.05033 any edge change would force a full container rebuild, a
device restage, a jit retrace, and a cold cache.  :class:`DynamicGraph`
makes mutation cheap instead, by layering three small mutable structures
over an untouched base:

* an **effective-weight copy** of the base arc weights (incoming and
  outgoing orientations — the two orientations are permutations of one
  another, so both copies must be written per mutation): weight updates
  write the new value, deletions write INF (an INF arc can never win a
  relax min, the container's own padding argument), re-insertions of a
  deleted base edge reuse its slots;
* an **insertion overlay**: brand-new arcs land in fixed-capacity padded
  arrays (``ov_src``/``ov_dst``/``ov_w``; free slots carry the inert
  (0, n, INF) sentinel).  The capacity is STATIC across versions — the
  staged device arrays keep their shapes, so repair and full solves hit
  the jit cache across versions instead of retracing per mutation;
* **deletion tombstones** are just INF weights (base slots) or freed
  overlay slots; no arc is ever physically removed between compactions.

``commit()`` turns the pending edits into one :class:`MutationBatch`
(per-edge net ``w_old -> w_new`` deltas; INF encodes "absent", so a
delete is an increase-to-INF and an insert a decrease-from-INF — exactly
the two repair directions dynamic/repair.py distinguishes), bumps the
version, and refreshes the staged device operands.  Once the live
overlay crosses ``compact_threshold``, ``compact()`` folds everything
into a fresh frozen ``CsrGraph`` base (rebuilding its memoized views
lazily like any other CsrGraph) — the amortized O(m log m) rebuild the
overlay exists to defer, paid once per threshold-many insertions rather
than per edit.

The effective arc set always equals ``snapshot()`` — the plain CsrGraph
of the current version — plus inert INF slots, so any engine run over
the overlay operands reaches the exact fixpoint a fresh solve on the
snapshot reaches, bitwise (min over the same f32 path sums).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

import numpy as np

from repro.core.csr import CsrGraph
from repro.core.graph import INF


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """Net effect of one batch on one edge: ``w_old -> w_new``, with INF
    meaning "absent" on either side (insert: w_old=INF; delete:
    w_new=INF).  For undirected graphs (u, v) is the canonical u < v
    form and the delta applies to both stored arcs."""

    u: int
    v: int
    w_old: float
    w_new: float


@dataclasses.dataclass(frozen=True)
class MutationBatch:
    """One committed mutation batch: the per-edge net deltas between two
    consecutive versions (edits that cancelled out are dropped)."""

    version_from: int
    version_to: int
    records: tuple

    def __len__(self) -> int:
        return len(self.records)


class DynamicGraph:
    """Versioned mutable view over a base :class:`CsrGraph`.

    Mutation API (all weights must be finite and > 0 — the repair
    engines' cone computation walks a predecessor tree, which is only a
    valid shortest-path tree under strictly positive weights, the same
    caveat as ``predecessors_from_dist_csr``):

    * ``add_edge(u, v, w)``    — edge must be absent;
    * ``update_edge(u, v, w)`` — edge must be present;
    * ``delete_edge(u, v)``    — edge must be present;
    * ``apply(edit)``          — one ``("add"|"update"|"delete", u, v[, w])``
      tuple, the registry's wire format.

    Edits take effect on the host immediately; ``commit()`` publishes
    them as a new version (device operands refreshed, snapshot memo
    dropped) and returns the :class:`MutationBatch` the repair engines
    and the serve layer's selective invalidation consume.
    """

    def __init__(
        self,
        base: CsrGraph,
        *,
        overlay_capacity: int = 64,
        compact_threshold: "int | None | str" = "auto",
    ):
        """``compact_threshold``: live overlay arcs that trigger an
        auto-compact at commit.  The default ("auto") is HALF the overlay
        capacity, leaving headroom so batches smaller than the remaining
        half cannot overflow the fixed slots — the capacity then stays
        static and the jit cache holds.  A SINGLE batch netting more
        inserts than the free slots still grows mid-batch (counted in
        ``overlay_growths`` — each growth is one retrace); size the
        capacity to a few times the largest expected batch.  An explicit
        ``None`` disables
        auto-compaction entirely; the overlay then GROWS by doubling when
        full, which is a shape-breaking event (new staged array shapes =
        one retrace) and unbounded memory under insert-heavy churn — use
        it only for bounded experiments."""
        if overlay_capacity < 1:
            raise ValueError(
                f"overlay_capacity must be >= 1, got {overlay_capacity}")
        self.base = base
        self.directed = base.directed
        self._version = 0
        self.compact_threshold = (max(1, overlay_capacity // 2)
                                  if compact_threshold == "auto"
                                  else compact_threshold)
        self.compactions = 0
        # shape-breaking events: a single batch netting more inserts than
        # the free slots still grows mid-batch (commit-time compaction
        # can't help a batch already in flight) — observable here so a
        # workload whose batches outrun the capacity shows up in stats
        # instead of silently retracing every engine.
        self.overlay_growths = 0
        self._capacity = int(overlay_capacity)
        self._rebind_base(base)
        self._pending: "dict[tuple, float]" = {}   # edge key -> w at batch start
        self._dops: Optional[dict] = None
        self._snapshot: Optional[CsrGraph] = None

    # -- base binding -----------------------------------------------------

    def _rebind_base(self, base: CsrGraph) -> None:
        """(Re)build the mutable state over ``base`` (init and compact)."""
        self.base = base
        out_indptr, out_dst, out_w = base.out_csr()
        self._in_w = np.asarray(base.weights, np.float32).copy()
        self._out_w = np.asarray(out_w, np.float32).copy()
        self._out_indptr = out_indptr
        self._out_dst = out_dst
        C = self._capacity
        self._ov_src = np.zeros(C, np.int32)
        self._ov_dst = np.full(C, base.n, np.int32)   # n = scatter-drop pad
        self._ov_w = np.full(C, INF, np.float32)
        self._ov_pos: "dict[tuple, int]" = {}         # (u, v) arc -> slot
        self._ov_free = list(range(C - 1, -1, -1))

    # -- introspection ----------------------------------------------------

    @property
    def n(self) -> int:
        return self.base.n

    @property
    def version(self) -> int:
        return self._version

    @property
    def overlay_used(self) -> int:
        """Live overlay arcs (insertions not yet folded by compact())."""
        return len(self._ov_pos)

    @property
    def overlay_capacity(self) -> int:
        return self._capacity

    @property
    def nnz_live(self) -> int:
        """Live arcs of the current version (tombstones excluded)."""
        return int(np.isfinite(self._in_w).sum()) + len(self._ov_pos)

    @property
    def nbytes(self) -> int:
        """Host bytes: base container + effective-weight copies + overlay."""
        return int(self.base.nbytes + self._in_w.nbytes + self._out_w.nbytes
                   + self._ov_src.nbytes + self._ov_dst.nbytes
                   + self._ov_w.nbytes)

    @property
    def staged_nbytes(self) -> int:
        """Device bytes currently pinned by :meth:`dyn_ops` (0 if never
        staged); each distinct buffer counted once."""
        if self._dops is None:
            return 0
        return sum({id(a): int(a.nbytes) for a in self._dops.values()
                    }.values())

    # -- arc addressing ---------------------------------------------------

    def _edge_key(self, u: int, v: int) -> tuple:
        return (u, v) if self.directed or u < v else (v, u)

    def _base_in_pos(self, u: int, v: int) -> int:
        """Position of arc u->v in the incoming arrays, or -1.  Row v is
        sorted by src, so this is a binary search in v's window."""
        lo, hi = int(self.base.indptr[v]), int(self.base.indptr[v + 1])
        i = lo + int(np.searchsorted(self.base.indices[lo:hi], u))
        return i if i < hi and int(self.base.indices[i]) == u else -1

    def _base_out_pos(self, u: int, v: int) -> int:
        """Position of arc u->v in the outgoing arrays, or -1."""
        lo, hi = int(self._out_indptr[u]), int(self._out_indptr[u + 1])
        i = lo + int(np.searchsorted(self._out_dst[lo:hi], v))
        return i if i < hi and int(self._out_dst[i]) == v else -1

    def weight_of(self, u: int, v: int) -> float:
        """Effective weight of arc u->v in the current version (INF when
        absent)."""
        p = self._base_in_pos(u, v)
        if p >= 0 and np.isfinite(self._in_w[p]):
            return float(self._in_w[p])
        slot = self._ov_pos.get((u, v))
        return float(self._ov_w[slot]) if slot is not None else float("inf")

    def has_edge(self, u: int, v: int) -> bool:
        return np.isfinite(self.weight_of(u, v))

    # -- mutation ---------------------------------------------------------

    def _check(self, u: int, v: int) -> tuple:
        u, v = int(u), int(v)
        if not (0 <= u < self.n and 0 <= v < self.n):
            raise IndexError(
                f"edge endpoints must be in [0, {self.n}); got ({u}, {v})")
        if u == v:
            raise ValueError("self-loops are not representable "
                             "(the 0 diagonal is implicit)")
        return u, v

    def _grow_overlay(self) -> None:
        C, C2 = self._capacity, 2 * self._capacity
        for name in ("_ov_src", "_ov_dst", "_ov_w"):
            old = getattr(self, name)
            pad = np.full(C, self.n, np.int32) if name == "_ov_dst" else (
                np.full(C, INF, np.float32) if name == "_ov_w"
                else np.zeros(C, np.int32))
            setattr(self, name, np.concatenate([old, pad]))
        self._ov_free.extend(range(C2 - 1, C - 1, -1))
        self._capacity = C2
        self.overlay_growths += 1

    def _set_arc(self, u: int, v: int, w: float) -> None:
        """Write one directed arc's effective weight (INF = tombstone)."""
        p = self._base_in_pos(u, v)
        if p >= 0:
            self._in_w[p] = w
            self._out_w[self._base_out_pos(u, v)] = w
            return
        slot = self._ov_pos.get((u, v))
        if slot is not None:
            if np.isfinite(w):
                self._ov_w[slot] = w
            else:                       # overlay delete frees the slot
                self._ov_src[slot] = 0
                self._ov_dst[slot] = self.n
                self._ov_w[slot] = INF
                del self._ov_pos[(u, v)]
                self._ov_free.append(slot)
            return
        if not np.isfinite(w):          # deleting an absent arc: no-op
            return
        if not self._ov_free:
            self._grow_overlay()
        slot = self._ov_free.pop()
        self._ov_src[slot] = u
        self._ov_dst[slot] = v
        self._ov_w[slot] = np.float32(w)
        self._ov_pos[(u, v)] = slot

    def _record_and_set(self, u: int, v: int, w: float) -> None:
        key = self._edge_key(u, v)
        if key not in self._pending:
            self._pending[key] = self.weight_of(*key)
        w32 = np.float32(w)
        self._set_arc(u, v, w32)
        if not self.directed:
            self._set_arc(v, u, w32)

    def add_edge(self, u: int, v: int, w: float) -> None:
        u, v = self._check(u, v)
        if self.has_edge(u, v):
            raise ValueError(f"edge ({u}, {v}) already present; "
                             "use update_edge")
        if not (np.isfinite(w) and w > 0):
            raise ValueError(f"edge weights must be finite and > 0, got {w}")
        self._record_and_set(u, v, w)

    def update_edge(self, u: int, v: int, w: float) -> None:
        u, v = self._check(u, v)
        if not self.has_edge(u, v):
            raise ValueError(f"edge ({u}, {v}) not present; use add_edge")
        if not (np.isfinite(w) and w > 0):
            raise ValueError(f"edge weights must be finite and > 0, got {w}")
        self._record_and_set(u, v, w)

    def delete_edge(self, u: int, v: int) -> None:
        u, v = self._check(u, v)
        if not self.has_edge(u, v):
            raise ValueError(f"edge ({u}, {v}) not present")
        self._record_and_set(u, v, INF)

    def apply(self, edit: tuple) -> None:
        """One ``("add"|"update"|"delete", u, v[, w])`` edit — the wire
        format serve/registry.py's ``mutate()`` forwards."""
        op = edit[0]
        if op == "add":
            self.add_edge(edit[1], edit[2], edit[3])
        elif op == "update":
            self.update_edge(edit[1], edit[2], edit[3])
        elif op == "delete":
            self.delete_edge(edit[1], edit[2])
        else:
            raise ValueError(f"unknown edit op {op!r}; "
                             "expected add/update/delete")

    # -- versioning -------------------------------------------------------

    def staged_ops(self) -> Optional[dict]:
        """Shallow copy of the currently staged operands WITHOUT forcing
        staging (None if :meth:`dyn_ops` was never called).  ``commit()``
        swaps fresh buffers into the live dict in place, so a caller that
        needs the pre-commit version — serve/registry.py's mutate hooks
        recover predecessor trees against it — must take this copy
        before committing; the jax buffers themselves are immutable."""
        return dict(self._dops) if self._dops else None

    def rollback(self) -> int:
        """Undo every uncommitted edit (restore each touched edge to its
        weight at batch start) and clear the pending record — the
        atomicity escape hatch registry.mutate uses when an edit in the
        middle of a batch turns out invalid.  Returns the number of
        edges restored."""
        pending, self._pending = self._pending, {}
        for (u, v), w_old in pending.items():
            w = np.float32(w_old)
            self._set_arc(u, v, w)
            if not self.directed:
                self._set_arc(v, u, w)
        return len(pending)

    def commit(self) -> MutationBatch:
        """Publish the pending edits as a new version.

        Coalesces per-edge (an add+delete in one batch cancels out), and
        only bumps the version / restages device weights when something
        net-changed.  Auto-compacts afterwards when the live overlay
        crossed ``compact_threshold``.
        """
        records = []
        for (u, v), w_old in self._pending.items():
            w_new = self.weight_of(u, v)
            if not (w_new == w_old
                    or (np.isinf(w_new) and np.isinf(w_old))):
                records.append(EdgeDelta(u, v, float(w_old), float(w_new)))
        self._pending.clear()
        if not records:
            return MutationBatch(self._version, self._version, ())
        old = self._version
        self._version += 1
        self._snapshot = None
        if (self.compact_threshold is not None
                and len(self._ov_pos) > self.compact_threshold):
            # compacting drops the staged operands entirely — don't pay
            # for a device restage that would be discarded one line later
            self.compact()
        elif self._dops is not None:
            self._restage_mutable()
        return MutationBatch(old, self._version, tuple(records))

    def compact(self) -> CsrGraph:
        """Fold the overlay + tombstones into a fresh frozen base CsrGraph
        (same graph, same version — this changes the representation, not
        the edge set).  The staged operands are dropped and re-staged
        lazily with the new base shapes (one jit retrace per compaction,
        the amortized cost the threshold bounds)."""
        new_base = self.snapshot()
        self._rebind_base(new_base)
        self._dops = None
        self._snapshot = new_base
        self.compactions += 1
        return new_base

    def snapshot(self) -> CsrGraph:
        """The current version as a plain frozen :class:`CsrGraph` (the
        verification/compaction view).  Memoized per version."""
        if self._snapshot is not None:
            return self._snapshot
        live = np.isfinite(self._in_w)
        src = np.asarray(self.base.indices)[live]
        dst = self.base.dst_ids()[live]
        w = self._in_w[live]
        ov_live = self._ov_dst < self.n
        if ov_live.any():
            src = np.concatenate([src, self._ov_src[ov_live]])
            dst = np.concatenate([dst, self._ov_dst[ov_live]])
            w = np.concatenate([w, self._ov_w[ov_live]])
        order = np.lexsort((src, dst))                 # by dst, then src
        dst = dst.astype(np.int64)[order]
        counts = np.bincount(dst, minlength=self.n)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self._snapshot = CsrGraph(
            indptr=indptr, indices=src[order].astype(np.int32),
            weights=w[order].astype(np.float32), n=self.n,
            directed=self.directed)
        return self._snapshot

    # -- device staging ---------------------------------------------------

    def dyn_ops(self) -> dict:
        """Staged device operands for the dynamic engines
        (dynamic/repair.py): the ``csr_operands`` pytree (src/dst/w, with
        w the EFFECTIVE weights) plus the frontier out-views, the
        incoming indptr (both with the one-extra-sentinel-row trick of
        ``frontier_operands``) and the padded overlay triple.  Built
        lazily; ``commit()`` swaps in fresh weight/overlay buffers while
        the index arrays stay pinned, so shapes — and therefore the jit
        cache — are stable across versions until a compaction."""
        if self._dops is None:
            import jax.numpy as jnp

            base = self.base
            in_indptr = np.concatenate(
                [base.indptr, base.indptr[-1:]]).astype(np.int32)
            out_indptr = np.concatenate(
                [self._out_indptr, self._out_indptr[-1:]]).astype(np.int32)
            self._dops = {
                "src": jnp.asarray(base.indices),
                "dst": jnp.asarray(base.dst_ids()),
                "in_indptr": jnp.asarray(in_indptr),
                "out_indptr": jnp.asarray(out_indptr),
                "out_dst": jnp.asarray(self._out_dst),
            }
            self._restage_mutable()
        return self._dops

    def _restage_mutable(self) -> None:
        # jnp.array (not asarray): on CPU backends asarray may zero-copy
        # ALIAS the host buffer, and these five mirrors are exactly the
        # arrays later edits write in place — an aliased staging would
        # let host writes leak into the "immutable" staged version (and
        # into the pre-commit old_ops view the repair hooks hold).  The
        # frozen base index arrays in dyn_ops() may alias freely.
        import jax.numpy as jnp

        self._dops.update(
            w=jnp.array(self._in_w),
            out_w=jnp.array(self._out_w),
            ov_src=jnp.array(self._ov_src),
            ov_dst=jnp.array(self._ov_dst),
            ov_w=jnp.array(self._ov_w),
        )
