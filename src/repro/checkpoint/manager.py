"""Fault-tolerant checkpointing: atomic commit, async writer, reshard-on-load.

Format: one ``.npy`` per pytree leaf (path-keyed filenames) plus a JSON
manifest.  A checkpoint directory is written under a ``tmp.`` prefix and
atomically ``os.rename``d to ``step_<N>`` only after every leaf and the
manifest are durably on disk — a killed writer can never leave a directory
that ``latest_step`` would pick up.

Restore is *elastic*: leaves are loaded as logical (global) arrays and
``jax.device_put`` with shardings derived from the *current* mesh, so a
checkpoint written on a 16×16 pod restores onto 2×16×16, a single host, or
any other mesh (checkpoints store logical arrays, not device layouts).

bfloat16 leaves are stored as a uint16 view (np.save round-trips custom
ml_dtypes unreliably across versions); real dtypes live in the manifest.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _leaf_name(path) -> str:
    s = jax.tree_util.keystr(path)
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", s).strip("_")


def _to_numpy(x) -> tuple[np.ndarray, str]:
    arr = np.asarray(x)
    dt = str(arr.dtype)
    if dt == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    return arr, dt


def _from_numpy(arr: np.ndarray, dtype: str) -> np.ndarray:
    if dtype == "bfloat16":
        import ml_dtypes
        return arr.view(ml_dtypes.bfloat16)
    return arr.astype(dtype) if str(arr.dtype) != dtype else arr


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def save_checkpoint(ckpt_dir: str, state: Any, step: int,
                    extra: Optional[dict] = None) -> str:
    """Synchronous atomic save.  Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr, dt = _to_numpy(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append(
            {"name": name, "dtype": dt, "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)   # atomic commit
    return final


def restore_checkpoint(ckpt_dir: str, state_shape: Any, *,
                       step: Optional[int] = None,
                       shardings: Any = None) -> tuple[Any, dict]:
    """Load the latest (or given) step into the structure of
    ``state_shape``; ``shardings`` (same pytree) triggers reshard-on-load.

    Returns (state, manifest_extra)."""
    s = step if step is not None else latest_step(ckpt_dir)
    if s is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{s}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    leaves, treedef = jax.tree_util.tree_flatten_with_path(state_shape)
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for (path, leaf), shd in zip(leaves, shard_leaves):
        name = _leaf_name(path)
        meta = by_name[name]
        arr = _from_numpy(np.load(os.path.join(d, name + ".npy")), meta["dtype"])
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"expected {leaf.shape}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jnp.asarray(arr))
    return treedef.unflatten(out), manifest.get("extra", {})


class CheckpointManager:
    """Async writer with bounded retention.

    ``save`` snapshots to host memory synchronously (cheap vs training
    step), then writes on a background thread; ``wait`` joins.  Keeps the
    newest ``keep`` checkpoints.
    """

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save(self, state: Any, step: int, extra: Optional[dict] = None,
             block: bool = False):
        self.wait()
        host_state = jax.tree.map(jax.device_get, state)

        def _write():
            try:
                save_checkpoint(self.ckpt_dir, host_state, step, extra)
                self._gc()
            except BaseException as e:     # surfaced on next wait()
                self.last_error = e

        if block:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.ckpt_dir)
            if (m := _STEP_RE.match(d)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)
