"""Self-tuning engine selection: measured cost model + replay gating.

The paper's central finding — the winning parallelization strategy is
workload- and hardware-dependent — made every hard-coded threshold in
serve/dispatch.py a guess.  This package closes the loop (ROADMAP item
4) on the cost records PR 9's observability layer already emits:

- `repro.tune.calibrate` — sweep the engine matrix over a design grid
  of (corpus, n, m, batch, nprocs, Δ) on the running backend, through
  the existing ``api.shortest_paths`` + ``CostLog`` shim; writes a
  versioned ``CALIBRATION.json``.
- `repro.tune.model` — deterministic per-(engine, nprocs) log-space
  least-squares cost model fitted from those records, with seeded
  bootstrap confidence, coverage reporting, and explicit calibrated
  support ranges.
- `repro.tune.select` — ``TunedPolicy``, a drop-in ``DispatchPolicy``
  that returns the predicted-fastest engine *plus its statics* (Δ,
  bucket cap B, shard arity) through the one existing seam, falling
  back to the hard-coded thresholds outside calibrated support.
- `repro.tune.features` — cheap memoized topology features (degree
  skew, BFS hop eccentricity / frontier width) that separate the
  corpora the engines diverge on.
- `repro.tune.replay` — trace-replay perf regression gate: a recorded
  cost log re-run against the fitted model fails CI when measured wall
  drifts beyond tolerance.

Selection never changes answers — every candidate engine is bitwise-
equal-to-serial (benchmarks/run_bench.py pins it); the model only moves
wall time.  benchmarks/tune_bench.py races the tuned policy against the
thresholds and records ``gate_tune`` in ``BENCH_tune.json``.
"""
from repro.tune.features import graph_features
from repro.tune.model import (CostModel, EngineFit, fit_model,
                              load_calibration, load_model)
from repro.tune.replay import replay_records
from repro.tune.select import TunedPolicy

__all__ = [
    "CostModel",
    "EngineFit",
    "TunedPolicy",
    "fit_model",
    "graph_features",
    "load_calibration",
    "load_model",
    "replay_records",
]
