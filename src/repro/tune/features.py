"""Cheap topology features for the cost model — the query-time view.

The per-engine log-linear fits (tune/model.py) need more than (n, m):
the frontier engine's sweep count tracks the graph's hop eccentricity
(a road grid takes ~200 sweeps where a random sparse graph takes ~10 at
the same size), and the Δ-routing profile tracks degree skew.  Both are
computable in one cheap numpy pass over the stored arcs plus one
level-synchronous BFS — the "degree skew" and "frontier width" axes of
the calibration design grid — and both are available at dispatch time,
unlike solve outcomes (sweeps, edges_relaxed), which a selector cannot
see before it selects.

Features are memoized on the graph instance (``CsrGraph._memo``, the
same seam ``delta_profile`` uses), so repeat routing of a pinned serving
handle costs a dict lookup.
"""
from __future__ import annotations

import numpy as np

__all__ = ["graph_features"]


def _bfs_profile(indptr: np.ndarray, src: np.ndarray, n: int) -> tuple:
    """Hop eccentricity of vertex 0 and mean frontier width, by
    level-synchronous BFS over the stored arcs treated as undirected
    (direction is irrelevant for a topology *feature*; exactness is an
    engine property, not a feature property).  O(hops · m) numpy work.
    """
    dst = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    src = np.asarray(src, dtype=np.int64)
    visited = np.zeros(n, dtype=bool)
    frontier = np.zeros(n, dtype=bool)
    visited[0] = frontier[0] = True
    hops = 0
    reached = 1
    while True:
        # arcs incident to the frontier, both orientations
        nxt = np.zeros(n, dtype=bool)
        nxt[dst[frontier[src]]] = True
        nxt[src[frontier[dst]]] = True
        nxt &= ~visited
        if not nxt.any():
            break
        visited |= nxt
        frontier = nxt
        hops += 1
        reached += int(nxt.sum())
    width = reached / max(hops, 1)
    return max(hops, 1), width, reached


def graph_features(cg) -> dict:
    """Topology features of a :class:`~repro.core.csr.CsrGraph`:

    - ``n``, ``m``: vertex / stored-arc counts;
    - ``skew``: max in-degree over mean in-degree (>= 1.0) — the hub
      corpus scores high, road grids near 1;
    - ``hops``: BFS eccentricity of vertex 0 (undirected view) — the
      frontier engine's sweep count proxy;
    - ``width``: mean BFS frontier width (vertices reached per hop);
    - ``reached``: vertices in vertex 0's undirected component.

    Memoized per graph instance.
    """
    def build():
        n = int(cg.n)
        m = int(cg.nnz)
        indeg = np.diff(cg.indptr)
        mean_deg = max(float(indeg.mean()) if n else 0.0, 1e-9)
        skew = max(float(indeg.max(initial=0)) / mean_deg, 1.0)
        hops, width, reached = _bfs_profile(cg.indptr, cg.indices, n)
        return {"n": n, "m": m, "skew": skew, "hops": hops,
                "width": width, "reached": reached}

    return cg._memo("_tune_features", build)
