"""Deterministic fitted cost model over per-solve cost records.

One log-space least-squares fit per ``(engine, nprocs)`` pair:

    log(wall_ms) ~ a0 + a1·log n + a2·log m + a3·log hops
                      + a4·log skew + a5·log batch

fitted with ``numpy.linalg.lstsq`` (minimum-norm, fully deterministic)
on the records of a versioned ``CALIBRATION.json`` sweep
(tune/calibrate.py).  The topology features (hops, skew — see
tune/features.py) separate the corpora the engines diverge on: a road
grid's ~200-sweep frontier solve and a random sparse graph's ~10-sweep
one sit at nearly the same (n, m).  At query points where a feature is
unknown (e.g. replaying a cost log that carries only the record fields)
the fit's mean value is imputed, making the prediction a marginal one —
tolerances downstream must absorb that (tune/replay.py's drift gate
does).

Determinism and confidence: the coefficients depend only on the records
(lstsq has no RNG); the ``seed`` drives a small bootstrap resample whose
prediction spread is reported as each fit's ``conf_log`` (one-sigma
log-space half-width).  Fitting twice with the same records and seed
yields byte-identical serialized models — tests/test_tune.py pins this.

Support and fallback: each fit records the (n, m, batch) ranges it was
trained on; a query point is in a fit's support only within
``SUPPORT_MARGIN``× of those ranges (log-space).  Callers
(tune/select.py) fall back to the hard-coded threshold policy whenever
the point is outside every relevant fit's support — the conservative
contract: the model only ever overrides a default where it has data.

Delta engines are fitted on the per-point MINIMUM over the calibrated Δ
candidates (the cost of the engine *with its best static*), and the
argmin Δ is retained per point so ``best_delta`` can return the
measured-best width for the nearest calibrated point.  ``best_batch``
does the same for the multisource bucket size (per-source cost argmin).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "MODEL_SCHEMA",
    "SUPPORT_MARGIN",
    "EngineFit",
    "CostModel",
    "fit_model",
    "load_calibration",
    "load_model",
]

MODEL_SCHEMA = 1

# multiplicative log-space slack around each fit's trained ranges:
# a query at n up to 2x outside the calibrated n-range still counts as
# supported; beyond it the selector must fall back to the thresholds.
SUPPORT_MARGIN = 2.0

# design-matrix feature order (after the intercept)
FEATURE_NAMES = ("log_n", "log_m", "log_hops", "log_skew", "log_batch")

# fits with fewer records than this are not trusted (rank-deficient fits
# are fine for lstsq but interpolate nothing)
MIN_RECORDS = 3

# a non-default Δ candidate must beat the auto width by this fraction at
# the nearest calibrated point before best_delta returns it.  Identical
# configs drift 20-35% between runs on shared CPU hosts, so anything
# inside that band is timer noise and must not displace the
# graph-derived auto width.
DELTA_WIN_MARGIN = 0.25


def _safe_log(x: float) -> float:
    return math.log(max(float(x), 1e-9))


def _row_features(rec: Dict[str, Any]) -> Dict[str, float]:
    return {
        "log_n": _safe_log(rec["n"]),
        "log_m": _safe_log(rec.get("m") or 1.0),
        "log_hops": _safe_log(rec.get("hops") or 1.0),
        "log_skew": _safe_log(rec.get("skew") or 1.0),
        "log_batch": _safe_log(rec.get("batch") or 1),
    }


@dataclasses.dataclass
class EngineFit:
    """One (engine, nprocs) log-linear fit plus its provenance."""

    engine: str
    nprocs: int
    coef: Tuple[float, ...]           # intercept + FEATURE_NAMES order
    n_records: int                    # rows the fit was trained on
    rms_log_err: float                # RMS log-residual on training rows
    conf_log: float                   # bootstrap one-sigma log half-width
    feature_means: Dict[str, float]   # mean log feature (imputation)
    support: Dict[str, Tuple[float, float]]   # raw-space (min, max)
    # per calibrated point: the measured-best statics for nearest-point
    # lookup — (n, m, best delta, best batch, best wall_ms)
    points: List[Dict[str, float]]

    def predict_log(self, feats: Dict[str, float]) -> float:
        x = [1.0] + [feats.get(name, self.feature_means[name])
                     if feats.get(name) is not None
                     else self.feature_means[name]
                     for name in FEATURE_NAMES]
        return float(np.dot(self.coef, x))

    def in_support(self, *, n: float, m: Optional[float] = None,
                   batch: Optional[float] = None,
                   margin: float = SUPPORT_MARGIN) -> bool:
        def ok(name, val):
            if val is None or name not in self.support:
                return True
            lo, hi = self.support[name]
            return lo / margin <= float(val) <= hi * margin
        return ok("n", n) and ok("m", m) and ok("batch", batch)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["coef"] = list(self.coef)
        d["support"] = {k: list(v) for k, v in self.support.items()}
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EngineFit":
        return cls(
            engine=d["engine"], nprocs=int(d["nprocs"]),
            coef=tuple(float(c) for c in d["coef"]),
            n_records=int(d["n_records"]),
            rms_log_err=float(d["rms_log_err"]),
            conf_log=float(d["conf_log"]),
            feature_means={k: float(v)
                           for k, v in d["feature_means"].items()},
            support={k: (float(v[0]), float(v[1]))
                     for k, v in d["support"].items()},
            points=[{k: float(v) for k, v in p.items()}
                    for p in d["points"]],
        )


def _point_key(r: Dict[str, Any]) -> tuple:
    return (r.get("corpus") or r.get("graph") or "", int(r["n"]),
            int(r.get("m") or 0), int(r.get("batch") or 1))


def _collapse_statics(records: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Per calibrated point (corpus, n, m, batch): keep the min-wall
    record over the swept statics (Δ candidates), remembering the argmin
    — the engine's cost *when tuned*, which is what selection compares."""
    best: Dict[tuple, Dict[str, Any]] = {}
    for r in records:
        key = _point_key(r)
        cur = best.get(key)
        if cur is None or float(r["wall_ms"]) < float(cur["wall_ms"]):
            best[key] = r
    return [best[k] for k in sorted(best)]


def _fit_one(engine: str, nprocs: int, records: List[Dict[str, Any]],
             seed: int) -> EngineFit:
    rows = _collapse_statics(records)
    feats = [_row_features(r) for r in rows]
    X = np.array([[1.0] + [f[name] for name in FEATURE_NAMES]
                  for f in feats], dtype=np.float64)
    y = np.array([_safe_log(r["wall_ms"]) for r in rows], dtype=np.float64)
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    resid = X @ coef - y
    rms = float(np.sqrt(np.mean(resid ** 2))) if len(rows) else 0.0
    means = {name: float(np.mean([f[name] for f in feats]))
             for name in FEATURE_NAMES}
    # seeded bootstrap: spread of the mean-point prediction across
    # resampled fits — reported, not used in selection
    conf = 0.0
    if len(rows) >= 4:
        rng = np.random.default_rng(seed)
        x_mean = np.array([1.0] + [means[n_] for n_ in FEATURE_NAMES])
        preds = []
        for _ in range(16):
            idx = rng.integers(0, len(rows), size=len(rows))
            cb, *_ = np.linalg.lstsq(X[idx], y[idx], rcond=None)
            preds.append(float(x_mean @ cb))
        conf = float(np.std(preds))
    support = {
        "n": (min(float(r["n"]) for r in rows),
              max(float(r["n"]) for r in rows)),
        "m": (min(float(r.get("m") or 1) for r in rows),
              max(float(r.get("m") or 1) for r in rows)),
        "batch": (min(float(r.get("batch") or 1) for r in rows),
                  max(float(r.get("batch") or 1) for r in rows)),
    }
    points = [{"n": float(r["n"]), "m": float(r.get("m") or 0),
               "batch": float(r.get("batch") or 1),
               "delta": float(r.get("delta") or 0.0),
               "wall_ms": float(r["wall_ms"])} for r in rows]
    # keep the auto-Δ candidate's own measurement alongside each point's
    # argmin, so best_delta can demand a real margin before overriding
    auto_at: Dict[tuple, Dict[str, Any]] = {}
    for r in records:
        if r.get("delta_kind") == "auto":
            auto_at[_point_key(r)] = r
    for p, r in zip(points, rows):
        a = auto_at.get(_point_key(r))
        if a is not None:
            p["delta_auto"] = float(a.get("delta") or 0.0)
            p["wall_auto"] = float(a["wall_ms"])
    return EngineFit(engine=engine, nprocs=nprocs,
                     coef=tuple(float(c) for c in coef),
                     n_records=len(rows), rms_log_err=rms, conf_log=conf,
                     feature_means=means, support=support, points=points)


class CostModel:
    """Per-(engine, nprocs) fitted cost surfaces + statics lookup."""

    def __init__(self, fits: Dict[Tuple[str, int], EngineFit],
                 meta: Optional[Dict[str, Any]] = None):
        self.fits = fits
        self.meta = dict(meta or {})

    # -- queries ----------------------------------------------------------

    def fit_for(self, engine: str, nprocs: int = 1) -> Optional[EngineFit]:
        return self.fits.get((engine, int(nprocs)))

    def engines(self) -> List[Tuple[str, int]]:
        return sorted(self.fits)

    def predict(self, engine: str, *, n: int, m: Optional[int] = None,
                hops: Optional[float] = None, skew: Optional[float] = None,
                batch: int = 1, nprocs: int = 1) -> Optional[float]:
        """Predicted wall_ms, or None when no fit exists for the pair.
        Missing features are imputed with the fit's training means."""
        fit = self.fit_for(engine, nprocs)
        if fit is None:
            return None
        feats = {
            "log_n": _safe_log(n),
            "log_m": _safe_log(m) if m else None,
            "log_hops": _safe_log(hops) if hops else None,
            "log_skew": _safe_log(skew) if skew else None,
            "log_batch": _safe_log(batch or 1),
        }
        return float(math.exp(fit.predict_log(feats)))

    def in_support(self, engine: str, *, n: int, m: Optional[int] = None,
                   batch: Optional[int] = None, nprocs: int = 1,
                   margin: float = SUPPORT_MARGIN) -> bool:
        fit = self.fit_for(engine, nprocs)
        return (fit is not None
                and fit.n_records >= MIN_RECORDS
                and fit.in_support(n=n, m=m, batch=batch, margin=margin))

    def _nearest_points(self, engine: str, nprocs: int, n: int,
                        m: Optional[int]) -> List[Dict[str, float]]:
        fit = self.fit_for(engine, nprocs)
        if fit is None or not fit.points:
            return []
        ln, lm = _safe_log(n), _safe_log(m or 1)

        def dist(p):
            d = (_safe_log(p["n"]) - ln) ** 2
            if m:
                d += (_safe_log(p["m"]) - lm) ** 2
            return d

        dmin = min(dist(p) for p in fit.points)
        return [p for p in fit.points if dist(p) <= dmin + 1e-12]

    def best_delta(self, engine: str, *, n: int, m: Optional[int] = None,
                   nprocs: int = 1) -> Optional[float]:
        """Measured-best Δ at the nearest calibrated point (None when the
        engine has no fit or the nearest point carried no Δ).  When the
        calibration tagged the auto-Δ candidate, a non-default width is
        returned only if it beat the auto one by ``DELTA_WIN_MARGIN`` —
        a within-noise win keeps the graph-derived default."""
        pts = [p for p in self._nearest_points(engine, nprocs, n, m)
               if p.get("delta")]
        if not pts:
            return None
        best = min(pts, key=lambda p: p["wall_ms"])
        auto_wall = best.get("wall_auto")
        if (auto_wall and best.get("delta_auto")
                and best["delta"] != best["delta_auto"]
                and best["wall_ms"] > (1.0 - DELTA_WIN_MARGIN) * auto_wall):
            return float(best["delta_auto"])
        return float(best["delta"])

    def best_batch(self, *, n: int, m: Optional[int] = None,
                   nprocs: int = 1,
                   engine: str = "multisource_csr") -> Optional[int]:
        """Calibrated bucket size minimizing per-source cost at the
        nearest (n, m) point of the batched engine's fit."""
        fit = self.fit_for(engine, nprocs)
        if fit is None or not fit.points:
            return None
        ln, lm = _safe_log(n), _safe_log(m or 1)
        by_point: Dict[tuple, List[Dict[str, float]]] = {}
        for p in fit.points:
            by_point.setdefault((p["n"], p["m"]), []).append(p)
        key = min(by_point, key=lambda k: (_safe_log(k[0]) - ln) ** 2
                  + ((_safe_log(k[1]) - lm) ** 2 if m else 0.0))
        best = min(by_point[key],
                   key=lambda p: p["wall_ms"] / max(p["batch"], 1.0))
        return int(best["batch"])

    # -- coverage / io ----------------------------------------------------

    def coverage(self) -> Dict[str, Any]:
        return {
            "engines": [f"{e}@P{p}" for e, p in self.engines()],
            "records": sum(f.n_records for f in self.fits.values()),
            "rms_log_err": {f"{e}@P{p}": round(self.fits[(e, p)].rms_log_err, 4)
                            for e, p in self.engines()},
            "conf_log": {f"{e}@P{p}": round(self.fits[(e, p)].conf_log, 4)
                         for e, p in self.engines()},
        }

    def to_json(self) -> str:
        doc = {
            "schema": MODEL_SCHEMA,
            "meta": self.meta,
            "fits": [self.fits[k].to_dict() for k in sorted(self.fits)],
        }
        return json.dumps(doc, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CostModel":
        doc = json.loads(text)
        if doc.get("schema") != MODEL_SCHEMA:
            raise ValueError(
                f"cost-model schema {doc.get('schema')!r} != {MODEL_SCHEMA}")
        fits = {}
        for fd in doc["fits"]:
            fit = EngineFit.from_dict(fd)
            fits[(fit.engine, fit.nprocs)] = fit
        return cls(fits, doc.get("meta"))


def fit_model(records: List[Dict[str, Any]], *, seed: int = 0,
              min_records: int = MIN_RECORDS,
              meta: Optional[Dict[str, Any]] = None) -> CostModel:
    """Fit one :class:`CostModel` from calibration (or cost-log) record
    dicts.  Non-converged records are dropped; (engine, nprocs) groups
    with fewer than ``min_records`` distinct points are skipped and
    reported in ``meta["skipped"]``."""
    groups: Dict[Tuple[str, int], List[Dict[str, Any]]] = {}
    dropped = 0
    for r in records:
        if not r.get("converged", True) or float(r.get("wall_ms", 0)) <= 0:
            dropped += 1
            continue
        groups.setdefault((str(r["engine"]), int(r.get("nprocs") or 1)),
                          []).append(r)
    fits: Dict[Tuple[str, int], EngineFit] = {}
    skipped = []
    for key in sorted(groups):
        pts = _collapse_statics(groups[key])
        if len(pts) < min_records:
            skipped.append(f"{key[0]}@P{key[1]}:{len(pts)}")
            continue
        fits[key] = _fit_one(key[0], key[1], groups[key], seed)
    out_meta = dict(meta or {})
    out_meta.setdefault("seed", seed)
    out_meta["dropped_records"] = dropped
    out_meta["skipped_groups"] = skipped
    return CostModel(fits, out_meta)


def load_calibration(path: str) -> Tuple[List[Dict[str, Any]],
                                         Dict[str, Any]]:
    """Read a tune/calibrate.py ``CALIBRATION.json``; returns
    ``(records, meta)``."""
    with open(path) as f:
        doc = json.load(f)
    if "records" not in doc:
        raise ValueError(f"{path}: not a calibration file (no records)")
    return list(doc["records"]), dict(doc.get("meta") or {})


def load_model(path: str, *, seed: int = 0) -> CostModel:
    """Fit a model straight from a ``CALIBRATION.json`` file — the
    one-call path selectors and CLIs use."""
    records, meta = load_calibration(path)
    keep = {k: meta.get(k) for k in ("backend", "device_kind", "schema",
                                     "smoke", "created_unix")
            if k in meta}
    keep["calibration"] = path
    return fit_model(records, seed=seed, meta=keep)
