"""Measured-model engine selection behind the one dispatch seam.

``TunedPolicy`` is a drop-in :class:`~repro.serve.dispatch.DispatchPolicy`
whose ``choose()`` consults a fitted :class:`~repro.tune.model.CostModel`
instead of the hard-coded size thresholds — so both entry points that
already route through the seam (``api.shortest_paths(engine="auto")``
and ``MicroBatchScheduler``) become self-tuning by swapping the policy,
nothing else:

    from repro.tune import TunedPolicy, load_model
    from repro.serve.dispatch import policy_override

    policy = TunedPolicy(load_model("CALIBRATION.json"), nprocs=4)
    with policy_override(policy):
        res = shortest_paths(cg, 0, engine="auto")

Selection compares the model's predicted wall time across the engines
legal for the query kind and returns the argmin *with its statics*: the
measured-best Δ for the Δ-stepping engine and the calibrated bucket
ceiling B for batched solves ride the returned ``EngineChoice``
(``via="model"``), so every caller's magic numbers resolve through this
one place.

Conservative fallback (the contract tests pin): the hard-coded
threshold rules decide whenever

- the graph is dynamic (overlays never shard and repair off-seam),
- the graph is not CSR-backed (no cheap features),
- the query point is outside the calibrated support of the incumbent
  (the engine the threshold policy would pick) or the incumbent pair
  has no fit at this shard arity — the model only overrides defaults
  where it has measured both the default and an alternative.

Every candidate engine is exact (bitwise-equal-to-serial is an engine
family invariant, benchmarks/run_bench.py pins it), so selection can
never change answers — only wall time.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.serve.dispatch import DispatchPolicy, EngineChoice, serving_mesh
from repro.tune.model import CostModel

__all__ = ["TunedPolicy"]

# engines the model may race per query kind, single-device family
_SINGLE_CANDIDATES = {
    "single": ("frontier", "bellman_csr", "delta_stepping"),
    # the batched engine is the only one with the shared-gather source
    # axis; p2p stays on frontier for the target= early exit
    "batch": ("multisource_csr",),
    "p2p": ("frontier",),
}
_SHARDED_CANDIDATES = {
    "single": ("frontier_sharded", "bellman_csr_sharded"),
    "batch": ("multisource_csr_sharded",),
    "p2p": ("frontier_sharded",),
}


class TunedPolicy(DispatchPolicy):
    """Threshold policy + fitted cost model; see module docstring.

    ``model``: a fitted :class:`CostModel` (``tune.load_model(path)``).
    The threshold knobs (``shard_threshold`` etc.) keep their defaults
    and govern the fallback arm.  ``model_routed`` / ``fallback_routed``
    count which arm decided each ``choose()`` call.
    """

    def __init__(self, model: CostModel, **kwargs):
        super().__init__(**kwargs)
        self.model = model
        self.model_routed = 0
        self.fallback_routed = 0

    # -- feature extraction ------------------------------------------------

    @staticmethod
    def _csr_of(g):
        """The underlying static CsrGraph of ``g`` (a CsrGraph itself, a
        registry GraphHandle, or None for dense/dynamic inputs)."""
        from repro.core.csr import CsrGraph

        if isinstance(g, CsrGraph):
            return g
        cg = getattr(g, "cg", None)
        return cg if isinstance(cg, CsrGraph) else None

    # -- batched admission ceiling ----------------------------------------

    def batch_cap(self, g) -> Optional[int]:
        cg = self._csr_of(g)
        if cg is None or getattr(g, "dyn", None) is not None:
            return None
        engine = ("multisource_csr_sharded"
                  if self.would_shard(cg.n) else "multisource_csr")
        nprocs = self.nprocs if engine.endswith("_sharded") else 1
        if not self.model.in_support(engine, n=cg.n, m=cg.nnz,
                                     nprocs=nprocs):
            return None
        return self.model.best_batch(n=cg.n, m=cg.nnz, engine=engine,
                                     nprocs=nprocs)

    # -- selection ---------------------------------------------------------

    def _candidates(self, cg, kind: str) -> List[Tuple[str, int]]:
        """(engine, nprocs) pairs legal for this kind on this graph."""
        out = [(e, 1) for e in _SINGLE_CANDIDATES[kind]]
        if "delta_stepping" in _SINGLE_CANDIDATES[kind]:
            from repro.core.delta_stepping import delta_profile

            if not delta_profile(cg)["routable"]:
                out = [(e, p) for e, p in out if e != "delta_stepping"]
        if self.nprocs > 1 and self.shard_threshold is not None:
            out += [(e, self.nprocs) for e in _SHARDED_CANDIDATES[kind]]
        return out

    def choose(self, g, *, kind: str = "single") -> EngineChoice:
        base = super().choose(g, kind=kind)
        from repro.dynamic.overlay import DynamicGraph

        dynamic = (isinstance(g, DynamicGraph)
                   or getattr(g, "dyn", None) is not None)
        cg = self._csr_of(g)
        if dynamic or cg is None:
            self.fallback_routed += 1
            return base
        from repro.tune.features import graph_features

        feats = graph_features(cg)
        n, m = feats["n"], feats["m"]
        # conservative gate: the incumbent (threshold choice) must itself
        # be fitted and in calibrated support, else fall back outright.
        if not self.model.in_support(base.engine, n=n, m=m,
                                     nprocs=base.nprocs):
            self.fallback_routed += 1
            return base
        scored = []
        for engine, nprocs in self._candidates(cg, kind):
            if not self.model.in_support(engine, n=n, m=m, nprocs=nprocs):
                continue
            pred = self.model.predict(engine, n=n, m=m,
                                      hops=feats["hops"],
                                      skew=feats["skew"], nprocs=nprocs)
            if pred is not None and np.isfinite(pred):
                scored.append((float(pred), engine, nprocs))
        if not scored:
            self.fallback_routed += 1
            return base
        scored.sort()
        _, engine, nprocs = scored[0]
        self.model_routed += 1
        mesh = serving_mesh(nprocs, self.axis) if nprocs > 1 else None
        delta = (self.model.best_delta(engine, n=n, m=m, nprocs=nprocs)
                 if engine == "delta_stepping" else None)
        cap = (self.batch_cap(g) if kind == "batch" else None)
        return EngineChoice(engine, mesh, self.axis, nprocs,
                            delta=delta, batch_cap=cap, via="model")
