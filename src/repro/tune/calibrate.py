"""Calibration harness: sweep the engine matrix, write CALIBRATION.json.

The paper's central finding is that the winning strategy is workload-
dependent — so the crossovers must be *measured on the running backend*,
not baked in.  This harness sweeps every applicable engine over a small
design grid spanning the axes the selector will be asked about:

- graph family (random sparse / road-grid / skewed-hub — i.e. degree
  skew and frontier width, see tune/features.py),
- size (n, m), batch width S, shard arity P (when devices exist),
- Δ candidates for the Δ-stepping engine.

Every solve goes through the existing ``api.shortest_paths`` +
``obs.CostLog`` shim — the calibration records ARE ordinary v2 cost
records, plus the per-graph topology features and corpus tag the model
fits on.  Per configuration the harness runs one warmup (jit compile)
plus ``repeats`` timed calls and keeps the MIN-wall record, the same
best-of-N envelope benchmarks/common.py uses.

    PYTHONPATH=src python -m repro.tune.calibrate [--smoke] [--devices P]
        [--repeats N] [--out CALIBRATION.json]

``--smoke`` shrinks the grid to CI size (< ~1 min on CPU).  The output
is versioned (``schema``) and stamped with the measuring backend; models
fitted from it refuse to replay logs from a different backend
(tune/replay.py).
"""
from __future__ import annotations

import os
import sys

# Device count must be fixed before jax initializes; parse --devices by
# hand (same pattern as benchmarks/run_bench.py).
_DEFAULT_DEVICES = 1
if __name__ == "__main__" and "--help" not in sys.argv and "-h" not in sys.argv:
    _n = _DEFAULT_DEVICES
    for _i, _a in enumerate(sys.argv):
        try:
            if _a == "--devices":
                _n = int(sys.argv[_i + 1])
            elif _a.startswith("--devices="):
                _n = int(_a.split("=", 1)[1])
        except (IndexError, ValueError):
            break
    if _n > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n} "
            + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import platform
import time
from typing import Any, Dict, List, Optional

import numpy as np

CALIBRATION_SCHEMA = 1
DEFAULT_OUT = "CALIBRATION.json"

# (corpus, n, m) grid points; m is None for the generator-shaped corpora
FULL_GRID = (
    ("sparse", 5000, 15000),
    ("sparse", 10000, 30000),
    ("sparse", 10000, 80000),     # m-variation: separates log m from log n
    ("sparse", 20000, 60000),
    ("road", 2500, None),
    ("road", 10000, None),
    ("road", 20000, None),
    ("hub", 2500, None),
    ("hub", 10000, None),
    ("hub", 20000, None),
)
SMOKE_GRID = (
    ("sparse", 256, 768),
    ("sparse", 512, 1536),
    ("sparse", 1024, 3072),
    ("sparse", 1024, 8192),
    ("road", 256, None),
    ("road", 1024, None),
    ("hub", 256, None),
    ("hub", 1024, None),
)

BATCHES_FULL = (4, 16)
BATCHES_SMOKE = (2, 4)


def make_graph(corpus: str, n: int, m: Optional[int]):
    from repro.core import csr as C

    if corpus == "sparse":
        return C.random_csr_graph(n, m, seed=n + m)
    if corpus == "road":
        return C.road_like_csr_graph(n, seed=n)
    if corpus == "hub":
        return C.skewed_hub_csr_graph(n, seed=n)
    raise ValueError(f"unknown corpus {corpus!r}")


def _delta_candidates(cg, smoke: bool) -> List[float]:
    """Δ widths to race for one graph: the profile's auto width always;
    full runs bracket it so the model can find a measured-better one."""
    from repro.core.delta_stepping import auto_delta

    d0 = float(auto_delta(cg))
    if smoke:
        return [d0]
    return [d0, d0 / 8.0, d0 * 2.0]


def _measure(fn, cost_log, repeats: int, extra: Dict[str, Any]):
    """warmup + repeats through the api shim; returns the min-wall cost
    record (as a dict) annotated with ``extra``."""
    fn()                              # jit warm; its record is discarded
    start = len(cost_log.records)
    for _ in range(repeats):
        fn()
    recs = cost_log.records[start:]
    best = min(recs, key=lambda r: r.wall_ms)
    row = best.to_dict()
    row.update(extra)
    return row


def sweep(grid, *, repeats: int = 3, devices: int = 1,
          smoke: bool = False, batches=None,
          verbose: bool = True) -> List[Dict[str, Any]]:
    """Run the calibration sweep over ``grid``; returns record dicts."""
    import jax

    from repro.core.api import shortest_paths
    from repro.core.delta_stepping import delta_profile
    from repro.obs import CostLog, set_cost_log
    from repro.tune.features import graph_features

    batches = batches if batches is not None else (
        BATCHES_SMOKE if smoke else BATCHES_FULL)
    mesh = None
    if devices > 1:
        if jax.device_count() < devices:
            raise SystemExit(
                f"--devices {devices} needs {devices} XLA devices but only "
                f"{jax.device_count()} exist (run via `python -m "
                f"repro.tune.calibrate`, which forces the host count)")
        from repro.core._compat import make_mesh
        mesh = make_mesh((devices,), ("data",))

    log = CostLog()
    prev = set_cost_log(log)
    records: List[Dict[str, Any]] = []
    try:
        for corpus, n, m in grid:
            cg = make_graph(corpus, n, m)
            feats = graph_features(cg)
            extra = {"corpus": corpus, "hops": feats["hops"],
                     "skew": round(feats["skew"], 4),
                     "width": round(feats["width"], 2),
                     "repeats": repeats}
            srcs = np.linspace(0, cg.n - 1, max(batches)).astype(np.int32)

            def tag(row):
                records.append(row)
                if verbose:
                    print(f"  {corpus} n={cg.n:6d} {row['engine']:24s} "
                          f"B={row['batch']:<3d} P={row['nprocs']} "
                          f"delta={row['delta']:<12.4g} "
                          f"{row['wall_ms']:9.2f}ms", flush=True)

            for engine in ("frontier", "bellman_csr"):
                tag(_measure(lambda e=engine: shortest_paths(cg, 0, engine=e),
                             log, repeats, extra))
            if delta_profile(cg)["routable"]:
                for j, dv in enumerate(_delta_candidates(cg, smoke)):
                    # the first candidate is the profile's auto width;
                    # model.best_delta only overrides it when an alt
                    # wins by a real margin (noise-robust statics)
                    kind = "auto" if j == 0 else "alt"
                    tag(_measure(
                        lambda d=dv: shortest_paths(
                            cg, 0, engine="delta_stepping", delta=d),
                        log, repeats, dict(extra, delta_kind=kind)))
            for b in batches:
                tag(_measure(
                    lambda b=b: shortest_paths(
                        cg, srcs[:b], engine="multisource_csr"),
                    log, repeats, extra))
            if mesh is not None:
                for engine in ("frontier_sharded", "bellman_csr_sharded"):
                    tag(_measure(
                        lambda e=engine: shortest_paths(
                            cg, 0, engine=e, mesh=mesh),
                        log, repeats, extra))
                for b in batches:
                    tag(_measure(
                        lambda b=b: shortest_paths(
                            cg, srcs[:b], engine="multisource_csr_sharded",
                            mesh=mesh),
                        log, repeats, extra))
    finally:
        set_cost_log(prev)
    return records


def run(smoke: bool = False, repeats: int = 3, devices: int = 1,
        out: str = DEFAULT_OUT, verbose: bool = True) -> str:
    import jax

    from repro.obs import backend_info

    grid = SMOKE_GRID if smoke else FULL_GRID
    t0 = time.time()
    records = sweep(grid, repeats=repeats, devices=devices, smoke=smoke,
                    verbose=verbose)
    backend, device_kind = backend_info()
    doc = {
        "schema": CALIBRATION_SCHEMA,
        "meta": {
            "created_unix": int(time.time()),
            "jax": jax.__version__,
            "backend": backend,
            "device_kind": device_kind,
            "platform": platform.platform(),
            "devices": devices,
            "smoke": smoke,
            "repeats": repeats,
            "grid_points": len(grid),
            "sweep_seconds": round(time.time() - t0, 1),
        },
        "records": records,
    }
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    if verbose:
        print(f"\nwrote {len(records)} calibration records to {out} "
              f"({doc['meta']['sweep_seconds']}s)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (< ~1 min on CPU)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--devices", type=int, default=_DEFAULT_DEVICES,
                    help="mesh size for the sharded engines (forced host "
                         "device count on CPU); 1 drops the sharded leg")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(args.smoke, repeats=args.repeats, devices=args.devices,
        out=args.out)
