"""Trace-replay perf regression gate: recorded cost log vs fitted model.

Correctness regressions are caught by replaying traces bitwise
(launch/sssp_serve.py); this is the perf analogue.  Given a recorded
serve/churn/bench cost log (obs/profile.py JSONL or an in-memory list)
and a calibration file, re-run every record through the fitted cost
model and fail when measured wall time drifts above prediction beyond a
tolerance — a hot path that silently got slower fails CI the same way a
wrong answer would.

Drift is judged per (engine, nprocs) group on the MEDIAN of per-record
``measured / predicted`` ratios: medians absorb the one-off outliers a
shared CI box produces, and the grouping stops one noisy engine from
hiding another's regression.  The gate is ONE-SIDED by default —
measured faster than predicted is never a failure (serve p2p solves
early-exit and legitimately beat the full-solve calibration; a future
optimization should not fail the gate).  Records outside the model's
calibrated support, from unfitted engines (e.g. dynamic ``repair``), or
non-converged are skipped and reported as uncovered, never silently.

Backends must match: a cost log measured on a different backend than the
calibration is refused (that is what the v2 ``backend`` field exists
for) unless ``--allow-backend-mismatch``.

    PYTHONPATH=src python -m repro.tune.replay COSTS.jsonl \
        --calibration CALIBRATION.json [--tol 3.0] [--min-records 3]

Exit 0 = within tolerance, 1 = drift (or nothing replayable), 2 = usage.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Any, Dict, List, Optional

from repro.tune.model import CostModel, load_model

__all__ = ["replay_records", "read_cost_jsonl", "main"]

DEFAULT_TOL = 3.0      # median measured/predicted above this fails
DEFAULT_MIN_RECORDS = 3


def read_cost_jsonl(path: str) -> List[Dict[str, Any]]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def _median(xs: List[float]) -> float:
    s = sorted(xs)
    k = len(s) // 2
    return s[k] if len(s) % 2 else 0.5 * (s[k - 1] + s[k])


def replay_records(records: List[Dict[str, Any]], model: CostModel, *,
                   tol: float = DEFAULT_TOL,
                   min_records: int = DEFAULT_MIN_RECORDS,
                   two_sided: bool = False,
                   expect_backend: Optional[str] = None) -> Dict[str, Any]:
    """Replay ``records`` against ``model``; returns the gate report.

    ``report["pass"]`` is False iff some (engine, nprocs) group with at
    least ``min_records`` replayable records drifts beyond ``tol``
    (measured/predicted median > tol; with ``two_sided`` also < 1/tol),
    or a backend mismatch is detected, or nothing was replayable at all.
    """
    groups: Dict[str, List[float]] = {}
    skipped: Dict[str, int] = {}
    backend_mismatch = 0
    for r in records:
        be = r.get("backend") or ""
        if expect_backend and be and be != expect_backend:
            backend_mismatch += 1
            continue
        engine = str(r.get("engine", ""))
        nprocs = int(r.get("nprocs") or 1)
        key = f"{engine}@P{nprocs}"
        if not r.get("converged", True):
            skipped["not_converged"] = skipped.get("not_converged", 0) + 1
            continue
        wall = float(r.get("wall_ms") or 0.0)
        if wall <= 0:
            skipped["zero_wall"] = skipped.get("zero_wall", 0) + 1
            continue
        if model.fit_for(engine, nprocs) is None:
            skipped[f"unfitted:{key}"] = skipped.get(f"unfitted:{key}",
                                                     0) + 1
            continue
        if not model.in_support(engine, n=int(r["n"]),
                                m=int(r.get("m") or 0) or None,
                                nprocs=nprocs):
            skipped[f"out_of_support:{key}"] = skipped.get(
                f"out_of_support:{key}", 0) + 1
            continue
        pred = model.predict(engine, n=int(r["n"]),
                             m=int(r.get("m") or 0) or None,
                             batch=int(r.get("batch") or 1),
                             nprocs=nprocs)
        if pred is None or not math.isfinite(pred) or pred <= 0:
            skipped[f"unpredictable:{key}"] = skipped.get(
                f"unpredictable:{key}", 0) + 1
            continue
        groups.setdefault(key, []).append(wall / pred)

    per_engine = {}
    failures = []
    for key in sorted(groups):
        ratios = groups[key]
        med = _median(ratios)
        counted = len(ratios) >= min_records
        drift = med > tol or (two_sided and med < 1.0 / tol)
        per_engine[key] = {
            "records": len(ratios),
            "median_ratio": round(med, 4),
            "max_ratio": round(max(ratios), 4),
            "counted": counted,
            "drift": bool(counted and drift),
        }
        if counted and drift:
            failures.append(key)
    replayed = sum(len(v) for v in groups.values())
    ok = not failures and replayed > 0 and backend_mismatch == 0
    return {
        "rule": (f"per-(engine,nprocs) median measured/predicted wall "
                 f"must stay <= {tol}x"
                 + (f" and >= {1/tol:.3g}x" if two_sided else "")
                 + f" (groups under {min_records} records reported, "
                 f"not gated)"),
        "tol": tol,
        "two_sided": two_sided,
        "replayed": replayed,
        "skipped": skipped,
        "backend_mismatch": backend_mismatch,
        "engines": per_engine,
        "failures": failures,
        "pass": bool(ok),
    }


def main(argv: List[str]) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune.replay",
        description="replay a recorded cost log against the fitted model")
    ap.add_argument("costs", help="cost-record JSONL (obs/profile.py)")
    ap.add_argument("--calibration", required=True,
                    help="CALIBRATION.json to fit the model from")
    ap.add_argument("--tol", type=float, default=DEFAULT_TOL)
    ap.add_argument("--min-records", type=int, default=DEFAULT_MIN_RECORDS)
    ap.add_argument("--two-sided", action="store_true",
                    help="also fail when measured is tol-times FASTER "
                         "than predicted (default: one-sided)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--allow-backend-mismatch", action="store_true")
    args = ap.parse_args(argv)

    model = load_model(args.calibration, seed=args.seed)
    records = read_cost_jsonl(args.costs)
    if not records:
        print("no cost records to replay", file=sys.stderr)
        return 1
    expect = None
    if not args.allow_backend_mismatch:
        expect = str(model.meta.get("backend") or "") or None
    report = replay_records(records, model, tol=args.tol,
                            min_records=args.min_records,
                            two_sided=args.two_sided,
                            expect_backend=expect)
    print(json.dumps(report, indent=1))
    if report["backend_mismatch"]:
        print(f"REPLAY FAIL: {report['backend_mismatch']} records from a "
              f"different backend than the calibration "
              f"({model.meta.get('backend')!r}); re-calibrate or pass "
              f"--allow-backend-mismatch", file=sys.stderr)
        return 1
    if report["replayed"] == 0:
        print("REPLAY FAIL: zero replayable records (all skipped)",
              file=sys.stderr)
        return 1
    if not report["pass"]:
        print(f"REPLAY FAIL: drift beyond {args.tol}x in "
              f"{report['failures']}", file=sys.stderr)
        return 1
    print(f"replay OK: {report['replayed']} records, "
          f"{len(report['engines'])} engine groups within {args.tol}x")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
