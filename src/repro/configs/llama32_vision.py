"""llama-3.2-vision-11b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision.

40-layer LM backbone: d_model 4096, 32 Q / 8 KV heads, head_dim 128,
d_ff 14336, vocab 128256; gated cross-attention image layers every 5th
layer (absolute layers 3, 8, ..., 38 — the (GGGCG, 8) pattern).  The vision
tower is a STUB per the assignment: ``input_specs()`` supplies 6400
precomputed patch embeddings at d_model (≈4 tiles × 1601 patches).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    segments=(("GGGCG", 8),),
    num_image_tokens=6400,
    rope_theta=500_000.0,
    bf16_partial_reduce=True,
    tie_embeddings=False,
)
