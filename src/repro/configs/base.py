"""Model / run configuration schema.

One frozen dataclass covers every assigned architecture family
(dense | moe | ssm | hybrid | vlm | audio).  The layer stack is described by
``segments``: an ordered list of (pattern, n_rep) pairs, where ``pattern`` is
a string of per-layer kinds repeated ``n_rep`` times.  Parameters inside a
segment are stacked over reps and the forward pass ``lax.scan``s over them,
so HLO size is O(pattern length), not O(depth) — a 61-layer 1T-param model
lowers in seconds.

Layer kinds:
    G  global (full / causal) attention + FFN (dense or MoE per config)
    L  local sliding-window attention + FFN
    C  cross-attention (+ FFN) — VLM image layers, enc-dec decoder layers
    M  Mamba2 (SSD) block
    S  Mamba2 block followed by the *shared* attention block (Zamba2)
    D  attention + dense FFN even when the model is MoE (Kimi's first layer)
    E  encoder self-attention (bidirectional) + FFN  (enc-dec encoder)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

Segments = Tuple[Tuple[str, int], ...]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    segments: Segments               # decoder / main stack
    # ---- attention ----
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: float = 0.0        # 0 = off (gemma2: 50.0)
    logit_softcap: float = 0.0       # 0 = off (gemma2: 30.0)
    rope_theta: float = 10_000.0
    local_rope_theta: Optional[float] = None   # gemma3: local layers use 10k
    sliding_window: int = 0          # window for 'L' layers
    use_post_norms: bool = False     # gemma2/3 sandwich norms
    # ---- MoE ----
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    expert_pad_to: int = 0           # pad expert count for EP divisibility
    moe_impl: str = "gspmd"          # gspmd | ep (shard_map expert-parallel)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # ---- SSM (Mamba2 / SSD) ----
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # ---- VLM ----
    num_image_tokens: int = 0        # vision stub sequence length
    # ---- enc-dec (audio) ----
    encoder_segments: Segments = ()
    audio_downsample: int = 8        # frames = seq_len // downsample
    # ---- numerics / misc ----
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d)
    remat: str = "full"              # none | dots | full (block-granularity)
    bf16_partial_reduce: bool = False  # TP partial-sums reduced in bf16
                                       # (halves Megatron-AR bytes; §Perf)
    loss_chunk: int = 512            # CE computed seq-chunked (0 = off)

    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return sum(len(p) * r for p, r in self.segments)

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_out_dim(self) -> int:
        return self.num_heads * self.head_dim

    def layer_kinds(self) -> list[str]:
        out = []
        for pat, rep in self.segments:
            out.extend(list(pat) * rep)
        return out

    # ---- analytic parameter / FLOP accounting (roofline §) -------------
    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        n = 0
        n += self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for kind in self.layer_kinds():
            if kind in "GLDE":
                n += d * (self.num_heads + 2 * self.num_kv_heads) * hd
                n += self.num_heads * hd * d
                if kind == "D" or self.num_experts == 0:
                    n += 3 * d * self.d_ff
                else:
                    n += self.num_experts * 3 * d * self.moe_d_ff
                    n += self.num_shared_experts * 3 * d * self.moe_d_ff
                    n += d * self.num_experts      # router
            elif kind == "C":
                n += d * (self.num_heads + 2 * self.num_kv_heads) * hd
                n += self.num_heads * hd * d
                n += 3 * d * self.d_ff
            elif kind in "MS":
                di, ns = self.d_inner, self.ssm_state
                n += d * (2 * di + 2 * ns + self.ssm_heads)  # in_proj
                n += di * d                                   # out_proj
                n += (di + 2 * ns) * self.ssm_conv            # conv
                n += 3 * self.ssm_heads                       # A, D, dt_bias
                if kind == "S":
                    pass  # shared block counted once below
        if any("S" in p for p, _ in self.segments):
            n += d * (self.num_heads + 2 * self.num_kv_heads) * hd
            n += self.num_heads * hd * d + 3 * d * self.d_ff
        for pat, rep in self.encoder_segments:
            for kind in pat * rep:
                n += d * (self.num_heads + 2 * self.num_kv_heads) * hd
                n += self.num_heads * hd * d + 3 * d * self.d_ff
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.num_experts == 0:
            return self.param_count()
        full = self.param_count()
        moe_layers = sum(
            1 for k in self.layer_kinds() if k in "GL" and self.num_experts
        )
        inactive = (
            moe_layers
            * (self.num_experts - self.moe_top_k)
            * 3 * self.d_model * self.moe_d_ff
        )
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# reduced shapes for CPU smoke tests
SMOKE_SHAPES = {
    "train": ShapeConfig("smoke_train", "train", 64, 2),
    "prefill": ShapeConfig("smoke_prefill", "prefill", 64, 2),
    "decode": ShapeConfig("smoke_decode", "decode", 64, 2),
}
