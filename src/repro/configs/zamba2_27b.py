"""zamba2-2.7b [hybrid] — arXiv:2411.15242.

54 Mamba2 layers, d_model 2560, ssm_state 64 (d_inner 5120, head_dim 64 →
80 SSM heads), with a single weight-SHARED attention+FFN block (32 heads,
head_dim 80, d_ff 10240) invoked after every 6th Mamba layer — the
(MMMMMS, 9) segment pattern.  vocab 32000.  The released model's
LoRA-per-invocation refinement of the shared block is omitted (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab_size=32_000,
    segments=(("MMMMMS", 9),),
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
