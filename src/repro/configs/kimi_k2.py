"""kimi-k2-1t-a32b [moe] — arXiv:2501.kimi2 (paper-table config).

61L, d_model 7168, 64 Q / 8 KV heads (assignment specifies GQA kv=8; the
released K2 uses MLA — recorded as a deviation in DESIGN.md §6), head_dim
128, vocab 163840, MoE: 384 experts / top-8 / expert d_ff 2048 + 1 shared
expert.  First layer dense (d_ff 18432), remaining 60 MoE — hence the
(D, 1), (G, 60) segment split.  ~1.04T params, ~32B active.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18_432,                 # the single dense layer
    vocab_size=163_840,
    segments=(("D", 1), ("G", 60)),
    num_experts=384,
    num_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    rope_theta=50_000.0,
    moe_impl="ep",
    bf16_partial_reduce=True,
    tie_embeddings=False,
)
