"""qwen1.5-0.5b [dense] — hf:Qwen/Qwen1.5-0.5B.

24L, d_model 1024, 16 heads (MHA: kv=16), head_dim 64, d_ff 2816,
vocab 151936, QKV bias, RoPE theta 1e6, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    segments=(("G", 24),),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
