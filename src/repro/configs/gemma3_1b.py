"""gemma3-1b [dense] — hf:google/gemma-3-1b-pt.

26L, d_model 1152, 4 Q heads / 1 KV head (GQA), head_dim 256, d_ff 6912,
vocab 262144, 5:1 local:global layer pattern (sliding window 512), dual RoPE
theta (10k local / 1M global), QK-norm, sandwich norms, tied embeddings.
26 = 4×(5L+1G) + 2 trailing local layers.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    segments=(("LLLLLG", 4), ("LL", 1)),
    sliding_window=512,
    rope_theta=1_000_000.0,
    local_rope_theta=10_000.0,
    qk_norm=True,
    use_post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
)
