"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B.

24L, d_model 2048, 16 heads (MHA kv=16), head_dim 128, vocab 151936,
MoE: 60 routed experts / top-4 / expert d_ff 1408 + 4 shared experts
(fused 4×1408 = 5632 shared width), QKV bias.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,                   # dense fallback width (unused: all-MoE)
    vocab_size=151_936,
    segments=(("G", 24),),
    num_experts=60,
    expert_pad_to=64,        # 4 dead experts -> expert-parallel over 16 chips
    num_shared_experts=4,
    moe_top_k=4,
    moe_d_ff=1408,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    moe_impl="ep",
    bf16_partial_reduce=True,
    tie_embeddings=False,
)
