"""gemma2-2b [dense] — arXiv:2408.00118.

26L, d_model 2304, 8 Q / 4 KV heads, head_dim 256, d_ff 9216, vocab 256000,
alternating local(4096):global layers, attn softcap 50, final logit softcap
30, sandwich norms, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    segments=(("LG", 13),),
    sliding_window=4096,
    rope_theta=10_000.0,
    attn_softcap=50.0,
    logit_softcap=30.0,
    use_post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
)
