"""Architecture registry: ``get_config(arch_id)`` / ``make_smoke(cfg)``.

Every assigned architecture is selectable by id (``--arch <id>``); smoke
variants keep the family structure (segment patterns, GQA ratios, MoE
routing, SSD shapes) at toy width so one CPU forward/train step runs in
seconds.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import SHAPES, SMOKE_SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "gemma2-2b": "gemma2_2b",
    "qwen1.5-0.5b": "qwen15_05b",
    "phi4-mini-3.8b": "phi4_mini",
    "kimi-k2-1t-a32b": "kimi_k2",
    "qwen2-moe-a2.7b": "qwen2_moe",
    "mamba2-130m": "mamba2_130m",
    "zamba2-2.7b": "zamba2_27b",
    "llama-3.2-vision-11b": "llama32_vision",
    "seamless-m4t-medium": "seamless_m4t",
}

ARCHS = tuple(_MODULES)

# archs with only full-attention layers skip long_500k (needs sub-quadratic
# attention; see DESIGN.md §Arch-applicability).
LONG_CONTEXT_ARCHS = ("gemma3-1b", "gemma2-2b", "mamba2-130m", "zamba2-2.7b")


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    import importlib

    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) cells.  40 total; long_500k is only
    runnable for sub-quadratic archs."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            runnable = (shape.name != "long_500k"
                        or arch in LONG_CONTEXT_ARCHS)
            if runnable or include_skipped:
                out.append((arch, shape.name, runnable))
    return out


def make_smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: tiny widths, same structure."""
    kv = max(1, 4 * cfg.num_kv_heads // max(cfg.num_heads, 1))
    seg = tuple((pat, min(rep, 2)) for pat, rep in cfg.segments)
    enc = tuple((pat, min(rep, 2)) for pat, rep in cfg.encoder_segments)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        d_model=64,
        num_heads=4,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        segments=seg,
        encoder_segments=enc,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        num_experts=min(cfg.num_experts, 8),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        num_image_tokens=32 if cfg.num_image_tokens else 0,
        loss_chunk=0,
        remat="none",
        # XLA:CPU cannot execute bf16 grouped dots (DotThunk); smoke runs
        # f32 — the bf16 path is exercised by the dry-run (compile-only).
        param_dtype="float32",
    )


__all__ = [
    "ARCHS", "LONG_CONTEXT_ARCHS", "SHAPES", "SMOKE_SHAPES",
    "ModelConfig", "ShapeConfig", "get_config", "make_smoke", "cells",
]
