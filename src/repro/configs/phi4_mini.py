"""phi4-mini-3.8b [dense] — arXiv:2412.08905.

32L, d_model 3072, 24 Q / 8 KV heads (GQA), head_dim 128, d_ff 8192,
vocab 200064, RoPE + SwiGLU, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    segments=(("G", 32),),
    rope_theta=10_000.0,
    tie_embeddings=True,
)
