"""seamless-m4t-medium [audio] — arXiv:2308.11596.

Encoder-decoder transformer backbone: 12 encoder + 12 decoder layers,
d_model 1024, 16 heads (MHA), head_dim 64, d_ff 4096, vocab 256206 padded
to 256256 (128-multiple for shardable embeddings).  The speech frontend is
a STUB per the assignment: ``input_specs()`` supplies precomputed frame
embeddings of length seq_len // 8 (audio downsampling).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_256,          # 256206 padded to 128-multiple
    segments=(("X", 12),),
    encoder_segments=(("E", 12),),
    audio_downsample=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
)
