"""mamba2-130m [ssm] — arXiv:2405.21060 (SSD / state-space duality).

24L, d_model 768, attention-free, ssm_state 128, expand 2 (d_inner 1536),
head_dim 64 (24 SSM heads), vocab 50280 padded to 50304 (next multiple of
128, for TP-shardable embeddings — GPT-NeoX tokenizer padding convention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    d_model=768,
    num_heads=1,                 # unused: attention-free
    num_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=50_304,           # 50280 padded to 128-multiple
    segments=(("M", 24),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
)
