"""Jittable train / serve steps.

``make_train_step``: pjit-style step (GSPMD distributes via in/out
shardings chosen by sharding/rules.py): value_and_grad -> clip -> AdamW.
Optional gradient-accumulation microbatching (scan over microbatches with
fp32 accumulators).

``make_ddp_train_step``: an explicit shard_map data-parallel step used to
exercise the int8 error-feedback gradient compression path (params
replicated in the DP group, local grads, compressed mean, identical
updates on every rank).

``make_prefill_step`` / ``make_decode_step``: serving entry points matching
the assigned prefill/decode/long cells.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core._compat import shard_map
from repro.models import transformer as T
from repro.train import compression as comp
from repro.train.optimizer import OptConfig, adamw_update
from repro.train.state import TrainState


def make_train_step(cfg, opt_cfg: OptConfig, *, grad_accum: int = 1):
    def loss_fn(params, batch):
        return T.train_loss(params, batch, cfg)

    def train_step(state: TrainState, batch):
        if grad_accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            B = batch["tokens"].shape[0]
            assert B % grad_accum == 0
            mb = B // grad_accum
            stacked = jax.tree.map(
                lambda x: x.reshape((grad_accum, mb) + x.shape[1:]), batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def body(carry, microbatch):
                acc_g, acc_l = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, microbatch)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g)
                return (acc_g, acc_l + l), m

            (grads, loss_sum), ms = lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), stacked)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)

        new_params, new_opt, om = adamw_update(
            grads, state.opt_state, state.params, opt_cfg)
        new_state = TrainState(new_params, new_opt, state.step + 1)
        return new_state, {"loss": loss, **metrics, **om}

    return train_step


def make_ddp_train_step(cfg, opt_cfg: OptConfig, mesh, *, axis: str = "data",
                        compress: bool = True):
    """Explicit-DP step over ``mesh[axis]`` with int8 EF compression."""

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), P(), P(), P(axis)),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
    def step(params, opt_state, err, batch):
        def loss_fn(p):
            loss, m = T.train_loss(p, batch, cfg)
            return loss, m

        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if compress:
            grads, err = comp.compress_tree(grads, err, axis)
        else:
            grads = jax.tree.map(lambda g: lax.pmean(g, axis), grads)
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               opt_cfg)
        return new_params, new_opt, err, lax.pmean(loss, axis)

    return step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, *, max_len: int):
    def prefill_step(params, tokens, image_embeds=None, encoder_frames=None):
        return T.prefill(params, tokens, cfg, max_len=max_len,
                         image_embeds=image_embeds,
                         encoder_frames=encoder_frames)
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, token, pos, caches, image_embeds=None):
        return T.decode_step(params, token, pos, caches, cfg,
                             image_embeds=image_embeds)
    return decode_step
