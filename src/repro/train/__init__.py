"""Training substrate package: optimizer, state, step, compression."""
