"""int8 error-feedback gradient compression for the data-parallel axis.

Scheme (1-bit-Adam-family): each DP rank quantizes (grad + carried error)
to int8 with a per-tensor scale, all-gathers the quantized shards, and
dequant-averages locally; the quantization residual is carried into the
next step (error feedback), which keeps SGD/Adam convergence (Karimireddy
et al., arXiv:1901.09847).  Payload per step is n/4 bytes per rank versus
2n for a ring all-reduce — a win on slow cross-pod links when the DP group
is small (the "pod" axis: 2), and exactly the kind of distributed-
optimization trick the assignment asks for.  Used by the shard_map DDP
trainer (train/step.py: make_ddp_train_step); off by default elsewhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8.  Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_mean(g: jax.Array, err: jax.Array, axis: str):
    """Inside shard_map: error-feedback int8 all-gather mean over ``axis``.

    Returns (g_hat mean-of-dequantized, new_err).
    """
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    qs = lax.all_gather(q, axis)                 # (P, ...) int8 payload
    ss = lax.all_gather(scale, axis)             # (P,)
    g_hat = jnp.mean(qs.astype(jnp.float32)
                     * ss.reshape((-1,) + (1,) * g.ndim), axis=0)
    return g_hat, new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_tree(grads, err_state, axis: str):
    """Apply compressed_mean leaf-wise."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    outs = [compressed_mean(g, e, axis) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, new_e
