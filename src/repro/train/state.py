"""Train state pytree + construction helpers (shape-only or materialized)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.train.optimizer import OptConfig, init_opt_state


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(key, cfg, opt_cfg: OptConfig) -> TrainState:
    params = T.init_params(key, cfg)
    return TrainState(
        params=params,
        opt_state=init_opt_state(params, opt_cfg),
        step=jnp.zeros((), jnp.int32),
    )


def train_state_shape(cfg, opt_cfg: OptConfig):
    """ShapeDtypeStruct pytree of the state — no allocation (dry-run path)."""
    return jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg))
