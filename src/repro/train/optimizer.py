"""AdamW with warmup+cosine schedule, global-norm clipping, weight decay,
and configurable moment dtype.

Moment dtype matters at the assigned scale: Kimi-K2's ~1.04T params make
fp32 Adam moments (8.3 TB) untenable on 512 × 16 GB chips; bf16 moments
halve that and are the default for the 1T-class dry-run cells (recorded in
EXPERIMENTS.md §Dry-run).  Everything is a pure function over pytrees so
the whole update stays inside the jitted train step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"     # float32 | bfloat16


def schedule(step, cfg: OptConfig):
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params, cfg: OptConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / scalars."""
    name = getattr(path[-1], "key", "")
    return name not in ("scale", "conv_b", "bq", "bk", "bv", "A_log", "D",
                        "dt_bias", "norm", "gate", "gate_ffn")


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    lr = schedule(count, cfg)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(path, p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu_f = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu_f = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g * g
        step = (mu_f / c1) / (jnp.sqrt(nu_f / c2) + cfg.eps)
        if _decay_mask(path):
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mu_f.astype(mdt), nu_f.astype(mdt)

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_l = treedef.flatten_up_to(grads)
    mu_l = treedef.flatten_up_to(opt_state["mu"])
    nu_l = treedef.flatten_up_to(opt_state["nu"])
    outs = [upd(path, p, g, mu, nu)
            for (path, p), g, mu, nu in zip(paths_leaves, g_l, mu_l, nu_l)]
    new_params = treedef.unflatten([o[0] for o in outs])
    new_mu = treedef.unflatten([o[1] for o in outs])
    new_nu = treedef.unflatten([o[2] for o in outs])
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    return new_params, new_state, {"grad_norm": gn, "lr": lr}
