"""Logical-axis → mesh-axis sharding rules with divisibility fallback.

MaxText-style: every tensor dim carries an ordered preference list of
*logical* axes; a logical axis resolves to one or more mesh axes ("dp" →
("pod", "data") on the multi-pod mesh); an assignment is taken only if the
dim is divisible by the product of the mesh-axis sizes and no mesh axis is
used twice in one spec.  Anything unassigned is replicated — e.g. gemma3's
4 Q-heads on a 16-way model axis fall back to replicated heads while FFN
and vocab stay 16-way tensor-parallel, and qwen2-moe's 60 experts fall back
to sharding the expert FFN dim instead.

Scheme (baseline):
  batch        -> dp  = ("pod", "data")
  heads/ff/vocab/experts -> tp = ("model",)
  param non-TP dim       -> fsdp = ("pod", "data")   (ZeRO-3-style)
  decode KV cache        -> batch over dp, kv-heads over tp,
                            sequence over dp when batch=1 (long_500k).
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding

from repro.core._compat import get_abstract_mesh
from jax.sharding import PartitionSpec as P


def logical_map(mesh: Mesh) -> dict[str, tuple[str, ...]]:
    names = mesh.axis_names
    return {
        "dp": tuple(a for a in ("pod", "data") if a in names),
        "data": tuple(a for a in ("data",) if a in names),
        "pod": tuple(a for a in ("pod",) if a in names),
        "tp": tuple(a for a in ("model",) if a in names),
    }


def _axis_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def assign_spec(shape: Sequence[int], prefs: Sequence[Sequence[str]],
                mesh: Mesh) -> P:
    """prefs[i] = ordered logical-axis candidates for dim i."""
    lm = logical_map(mesh)
    used: set[str] = set()
    out: list[Any] = [None] * len(shape)
    for i, cands in enumerate(prefs):
        for logical in cands:
            axes = lm.get(logical, ())
            if not axes or any(a in used for a in axes):
                continue
            if shape[i] % _axis_size(mesh, axes) != 0:
                continue
            out[i] = axes if len(axes) > 1 else axes[0]
            used.update(axes)
            break
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules (matched on leaf name; see models/* for layouts)
# ---------------------------------------------------------------------------

_PARAM_RULES: dict[str, list[list[str]]] = {
    # name: prefs per dim (excluding any leading scan-rep dim)
    "tok":      [["tp"], ["dp"]],                    # (V, d)
    "lm_head":  [["dp"], ["tp"]],                    # (d, V)
    "wq":       [["dp"], ["tp"], []],                # (d, H, hd)
    "wk":       [["dp"], ["tp"], []],
    "wv":       [["dp"], ["tp"], []],
    "attn_wo":  [["tp"], [], ["dp"]],                # (H, hd, d)
    "bq":       [["tp"], []],
    "bk":       [["tp"], []],
    "bv":       [["tp"], []],
    "wi_gate":  [["dp"], ["tp"]],                    # (d, ff)
    "wi_up":    [["dp"], ["tp"]],
    "mlp_wo":   [["tp"], ["dp"]],                    # (ff, d)
    "router":   [["dp"], []],                        # (d, E)
    "moe_wi":   [["tp"], ["dp"], ["tp"]],            # (E, d, ff) E->tp else ff
    "moe_wo":   [["tp"], ["tp"], ["dp"]],            # (E, ff, d)
    "in_proj":  [["dp"], ["tp"]],                    # (d, 2di+2N+H)
    "out_proj": [["tp"], ["dp"]],                    # (di, d)
    "conv_w":   [[], ["tp"]],                        # (k, conv_dim)
    "conv_b":   [["tp"]],
}

_MOE_LEAVES = {"wi_gate", "wi_up", "wo"}


def _leaf_rule(path) -> tuple[str, bool]:
    """(rule key, has_leading_rep_dim) from a tree path.

    MoE expert tensors share leaf names with dense MLPs (wi_gate/wi_up/wo);
    they are disambiguated by rank in spec_for_param (expert tensors are
    3-D after stripping the scan-rep dim)."""
    keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    name = keys[-1]
    in_segment = "segments" in keys or "enc_segments" in keys
    parent = keys[-2] if len(keys) >= 2 else None
    if name == "wo":
        name = "attn_wo" if parent in ("attn", "xattn") else "mlp_wo"
    return name, in_segment


def spec_for_param(path, shape, mesh: Mesh) -> P:
    name, in_segment = _leaf_rule(path)
    dims = list(shape)
    lead = 0
    if in_segment:
        lead = 1
        dims = dims[1:]
    # disambiguate dense-vs-moe expert tensors by rank
    if name in ("wi_gate", "wi_up") and len(dims) == 3:
        name = "moe_wi"
    if name == "mlp_wo" and len(dims) == 3:
        name = "moe_wo"
    prefs = _PARAM_RULES.get(name)
    if prefs is None or len(prefs) != len(dims):
        # norms, scalars, biases, A_log, gates, ... -> replicated
        return P(*([None] * (lead + len(dims))))
    spec = assign_spec(dims, prefs, mesh)
    return P(*([None] * lead + list(spec)))


def param_shardings(params_shape, mesh: Mesh):
    """NamedSharding pytree for a params (or ShapeDtypeStruct) pytree."""
    def f(path, leaf):
        return NamedSharding(mesh, spec_for_param(path, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(f, params_shape)


# ---------------------------------------------------------------------------
# activations / batch / cache
# ---------------------------------------------------------------------------

def batch_spec(shape, mesh: Mesh) -> P:
    """Token-like (B, S[, d]) arrays: batch over dp."""
    prefs = [["dp"]] + [[] for _ in shape[1:]]
    return assign_spec(shape, prefs, mesh)


def batch_shardings(batch_shape, mesh: Mesh):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)), batch_shape)


def cache_spec(shape, mesh: Mesh) -> P:
    """KV cache (rep, B, S, KV, hd) / ssm state (rep, B, H, P, N) /
    conv state (rep, B, k-1, conv).  Batch over dp; if batch is
    unshardable (long_500k B=1) the sequence/state dim takes dp;
    kv-heads take tp.

    §Perf hillclimb (EXPERIMENTS.md): when the arch's KV-head count is
    indivisible by the model axis (phi4 kv=8, kimi kv=8, gemma2 kv=4 on a
    16-way axis), the *sequence* dim takes tp instead — split-K/flash-decode
    style cache partitioning.  Without this the scores constraint and the
    S-replicated cache disagree and GSPMD all-gathers the whole cache in
    f32 every decode step (34 GB/step for phi4 decode_32k).  Disable with
    REPRO_NO_CACHE_SEQ_FALLBACK=1 to reproduce the baseline."""
    import os
    if len(shape) >= 4:
        prefs = [[], ["dp"], ["dp"], ["tp"], []][: len(shape)]
        while len(prefs) < len(shape):
            prefs.append([])
        if (len(shape) >= 5
                and not os.environ.get("REPRO_NO_CACHE_SEQ_FALLBACK")):
            lm = logical_map(mesh)
            tp = lm.get("tp", ())
            kv_ok = tp and shape[3] % _axis_size(mesh, tp) == 0
            if not kv_ok:
                prefs[2] = ["dp", "tp"]     # sequence takes the model axis
        return assign_spec(shape, prefs, mesh)
    return assign_spec(shape, [[]] + [["dp"]] * (len(shape) - 1), mesh)


def cache_shardings(cache_shape, mesh: Mesh):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, cache_spec(l.shape, mesh)), cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# in-model activation constraints
# ---------------------------------------------------------------------------

_ACT_RULES: dict[str, list[list[str]]] = {
    # (B, S, d) hidden states: batch over dp
    "hidden": [["dp", "data", "pod"], [], []],
    # (B, S, H, hd) projected heads: batch over dp, heads over tp
    "heads": [["dp", "data", "pod"], [], ["tp"], []],
    # (B, S, ff) FFN intermediate: batch over dp, ff over tp
    "ffh": [["dp", "data", "pod"], [], ["tp"]],
    # (B, c, V) logits: batch over dp, vocab over tp
    "logits": [["dp", "data", "pod"], [], ["tp"]],
    # (E, C, d) / (E, C, ff) MoE expert buffers: experts over tp
    "experts": [["tp"], [], []],
    # (G, E, C, d|ff) grouped MoE dispatch buffers: groups over dp,
    # experts over tp (falls back to replicated experts when E indivisible;
    # the expert einsum then partitions over ff via the weight sharding)
    "moe_buffer": [["dp", "data", "pod"], ["tp"], [], []],
    # (G, Tg, d) grouped token buffers
    "tokens_grouped": [["dp", "data", "pod"], [], []],
    # (B, KV, G, Sq, Tk) attention scores: kv-heads over tp; when the
    # arch's KV count is indivisible (gemma3: KV=1) the *key* axis takes
    # tp instead — context-parallel attention (softmax partials reduced
    # by GSPMD), which also split-K-parallelizes long-context decode.
    "scores": [["dp", "data", "pod"], ["tp"], [], [], ["tp"]],
    # (B, H, Sq, Tk) merged-head scores (expanded-KV path): heads over tp
    "scores_h": [["dp", "data", "pod"], ["tp"], [], []],
    # (T, d) flat token buffers (MoE dispatch): tokens over dp
    "tokens_flat": [["dp", "data", "pod"], []],
}


def dp_size() -> int:
    """Size of the ambient mesh's data-parallel axes (1 off-mesh)."""
    am = get_abstract_mesh()
    if am is None or not am.axis_names:
        return 1
    return math.prod(am.shape[a] for a in ("pod", "data")
                     if a in am.axis_names)


def tp_size() -> int:
    """Size of the ambient mesh's model axis (1 off-mesh)."""
    am = get_abstract_mesh()
    if am is None or "model" not in am.axis_names:
        return 1
    return am.shape["model"]


def constrain(x, rule: str):
    """with_sharding_constraint against the ambient mesh; no-op outside a
    mesh context (keeps model code mesh-agnostic — smoke tests run as-is)."""
    am = get_abstract_mesh()
    if am is None or not am.axis_names:
        return x
    prefs = _ACT_RULES[rule]
    if len(prefs) != x.ndim:
        return x
    spec = assign_spec(x.shape, prefs, am)
    return jax.lax.with_sharding_constraint(x, spec)
