"""Sharding rules package (see rules.py)."""
