"""Column-parallel Dijkstra — the paper's Algorithm 2 (MPI analogue).

The paper 1-D-partitions the adjacency matrix by *columns* across P
processes (each process owns n/P vertices), pads n to a multiple of P, and
per iteration does: local argmin over the unvisited owned vertices, a global
``MPI_Allreduce(MINLOC)``, then a local relax of the owned column block from
the winning vertex's row; results are reassembled with ``MPI_Gather``.

TPU/JAX mapping (see DESIGN.md §2):
  * processes            -> mesh devices along one axis, via the
                            version-portable shard_map (core/_compat.py)
  * column partition     -> in_specs P(None, axis) on the padded adjacency
  * MPI_Allreduce MINLOC -> minloc_allgather (baseline: one lax.all_gather of
                            P (dist, index) candidates + deterministic argmin)
                            or minloc_pmin (two lax.pmin, hillclimb variant)
  * MPI_Gather           -> out_specs P(axis): GSPMD reassembles shards
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core._axes import axis_size, axis_tuple
from repro.core._compat import pvary, shard_map

INF = jnp.inf

MinlocImpl = Literal["allgather", "pmin", "packed"]


def minloc_allgather(d: jax.Array, idx: jax.Array, axis: str):
    """MINLOC via one all-gather of P candidate pairs (baseline, 1 collective).

    Deterministic tie-break: smallest global index among equal distances —
    matching the serial argmin semantics exactly.
    """
    ds = lax.all_gather(d, axis)          # (P,)
    idxs = lax.all_gather(idx, axis)      # (P,)
    best = jnp.min(ds)
    cand = jnp.where(ds == best, idxs, jnp.iinfo(jnp.int32).max)
    return best, jnp.min(cand)


def minloc_pmin(d: jax.Array, idx: jax.Array, axis: str):
    """MINLOC via two min-allreduces (latency 2·alpha, O(1) payload).

    First pmin finds the winning distance; the second pmin selects the
    smallest index whose local candidate equals it.
    """
    best = lax.pmin(d, axis)
    cand = jnp.where(d == best, idx, jnp.iinfo(jnp.int32).max)
    return best, lax.pmin(cand, axis)


def minloc_packed(d: jax.Array, idx: jax.Array, axis: str):
    """MINLOC in ONE collective (§Perf hillclimb B).

    Distances are non-negative f32, so their IEEE-754 bit patterns are
    order-preserving as u32 (+inf included).  Packing [dist_bits, idx]
    into one (2,)-u32 payload and doing a single all-gather halves the
    per-iteration collective *count* — and the Dijkstra engine is
    latency-bound (n iterations × α), so this directly attacks the
    dominant roofline term.  Tie-break (smallest index at equal distance)
    matches the serial argmin exactly.
    """
    d_bits = jax.lax.bitcast_convert_type(d, jnp.uint32)
    packed = jnp.stack([d_bits, idx.astype(jnp.uint32)])        # (2,)
    allp = lax.all_gather(packed, axis)                         # (P, 2)
    bits, idxs = allp[:, 0], allp[:, 1]
    best_bits = jnp.min(bits)
    cand = jnp.where(bits == best_bits, idxs, jnp.uint32(0xFFFFFFFF))
    best_idx = jnp.min(cand).astype(jnp.int32)
    best = jax.lax.bitcast_convert_type(best_bits, jnp.float32)
    return best, best_idx


_MINLOC = {"allgather": minloc_allgather, "pmin": minloc_pmin,
           "packed": minloc_packed}


def dijkstra_sharded(
    adj_padded: jax.Array,
    source: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    n_true: int | None = None,
    minloc: MinlocImpl = "allgather",
):
    """Parallel Dijkstra over ``mesh[axis]`` (paper Alg. 2).

    adj_padded: (n_pad, n_pad) with n_pad a multiple of mesh.shape[axis]
                (use Graph.padded(P) — the paper's padding step).
    n_true:     true vertex count; iterations run n_true times as in the
                paper's ``for i in 0..n-1`` (padding vertices are INF-
                isolated and can never win the argmin).
    Returns (dist, pred) of shape (n_pad,): valid entries are [:n_true].
    """
    nprocs = axis_size(mesh, axis)
    n_pad = adj_padded.shape[0]
    assert n_pad % nprocs == 0, "pad the graph first (Graph.padded)"
    loc_n = n_pad // nprocs
    iters = int(n_true if n_true is not None else n_pad)
    minloc_fn = _MINLOC[minloc]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(None, axis), P()),
        out_specs=(P(axis), P(axis)),
    )
    def run(adj_loc, src):
        # adj_loc: (n_pad, loc_n) — this device's column block.
        my_p = lax.axis_index(axis)
        v_base = my_p * loc_n                       # first owned global vertex
        owned = v_base + jnp.arange(loc_n, dtype=jnp.int32)

        loc_dist = jnp.where(owned == src, 0.0, INF).astype(adj_loc.dtype)
        # pvary: mark the device-invariant initial carries as axis-varying so
        # the fori_loop carry types match the (varying) body outputs.
        loc_pred = pvary(jnp.full((loc_n,), -1, jnp.int32), axis_tuple(axis))
        loc_visited = pvary(jnp.zeros((loc_n,), jnp.bool_), axis_tuple(axis))

        def body(_, carry):
            loc_dist, loc_pred, loc_visited = carry
            # --- local argmin over unvisited owned vertices ---------------
            masked = jnp.where(loc_visited, INF, loc_dist)
            loc_arg = jnp.argmin(masked)
            loc_min = masked[loc_arg]
            loc_u = (v_base + loc_arg).astype(jnp.int32)
            # unreachable local candidate must not win ties at INF with a
            # lower index; push its index to +inf sentinel.
            loc_u = jnp.where(jnp.isfinite(loc_min), loc_u,
                              jnp.iinfo(jnp.int32).max)
            # --- global MINLOC (the paper's MPI_Allreduce) -----------------
            du, u = minloc_fn(loc_min, loc_u, axis)
            u_safe = jnp.clip(u, 0, n_pad - 1)
            # --- owner marks u visited -------------------------------------
            off = jnp.clip(u_safe - v_base, 0, loc_n - 1)
            is_mine = (u_safe >= v_base) & (u_safe < v_base + loc_n)
            is_mine &= jnp.isfinite(du)
            loc_visited = loc_visited.at[off].set(loc_visited[off] | is_mine)
            # --- relax owned columns from row u ----------------------------
            row_u = lax.dynamic_slice_in_dim(adj_loc, u_safe, 1, axis=0)[0]
            cand = du + row_u
            better = (cand < loc_dist) & ~loc_visited
            loc_dist = jnp.where(better, cand, loc_dist)
            loc_pred = jnp.where(better, u, loc_pred)
            return loc_dist, loc_pred, loc_visited

        loc_dist, loc_pred, _ = lax.fori_loop(
            0, iters, body, (loc_dist, loc_pred, loc_visited)
        )
        return loc_dist, loc_pred

    return run(adj_padded, jnp.asarray(source, jnp.int32))


def dijkstra_sharded_jit(mesh, axis="data", n_true=None, minloc="allgather"):
    """jit-compiled closure (lower/compile entry point for the dry-run)."""
    def fn(adj_padded, source):
        return dijkstra_sharded(
            adj_padded, source, mesh, axis=axis, n_true=n_true, minloc=minloc
        )
    return jax.jit(fn)
