"""Serial Dijkstra — the paper's Algorithm 1, in JAX.

The textbook O(n^2) loop: n iterations of (argmin over unvisited, mark
visited, relax the chosen row).  This is the baseline every parallel engine
is validated against and the reference for the paper's speedup claims.

jnp.inf is the paper's ∞.  Predecessors (`pred`) are tracked exactly as in
Alg. 1 lines 13-14.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INF = jnp.inf


@functools.partial(jax.jit, static_argnames=("max_iters",))
def dijkstra_serial(adj: jax.Array, source: jax.Array, max_iters: int | None = None):
    """Single-source shortest paths on a dense adjacency matrix.

    adj:    (n, n) float32, INF for missing edges.
    source: scalar int32.
    Returns (dist (n,), pred (n,)): pred[v] = -1 for source/unreached.
    """
    n = adj.shape[0]
    iters = n if max_iters is None else max_iters
    dist = jnp.full((n,), INF, adj.dtype).at[source].set(0.0)
    pred = jnp.full((n,), -1, jnp.int32)
    visited = jnp.zeros((n,), jnp.bool_)

    def body(_, carry):
        dist, pred, visited = carry
        # Alg.1 line 9: u <- unvisited node with min dist
        masked = jnp.where(visited, INF, dist)
        u = jnp.argmin(masked)                  # ties: lowest index (determ.)
        du = masked[u]
        visited = visited.at[u].set(True)
        # Alg.1 lines 11-15: relax u's row.  du == INF => du + w == INF,
        # never better, so the "if dist[u] != INF" guard is implicit.
        cand = du + adj[u]
        better = (cand < dist) & ~visited
        dist = jnp.where(better, cand, dist)
        pred = jnp.where(better, u.astype(jnp.int32), pred)
        return dist, pred, visited

    dist, pred, _ = jax.lax.fori_loop(0, iters, body, (dist, pred, visited))
    return dist, pred


def dijkstra_serial_np(adj, source):
    """Pure-numpy oracle of Alg. 1 (used by tests as an independent check)."""
    import numpy as np

    n = adj.shape[0]
    dist = np.full((n,), np.inf, np.float64)
    pred = np.full((n,), -1, np.int64)
    visited = np.zeros((n,), bool)
    dist[source] = 0.0
    for _ in range(n):
        masked = np.where(visited, np.inf, dist)
        u = int(np.argmin(masked))
        if not np.isfinite(masked[u]):
            break
        visited[u] = True
        cand = dist[u] + adj[u].astype(np.float64)
        better = (cand < dist) & ~visited
        pred[better] = u
        dist = np.where(better, cand, dist)
    return dist, pred
