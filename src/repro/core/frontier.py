"""Frontier-compacted SSSP over outgoing CSR edges — O(frontier out-degree)
per sweep.

The paper's §V diagnosis (inherited verbatim by ``bellman_csr``): the
fixpoint relaxes *every* edge every sweep, so sweeps late in convergence do
O(m) work to improve a handful of vertices.  Δ-stepping (Kranjčević et al.,
arXiv:1604.02113) and Kainer & Träff (arXiv:1903.12085) both locate the win
in restricting relaxation to the **active frontier** — the vertices whose
distance improved last sweep.  This engine does exactly that, with every
shape static so the whole loop stays inside one jit:

1. **Compact** the frontier mask with a static-size ``jnp.nonzero`` (padded
   with the sentinel id n) and an exclusive cumsum of out-degrees — the
   classic stream-compaction step of GPU frontier BFS/SSSP.
2. **Gather** only the frontier vertices' out-edge windows from the
   outgoing CSR view (``CsrGraph.out_csr()``), a chunk of edge slots at a
   time: the *number of chunks* ``ceil(E / chunk)`` is a traced value of an
   inner ``lax.while_loop``, so per-sweep work tracks the actual frontier
   edge count E (rounded up to one chunk) instead of m.
3. **Scatter-min** the candidates ``dist[u] + w`` into the new distance
   vector with ``.at[dst].min`` — the TPU-legal replacement for the CUDA
   kernel's ``atomicMin``, associative and deterministic.

Per-sweep results are bitwise identical to ``bellman_csr`` restricted to
the frontier's candidate set, and the fixpoint (hence the distances) is
bitwise identical to every other engine: min over the same f32 path sums.

An optional **Δ-bucket schedule** (``delta=...``) bounds frontier growth on
weighted graphs: only pending vertices with ``dist <= limit`` are expanded,
and the limit advances by Δ when the current bucket drains — Δ-stepping
restricted to the jit-static state (dist, pending, limit).  ``delta=None``
(default) expands the full improved set each sweep (Bellman-Ford ordering).
The TRUE Δ-stepping engine — light/heavy edge split, per-bucket light
fixpoint, one heavy pass per settled bucket — lives in
core/delta_stepping.py and reuses this module's compaction machinery
(:func:`relax_active`, :func:`make_flat_sweep_fn`, :func:`sweep_cap`).

An optional **target early exit** (``target=...``) stops the fixpoint as
soon as ``dist[target]`` is provably final: with nonnegative weights any
future improvement to the target must route through a pending vertex ``u``
with ``dist[u] < dist[target]``, so once every pending label is >=
``dist[target]`` no relaxation sequence can lower it — the Dijkstra
settled-vertex argument applied to the whole pending set.  The returned
``dist[target]`` is bitwise identical to the full solve's; other entries
may still be above their fixpoint (only vertices with ``dist <
dist[target]`` are guaranteed settled).  ``target_lb=`` sharpens the rule
with an admissible lower bound (e.g. an ALT landmark bound, see
serve/landmarks.py): the loop also stops when ``dist[target] <=
target_lb``, exact because a label can only equal the true distance once
it is <= any admissible bound.  An inadmissible (too large) bound would
break exactness; a too-small bound merely never fires.

The engine also counts **edges relaxed** (sum of frontier out-degrees over
all sweeps) so the O(frontier) claim is measurable: ``bellman_csr`` relaxes
``nnz * sweeps``; this engine's counter is strictly smaller whenever any
sweep's frontier misses a vertex (see benchmarks/run_bench.py's gate).

The kernel path (api engine ``frontier_kernel``) swaps the inner chunk
relax for the Pallas candidate kernel in kernels/frontier_relax, which
streams the compacted frontier's padded out-ELL windows (CsrGraph.out_ell)
in fixed-size row blocks.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.bellman_csr import csr_operands, predecessors_from_dist_csr
from repro.obs.metrics import mark_trace

INF = jnp.inf


def frontier_operands(cg, *, with_ell: bool = False,
                      base_ops: Optional[dict] = None) -> dict:
    """Stage a core.csr.CsrGraph for the frontier engine.

    Extends :func:`csr_operands` (incoming src/dst/w — kept for the O(m)
    pred recovery at the fixpoint) with the outgoing CSR view.  The
    out-indptr is staged with one extra trailing entry so the compaction
    sentinel id n indexes a zero-degree row instead of falling off the end.
    ``with_ell`` adds the padded out-ELL view the Pallas kernel consumes.
    ``base_ops`` reuses already-staged :func:`csr_operands` arrays instead
    of uploading src/dst/w again (serve/registry.py holds both views on
    one long-lived handle and must not double-stage the O(m) arrays).
    """
    ops = dict(base_ops) if base_ops is not None else csr_operands(cg)
    indptr, out_dst, out_w = cg.out_csr()
    indptr_s = np.concatenate([indptr, indptr[-1:]])     # (n + 2,)
    ops["out_indptr"] = jnp.asarray(indptr_s, jnp.int32)
    ops["out_dst"] = jnp.asarray(out_dst)
    ops["out_w"] = jnp.asarray(out_w)
    if with_ell:
        ell_idx, ell_w = cg.out_ell()
        ops["out_ell_idx"] = jnp.asarray(ell_idx)
        ops["out_ell_w"] = jnp.asarray(ell_w)
    return ops


def _slot_minloop(nd, starts, off, E, m, F, *, chunk: int, emit,
                  scatter=None):
    """Chunked slot walker shared by the push and pull relax forms: walk
    ``E`` edge slots ``chunk`` at a time in a ``lax.while_loop`` (trip
    count tracks the actual slot count, the stream-compaction core of
    the frontier engines), map each slot to its owning compacted row —
    ``searchsorted(off, slot, 'right') - 1`` picks the last row whose
    window starts at or before the slot, landing past zero-degree ties —
    and its in-window position, then scatter-min whatever ``emit(row,
    pos, valid) -> (cand, tgt)`` produces (invalid slots must emit INF
    aimed at a drop id; scatter mode="drop").  ``scatter`` overrides the
    per-slot scatter-min for callers whose state isn't a flat (n,) row —
    the multisource form scatter-mins a (S, chunk) candidate block into
    distance-matrix columns."""
    if scatter is None:
        def scatter(nd2, tgt, cand):
            return nd2.at[tgt].min(cand, mode="drop")

    def cond(carry):
        _, c = carry
        return c * chunk < E

    def body(carry):
        nd2, c = carry
        slots = c * chunk + jnp.arange(chunk, dtype=jnp.int32)
        valid = slots < E
        row = jnp.searchsorted(off, slots, side="right") - 1
        row = jnp.clip(row, 0, F - 1)
        pos = starts[row] + (slots - off[row])
        pos = jnp.clip(pos, 0, m - 1)
        cand, tgt = emit(row, pos, valid)
        return scatter(nd2, tgt, cand), c + 1

    nd, _ = lax.while_loop(cond, body, (nd, jnp.int32(0)))
    return nd


def relax_edge_slots(nd, row_dist, starts, off, E, out_dst, out_w, *,
                     chunk: int, drop_id):
    """Scatter-min ``row_dist[row] + w`` over a compacted frontier's edge
    slots (the PUSH form of :func:`_slot_minloop`).

    Shared by the single-device flat sweep (:func:`make_flat_sweep_fn`)
    and the vertex-partitioned local relax (core/sharded_csr.py) — the
    callers differ only in where the source distances come from (the
    local ``dist`` snapshot vs the exchanged frontier pairs) and in the
    scatter target space (global ids dropped at n vs block-local ids
    dropped at loc_n, via ``drop_id``).

    row_dist: (F,) source distance per frontier row; starts/off: each
    row's window start in (out_dst, out_w) / exclusive cumsum of window
    lengths; E: total slots; out-of-window slots produce INF candidates
    aimed at ``drop_id``.
    """
    m = out_dst.shape[0]
    if m == 0:                                    # edgeless graph: no work
        return nd

    def emit(row, pos, valid):
        cand = jnp.where(valid, row_dist[row] + out_w[pos], INF)
        tgt = jnp.where(valid, out_dst[pos], drop_id)
        return cand, tgt

    return _slot_minloop(nd, starts, off, E, m, row_dist.shape[0],
                         chunk=chunk, emit=emit)


def relax_edge_slots_multi(ND, row_D, starts, off, E, out_dst, out_w, *,
                           chunk: int, drop_id):
    """Multisource PUSH form of :func:`_slot_minloop`: scatter-min
    ``row_D[:, row] + w`` into ``ND[:, dst]`` for every source at once.

    The multisource coalescing of :func:`relax_edge_slots`: the edge-slot
    walk — window arithmetic, out_dst/out_w gathers — runs ONCE per slot
    chunk and is shared by all S sources; only the candidate block is
    per-source ((S, chunk), one gathered edge weight broadcast across the
    source axis).  Used by the vertex-partitioned batched engine
    (core/sharded_csr.sssp_multisource_csr_sharded), where the compacted
    frontier is the UNION over sources of last sweep's improved vertices.

    ND: (S, n') distance matrix; row_D: (S, F) per-source distances of the
    compacted frontier rows; remaining args as in :func:`relax_edge_slots`.
    """
    m = out_dst.shape[0]
    if m == 0:                                    # edgeless graph: no work
        return ND

    def emit(row, pos, valid):
        cand = jnp.where(valid[None, :], row_D[:, row] + out_w[pos][None, :],
                         INF)
        tgt = jnp.where(valid, out_dst[pos], drop_id)
        return cand, tgt

    def scatter(nd2, tgt, cand):
        return nd2.at[:, tgt].min(cand, mode="drop")

    return _slot_minloop(ND, starts, off, E, m, row_D.shape[1],
                         chunk=chunk, emit=emit, scatter=scatter)


def pull_edge_slots(nd, fids, src_dist, starts, off, E, in_src, in_w, *,
                    chunk: int, drop_id):
    """The PULL form of :func:`_slot_minloop`: scatter-min
    ``src_dist[in_src[pos]] + in_w[pos]`` into each compacted row's OWN
    vertex.

    Where the push form relaxes a frontier row's *outgoing* window toward
    per-slot destinations, this relaxes a row's *incoming* window toward
    the row itself — ``fids[row]`` is the scatter target and the source
    distance is gathered per slot.  dynamic/repair.py uses it to re-derive
    the invalidated cone's labels from its boundary in O(cone in-degree):
    the compacted rows are the affected vertices, the windows come from
    the incoming CSR, and non-boundary sources carry INF so only live
    support contributes.  Sentinel rows (``fids == drop_id``) scatter to
    ``drop_id`` and are dropped.
    """
    m = in_src.shape[0]
    if m == 0:
        return nd

    def emit(row, pos, valid):
        cand = jnp.where(valid, src_dist[in_src[pos]] + in_w[pos], INF)
        tgt = jnp.where(valid, fids[row], drop_id)
        return cand, tgt

    return _slot_minloop(nd, starts, off, E, m, fids.shape[0],
                         chunk=chunk, emit=emit)


@functools.lru_cache(maxsize=None)
def make_flat_sweep_fn(chunk: int = 1024) -> Callable:
    """Default frontier sweep: flat-CSR edge windows, ``chunk`` edge slots
    per inner step.  Memoized so the closure identity is stable — it is a
    static jit argument of the engine (same contract as make_csr_sweep_fn).

    The sweep contract (shared with kernels/frontier_relax/ops.py):
    ``sweep(dist, fids, starts, off, E, fcount, ops) -> new_dist`` where
    fids (n,) are the compacted frontier ids (sentinel-n padded), starts
    their out-window starts, off the exclusive cumsum of their out-degrees,
    E the total frontier out-degree and fcount the frontier size.  Reads
    come from the ``dist`` snapshot (Jacobi sweep semantics, like every
    other engine), writes scatter-min into the running copy.
    """

    def sweep(dist, fids, starts, off, E, fcount, ops):
        # trace-time marker: the sweep body re-executes only when some
        # enclosing engine retraces (shape/static drift) — the counter
        # tests/test_obs.py pins at zero across repeat ticks/versions
        mark_trace("flat_sweep")
        n = dist.shape[0]
        row_dist = dist[jnp.minimum(fids, n - 1)]   # sentinel rows: 0 slots
        return relax_edge_slots(
            dist, row_dist, starts, off, E, ops["out_dst"], ops["out_w"],
            chunk=chunk, drop_id=jnp.int32(n),
        )

    return sweep


def relax_active(ops: dict, dist, active, *, n: int, sweep: Callable):
    """Compact the ``active`` mask and relax its out-edge windows once —
    the stream-compaction + sweep core shared by :func:`frontier_fixpoint`
    and the Δ-stepping heavy phase (core/delta_stepping.py), so the two
    schedules cannot drift in compaction or window arithmetic.

    ``ops`` needs the sweep contract's keys (out_indptr staged with the
    trailing sentinel row, out_dst, out_w — see :func:`frontier_operands`;
    the Δ engine passes an aliased view of its heavy split).  Must be
    called inside jit.  Returns ``(new_dist, E)`` with E the total
    out-degree of the active set (the edges-relaxed increment).
    """
    fids = jnp.nonzero(active, size=n, fill_value=n)[0].astype(jnp.int32)
    fcount = jnp.sum(active)
    starts = ops["out_indptr"][fids]
    degs = ops["out_indptr"][fids + 1] - starts
    csum = jnp.cumsum(degs)
    E, off = csum[-1], csum - degs
    new = sweep(dist, fids, starts, off, E, fcount, ops)
    return new, E


def sweep_cap(n: int, delta: float | None, max_sweeps: int | None,
              max_dist=None):
    """Fixpoint sweep bound shared by every frontier-family engine
    (sssp_frontier here, sssp_frontier_dynamic / sssp_repair in
    dynamic/repair.py, and the Δ-stepping engine's outer-phase cap): the
    hop-diameter bound n for the plain schedule; headroom under
    Δ-bucketing, whose deferred vertices re-enter later buckets.  The
    pending-empty exit is the real stop — the cap is a divergence guard.

    With ``max_dist`` (an upper bound on the largest finite distance,
    e.g. (n-1)·w_max from the staged weights) the Δ headroom is derived
    instead of guessed: the bucket limit only ever advances past the
    current minimum pending label, so it advances at most
    ``ceil(max_dist / Δ) + 1`` times before clearing every finite label;
    every other sweep relaxes a nonempty active set containing the
    minimum pending vertex, whose label is final (the Dijkstra argument),
    so at most n such sweeps exist.  Hence
    ``cap = n + ceil(max_dist / Δ) + 1``, with the legacy ``4·n``
    constant kept as a floor for callers whose bound is loose or traced.
    ``max_dist`` may be a traced scalar — the result is then traced too
    (fine as a ``lax.while_loop`` bound); without it the legacy static
    ``4·n`` is returned unchanged.
    """
    if max_sweeps is not None:
        return max_sweeps
    if delta is None:
        return n
    if max_dist is None:
        return 4 * n
    buckets = jnp.ceil(jnp.asarray(max_dist, jnp.float32)
                       / jnp.float32(delta)) + 1.0
    # non-finite or huge bounds (disconnected staging, f32 overflow) would
    # wrap int32: clamp the bucket count, the floor still applies.
    buckets = jnp.where(jnp.isfinite(buckets), buckets, 2.0 ** 30)
    buckets = jnp.clip(buckets, 0.0, 2.0 ** 30).astype(jnp.int32)
    return jnp.maximum(jnp.int32(4 * n), jnp.int32(n) + buckets)


def frontier_fixpoint(
    ops: dict,
    dist0,
    pending0,
    *,
    n: int,
    sweep: Callable,
    cap: int,
    delta: float | None = None,
    target=None,
    target_lb=None,
    edges0=0,
):
    """The frontier relax loop on an ARBITRARY initial state — factored out
    of :func:`sssp_frontier` so callers with a warm start can reuse the
    exact machinery (compaction, Δ-bucket schedule, target early exit,
    edge counter).  dynamic/repair.py seeds it with a mutated graph's
    partially-invalidated distance vector instead of a cold source.

    Correctness contract for a warm start: ``dist0`` must be pointwise >=
    the true fixpoint with ``dist0[source] == 0``, every finite label must
    be a real path length in the graph ``ops`` describes, and ``pending0``
    must cover every vertex whose label has improved relative to what its
    out-neighbors last saw — the loop then converges to the same fixpoint
    a cold solve reaches, bitwise (min over the same f32 path sums).

    Must be called inside jit (trace-time only).  Returns
    ``(dist, sweeps, edges_relaxed, converged)`` with ``edges_relaxed``
    accumulated on top of ``edges0`` and ``converged`` True iff the loop
    exited because the fixpoint (or the target's settled condition) was
    reached rather than because the sweep ``cap`` ran out — the solver
    guardrail serve/errors.NotConverged consumes.
    """
    limit0 = jnp.float32(0.0 if delta is None else delta)

    def settled_or_done(dist, pending):
        done = ~jnp.any(pending)
        if target is not None:
            dt = dist[target]
            # settled once no pending label is below the target's: every
            # future candidate is dist[u] + w >= dist[u] >= min pending.
            settled = jnp.min(jnp.where(pending, dist, INF)) >= dt
            if target_lb is not None:
                # an admissible bound pins the label from below; label >=
                # true distance always, so equality at the bound is final.
                settled = settled | (dt <= target_lb)
            done = done | settled
        return done

    def cond(carry):
        dist, pending, _, it, _ = carry
        return (it < cap) & ~settled_or_done(dist, pending)

    def body(carry):
        dist, pending, limit, it, edges = carry
        if delta is None:
            active = pending
        else:
            has = jnp.any(pending & (dist <= limit))
            nxt = jnp.min(jnp.where(pending, dist, INF)) + delta
            limit = jnp.where(has, limit, nxt)
            active = pending & (dist <= limit)
        new, E = relax_active(ops, dist, active, n=n, sweep=sweep)
        improved = new < dist
        pending = (pending & ~active) | improved
        return new, pending, limit, it + 1, edges + E

    dist, pending, _, sweeps, edges = lax.while_loop(
        cond, body,
        (dist0, pending0, limit0, jnp.int32(0), jnp.int32(edges0)),
    )
    return dist, sweeps, edges, settled_or_done(dist, pending)


@functools.partial(
    jax.jit, static_argnames=("n", "sweep_fn", "max_sweeps", "delta", "chunk")
)
def sssp_frontier(
    ops: dict,
    source: jax.Array,
    *,
    n: int,
    sweep_fn: Optional[Callable] = None,
    max_sweeps: int | None = None,
    delta: float | None = None,
    chunk: int = 1024,
    target: Optional[jax.Array] = None,
    target_lb: Optional[jax.Array] = None,
):
    """Frontier-compacted fixpoint SSSP on :func:`frontier_operands`.

    Returns ``(dist, pred, num_sweeps, edges_relaxed, converged)`` —
    ``edges_relaxed`` being the total frontier out-degree summed over
    sweeps, the engine's actual relaxation work (compare ``nnz *
    num_sweeps`` for ``bellman_csr``), and ``converged`` the guardrail
    flag: False iff ``max_sweeps=`` stopped the loop before the pending
    set drained (or, for target solves, before the target settled) — the
    labels may then sit above their fixpoint and must not be served as
    exact (serve/errors.NotConverged).

    ``delta`` enables the Δ-bucket schedule (see module docstring): when a
    bucket drains, the same sweep advances the limit and immediately
    relaxes the next bucket's active set, so every sweep does edge work —
    but deferred vertices re-enter later buckets, which can take more
    sweeps than the plain schedule.  ``chunk`` sizes the inner edge-slot
    blocks of the default sweep (ignored when ``sweep_fn`` is given).

    ``target`` enables the early-exit stopping rule (module docstring):
    the loop also stops once ``min(dist[pending]) >= dist[target]`` — or,
    with an admissible ``target_lb``, once ``dist[target] <= target_lb``.
    ``dist[target]`` (and every vertex with a smaller label) is then final
    and bitwise-equal to the full solve; labels above it may be partial,
    so the returned ``pred`` is None (recovering a part-invalid tree
    would cost a full O(m) pass every target caller discards).
    """
    mark_trace("frontier")
    sweep = sweep_fn or make_flat_sweep_fn(chunk)
    cap = sweep_cap(n, delta, max_sweeps)
    dist0 = jnp.full((n,), INF, ops["out_w"].dtype).at[source].set(0.0)
    pending0 = dist0 < INF
    dist, sweeps, edges, converged = frontier_fixpoint(
        ops, dist0, pending0, n=n, sweep=sweep, cap=cap, delta=delta,
        target=target, target_lb=target_lb,
    )
    if target is not None:
        # a target= solve is partial: labels above dist[target] may sit
        # off their fixpoint, so the O(m) recovery would produce a
        # part-invalid tree every caller discards anyway — skip it
        # (trace-time branch: target's presence already keys the trace).
        return dist, None, sweeps, edges, converged
    pred = predecessors_from_dist_csr(dist, ops, source)
    return dist, pred, sweeps, edges, converged
