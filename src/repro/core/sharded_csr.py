"""Vertex-partitioned sparse SSSP over per-owner CSR blocks — the paper's
Algorithm 2 partitioning scheme, re-based from the dense O(n²/P) column
slabs onto O(m/P) CSR row blocks.

The paper's MPI version 1-D-partitions the *adjacency matrix*: each of the
P processes owns n/P columns and sweeps them densely, which inherits the
exact §V memory/density ceiling the single-device CSR engines (PR 1–2)
already lifted.  Here each device owns n_pad/P vertices and holds only the
arcs *targeting* its owned block (``CsrGraph.partitioned`` — incoming-CSR
row slices, the sparse analogue of the paper's column slabs), so per-device
graph memory is ~m/P and per-sweep local work is O(m/P) instead of O(n²/P).
Kainer & Träff (arXiv:1903.12085) and the Δ-stepping line (arXiv:1604.02113)
both locate scalable SSSP exactly here: partitioned sparse relaxation with
small per-round exchanges.

Two engines, both running the whole fixpoint inside one shard_map region
(one jit, collectives inside the loop):

* :func:`sssp_bellman_csr_sharded` — every sweep each owner segment-mins
  its local arcs (O(m/P)) and ONE tiled all-gather reassembles the
  replicated distance vector; convergence is the replicated
  ``any(dist != prev)`` flag (the all-reduce-min analogue: every device
  computes the identical flag from the identical gathered vector).  The
  sparse twin of ``bellman.sssp_bellman_sharded``.

* :func:`sssp_frontier_sharded` — the MPI-message analogue of PR 2's
  frontier engine.  Each sweep every owner compacts its *owned* improved
  vertices and the devices exchange only those ``(global id, dist)`` pairs,
  a fixed-size chunk per all-gather inside a ``lax.while_loop`` whose trip
  count tracks the *largest per-owner frontier* — payload
  O(max_p |frontier_p|) per sweep, not O(n).  Each owner then pushes the
  received frontier through its local source-indexed out-CSR
  (``CsrPartition.out_*``) with the same chunked gather/scatter-min scheme
  as ``core/frontier.py``, so per-sweep relax work is O(arcs from the
  frontier into the owned block) and the psum of the per-owner counters
  equals the single-device engine's ``edges_relaxed`` exactly (each arc
  has one owner).

Distances are bitwise-identical to every other engine: the fixpoint is a
min over the same f32 path sums, and mins are associative/commutative
exactly (same argument as bellman_csr / frontier, covered by
tests/test_sharded_csr.py through n=10000 at P ∈ {1, 2, 4, 8}).

Δ-bucketing is not offered here: the Δ schedule trades sweeps for frontier
width, and the sharded engine's per-sweep cost is already dominated by the
exchange — see core/frontier.py for the single-device Δ variant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core._axes import axis_size, axis_tuple
from repro.core._compat import pvary, shard_map
from repro.core.frontier import relax_edge_slots, relax_edge_slots_multi
from repro.obs.metrics import mark_trace

INF = jnp.inf


def partition_operands(parts) -> dict:
    """Stage a core.csr.CsrPartition onto the device as the pytree the
    sharded engines consume.  Not memoized, same rationale as
    ``csr_operands``: the host numpy blocks are already cached on the
    CsrGraph, so repeat staging is a plain copy, and caching jax buffers
    on the host container would pin device memory.  Long-lived callers
    that SHOULD pin (serve/registry.py's graph handles) stage once and
    pass the dict back through the engines' ``ops=``."""
    return {
        "in_src": jnp.asarray(parts.in_src),
        "in_dst_loc": jnp.asarray(parts.in_dst_loc),
        "in_w": jnp.asarray(parts.in_w),
        "out_indptr": jnp.asarray(parts.out_indptr),
        "out_dst_loc": jnp.asarray(parts.out_dst_loc),
        "out_w": jnp.asarray(parts.out_w),
    }


def sssp_bellman_csr_sharded(
    parts,
    source,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    max_sweeps: int | None = None,
    ops: dict | None = None,
):
    """Sharded fixpoint SSSP on a CsrPartition.  Returns
    ``(dist (n_pad,), pred (n_pad,), sweeps, converged)``; valid entries
    ``[:n]``.  ``converged`` (0/1) is the replicated guardrail flag:
    0 iff ``max_sweeps=`` capped the loop before the gathered vector
    stopped changing (labels may sit above their fixpoint — see
    serve/errors.NotConverged).

    Per sweep: local O(m/P) segment-min over the owner's incoming arcs,
    one tiled all-gather of the (loc_n,) block — the same one-collective-
    per-sweep granularity as the dense ``bellman_sharded``, at sparse
    cost.  pred is recovered per owner from its own arcs at the fixpoint
    (same lowest-u tie-break as ``predecessors_from_dist_csr``).
    ``ops=`` accepts an already-staged :func:`partition_operands` dict
    (serve/registry.py pins one per handle) instead of re-staging.
    """
    nprocs = axis_size(mesh, axis)
    assert parts.nprocs == nprocs, (parts.nprocs, nprocs)
    cap = int(parts.n_pad if max_sweeps is None else max_sweeps)
    if ops is None:
        ops = partition_operands(parts)
    run = _build_bellman(mesh, _axis_key(axis), parts.n_pad, parts.loc_n,
                         cap)
    return run(ops["in_src"], ops["in_dst_loc"], ops["in_w"],
               jnp.asarray(source, jnp.int32))


def _axis_key(axis):
    """Hashable axis argument for the lru_cache'd builders (engines accept
    a name or a tuple of names, like the dense sharded engines)."""
    return axis if isinstance(axis, (str, tuple)) else tuple(axis)


@functools.lru_cache(maxsize=None)
def _build_bellman(mesh, axis, n_pad, loc_n, cap):
    """jit-compiled sharded fixpoint, memoized per (mesh, statics) so
    repeat solves reuse the compiled executable instead of re-tracing the
    shard_map closure every call (same rationale as make_csr_sweep_fn)."""
    nprocs = axis_size(mesh, axis)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P()),
        out_specs=(P(axis), P(axis), P(), P()),
    )
    def run(in_src, in_dst_loc, in_w, src):
        mark_trace("bellman_csr_sharded")
        in_src, in_dst_loc, in_w = in_src[0], in_dst_loc[0], in_w[0]
        my_p = lax.axis_index(axis)
        v_base = (my_p * loc_n).astype(jnp.int32)
        dist0 = jnp.full((n_pad,), INF, in_w.dtype).at[src].set(0.0)
        dist0 = pvary(dist0, axis_tuple(axis))
        prev0 = pvary(jnp.full((n_pad,), -1.0, in_w.dtype), axis_tuple(axis))

        def seg_min(vals):
            return jax.ops.segment_min(
                vals, in_dst_loc, num_segments=loc_n, indices_are_sorted=True
            )

        def cond(c):
            dist, prev, it = c
            return (it < cap) & jnp.any(dist != prev)

        def body(c):
            dist, _, it = c
            cand = seg_min(dist[in_src] + in_w)          # O(m/P)
            mine = lax.dynamic_slice_in_dim(dist, v_base, loc_n)
            loc_new = jnp.minimum(mine, cand)
            new = lax.all_gather(loc_new, axis, tiled=True)
            return new, dist, it + 1

        it0 = pvary(jnp.int32(0), axis_tuple(axis))
        dist, prev, sweeps = lax.while_loop(cond, body, (dist0, prev0, it0))
        # every device computes the identical flag from the identical
        # gathered vectors; the psum//nprocs makes replication explicit
        # (same pattern as the sweeps counter below).
        conv = (~jnp.any(dist != prev)).astype(jnp.int32)

        # local pred recovery from the owner's own arcs (sentinel arcs are
        # INF and can only attain on rows whose best is INF, which the
        # reached mask excludes) — matches predecessors_from_dist_csr.
        via = dist[in_src] + in_w
        best = seg_min(via)
        attains = via <= best[in_dst_loc]
        u_cand = jnp.where(attains, in_src, jnp.int32(n_pad))
        u_best = seg_min(u_cand)
        mine = lax.dynamic_slice_in_dim(dist, v_base, loc_n)
        owned = v_base + jnp.arange(loc_n, dtype=jnp.int32)
        reached = jnp.isfinite(mine) & (u_best < n_pad)
        pred = jnp.where(reached & (owned != src), u_best, -1)
        return (mine, pred, lax.psum(sweeps, axis) // nprocs,
                lax.psum(conv, axis) // nprocs)

    return jax.jit(run)


def sssp_frontier_sharded(
    parts,
    source,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    max_sweeps: int | None = None,
    exchange_chunk: int = 256,
    relax_chunk: int = 1024,
    ops: dict | None = None,
):
    """Sharded frontier-compacted SSSP on a CsrPartition.  Returns
    ``(dist (n_pad,), sweeps, edges_relaxed, converged)``; valid entries
    ``[:n]``.  ``converged`` (0/1, replicated) is 0 iff ``max_sweeps=``
    stopped the loop while some owner still had an improving frontier —
    the labels may then sit above their fixpoint (serve/errors.
    NotConverged is the serving-layer consumer).
    pred is recovered by the caller at the fixpoint (api.shortest_paths
    reuses the O(m) single-device recovery — the tree is a pure function
    of (dist, graph), so nothing is lost by recovering off-engine).

    Per sweep, each owner ships its improved owned vertices as compacted
    ``(id, dist)`` pairs, ``exchange_chunk`` entries per all-gather; the
    number of exchange rounds is a traced value driven by the largest
    per-owner frontier, so the per-sweep payload is O(max_p |frontier_p|)
    (rounded up to one chunk), not O(n).  Received pairs are pushed
    through the owner's local out-CSR ``relax_chunk`` arc slots at a
    time, the exact scheme of core/frontier.make_flat_sweep_fn.

    ``edges_relaxed`` is the psum over owners of the arcs windowed by the
    received frontier — equal to the single-device frontier engine's
    counter (each arc has exactly one owner; benchmarks/run_bench.py
    gates on this).  ``ops=`` as in :func:`sssp_bellman_csr_sharded`.
    """
    nprocs = axis_size(mesh, axis)
    assert parts.nprocs == nprocs, (parts.nprocs, nprocs)
    cap = int(parts.n_pad if max_sweeps is None else max_sweeps)
    if ops is None:
        ops = partition_operands(parts)
    run = _build_frontier(mesh, _axis_key(axis), parts.n_pad, parts.loc_n,
                          parts.nnz_max, cap,
                          int(min(exchange_chunk, max(parts.loc_n, 1))),
                          int(relax_chunk))
    return run(ops["out_indptr"], ops["out_dst_loc"], ops["out_w"],
               jnp.asarray(source, jnp.int32))


@functools.lru_cache(maxsize=None)
def _build_frontier(mesh, axis, n_pad, loc_n, nnz_max, cap, CH, RC):
    """jit-compiled sharded frontier engine, memoized like _build_bellman."""
    nprocs = axis_size(mesh, axis)
    fcap = -(-loc_n // CH) * CH                  # frontier buffer, CH-aligned

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P()),
        out_specs=(P(axis), P(), P(), P()),
    )
    def run(out_indptr, out_dst_loc, out_w, src):
        mark_trace("frontier_sharded")
        out_indptr, out_dst_loc, out_w = (
            out_indptr[0], out_dst_loc[0], out_w[0])
        my_p = lax.axis_index(axis)
        v_base = (my_p * loc_n).astype(jnp.int32)
        owned = v_base + jnp.arange(loc_n, dtype=jnp.int32)
        dist0 = jnp.where(owned == src, 0.0, INF).astype(out_w.dtype)
        fmask0 = owned == src

        def relax(nd, all_ids, all_ds, edges):
            """Push one gathered frontier chunk through the local out-CSR
            with the same chunked slot-relax as the single-device engine
            (core/frontier.relax_edge_slots) — source distances come from
            the exchanged pairs, targets are block-local ids."""
            starts = out_indptr[all_ids]
            degs = out_indptr[all_ids + 1] - starts
            csum = jnp.cumsum(degs)
            E, off = csum[-1], csum - degs
            nd = relax_edge_slots(
                nd, all_ds, starts, off, E, out_dst_loc, out_w,
                chunk=RC, drop_id=jnp.int32(loc_n),
            )
            return nd, edges + E

        def cond(c):
            _, _, it, _, go = c
            return (it < cap) & go

        def body(c):
            dist, fmask, it, edges, _ = c
            # compact this owner's frontier: (global id, snapshot dist),
            # sentinel (n_pad, INF) — zero out-degree via the extra row.
            fidx = jnp.nonzero(fmask, size=fcap, fill_value=loc_n)[0]
            fidx = fidx.astype(jnp.int32)
            live = fidx < loc_n
            gid = jnp.where(live, v_base + fidx, jnp.int32(n_pad))
            fd = jnp.where(live, dist[jnp.minimum(fidx, loc_n - 1)], INF)
            max_cnt = lax.pmax(jnp.sum(fmask), axis)

            def ex_cond(c2):
                return c2[2] * CH < max_cnt

            def ex_body(c2):
                nd, e, k = c2
                ids = lax.dynamic_slice_in_dim(gid, k * CH, CH)
                ds = lax.dynamic_slice_in_dim(fd, k * CH, CH)
                all_ids = lax.all_gather(ids, axis, tiled=True)  # (P*CH,)
                all_ds = lax.all_gather(ds, axis, tiled=True)
                nd, e = relax(nd, all_ids, all_ds, e)
                return nd, e, k + 1

            nd, edges, _ = lax.while_loop(
                ex_cond, ex_body, (dist, edges, jnp.int32(0)))
            improved = nd < dist
            go = lax.psum(jnp.any(improved).astype(jnp.int32), axis) > 0
            return nd, improved, it + 1, edges, go

        it0 = pvary(jnp.int32(0), axis_tuple(axis))
        e0 = pvary(jnp.int32(0), axis_tuple(axis))
        go0 = pvary(jnp.bool_(True), axis_tuple(axis))
        dist, _, sweeps, edges, go = lax.while_loop(
            cond, body, (dist0, fmask0, it0, e0, go0))
        # go is the psummed work-remains flag (replicated): exiting with
        # it still set means the cap fired mid-convergence.
        conv = (~go).astype(jnp.int32)
        return (dist, lax.psum(sweeps, axis) // nprocs,
                lax.psum(edges, axis), lax.psum(conv, axis) // nprocs)

    return jax.jit(run)


def sssp_multisource_csr_sharded(
    parts,
    sources,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    max_sweeps: int | None = None,
    exchange_chunk: int = 256,
    relax_chunk: int = 1024,
    ops: dict | None = None,
):
    """Batched vertex-partitioned SSSP from S sources on a CsrPartition —
    the multisource coalescing of :func:`sssp_frontier_sharded`.  Returns
    ``(D (S, n_pad), sweeps, edges_relaxed, converged)``; valid columns
    ``[:n]``.  ``converged`` (0/1, replicated) is the joint guardrail
    flag over all S rows, same contract as the other sharded engines.

    Per sweep each owner compacts the UNION over sources of its owned
    improved vertices and the devices exchange ``(global id, per-source
    dist column)`` pairs — the id chunk is the same payload as the
    single-source engine, the distance chunk grows to (S, CH).  Each
    received frontier vertex's out-arc window is then gathered ONCE and
    relaxed against all S source rows (core/frontier.
    relax_edge_slots_multi), so the edge-index loads are amortized S ways
    on top of the P-way partitioning — Kainer & Träff's many-settled-
    vertices-per-round observation (arXiv:1903.12085) applied across the
    batch axis.

    ``edges_relaxed`` counts each windowed arc ONCE per sweep however
    many sources share the gather (psummed over owners) — directly
    comparable to S single-source ``frontier`` solves, whose counters
    sum the same windows per source; whenever two batched sources'
    frontiers overlap in a sweep the union counter is strictly smaller
    (benchmarks/serve_bench.py's sharded gate measures exactly this).

    Per-source rows are bitwise-equal to S independent solves of any
    engine: the union frontier is a superset of every per-source
    frontier, so no per-source improvement is ever missed, and the
    fixpoint is the same min over the same f32 path sums.  pred is not
    recovered (same contract as ``multisource_csr``; api.recover_pred
    rebuilds rows on demand).  ``ops=`` as in the other engines here.
    """
    nprocs = axis_size(mesh, axis)
    assert parts.nprocs == nprocs, (parts.nprocs, nprocs)
    cap = int(parts.n_pad if max_sweeps is None else max_sweeps)
    if ops is None:
        ops = partition_operands(parts)
    srcs = jnp.atleast_1d(jnp.asarray(sources, jnp.int32))
    run = _build_multisource_frontier(
        mesh, _axis_key(axis), parts.n_pad, parts.loc_n, cap,
        int(min(exchange_chunk, max(parts.loc_n, 1))), int(relax_chunk),
        int(srcs.shape[0]))
    return run(ops["out_indptr"], ops["out_dst_loc"], ops["out_w"], srcs)


@functools.lru_cache(maxsize=None)
def _build_multisource_frontier(mesh, axis, n_pad, loc_n, cap, CH, RC, S):
    """jit-compiled sharded multisource union-frontier engine, memoized
    per (mesh, statics, S) — serving buckets the source axis to powers of
    two (serve/scheduler.py), so the cache stays small."""
    nprocs = axis_size(mesh, axis)
    fcap = -(-loc_n // CH) * CH                  # frontier buffer, CH-aligned

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None), P()),
        out_specs=(P(None, axis), P(), P(), P()),
    )
    def run(out_indptr, out_dst_loc, out_w, srcs):
        mark_trace("multisource_csr_sharded")
        out_indptr, out_dst_loc, out_w = (
            out_indptr[0], out_dst_loc[0], out_w[0])
        my_p = lax.axis_index(axis)
        v_base = (my_p * loc_n).astype(jnp.int32)
        owned = v_base + jnp.arange(loc_n, dtype=jnp.int32)
        is_src = owned[None, :] == srcs[:, None]          # (S, loc_n)
        D0 = jnp.where(is_src, 0.0, INF).astype(out_w.dtype)
        fmask0 = jnp.any(is_src, axis=0)

        def relax(ND, all_ids, all_D, edges):
            """Push one gathered union-frontier chunk through the local
            out-CSR: window arithmetic and arc gathers once per slot,
            candidates per source (relax_edge_slots_multi)."""
            starts = out_indptr[all_ids]
            degs = out_indptr[all_ids + 1] - starts
            csum = jnp.cumsum(degs)
            E, off = csum[-1], csum - degs
            ND = relax_edge_slots_multi(
                ND, all_D, starts, off, E, out_dst_loc, out_w,
                chunk=RC, drop_id=jnp.int32(loc_n),
            )
            return ND, edges + E

        def cond(c):
            _, _, it, _, go = c
            return (it < cap) & go

        def body(c):
            D, fmask, it, edges, _ = c
            # compact the union frontier; every live pair ships its FULL
            # per-source distance column — a vertex improved for one
            # source re-pushes its (already-applied) labels for the
            # others, inert under min.
            fidx = jnp.nonzero(fmask, size=fcap, fill_value=loc_n)[0]
            fidx = fidx.astype(jnp.int32)
            live = fidx < loc_n
            gid = jnp.where(live, v_base + fidx, jnp.int32(n_pad))
            fdm = jnp.where(live[None, :],
                            D[:, jnp.minimum(fidx, loc_n - 1)], INF)
            max_cnt = lax.pmax(jnp.sum(fmask), axis)

            def ex_cond(c2):
                return c2[2] * CH < max_cnt

            def ex_body(c2):
                ND, e, k = c2
                ids = lax.dynamic_slice_in_dim(gid, k * CH, CH)
                ds = lax.dynamic_slice_in_dim(fdm, k * CH, CH, axis=1)
                all_ids = lax.all_gather(ids, axis, tiled=True)  # (P*CH,)
                all_D = lax.all_gather(ds, axis, axis=1, tiled=True)
                ND, e = relax(ND, all_ids, all_D, e)
                return ND, e, k + 1

            ND, edges, _ = lax.while_loop(
                ex_cond, ex_body, (D, edges, jnp.int32(0)))
            improved = jnp.any(ND < D, axis=0)
            go = lax.psum(jnp.any(improved).astype(jnp.int32), axis) > 0
            return ND, improved, it + 1, edges, go

        it0 = pvary(jnp.int32(0), axis_tuple(axis))
        e0 = pvary(jnp.int32(0), axis_tuple(axis))
        go0 = pvary(jnp.bool_(True), axis_tuple(axis))
        D, _, sweeps, edges, go = lax.while_loop(
            cond, body, (D0, fmask0, it0, e0, go0))
        conv = (~go).astype(jnp.int32)
        return (D, lax.psum(sweeps, axis) // nprocs,
                lax.psum(edges, axis), lax.psum(conv, axis) // nprocs)

    return jax.jit(run)
