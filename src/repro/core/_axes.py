"""Axis helpers: every sharded SSSP engine accepts a single mesh-axis name
or a tuple of names (e.g. ("pod", "data", "model") to shard columns over
all 512 chips in the multi-pod dry-run)."""
from __future__ import annotations

import math


def axis_tuple(axis):
    return axis if isinstance(axis, tuple) else (axis,)


def axis_size(mesh, axis) -> int:
    return math.prod(mesh.shape[a] for a in axis_tuple(axis))
