"""Sparse CSR graph container — lifting the paper's adjacency-matrix ceiling.

The paper's own §V diagnosis: the dense adjacency matrix burns O(n²) memory
(Table II's 40,000-vertex graph has only 120k edges but needs a 1.6 GB
matrix) and the dense relax sweep does O(n²) work per iteration regardless
of density.  This module stores edges in O(n + m):

* **CSR over incoming edges** (i.e. CSR of the adjacency transpose): every
  relax engine asks "which u reach v?" — ``new[v] = min(dist[v],
  min_{(u,w)->v} dist[u] + w)`` — so row v holds v's *incoming* arcs.
  For undirected graphs both orientations are stored, exactly like the
  symmetric dense matrix.

* **Padded ELL** (``ell()``): the TPU-friendly fixed-width view, rows padded
  to a common width K with (index 0, weight INF) sentinels that can never
  win a min.  This is what the Pallas kernel (kernels/csr_relax) consumes —
  fixed row width means static block shapes, the same trick the paper's
  padding plays for its process count (§III-B.2).

This file is deliberately numpy-only (container layer); device-array
staging lives in core/bellman_csr.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import Graph, INF, random_edge_list


def _freeze(*arrays: np.ndarray):
    """Mark arrays read-only (see CsrGraph.__post_init__'s immutability
    contract): memoized views are shared across callers, so the builders
    freeze everything they cache."""
    for a in arrays:
        a.flags.writeable = False
    return arrays if len(arrays) > 1 else arrays[0]


def _build_ell(
    indptr: np.ndarray, ids: np.ndarray, weights: np.ndarray,
    n: int, width_multiple: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Pack one CSR orientation into padded ELL: (n, K) int32 ids and
    (n, K) float32 weights, K = max row degree rounded up to
    ``width_multiple`` (min one lane group even for edgeless graphs).
    Padding slots are (0, INF): an INF candidate can never win a min, the
    same unreachable-padding argument as the paper's padded matrix.
    Shared by the incoming (``ell``) and outgoing (``out_ell``) views so
    the padding rules cannot diverge."""
    deg = np.diff(indptr)
    max_deg = int(deg.max()) if deg.size else 0
    K = -(-max(max_deg, 1) // width_multiple) * width_multiple
    idx = np.zeros((n, K), np.int32)
    w = np.full((n, K), INF, np.float32)
    rows = np.repeat(np.arange(n), deg)
    pos = np.arange(int(indptr[-1])) - np.repeat(indptr[:-1], deg)
    idx[rows, pos] = ids
    w[rows, pos] = weights
    return _freeze(idx, w)


def _masked_row_counts(mask: np.ndarray, indptr: np.ndarray,
                       n: int) -> np.ndarray:
    """Per-row count of True arcs under a per-arc ``mask``, for rows
    delimited by ``indptr``.  ``np.add.reduceat`` mishandles empty rows
    (it returns the element AT the boundary, and raises outright when a
    trailing empty row's boundary equals len(mask)), so empty rows are
    clipped and zeroed explicitly."""
    deg = np.diff(indptr)
    if mask.size == 0:
        return np.zeros(n, np.int64)
    starts = np.minimum(np.asarray(indptr[:-1], np.int64), mask.size - 1)
    return np.where(deg > 0, np.add.reduceat(mask, starts), 0).astype(np.int64)


@dataclasses.dataclass(frozen=True)
class CsrGraph:
    """Incoming-edge CSR graph.

    indptr:  (n+1,) int64 — row v's incoming arcs live in
             ``[indptr[v], indptr[v+1])``; rows sorted by (dst, src).
    indices: (nnz,) int32 — source vertex u of each stored arc.
    weights: (nnz,) float32.
    n:        vertex count.
    directed: as in Graph; undirected graphs store both orientations, so
              ``num_edges == nnz // 2`` there.
    """

    indptr: np.ndarray
    indices: np.ndarray
    weights: np.ndarray
    n: int
    directed: bool = False

    def __post_init__(self):
        # Immutability contract: every derived view (dst_ids / ell /
        # out_csr / out_ell / partitioned / to_dense) is memoized per
        # instance and SHARED by every later caller — serve/registry.py
        # pins them on long-lived handles and dynamic/overlay.py layers
        # mutable overlays on top of a frozen base.  An in-place write to
        # any field array would silently corrupt whichever memoized views
        # were already built from it, so the arrays are marked read-only
        # here (and the memoized views are frozen by their builders).
        # Mutation goes through dynamic.DynamicGraph, which copies what
        # it needs; numpy raises ValueError on any write attempt below.
        for arr in (self.indptr, self.indices, self.weights):
            arr.flags.writeable = False

    @property
    def nnz(self) -> int:
        """Stored arcs (both orientations for undirected graphs)."""
        return int(self.indices.shape[0])

    @property
    def num_edges(self) -> int:
        # matches Graph.num_edges (which counts finite adj > 0): zero- or
        # INF-weight arcs are stored and relaxed but not counted as edges.
        cnt = int((np.isfinite(self.weights) & (self.weights > 0)).sum())
        return cnt if self.directed else cnt // 2

    @property
    def nbytes(self) -> int:
        return self.indptr.nbytes + self.indices.nbytes + self.weights.nbytes

    def _memo(self, key, build):
        # derived views are memoized per instance; writing through __dict__
        # sidesteps the frozen-dataclass __setattr__ (fields stay immutable,
        # dataclass __eq__ ignores non-field entries).
        if key not in self.__dict__:
            self.__dict__[key] = build()
        return self.__dict__[key]

    def dst_ids(self) -> np.ndarray:
        """(nnz,) int32 destination id of each stored arc (segment ids for
        the segment-min relax sweep); ascending by construction.  Memoized."""
        def build():
            deg = np.diff(self.indptr)
            return _freeze(np.repeat(np.arange(self.n, dtype=np.int32), deg))
        return self._memo("_dst_ids", build)

    def ell(self, width_multiple: int = 8) -> tuple[np.ndarray, np.ndarray]:
        """Padded-ELL view: (n, K) int32 indices and (n, K) float32 weights.

        K = max in-degree rounded up to ``width_multiple`` (min one lane
        group even for edgeless graphs).  Padding slots are (0, INF):
        ``dist[0] + INF == INF`` never beats a real candidate, the same
        unreachable-padding argument as the paper's padded matrix.
        Memoized per width_multiple.

        Note this view is O(n · max_in_degree), not O(n + m): on heavily
        skewed degree distributions (a hub with ~n incoming arcs) it
        re-approaches the dense matrix — the flat CSR arrays (and the
        ``bellman_csr`` engine) stay O(n + m) regardless.
        """
        def build():
            return _build_ell(self.indptr, self.indices, self.weights,
                              self.n, width_multiple)
        return self._memo(("_ell", width_multiple), build)

    def out_csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Outgoing-edge CSR view: ``(out_indptr, out_dst, out_w)``.

        Row u holds u's *outgoing* arcs — ``out_dst[out_indptr[u] :
        out_indptr[u+1]]`` are the vertices u reaches — sorted by
        (src, dst).  The stored container is incoming-only (rows = "who
        reaches v?", the pull formulation every whole-graph sweep wants);
        frontier-driven relaxation asks the opposite question ("whom does
        the improved vertex u push to?"), so this is the transpose,
        built once in O(m log m) and memoized like the other views.
        """
        def build():
            src = np.asarray(self.indices, np.int64)
            dst = self.dst_ids().astype(np.int64)
            order = np.lexsort((dst, src))              # by src, then dst
            out_dst = dst[order].astype(np.int32)
            out_w = np.asarray(self.weights)[order]
            counts = np.bincount(src, minlength=self.n)
            indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
            return _freeze(indptr, out_dst, out_w)
        return self._memo("_out_csr", build)

    def out_ell(self, width_multiple: int = 8) -> tuple[np.ndarray, np.ndarray]:
        """Padded-ELL view of :meth:`out_csr`: (n, K) int32 destination ids
        and (n, K) float32 weights, K = max *out*-degree rounded up to
        ``width_multiple``.  Padding slots are (0, INF) — an INF candidate
        scatter-min'd into vertex 0 never wins, the push-side twin of
        ``ell()``'s unreachable-padding argument.  Memoized per width.
        """
        def build():
            indptr, out_dst, out_w = self.out_csr()
            return _build_ell(indptr, out_dst, out_w, self.n, width_multiple)
        return self._memo(("_out_ell", width_multiple), build)

    def light_in_ell(
        self, delta: float, width_multiple: int = 8
    ) -> tuple[np.ndarray, np.ndarray]:
        """Padded-ELL view of the *light* incoming arcs (weight <= Δ):
        (n, K_light) int32 source ids and (n, K_light) float32 weights —
        the Δ-stepping light phase's pull operand (core/delta_stepping.py).

        The split is the classic Δ-stepping light/heavy partition
        (Meyer & Sanders; revisited by arXiv 1604.02113): light arcs can
        re-improve labels inside the current Δ-bucket and are iterated to
        a fixpoint, heavy arcs (weight > Δ) can only reach later buckets
        and are relaxed once per bucket — see ``heavy_out_csr`` for the
        other half.  K_light = max light in-degree rounded up to
        ``width_multiple``; padding slots are the usual (0, INF)
        sentinels.  Memoized per (Δ, width): serving solves on a pinned
        handle pay the O(m) split once.
        """
        def build():
            mask = np.asarray(self.weights) <= np.float32(delta)
            ldeg = _masked_row_counts(mask, self.indptr, self.n)
            lip = np.concatenate([[0], np.cumsum(ldeg)]).astype(np.int64)
            return _build_ell(lip, self.indices[mask], self.weights[mask],
                              self.n, width_multiple)
        return self._memo(("_light_in_ell", float(delta), width_multiple),
                          build)

    def heavy_out_csr(
        self, delta: float
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Outgoing-edge CSR restricted to the *heavy* arcs (weight > Δ):
        ``(indptr, dst, w)`` with the same (src, dst) ordering as
        ``out_csr``.  A heavy arc can never land inside the bucket it
        leaves (its weight alone exceeds the bucket width), so Δ-stepping
        relaxes each settled bucket's heavy out-arcs exactly once — a
        push over this view — instead of re-touching them every inner
        iteration.  Complement of ``light_in_ell`` (disjoint by the same
        weight <= Δ test).  Memoized per Δ.
        """
        def build():
            indptr, out_dst, out_w = self.out_csr()
            mask = out_w > np.float32(delta)
            hdeg = _masked_row_counts(mask, indptr, self.n)
            hip = np.concatenate([[0], np.cumsum(hdeg)]).astype(np.int64)
            return _freeze(hip, out_dst[mask], out_w[mask])
        return self._memo(("_heavy_out_csr", float(delta)), build)

    def partitioned(self, nprocs: int, *, pad_multiple: int = 8) -> "CsrPartition":
        """1-D vertex partition of this graph across ``nprocs`` owners —
        the sparse twin of ``Graph.padded(P)`` + column slicing (the
        paper's §III-B.2 partitioning step, at O(m/P) per owner instead
        of O(n²/P)).

        Vertices are padded to ``n_pad = ceil(n / P) * P`` and owner p
        gets the contiguous block ``[p*loc_n, (p+1)*loc_n)``.  Each owner
        stores exactly the arcs *targeting* its owned vertices (the
        incoming-CSR row block), in two per-owner orientations:

        * ``in_*``: sorted by (local dst, src) — the segment-min sweep
          layout (core/sharded_csr.sssp_bellman_csr_sharded);
        * ``out_*``: the same arcs re-sorted by (global src, local dst)
          behind a per-owner CSR over *all* global sources — the
          frontier-push layout (sssp_frontier_sharded): given a frontier
          vertex u, ``out_indptr[p, u] : out_indptr[p, u+1]`` window the
          arcs u sends into p's owned block.

        Blocks are stacked along a leading owner axis and padded to the
        max block nnz (rounded up to ``pad_multiple``) with inert
        sentinel arcs (w = INF, src 0, dst = last local row) so shard_map
        sees one rectangular array per field.  Memoized per (P, pad).
        """
        def build():
            return _partition_csr(self, nprocs, pad_multiple)
        return self._memo(("_part", nprocs, pad_multiple), build)

    @classmethod
    def from_dense(cls, g: Graph) -> "CsrGraph":
        """Capture every finite off-diagonal entry of ``g.adj`` as an arc.

        Uses the full (possibly padded) matrix dimension as the vertex
        count, matching how the dense engines treat a padded Graph.
        """
        adj = np.asarray(g.adj, np.float32)
        n = adj.shape[0]
        mask = np.isfinite(adj)
        np.fill_diagonal(mask, False)
        u, v = np.nonzero(mask)
        order = np.lexsort((u, v))                       # by dst, then src
        src = u[order].astype(np.int32)
        dst = v[order]
        w = adj[u, v][order].astype(np.float32)
        counts = np.bincount(dst, minlength=n)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return cls(indptr=indptr, indices=src, weights=w, n=n,
                   directed=g.directed)

    def to_dense(self) -> Graph:
        """Materialize the O(n²) matrix (INF off-edges, 0 diagonal).
        Memoized like the other derived views — repeat dense-engine solves
        of one CsrGraph reuse the matrix instead of refilling n² entries."""
        def build():
            adj = np.full((self.n, self.n), INF, dtype=np.float32)
            np.fill_diagonal(adj, 0.0)
            adj[self.indices, self.dst_ids()] = self.weights
            return Graph(adj=_freeze(adj), n=self.n, directed=self.directed)
        return self._memo("_dense", build)


@dataclasses.dataclass(frozen=True)
class CsrPartition:
    """Per-owner row blocks of a :class:`CsrGraph` (see
    ``CsrGraph.partitioned``).  All arrays are numpy, stacked along a
    leading owner axis of size ``nprocs``; device staging lives in
    core/sharded_csr.py.

    in_src:     (P, nnz_max) int32  global source of each arc.
    in_dst_loc: (P, nnz_max) int32  LOCAL destination row, ascending per
                owner (segment ids for the local segment-min); sentinel
                padding uses the last local row so the ascending order
                survives.
    in_w:       (P, nnz_max) f32    weights, INF on padding.
    out_indptr: (P, n_pad + 2) int32  per-owner CSR over global sources:
                row u of owner p windows the arcs u -> (p's owned block).
                One extra trailing row (always empty) absorbs the
                frontier engines' sentinel id n_pad.
    out_dst_loc, out_w: the in_* arcs re-sorted by (src, local dst).
    """

    nprocs: int
    n: int
    n_pad: int
    loc_n: int
    nnz_max: int
    in_src: np.ndarray
    in_dst_loc: np.ndarray
    in_w: np.ndarray
    out_indptr: np.ndarray
    out_dst_loc: np.ndarray
    out_w: np.ndarray

    @property
    def per_device_edge_bytes(self) -> int:
        """Edge-array bytes held by ONE owner (the O(m/P) payload; the
        out_indptr index is O(n) per owner and reported separately)."""
        per = self.nnz_max * (self.in_src.itemsize + self.in_dst_loc.itemsize
                              + self.in_w.itemsize + self.out_dst_loc.itemsize
                              + self.out_w.itemsize)
        return int(per)

    @property
    def per_device_index_bytes(self) -> int:
        return int((self.n_pad + 2) * self.out_indptr.itemsize)

    @property
    def nbytes(self) -> int:
        """Total host bytes of the partition view across ALL owners — what
        serve/registry.py charges against its byte budget when it stages a
        partition (the staged device arrays mirror these buffers 1:1)."""
        return int(self.in_src.nbytes + self.in_dst_loc.nbytes
                   + self.in_w.nbytes + self.out_indptr.nbytes
                   + self.out_dst_loc.nbytes + self.out_w.nbytes)


def _partition_csr(cg: CsrGraph, nprocs: int, pad_multiple: int) -> CsrPartition:
    if nprocs < 1:
        raise ValueError(f"nprocs must be >= 1, got {nprocs}")
    n = cg.n
    loc_n = -(-n // nprocs)
    n_pad = loc_n * nprocs
    dst = cg.dst_ids()                         # ascending => owner-grouped
    # owner p's arcs are the contiguous indptr range of its row block.
    row_edges = np.minimum(np.arange(nprocs + 1) * loc_n, n)
    bounds = np.asarray(cg.indptr)[row_edges]
    blk_nnz = np.diff(bounds)
    nnz_max = int(-(-max(int(blk_nnz.max()) if nprocs else 1, 1)
                    // pad_multiple) * pad_multiple)

    in_src = np.zeros((nprocs, nnz_max), np.int32)
    in_dst_loc = np.full((nprocs, nnz_max), loc_n - 1, np.int32)
    in_w = np.full((nprocs, nnz_max), INF, np.float32)
    out_indptr = np.zeros((nprocs, n_pad + 2), np.int32)
    out_dst_loc = np.zeros((nprocs, nnz_max), np.int32)
    out_w = np.full((nprocs, nnz_max), INF, np.float32)

    for p in range(nprocs):
        lo, hi = int(bounds[p]), int(bounds[p + 1])
        k = hi - lo
        src = np.asarray(cg.indices[lo:hi], np.int32)
        dloc = (dst[lo:hi] - p * loc_n).astype(np.int32)
        w = np.asarray(cg.weights[lo:hi], np.float32)
        in_src[p, :k] = src
        in_dst_loc[p, :k] = dloc
        in_w[p, :k] = w
        order = np.lexsort((dloc, src))        # by src, then local dst
        out_dst_loc[p, :k] = dloc[order]
        out_w[p, :k] = w[order]
        counts = np.bincount(src, minlength=n_pad)
        ptr = np.concatenate([[0], np.cumsum(counts)])
        out_indptr[p, :n_pad + 1] = ptr
        out_indptr[p, n_pad + 1] = ptr[-1]     # sentinel row: zero degree
    return CsrPartition(
        nprocs=nprocs, n=n, n_pad=n_pad, loc_n=loc_n, nnz_max=nnz_max,
        in_src=in_src, in_dst_loc=in_dst_loc, in_w=in_w,
        out_indptr=out_indptr, out_dst_loc=out_dst_loc, out_w=out_w,
    )


def csr_from_edge_list(
    n: int,
    edges: np.ndarray,
    weights: np.ndarray,
    directed: bool = False,
) -> CsrGraph:
    """Build an incoming-edge CSR from an edge list in O(m log m).

    Same semantics as graph.from_edge_list: undirected edges are mirrored,
    self-loops dropped (the diagonal is implicit), and duplicate arcs keep
    the minimum weight.
    """
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    w = np.asarray(weights, np.float32).reshape(-1)
    if edges.size and (edges.min() < 0 or edges.max() >= n):
        # fail fast like the dense sibling's fancy-indexing would; the
        # (dst, src) -> dst*n+src packing below would otherwise silently
        # alias out-of-range ids onto valid arcs.
        raise IndexError(
            f"edge endpoints must be in [0, {n}); got "
            f"[{edges.min()}, {edges.max()}]"
        )
    u, v = edges[:, 0], edges[:, 1]
    if not directed:
        u, v = np.concatenate([u, v]), np.concatenate([v, u])
        w = np.concatenate([w, w])
    keep = u != v
    u, v, w = u[keep], v[keep], w[keep]
    # dedupe (dst, src) pairs keeping the min weight, sorted by (dst, src).
    key = v * np.int64(n) + u
    uniq, inv = np.unique(key, return_inverse=True)
    wmin = np.full(uniq.shape[0], INF, np.float32)
    np.minimum.at(wmin, inv, w)
    dst = (uniq // n).astype(np.int64)
    src = (uniq % n).astype(np.int32)
    counts = np.bincount(dst, minlength=n)
    indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return CsrGraph(indptr=indptr, indices=src, weights=wmin, n=n,
                    directed=directed)


def random_csr_graph(
    n: int,
    m: int,
    *,
    seed: int = 0,
    directed: bool = False,
    max_weight: float = 100.0,
    connected: bool = True,
) -> CsrGraph:
    """CSR-native random graph — same RNG stream as graph.random_graph, so
    equal seeds yield the identical graph in either representation, without
    ever allocating the dense matrix."""
    e, w = random_edge_list(
        n, m, seed=seed, max_weight=max_weight, connected=connected
    )
    return csr_from_edge_list(n, e, w, directed=directed)


def sparse_csr_graph(n: int, *, seed: int = 0) -> CsrGraph:
    """Paper Table II corpus shape (m = 3n) in O(n) memory — usable far
    beyond the dense generator's n≈40k ceiling."""
    return random_csr_graph(n, 3 * n, seed=seed)


def road_like_csr_graph(n: int, *, seed: int = 0) -> CsrGraph:
    """Long-diameter grid corpus (graph.road_like_edge_list) as a CSR —
    the Δ-stepping gate's road-network stand-in.  ``n`` rounds down to a
    perfect square; read the actual count back from ``.n``."""
    from repro.core.graph import road_like_edge_list

    nn, e, w = road_like_edge_list(n, seed=seed)
    return csr_from_edge_list(nn, e, w)


def skewed_hub_csr_graph(n: int, *, seed: int = 0) -> CsrGraph:
    """Heavy-tailed hub corpus (graph.skewed_hub_edge_list) as a CSR —
    the Δ-stepping gate's skewed-weight stand-in."""
    from repro.core.graph import skewed_hub_edge_list

    e, w = skewed_hub_edge_list(n, seed=seed)
    return csr_from_edge_list(n, e, w)
