"""Public facade for the SSSP engine — the paper's three implementations
(plus beyond-paper variants) behind one call.

    from repro.core.api import shortest_paths
    res = shortest_paths(graph, source=0, engine="serial")

Engines (paper §III):
    serial             Alg. 1, O(n²) textbook loop               (paper)
    dijkstra_sharded   Alg. 2, 1-D column-parallel + MINLOC      (paper, MPI)
    bellman            Alg. 3/4 relax-to-fixpoint, jnp sweep     (paper, CUDA)
    bellman_kernel     Alg. 3/4 with the Pallas min-plus kernel  (paper, CUDA->TPU)
    bellman_sharded    fixpoint + 1 all-gather/sweep             (beyond-paper)
    multisource        batched (S, n) fixpoint                   (beyond-paper)
    bellman_csr        fixpoint, O(m) segment-min sweep on CSR   (beyond-paper)
    bellman_csr_kernel fixpoint with the Pallas padded-ELL kernel (beyond-paper)

Choosing dense vs CSR (the paper's Table I vs Table II trade-off):
    The dense engines sweep the n² adjacency matrix per relaxation, so
    their cost depends on n only — ideal for dense graphs (Table I, m ≈
    n²/2) where the matrix *is* the edge set.  For sparse graphs (Table II,
    m = 3n) the matrix is ~n/6 times larger than the edges and the paper's
    §V flags exactly this as its memory/perf ceiling (40k vertices = 1.6 GB
    dense).  The ``bellman_csr*`` engines store O(n + m) and do O(m) work
    per sweep: prefer them whenever m << n², and use a ``CsrGraph``
    (core/csr.py) directly to skip the dense matrix entirely.  Dense
    ``Graph`` inputs are auto-converted; ``CsrGraph`` inputs passed to a
    dense engine are densified (O(n²) — only sensible for small n).
    Caveat: ``bellman_csr_kernel`` builds the padded-ELL view, which is
    O(n · max_in_degree) — on heavily skewed graphs (a hub vertex with ~n
    incoming arcs) that re-approaches O(n²); use ``bellman_csr`` (flat
    segment-min, strictly O(n + m)) for such degree distributions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csr as csr_mod
from repro.core import graph as graph_mod
from repro.core.bellman import sssp_bellman, sssp_bellman_sharded
from repro.core.bellman_csr import csr_operands, sssp_bellman_csr
from repro.core.multisource import sssp_multisource, sssp_multisource_sharded
from repro.core.serial import dijkstra_serial
from repro.core.sharded import dijkstra_sharded

ENGINES = (
    "serial",
    "dijkstra_sharded",
    "bellman",
    "bellman_kernel",
    "bellman_sharded",
    "multisource",
    "bellman_csr",
    "bellman_csr_kernel",
)

CSR_ENGINES = ("bellman_csr", "bellman_csr_kernel")


@dataclasses.dataclass
class SsspResult:
    dist: np.ndarray            # (n,) or (S, n)
    pred: Optional[np.ndarray]  # (n,) or None (multisource recovers on demand)
    sweeps: Optional[int]       # fixpoint engines only
    engine: str


def shortest_paths(
    g: "graph_mod.Graph | csr_mod.CsrGraph | jax.Array | np.ndarray",
    source,
    *,
    engine: str = "serial",
    mesh: Optional[jax.sharding.Mesh] = None,
    axis: str = "data",
    block: int = 256,
    max_sweeps: int | None = None,
) -> SsspResult:
    """Run one SSSP engine.  ``source`` is an int (or int array for
    ``multisource``).  Sharded engines need a ``mesh``; the adjacency is
    padded to the mesh-axis size automatically (paper §III-B.2)."""
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")

    if isinstance(g, csr_mod.CsrGraph):
        cg, n_true = g, g.n
        if engine not in CSR_ENGINES:
            # dense engines need the matrix; O(n²), small-n convenience only.
            g = cg.to_dense()
    else:
        if isinstance(g, graph_mod.Graph):
            n_true = g.n
        else:
            adj_np = np.asarray(g)
            n_true = adj_np.shape[0]
            g = graph_mod.Graph(adj=adj_np.astype(np.float32), n=n_true)
        cg = None

    if engine in CSR_ENGINES:
        if cg is None:
            cg = g.to_csr()
        use_kernel = engine == "bellman_csr_kernel"
        operands = csr_operands(cg, with_ell=use_kernel)
        sweep_fn = None
        if use_kernel:
            from repro.kernels.csr_relax.ops import make_csr_sweep_fn

            sweep_fn = make_csr_sweep_fn(block_v=block)
        d, p, s = sssp_bellman_csr(
            operands,
            jnp.int32(source),
            n=cg.n,
            sweep_fn=sweep_fn,
            max_sweeps=max_sweeps,
        )
        return SsspResult(np.asarray(d), np.asarray(p), int(s), engine)

    if engine == "serial":
        d, p = dijkstra_serial(jnp.asarray(g.adj), jnp.int32(source))
        return SsspResult(np.asarray(d), np.asarray(p), None, engine)

    if engine == "bellman":
        d, p, s = sssp_bellman(
            jnp.asarray(g.adj), jnp.int32(source), max_sweeps=max_sweeps
        )
        return SsspResult(np.asarray(d), np.asarray(p), int(s), engine)

    if engine == "bellman_kernel":
        from repro.kernels.sssp_relax.ops import make_sweep_fn

        d, p, s = sssp_bellman(
            jnp.asarray(g.adj),
            jnp.int32(source),
            sweep_fn=make_sweep_fn(block_u=block, block_v=block),
            max_sweeps=max_sweeps,
        )
        return SsspResult(np.asarray(d), np.asarray(p), int(s), engine)

    if engine == "multisource":
        srcs = jnp.atleast_1d(jnp.asarray(source, jnp.int32))
        if mesh is not None:
            gp = g.padded(mesh.shape[axis])
            D, s = sssp_multisource_sharded(
                jnp.asarray(gp.adj), srcs, mesh, axis=axis, max_sweeps=max_sweeps
            )
            return SsspResult(np.asarray(D)[:, :n_true], None, int(s), engine)
        D, s = sssp_multisource(jnp.asarray(g.adj), srcs, max_sweeps=max_sweeps)
        return SsspResult(np.asarray(D), None, int(s), engine)

    # --- sharded engines -------------------------------------------------
    if mesh is None:
        raise ValueError(f"engine {engine!r} needs a mesh")
    gp = g.padded(mesh.shape[axis])

    if engine == "dijkstra_sharded":
        d, p = dijkstra_sharded(
            jnp.asarray(gp.adj), source, mesh, axis=axis, n_true=n_true
        )
        return SsspResult(
            np.asarray(d)[:n_true], np.asarray(p)[:n_true], None, engine
        )

    d, p, s = sssp_bellman_sharded(
        jnp.asarray(gp.adj), source, mesh, axis=axis, max_sweeps=max_sweeps
    )
    return SsspResult(
        np.asarray(d)[:n_true], np.asarray(p)[:n_true], int(s), engine
    )
