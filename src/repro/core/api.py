"""Public facade for the SSSP engine — the paper's three implementations
(plus beyond-paper variants) behind one call.

    from repro.core.api import shortest_paths
    res = shortest_paths(graph, source=0, engine="serial")

Engines (paper §III):
    serial             Alg. 1, O(n²) textbook loop               (paper)
    dijkstra_sharded   Alg. 2, 1-D column-parallel + MINLOC      (paper, MPI)
    bellman            Alg. 3/4 relax-to-fixpoint, jnp sweep     (paper, CUDA)
    bellman_kernel     Alg. 3/4 with the Pallas min-plus kernel  (paper, CUDA->TPU)
    bellman_sharded    fixpoint + 1 all-gather/sweep             (beyond-paper)
    multisource        batched (S, n) fixpoint                   (beyond-paper)
    bellman_csr        fixpoint, O(m) segment-min sweep on CSR   (beyond-paper)
    bellman_csr_kernel fixpoint with the Pallas padded-ELL kernel (beyond-paper)
    frontier           frontier-compacted sweeps, O(active out-degree)
                       per sweep (beyond-paper, core/frontier.py)
    frontier_kernel    same, Pallas candidate kernel (kernels/frontier_relax)
    delta_stepping     true Δ-stepping: light/heavy edge split, per-bucket
                       light pull fixpoint + one heavy push per bucket
                       (beyond-paper, core/delta_stepping.py)
    delta_stepping_kernel
                       same, fused Pallas bucket-relax kernel
                       (kernels/bucket_relax)
    multisource_csr    batched (S, n) fixpoint on CSR edges      (beyond-paper)
    bellman_csr_sharded vertex-partitioned CSR fixpoint: O(m/P) local
                       segment-min + 1 all-gather/sweep (beyond-paper,
                       core/sharded_csr.py; needs a mesh)
    frontier_sharded   vertex-partitioned frontier push: per sweep the
                       devices exchange only the compacted (id, dist)
                       frontier pairs — the MPI-message analogue — and
                       each relaxes O(frontier arcs into its block)
                       (beyond-paper, core/sharded_csr.py; needs a mesh)
    multisource_csr_sharded
                       batched (S, n) union-frontier push on the same
                       partition: the S sources share one compacted
                       frontier exchange and one arc-window gather per
                       sweep, so edge loads amortize S ways on top of
                       the P-way split (beyond-paper; needs a mesh)

    ``engine="auto"`` delegates the choice to the serving layer's
    dispatch policy (serve/dispatch.py): graphs at or above its
    shard-threshold route to the sharded CSR engines on a cached
    host-device mesh, everything else to the single-device frontier /
    multisource engines.  Same bitwise answers either way.

Choosing dense vs CSR vs frontier (the paper's Table I vs Table II
trade-off, plus its §V "every edge, every sweep" complaint):
    The dense engines sweep the n² adjacency matrix per relaxation, so
    their cost depends on n only — ideal for dense graphs (Table I, m ≈
    n²/2) where the matrix *is* the edge set.  For sparse graphs (Table II,
    m = 3n) the matrix is ~n/6 times larger than the edges and the paper's
    §V flags exactly this as its memory/perf ceiling (40k vertices = 1.6 GB
    dense).  The ``bellman_csr*`` engines store O(n + m) and do O(m) work
    per sweep: prefer them whenever m << n², and use a ``CsrGraph``
    (core/csr.py) directly to skip the dense matrix entirely.  Dense
    ``Graph`` inputs are auto-converted; ``CsrGraph`` inputs passed to a
    dense engine are densified (O(n²) — only sensible for small n).
    Caveat: ``bellman_csr_kernel`` builds the padded-ELL view, which is
    O(n · max_in_degree) — on heavily skewed graphs (a hub vertex with ~n
    incoming arcs) that re-approaches O(n²); use ``bellman_csr`` (flat
    segment-min, strictly O(n + m)) for such degree distributions.

    The ``frontier*`` engines go one step further: each sweep relaxes only
    the out-edges of vertices whose distance improved last sweep, so
    per-sweep work is O(frontier out-degree) instead of O(m).  They win
    whenever frontiers stay narrow relative to the edge set — long-diameter
    sparse graphs (road-network-like, the Table II shape at large n), where
    late sweeps of ``bellman_csr`` touch all m arcs to improve a handful of
    vertices.  They *lose* on dense diameter-2 graphs (Table I): there the
    first frontier is essentially every vertex, so compaction adds overhead
    while the sweep still touches ~every edge — keep ``bellman`` /
    ``bellman_csr`` for those.  On heavy-tailed weight distributions pass
    ``delta=`` to bucket the frontier Δ-stepping-style.  ``SsspResult.
    edges_relaxed`` reports the measured relaxation work for all CSR-family
    engines (benchmarks/run_bench.py tracks the ratio as a perf gate).

    The ``delta_stepping*`` engines are the full Δ-stepping algorithm, not
    the frontier engine's bucket throttle: edges are split once by weight
    at staging (light <= Δ as a padded in-ELL, heavy > Δ as an outgoing
    CSR), each bucket's light arcs iterate to a fixpoint via a fused dense
    PULL (no per-sweep frontier compaction at all), and each settled
    bucket's heavy arcs are pushed exactly once.  They win where the
    frontier engine's per-sweep ``nonzero`` compaction dominates — long-
    diameter graphs (road-like grids: hundreds of frontier sweeps collapse
    into a handful of bucket phases) and heavy-tailed weight mixes (hub
    fan-outs relaxed once per bucket instead of per sweep).  They lose
    when the light in-ELL is wide (dense or hub-in-degree-skewed graphs —
    the pull does O(n·K_light) work per pass; ``delta_profile`` reports
    ``routable=False`` and serve/dispatch.py keeps the frontier engine).
    Distances stay bitwise-equal to ``serial`` for ANY positive Δ; Δ only
    moves work between phases.  ``delta="auto"`` (also the delta engines'
    default) picks Δ per graph from the weight distribution
    (core/delta_stepping.auto_delta — deterministic, memoized).  For these
    engines ``sweeps`` counts outer bucket phases and ``edges_relaxed``
    charges every light pass at the full light arc count — honest
    accounting for the pull's regular-but-total touch pattern.

    ``multisource_csr`` batches S sources over one shared edge gather per
    sweep (the sparse twin of ``multisource``): use it to amortize the
    edge-index loads when solving many sources on one sparse graph.  Like
    ``multisource`` it returns ``pred=None``; :func:`recover_pred` rebuilds
    the predecessor rows on demand at O(m) per source.

Dense vs sparse partitioning (the sharded engines' trade-off):
    The dense sharded engines (``dijkstra_sharded``/``bellman_sharded``/
    ``multisource``) split the O(n²) adjacency matrix into column slabs —
    each device stores n²/P entries however sparse the graph, which is the
    paper's own §V ceiling merely divided by P.  The CSR sharded engines
    partition the *vertices* and give each device only the O(m/P) arcs
    targeting its block (``CsrGraph.partitioned``), so sparse graphs shard
    at sparse cost; the dense slabs remain the right choice only when the
    matrix is the edge set (Table I density).  Within the CSR pair:
    ``bellman_csr_sharded`` moves O(n) per sweep (the gathered distance
    vector) and touches every local arc; ``frontier_sharded`` moves only
    the compacted frontier pairs and touches only frontier arcs — wins
    whenever frontiers are narrow (long-diameter sparse graphs), loses the
    exchange overhead when the frontier is ~everything (dense diameter-2
    graphs, where ``bellman_csr_sharded``'s single collective is cheaper).
    Both report ``edges_relaxed``; benchmarks/run_bench.py gates
    ``frontier_sharded`` at P=4 against single-device ``frontier`` (same
    work, partitioned — each arc has exactly one owner).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import csr as csr_mod
from repro.core import graph as graph_mod
from repro.core.bellman import (predecessors_from_dist, sssp_bellman,
                                sssp_bellman_sharded)
from repro.core.bellman_csr import (csr_operands, predecessors_from_dist_csr,
                                    sssp_bellman_csr, sssp_multisource_csr)
from repro.core.delta_stepping import (auto_delta, delta_operands,
                                       sssp_delta_stepping)
from repro.core.frontier import frontier_operands, sssp_frontier
from repro.core.multisource import sssp_multisource, sssp_multisource_sharded
from repro.core.serial import dijkstra_serial
from repro.core.sharded import dijkstra_sharded

ENGINES = (
    "serial",
    "dijkstra_sharded",
    "bellman",
    "bellman_kernel",
    "bellman_sharded",
    "multisource",
    "bellman_csr",
    "bellman_csr_kernel",
    "frontier",
    "frontier_kernel",
    "delta_stepping",
    "delta_stepping_kernel",
    "multisource_csr",
    "bellman_csr_sharded",
    "frontier_sharded",
    "multisource_csr_sharded",
)

# single-source engines that consume CsrGraph operands natively (and return
# a pred tree); multisource_csr also runs on CSR but is batched/pred-less.
CSR_ENGINES = ("bellman_csr", "bellman_csr_kernel",
               "frontier", "frontier_kernel")
FRONTIER_ENGINES = ("frontier", "frontier_kernel")
# true Δ-stepping engines (core/delta_stepping.py): light/heavy split,
# bucketed schedule; delta= selects the bucket width ("auto" = per-graph)
DELTA_ENGINES = ("delta_stepping", "delta_stepping_kernel")
# every engine that consumes (rather than ignores) the delta= argument
_DELTA_CONSUMERS = FRONTIER_ENGINES + DELTA_ENGINES
# mesh-requiring engines on vertex-partitioned CSR blocks (core/sharded_csr)
SHARDED_CSR_ENGINES = ("bellman_csr_sharded", "frontier_sharded",
                       "multisource_csr_sharded")
# every engine that consumes CsrGraph input without densifying it
_CSR_NATIVE = (CSR_ENGINES + DELTA_ENGINES + ("multisource_csr",)
               + SHARDED_CSR_ENGINES)


@dataclasses.dataclass
class SsspResult:
    dist: np.ndarray            # (n,) or (S, n)
    pred: Optional[np.ndarray]  # (n,) or None (recover_pred rebuilds it)
    sweeps: Optional[int]       # fixpoint engines only
    engine: str
    # measured relaxation work, CSR-family engines only: the frontier
    # engines count actual frontier out-degrees; bellman_csr* relax all
    # nnz arcs every sweep.  The run_bench.py perf gate diffs these.
    edges_relaxed: Optional[int] = None
    # sources as submitted (multisource engines), for recover_pred.
    sources: Optional[np.ndarray] = None
    # solver guardrail, fixpoint families (bellman_csr*, frontier*,
    # multisource_csr, the sharded CSR trio, and the dynamic solves):
    # False means a max_sweeps= cap stopped the loop before the fixpoint
    # and dist may sit above the true distances — callers must not treat
    # such a result as exact (serve/errors.NotConverged is the serving
    # consumer).  None for engines without the flag (serial, dense).
    converged: Optional[bool] = None


def _edge_count(g) -> int:
    """Cheap arc count for observability payloads: exact for CSR/dynamic
    inputs, 0 for dense (counting finite off-diagonals would cost O(n²))."""
    from repro.dynamic.overlay import DynamicGraph

    if isinstance(g, DynamicGraph):
        return int(g.nnz_live)
    if isinstance(g, csr_mod.CsrGraph):
        return int(g.nnz)
    return 0


def _resolve_auto(g, source, *, engine, mesh, axis, delta, target):
    """Resolve ``engine="auto"`` through the serving layer's one dispatch
    seam (serve/dispatch.py): the process-default policy picks the engine
    AND its statics — a measured-model policy (repro/tune/select.py)
    returns a calibrated Δ, which binds only when the caller passed none
    (an explicit ``delta=`` always wins).  Lazy import keeps core free of
    a hard serve dependency.  Returns the concrete
    ``(engine, mesh, axis, delta)``; non-auto calls pass through."""
    if engine != "auto":
        return engine, mesh, axis, delta
    from repro.serve.dispatch import default_policy

    multi = np.ndim(source) > 0
    choice = default_policy().choose(
        g, kind="batch" if multi else ("p2p" if target is not None
                                       else "single"))
    engine, mesh, axis = choice.engine, choice.mesh, choice.axis
    if (delta is None and choice.delta is not None
            and engine in _DELTA_CONSUMERS):
        delta = float(choice.delta)
    return engine, mesh, axis, delta


def shortest_paths(
    g: "graph_mod.Graph | csr_mod.CsrGraph | jax.Array | np.ndarray",
    source,
    *,
    engine: str = "serial",
    mesh: Optional[jax.sharding.Mesh] = None,
    axis: str = "data",
    block: int = 256,
    max_sweeps: int | None = None,
    delta: Union[float, str, None] = None,
    target: int | None = None,
    target_lb: float | None = None,
) -> SsspResult:
    """Observability shim over :func:`_shortest_paths` (the real facade,
    same signature + docs).  When a tracer or cost log is installed
    (repro/obs), every solve runs inside a ``solve`` span and emits one
    per-solve cost record (engine, n, m, sweeps, edges_relaxed, wall_ms);
    when both are disabled this adds two attribute reads and one branch.
    """
    from repro.obs.profile import get_cost_log
    from repro.obs.trace import get_tracer

    tr = get_tracer()
    cl = get_cost_log()
    if not (tr.enabled or cl.enabled):
        return _shortest_paths(g, source, engine=engine, mesh=mesh,
                               axis=axis, block=block,
                               max_sweeps=max_sweeps, delta=delta,
                               target=target, target_lb=target_lb)

    import time as _time

    # resolve "auto" HERE so the record carries the routed engine's real
    # decision inputs (mesh arity, model-chosen Δ) — the facade below
    # passes the already-concrete engine straight through.
    engine, mesh, axis, delta = _resolve_auto(
        g, source, engine=engine, mesh=mesh, axis=axis, delta=delta,
        target=target)
    kw = dict(engine=engine, mesh=mesh, axis=axis, block=block,
              max_sweeps=max_sweeps, delta=delta, target=target,
              target_lb=target_lb)
    m = _edge_count(g)
    t0 = _time.perf_counter()
    with tr.span("solve", engine=engine) as sp:
        res = _shortest_paths(g, source, **kw)
        wall_ms = (_time.perf_counter() - t0) * 1e3
        n = int(np.shape(res.dist)[-1])
        batch = int(np.shape(res.dist)[0]) if np.ndim(res.dist) == 2 else 1
        sweeps = 0 if res.sweeps is None else int(res.sweeps)
        edges = 0 if res.edges_relaxed is None else int(res.edges_relaxed)
        conv = True if res.converged is None else bool(res.converged)
        sp.set(engine=res.engine, n=n, m=m, batch=batch, sweeps=sweeps,
               edges_relaxed=edges, converged=conv)
    nprocs = (int(mesh.devices.size)
              if mesh is not None and res.engine in SHARDED_CSR_ENGINES
              else 1)
    # the Δ the solve actually used: an explicit width verbatim; the
    # delta engines' None/"auto" resolves per graph via the memoized
    # auto_delta (identical to what the facade resolved); 0.0 otherwise.
    if isinstance(delta, (int, float)) and not isinstance(delta, bool):
        dval = float(delta)
    elif (res.engine in DELTA_ENGINES
          and isinstance(g, csr_mod.CsrGraph)):
        dval = float(auto_delta(g))
    else:
        dval = 0.0
    cl.emit(engine=res.engine, n=n, m=m, batch=batch, nprocs=nprocs,
            delta=dval, sweeps=sweeps, edges_relaxed=edges,
            wall_ms=wall_ms, converged=conv)
    return res


def _shortest_paths(
    g: "graph_mod.Graph | csr_mod.CsrGraph | jax.Array | np.ndarray",
    source,
    *,
    engine: str = "serial",
    mesh: Optional[jax.sharding.Mesh] = None,
    axis: str = "data",
    block: int = 256,
    max_sweeps: int | None = None,
    delta: Union[float, str, None] = None,
    target: int | None = None,
    target_lb: float | None = None,
) -> SsspResult:
    """Run one SSSP engine.  ``source`` is an int (or int array for
    ``multisource`` / ``multisource_csr``).  Sharded engines need a
    ``mesh``; the adjacency is padded to the mesh-axis size automatically
    (paper §III-B.2).

    ``delta`` sets the Δ-bucket width for the engines that consume it —
    the frontier engines' bucket throttle and the ``delta_stepping*``
    engines' light/heavy split (see the module docstring for when each
    wins).  It must be a positive finite number or the string ``"auto"``
    (resolve per graph via core/delta_stepping.auto_delta; for the delta
    engines ``None`` also means auto, since they cannot run without a Δ).
    Nonpositive, non-finite, or non-numeric values raise ``ValueError``
    eagerly — a nonpositive Δ would make every edge heavy and the bucket
    window empty — as does passing ``delta=`` to any engine that would
    silently ignore it.  Note the frontier engines compile Δ in as a
    static argument (their schedule branches on it at trace time), so
    ``"auto"``'s per-graph values recompile per graph there; the delta
    engines trace Δ as a runtime scalar and recompile only per graph
    shape.

    ``target=`` (frontier engines only) turns the solve into a
    point-to-point query with an early exit: the fixpoint loop stops as
    soon as ``dist[target]`` is provably final — with nonnegative weights,
    once no pending vertex's label is below the target's, no relaxation
    sequence can improve it (the Dijkstra settled-set argument).  The
    returned ``dist[target]`` is bitwise-equal to the full solve's, as is
    every entry with ``dist < dist[target]``; entries above it may still
    sit above their fixpoint, so a target result is a *partial* solve:
    its ``pred`` is ``None`` (a part-invalid tree is never recovered)
    and its row must not be cached as if it were complete
    (serve/scheduler.py treats it accordingly).
    ``target_lb=`` optionally sharpens the exit with an admissible lower
    bound on the s→t distance (e.g. a serve/landmarks.py ALT bound): the
    loop additionally stops once ``dist[target] <= target_lb``.  The bound
    MUST be admissible (never above the true distance) or exactness is
    lost; too-small bounds are merely inert.  ``SsspResult.edges_relaxed``
    and ``sweeps`` report the actual (reduced) work, which is what
    benchmarks/serve_bench.py measures for the point-to-point scenario.
    """
    if engine == "auto":
        # the serving layer's one dispatch seam (serve/dispatch.py) picks
        # the engine and its statics (a model policy's calibrated Δ binds
        # only when the caller passed no delta= of their own).
        engine, mesh, axis, delta = _resolve_auto(
            g, source, engine=engine, mesh=mesh, axis=axis, delta=delta,
            target=target)
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; choose from {ENGINES}")
    # Δ validation is EAGER (before any staging): a bad width would
    # otherwise surface as a silently-ignored kwarg or a hung bucket loop.
    if delta is not None:
        if engine not in _DELTA_CONSUMERS:
            raise ValueError(
                f"delta= is consumed only by {_DELTA_CONSUMERS}; engine "
                f"{engine!r} would silently ignore it")
        if delta != "auto":
            try:
                delta = float(delta)
            except (TypeError, ValueError):
                raise ValueError(
                    f"delta must be a positive finite number or 'auto', "
                    f"got {delta!r}") from None
            if not (math.isfinite(delta) and delta > 0):
                raise ValueError(
                    f"delta must be positive and finite, got {delta!r}")
    # target= early exit is frontier-only; frontier_sharded accepts target=
    # too but runs the FULL fixpoint (its row is a superset of the partial
    # solve, dist[target] bitwise-identical — serve caches it as complete).
    if target is not None and engine not in FRONTIER_ENGINES + (
            "frontier_sharded",):
        raise ValueError(
            f"target= early exit needs a frontier engine "
            f"{FRONTIER_ENGINES}; got {engine!r}")

    from repro.dynamic.overlay import DynamicGraph  # local: dynamic uses api

    if isinstance(g, DynamicGraph):
        # facade convenience: solve the CURRENT version via its snapshot
        # CSR (exact by construction).  The overlay-native engines — which
        # skip the snapshot and keep the jit cache warm across versions —
        # live in dynamic/repair.py (solve_dynamic / repair_sssp) and are
        # what the serving layer uses.
        g = g.snapshot()

    if isinstance(g, csr_mod.CsrGraph):
        cg, n_true = g, g.n
        if engine not in _CSR_NATIVE:
            # dense engines need the matrix; O(n²), small-n convenience only.
            g = cg.to_dense()
    else:
        if isinstance(g, graph_mod.Graph):
            n_true = g.n
        else:
            adj_np = np.asarray(g)
            n_true = adj_np.shape[0]
            g = graph_mod.Graph(adj=adj_np.astype(np.float32), n=n_true)
        cg = None

    if engine in SHARDED_CSR_ENGINES:
        if mesh is None:
            raise ValueError(f"engine {engine!r} needs a mesh")
        from repro.core._axes import axis_size
        from repro.core.sharded_csr import (sssp_bellman_csr_sharded,
                                            sssp_frontier_sharded,
                                            sssp_multisource_csr_sharded)

        if cg is None:
            cg = g.to_csr()
        parts = cg.partitioned(axis_size(mesh, axis))
        if engine == "multisource_csr_sharded":
            srcs = jnp.atleast_1d(jnp.asarray(source, jnp.int32))
            D, s, e, c = sssp_multisource_csr_sharded(
                parts, srcs, mesh, axis=axis, max_sweeps=max_sweeps
            )
            return SsspResult(np.asarray(D)[:, :n_true], None, int(s),
                              engine, edges_relaxed=int(e),
                              sources=np.asarray(srcs), converged=bool(c))
        if engine == "bellman_csr_sharded":
            d, p, s, c = sssp_bellman_csr_sharded(
                parts, source, mesh, axis=axis, max_sweeps=max_sweeps
            )
            # actual partitioned work: every owner sweeps its padded block.
            edges = int(s) * parts.nprocs * parts.nnz_max
            return SsspResult(np.asarray(d)[:n_true], np.asarray(p)[:n_true],
                              int(s), engine, edges_relaxed=edges,
                              converged=bool(c))
        d, s, e, c = sssp_frontier_sharded(
            parts, source, mesh, axis=axis, max_sweeps=max_sweeps
        )
        dist = jnp.asarray(d)[:n_true]
        # fixpoint pred is a pure function of (dist, graph): reuse the O(m)
        # single-device recovery, same tie-breaks as every other engine.
        pred = predecessors_from_dist_csr(dist, csr_operands(cg),
                                          jnp.int32(source))
        return SsspResult(np.asarray(dist), np.asarray(pred), int(s), engine,
                          edges_relaxed=int(e), converged=bool(c))

    if engine in DELTA_ENGINES:
        if cg is None:
            cg = g.to_csr()
        # None and "auto" both resolve per graph: the engine cannot run
        # without a width, and auto_delta is deterministic + memoized.
        dval = auto_delta(cg) if delta in (None, "auto") else delta
        operands = delta_operands(cg, dval)
        pull_fn = None
        if engine == "delta_stepping_kernel":
            from repro.kernels.bucket_relax.ops import make_bucket_pull_fn

            pull_fn = make_bucket_pull_fn(block_v=block)
        d, p, s, e, c = sssp_delta_stepping(
            operands,
            jnp.int32(source),
            jnp.float32(dval),
            n=cg.n,
            pull_fn=pull_fn,
            max_sweeps=max_sweeps,
        )
        return SsspResult(np.asarray(d), np.asarray(p), int(s), engine,
                          edges_relaxed=int(e), converged=bool(c))

    if engine in FRONTIER_ENGINES:
        if cg is None:
            cg = g.to_csr()
        if delta == "auto":
            delta = auto_delta(cg)
        use_kernel = engine == "frontier_kernel"
        operands = frontier_operands(cg, with_ell=use_kernel)
        sweep_fn = None
        if use_kernel:
            from repro.kernels.frontier_relax.ops import make_frontier_sweep_fn

            sweep_fn = make_frontier_sweep_fn(block_f=block)
        d, p, s, e, c = sssp_frontier(
            operands,
            jnp.int32(source),
            n=cg.n,
            sweep_fn=sweep_fn,
            max_sweeps=max_sweeps,
            delta=delta,
            target=None if target is None else jnp.int32(target),
            target_lb=None if target_lb is None else jnp.float32(target_lb),
        )
        # target= solves return pred=None: the partial row's tree would be
        # part-invalid (see the target docs above), and skipping the O(m)
        # recovery is the point of the early exit.
        return SsspResult(np.asarray(d),
                          None if p is None else np.asarray(p), int(s),
                          engine, edges_relaxed=int(e), converged=bool(c))

    if engine == "multisource_csr":
        if cg is None:
            cg = g.to_csr()
        srcs = jnp.atleast_1d(jnp.asarray(source, jnp.int32))
        D, s, c = sssp_multisource_csr(
            csr_operands(cg), srcs, n=cg.n, max_sweeps=max_sweeps
        )
        return SsspResult(np.asarray(D), None, int(s), engine,
                          edges_relaxed=int(s) * cg.nnz * srcs.shape[0],
                          sources=np.asarray(srcs), converged=bool(c))

    if engine in CSR_ENGINES:
        if cg is None:
            cg = g.to_csr()
        use_kernel = engine == "bellman_csr_kernel"
        operands = csr_operands(cg, with_ell=use_kernel)
        sweep_fn = None
        if use_kernel:
            from repro.kernels.csr_relax.ops import make_csr_sweep_fn

            sweep_fn = make_csr_sweep_fn(block_v=block)
        d, p, s, c = sssp_bellman_csr(
            operands,
            jnp.int32(source),
            n=cg.n,
            sweep_fn=sweep_fn,
            max_sweeps=max_sweeps,
        )
        return SsspResult(np.asarray(d), np.asarray(p), int(s), engine,
                          edges_relaxed=int(s) * cg.nnz, converged=bool(c))

    if engine == "serial":
        d, p = dijkstra_serial(jnp.asarray(g.adj), jnp.int32(source))
        return SsspResult(np.asarray(d), np.asarray(p), None, engine)

    if engine == "bellman":
        d, p, s = sssp_bellman(
            jnp.asarray(g.adj), jnp.int32(source), max_sweeps=max_sweeps
        )
        return SsspResult(np.asarray(d), np.asarray(p), int(s), engine)

    if engine == "bellman_kernel":
        from repro.kernels.sssp_relax.ops import make_sweep_fn

        d, p, s = sssp_bellman(
            jnp.asarray(g.adj),
            jnp.int32(source),
            sweep_fn=make_sweep_fn(block_u=block, block_v=block),
            max_sweeps=max_sweeps,
        )
        return SsspResult(np.asarray(d), np.asarray(p), int(s), engine)

    if engine == "multisource":
        srcs = jnp.atleast_1d(jnp.asarray(source, jnp.int32))
        if mesh is not None:
            gp = g.padded(mesh.shape[axis])
            D, s = sssp_multisource_sharded(
                jnp.asarray(gp.adj), srcs, mesh, axis=axis, max_sweeps=max_sweeps
            )
            return SsspResult(np.asarray(D)[:, :n_true], None, int(s), engine,
                              sources=np.asarray(srcs))
        D, s = sssp_multisource(jnp.asarray(g.adj), srcs, max_sweeps=max_sweeps)
        return SsspResult(np.asarray(D), None, int(s), engine,
                          sources=np.asarray(srcs))

    # --- sharded engines -------------------------------------------------
    if mesh is None:
        raise ValueError(f"engine {engine!r} needs a mesh")
    gp = g.padded(mesh.shape[axis])

    if engine == "dijkstra_sharded":
        d, p = dijkstra_sharded(
            jnp.asarray(gp.adj), source, mesh, axis=axis, n_true=n_true
        )
        return SsspResult(
            np.asarray(d)[:n_true], np.asarray(p)[:n_true], None, engine
        )

    d, p, s = sssp_bellman_sharded(
        jnp.asarray(gp.adj), source, mesh, axis=axis, max_sweeps=max_sweeps
    )
    return SsspResult(
        np.asarray(d)[:n_true], np.asarray(p)[:n_true], int(s), engine
    )


def recover_pred(
    result: SsspResult,
    g: "graph_mod.Graph | csr_mod.CsrGraph | jax.Array | np.ndarray",
) -> np.ndarray:
    """Rebuild predecessor rows for a result that skipped them.

    The multisource engines return ``pred=None`` because at the fixpoint
    the tree is a pure function of (dist, graph) — materializing S rows
    eagerly would waste memory on callers that only need distances.  This
    reuses the same recovery helpers the single-source engines run (so the
    trees match them exactly, tie-breaks included): the O(m) segment-min
    over CSR arcs for a ``CsrGraph``, the O(n²) masked argmin for a dense
    graph.  Results that already carry a pred are returned as-is.

    Output matches ``result.dist``'s shape: (S, n) for batched results,
    (n,) for single-source.  Same validity caveat as the eager recoveries:
    a valid tree whenever edge weights are strictly positive.
    """
    if result.pred is not None:
        return result.pred
    D = jnp.atleast_2d(jnp.asarray(result.dist, jnp.float32))
    if result.sources is not None:
        srcs = jnp.atleast_1d(jnp.asarray(result.sources, jnp.int32))
    else:
        # dist[source] == 0 is each row's minimum under nonnegative weights.
        srcs = jnp.argmin(D, axis=1).astype(jnp.int32)
    if isinstance(g, csr_mod.CsrGraph):
        ops = csr_operands(g)
        P = jax.vmap(lambda d, s: predecessors_from_dist_csr(d, ops, s))(
            D, srcs
        )
    else:
        adj = jnp.asarray(g.adj if isinstance(g, graph_mod.Graph) else g,
                          jnp.float32)
        P = jax.vmap(lambda d, s: predecessors_from_dist(d, adj, s))(D, srcs)
    P = np.asarray(P)
    return P if np.ndim(result.dist) == 2 else P[0]
