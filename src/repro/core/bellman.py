"""Relax-to-fixpoint SSSP — the paper's Algorithm 3/4 (CUDA analogue).

The paper's CUDA kernel assigns one thread per vertex ``tid``; each thread
sweeps tid's outgoing edges doing ``atomicMin(&dist[v], dist[tid]+w)`` and
marks ``updated[v]``; the host loops the kernel until a Thrust
``reduce(logical_or)`` over ``updated`` reports no change.

TPU adaptation (DESIGN.md §2): TPU has no atomics and no free-running scalar
threads. One full kernel launch computes, for every v,

    new_dist[v] = min(dist[v], min_u (dist[u] + A[u, v]))

which is exactly a **min-plus matrix-vector product** — an associative
reduction the TPU executes deterministically, replacing atomicMin.  The
fixpoint (and hence the result) is identical to the CUDA version; iteration
count is bounded by the shortest-path hop diameter, the same bound behind the
paper's ``repeat ... until not anyUpdated``.

Device-side convergence: ``lax.while_loop`` on ``jnp.any(new != old)`` — the
check never leaves the device, which is precisely why the paper reached for
Thrust instead of copying ``updated[]`` back to the host.

Also here (beyond-paper, DESIGN.md §2):
  * ``sssp_bellman_sharded`` — the fixpoint engine distributed over a mesh
    axis: ONE all-gather of the dist vector per sweep instead of the
    Dijkstra engine's one MINLOC allreduce per *vertex*.  This directly
    attacks the paper's own diagnosis of its MPI scaling collapse (§V.2).
  * ``use_frontier`` — rows whose dist did not improve last sweep are masked
    to INF so they contribute nothing; keeps the dense layout (no gathers).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core._axes import axis_size, axis_tuple
from repro.core._compat import pvary, shard_map

INF = jnp.inf


def relax_sweep_ref(dist: jax.Array, adj: jax.Array) -> jax.Array:
    """One full relaxation sweep: min-plus matvec.  (n,),(n,n) -> (n,)."""
    return jnp.minimum(dist, jnp.min(dist[:, None] + adj, axis=0))


def _sweep_blocked(dist, adj, block: int):
    """Sweep with the contraction blocked over u — same math, smaller
    live intermediate ((block, n) instead of (n, n)); XLA fuses the rest."""
    n = adj.shape[0]
    if n % block != 0 or n == block:
        return relax_sweep_ref(dist, adj)

    def body(carry, ub):
        du = lax.dynamic_slice_in_dim(dist, ub * block, block)
        au = lax.dynamic_slice_in_dim(adj, ub * block, block, axis=0)
        cand = jnp.min(du[:, None] + au, axis=0)
        return jnp.minimum(carry, cand), None

    out, _ = lax.scan(body, dist, jnp.arange(n // block))
    return out


@functools.partial(
    jax.jit, static_argnames=("sweep_fn", "max_sweeps", "use_frontier")
)
def sssp_bellman(
    adj: jax.Array,
    source: jax.Array,
    *,
    sweep_fn: Optional[Callable] = None,
    max_sweeps: int | None = None,
    use_frontier: bool = False,
):
    """Fixpoint SSSP (paper Alg. 3).  Returns (dist, pred, num_sweeps).

    sweep_fn(dist, adj) -> new_dist lets callers swap in the Pallas kernel
    (kernels/sssp_relax/ops.py) for the jnp path; both satisfy the same
    oracle (kernels/sssp_relax/ref.py).
    """
    n = adj.shape[0]
    cap = n if max_sweeps is None else max_sweeps
    sweep = sweep_fn or relax_sweep_ref
    dist0 = jnp.full((n,), INF, adj.dtype).at[source].set(0.0)

    def cond(carry):
        dist, prev, it, frontier = carry
        return (it < cap) & jnp.any(dist != prev)

    def body(carry):
        dist, _, it, frontier = carry
        src = jnp.where(frontier, dist, INF) if use_frontier else dist
        new = sweep(src, adj)
        new = jnp.minimum(new, dist)  # monotone even under frontier masking
        return new, dist, it + 1, (new < dist) if use_frontier else frontier
    frontier0 = dist0 < INF
    # prev sentinel differs from dist0 so the loop runs at least once.
    prev0 = jnp.full_like(dist0, -1.0)
    dist, _, sweeps, _ = lax.while_loop(
        cond, body, (dist0, prev0, jnp.int32(0), frontier0)
    )
    pred = predecessors_from_dist(dist, adj, source)
    return dist, pred, sweeps


def predecessors_from_dist(dist, adj, source):
    """Recover pred[] at the fixpoint: pred[v] = argmin_u dist[u] + A[u,v].

    At the fixpoint dist[v] == min_u(dist[u] + A[u,v]) for every reachable
    v != source, so this reproduces a valid shortest-path tree (the paper
    updates pred inside the kernel; doing it once at the end is equivalent
    at the fixpoint and cheaper — recorded in EXPERIMENTS.md §Perf).

    The diagonal (A[v,v] == 0, i.e. via[v,v] == dist[v]) is masked out:
    it always ties the fixpoint minimum, and letting the argmin pick it
    would emit pred[v] == v — a self-loop that breaks path reconstruction.

    The result is a valid tree whenever edge weights are strictly positive
    (then every pred edge strictly decreases dist, so no cycles).  Known
    limitation shared with the CSR recovery: explicit zero-weight edges
    between equal-dist vertices can make two such vertices pick each other
    (a 2-cycle); orienting zero-weight components needs a multi-pass
    recovery no single argmin tie-break can express.
    """
    n = adj.shape[0]
    via = dist[:, None] + adj                     # (u, v)
    diag = jnp.arange(n)
    via = via.at[diag, diag].set(INF)             # no self-predecessors
    u_best = jnp.argmin(via, axis=0).astype(jnp.int32)
    reached = jnp.isfinite(dist)
    pred = jnp.where(reached, u_best, -1)
    return pred.at[source].set(-1)


def sssp_bellman_sharded(
    adj_padded: jax.Array,
    source: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    max_sweeps: int | None = None,
):
    """Distributed fixpoint SSSP: columns sharded, dist replicated.

    Per sweep each device relaxes its own column block (a (n, loc_n)
    min-plus matvec) and the new dist vector is reassembled with ONE
    ``lax.all_gather`` — one collective per sweep (≈ hop diameter sweeps)
    vs. Dijkstra's one MINLOC per vertex (n collectives).  This is the
    "better-granularity synchronization" the paper calls for in §V.2.

    Returns (dist (n_pad,), pred (n_pad,), sweeps).
    """
    nprocs = axis_size(mesh, axis)
    n_pad = adj_padded.shape[0]
    assert n_pad % nprocs == 0
    loc_n = n_pad // nprocs
    cap = int(max_sweeps if max_sweeps is not None else n_pad)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(None, axis), P()),
        out_specs=(P(axis), P(axis), P()),
    )
    def run(adj_loc, src):
        my_p = lax.axis_index(axis)
        v_base = my_p * loc_n
        dist0 = jnp.full((n_pad,), INF, adj_loc.dtype).at[src].set(0.0)
        # initial carries are device-invariant; body outputs are varying.
        dist0 = pvary(dist0, axis_tuple(axis))
        prev0 = pvary(jnp.full((n_pad,), -1.0, adj_loc.dtype), axis_tuple(axis))

        def cond(c):
            dist, prev, it = c
            return (it < cap) & jnp.any(dist != prev)

        def body(c):
            dist, _, it = c
            loc_new = jnp.min(dist[:, None] + adj_loc, axis=0)   # (loc_n,)
            mine = lax.dynamic_slice_in_dim(dist, v_base, loc_n)
            loc_new = jnp.minimum(mine, loc_new)
            new = lax.all_gather(loc_new, axis, tiled=True)      # (n_pad,)
            return new, dist, it + 1

        it0 = pvary(jnp.int32(0), axis_tuple(axis))
        dist, _, sweeps = lax.while_loop(cond, body, (dist0, prev0, it0))
        # local pred for owned vertices, from the fixpoint dist.  Mask the
        # diagonal (global row v for local column v) so the argmin never
        # emits a pred[v] == v self-loop (same as predecessors_from_dist).
        via = dist[:, None] + adj_loc                            # (n, loc_n)
        loc_cols = jnp.arange(loc_n, dtype=jnp.int32)
        via = via.at[v_base + loc_cols, loc_cols].set(INF)
        u_best = jnp.argmin(via, axis=0).astype(jnp.int32)
        mine = lax.dynamic_slice_in_dim(dist, v_base, loc_n)
        owned = v_base + jnp.arange(loc_n, dtype=jnp.int32)
        pred = jnp.where(jnp.isfinite(mine) & (owned != src), u_best, -1)
        # sweeps is identical on every device; psum-and-divide makes it
        # provably axis-invariant so it can leave with out_specs P().
        sweeps_inv = lax.psum(sweeps, axis) // nprocs
        return mine, pred, sweeps_inv

    dist, pred, sweeps = run(adj_padded, jnp.asarray(source, jnp.int32))
    return dist, pred, sweeps
