"""jax-version compatibility shim for the sharding API.

The container pins jax 0.4.37, where ``shard_map`` lives in
``jax.experimental.shard_map`` and the modern mesh helpers
(``jax.set_mesh``, ``jax.sharding.AxisType``, ``lax.pvary``,
``jax.sharding.get_abstract_mesh``) do not exist yet; CI also runs the
latest jax, where the experimental import is gone and the modern names are
canonical.  Every sharded engine, trainer, and test imports the sharding
surface from here instead of from jax directly, so the same source runs on
both — the 13 previously version-gated sharding tests included.

Differences papered over:

* ``shard_map``      — modern ``jax.shard_map`` (keyword mesh, optional —
                       falls back to the ambient ``set_mesh`` mesh) vs the
                       0.4.x functional form.  On 0.4.x we always pass
                       ``check_rep=False``: the old replication checker has
                       no rule for ``while`` (every fixpoint engine here
                       loops) and the modern ``check_vma`` machinery it
                       approximates doesn't exist anyway.
* ``pvary``          — identity on 0.4.x.  The modern varying-manual-axes
                       type system needs device-invariant loop carries
                       marked varying; old jax has no such distinction.
* ``make_mesh``      — drops the ``axis_types`` keyword: ``Auto`` is the
                       modern default and the concept is absent on 0.4.x.
* ``abstract_mesh``  — modern ``AbstractMesh(sizes, names)`` vs the 0.4.x
                       ``AbstractMesh(((name, size), ...))`` tuple form.
                       Both expose ``.axis_names`` and the ``.shape`` dict
                       the sharding rules consume.
* ``set_mesh`` /     — modern jax tracks an ambient abstract mesh; on
  ``get_abstract_mesh``  0.4.x we keep our own stack (entering the concrete
                       ``Mesh`` context manager too, so bare-PartitionSpec
                       ``with_sharding_constraint`` keeps working inside
                       jit).  A concrete Mesh duck-types the abstract one
                       for every consumer here (``axis_names`` + ``shape``).
"""
from __future__ import annotations

import contextlib
import functools
import inspect

import jax

MODERN_SHARDING = hasattr(jax, "shard_map")

if MODERN_SHARDING:
    _check_kw = (
        "check_vma"
        if "check_vma" in inspect.signature(jax.shard_map).parameters
        else "check_rep"
    )
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04x

# ambient mesh stack for 0.4.x set_mesh / get_abstract_mesh
_MESH_STACK: list = []


def shard_map(f=None, *, mesh=None, in_specs, out_specs, check_vma=True):
    """Version-portable ``shard_map``.

    Usable exactly like the modern API, including as a decorator via
    ``functools.partial(shard_map, mesh=..., in_specs=..., out_specs=...)``
    and with ``mesh=None`` meaning "the ambient :func:`set_mesh` mesh".
    ``check_vma`` is honored on modern jax and ignored (forced off) on
    0.4.x — see module docstring.
    """
    if f is None:
        return functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
    if MODERN_SHARDING:
        kw = {_check_kw: check_vma}
        if mesh is not None:
            kw["mesh"] = mesh
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs, **kw)

    @functools.wraps(f)
    def call(*args):
        m = mesh if mesh is not None else get_abstract_mesh()
        if m is None:
            raise ValueError(
                "shard_map without an explicit mesh needs an ambient mesh: "
                "wrap the call in repro.core._compat.set_mesh(mesh)")
        return _shard_map_04x(f, mesh=m, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)(*args)

    return call


def pvary(x, axis_name):
    """Mark ``x`` as varying over ``axis_name`` (identity on 0.4.x)."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axis_name)
    return x


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with the Auto axis types both versions default to."""
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         devices=devices)


def abstract_mesh(axis_shapes, axis_names):
    """Device-less mesh for shape-only sharding decisions (rules tests,
    spec assignment)."""
    AM = jax.sharding.AbstractMesh
    axis_shapes, axis_names = tuple(axis_shapes), tuple(axis_names)
    if MODERN_SHARDING:
        return AM(axis_shapes, axis_names)
    return AM(tuple(zip(axis_names, axis_shapes)))


@contextlib.contextmanager
def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
        return
    _MESH_STACK.append(mesh)
    try:
        with mesh:  # Mesh ctx: bare-spec with_sharding_constraint resolves
            yield mesh
    finally:
        _MESH_STACK.pop()


def get_abstract_mesh():
    """The ambient mesh, or None outside any :func:`set_mesh` context.

    Modern jax returns its tracked abstract mesh; 0.4.x returns the
    concrete mesh from our stack (same ``axis_names`` / ``shape`` surface).
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        try:
            am = jax.sharding.get_abstract_mesh()
        except Exception:
            am = None
        if am is not None and am.axis_names:
            return am
        # fall through: mid-vintage jax has get_abstract_mesh but no
        # set_mesh, so the ambient mesh lives on our stack instead.
    return _MESH_STACK[-1] if _MESH_STACK else None
