"""True Δ-stepping SSSP — device-resident bucketed frontiers over a
light/heavy edge split (Meyer & Sanders' algorithm, the GPU formulation of
Kranjčević et al., arXiv:1604.02113), built ON the frontier engine's
machinery rather than beside it.

The frontier engine's ``delta=`` option only *throttles* its push schedule:
every sweep still walks the full active set's out-windows, light and heavy
arcs alike.  Real Δ-stepping splits the edges once by weight at staging
time and gives each class the schedule it wants:

* **Light arcs** (weight <= Δ) can re-improve labels inside the current
  Δ-bucket, so they are iterated to a *per-bucket fixpoint*.  Here that
  fixpoint is a **pull**: one fused pass computes every vertex's best
  incoming light candidate from the padded light in-ELL
  (``CsrGraph.light_in_ell``) — a dense gather + row-min with no frontier
  compaction, no ``jnp.nonzero``, no scatter.  On long-diameter graphs this
  is the whole win: the frontier engine pays a ~O(n)-sized compaction per
  sweep for hundreds of sweeps, while a pull pass is a few fused
  element-wise ops and improvements propagate graph-wide (vertices outside
  the bucket window ride along for free — harmless, since relaxation is
  monotone and idempotent).

* **Heavy arcs** (weight > Δ) cannot land inside the bucket they leave —
  their weight alone exceeds the bucket width — so each settled bucket's
  heavy out-windows are relaxed exactly ONCE per bucket, as a push through
  the same compaction + chunked scatter path the frontier engine uses
  (:func:`repro.core.frontier.relax_active` with
  :func:`repro.core.frontier.make_flat_sweep_fn`): the heavy set is
  usually tiny, which is exactly when compaction pays.

**Bucket structure.**  Buckets are never materialized: a vertex's bucket is
``floor(dist / Δ)`` recomputed from the live distance vector, and bucket
membership is a mask — the static-shape analogue of the paper's worklists.
The engine's whole state is ``(dist, hpend)`` where ``hpend`` marks finite
vertices whose heavy out-arcs have not yet been relaxed at their final
label.  Each outer phase: find the minimum pending label, window the
current bucket ``[lo, hi)`` around it, run the light pull to a fixpoint
(exit when no improvement lands strictly below ``hi``), then heavy-push the
settled bucket once.  Windows are fp-robust: ``hi`` is forced strictly
above the minimum pending label (``nextafter``) so the phase always makes
progress even when ``floor(dmin/Δ)·Δ + Δ`` rounds to <= ``dmin`` in f32.

**Exactness.**  At exit ``hpend`` is empty: the last pull pass improved
nothing anywhere (global fixpoint over light arcs) and every heavy arc was
relaxed at its source's final label — the full relaxation fixpoint.  Any
relaxation schedule run to fixpoint yields the same labels: each is a min
over the same left-associated f32 path sums, and min is exact in floating
point.  So distances are **bitwise identical** to ``serial`` and every
other engine, for any positive Δ (worst Δ merely wastes phases).

**Auto-Δ** (:func:`auto_delta`): the classic heuristics tie Δ to w_max /
mean degree (1604.02113 uses Δ = c·w_max/d̄); on this engine's pull
formulation the binding constraint is the light in-ELL width K (the pull
touches n·K slots per pass), so the rule picks the LARGEST of a fixed
candidate ladder — weight quantiles p50/p75/p90, w_max, and an all-light
sentinel — whose max light in-degree stays within ``max(8, 4·d̄)``.
Grid-like uniform-weight graphs resolve to all-light (one bucket, pure
pull-Jacobi — Δ-stepping's documented degeneration to Bellman-Ford);
heavy-tailed graphs land between the light and heavy weight ranges.  The
rule is deterministic: same graph, same Δ.

``sweeps`` in this engine's results counts OUTER BUCKET PHASES (each phase
= one light fixpoint + one heavy pass), the unit comparable across runs;
``edges_relaxed`` charges every light pass at the full light arc count
(the pull really does touch all n·K slots — honest accounting, larger
than the frontier engine's counter on all-light graphs) plus the compacted
heavy out-degree per phase.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.bellman_csr import csr_operands, predecessors_from_dist_csr
from repro.core.csr import _masked_row_counts
from repro.core.frontier import (INF, make_flat_sweep_fn, relax_active,
                                 sweep_cap)
from repro.obs.metrics import mark_trace

#: candidate quantiles of the weight distribution tried by auto_delta,
#: below the w_max and all-light rungs.
AUTO_DELTA_QUANTILES = (0.5, 0.75, 0.9)


def delta_profile(cg) -> dict:
    """Deterministic Δ selection profile for a CsrGraph — memoized on the
    graph like its other derived views.

    Returns ``{"delta", "light_max_deg", "k_cap", "routable"}``: the
    chosen Δ, the max light in-degree it induces (the pull ELL's width
    driver), the width cap it was held to, and ``routable`` — whether the
    choice satisfies the cap (False means even the narrowest candidate
    blows the ELL width, e.g. dense diameter-2 graphs, where the serving
    dispatch should keep the frontier engine).
    """
    def build():
        n, w = cg.n, np.asarray(cg.weights)
        if cg.nnz == 0:
            return {"delta": 1.0, "light_max_deg": 0, "k_cap": 8.0,
                    "routable": True}
        mean_deg = cg.nnz / max(n, 1)
        k_cap = max(8.0, 4.0 * mean_deg)
        wmax = float(w.max())
        # all-light sentinel: >= any finite distance, so every arc is light
        # and the schedule degenerates to one bucket of pure pull-Jacobi.
        all_light = float(np.float32(max(n, 2)) * np.float32(max(wmax, 1.0)))
        cands = [float(np.quantile(w, q)) for q in AUTO_DELTA_QUANTILES]
        cands += [wmax, all_light]
        best, best_ldeg, ok = cands[0], None, False
        for c in cands:
            mask = w <= np.float32(c)
            ldeg = int(_masked_row_counts(mask, cg.indptr, n).max())
            if best_ldeg is None:
                best_ldeg = ldeg               # narrowest rung = fallback
            if ldeg <= k_cap and c >= best:
                best, best_ldeg, ok = c, ldeg, True
        return {"delta": float(best), "light_max_deg": int(best_ldeg),
                "k_cap": float(k_cap), "routable": bool(ok)}
    return cg._memo("_delta_profile", build)


def auto_delta(cg) -> float:
    """The Δ ``delta="auto"`` resolves to for this graph (see module
    docstring for the rule).  Deterministic and memoized per graph."""
    return delta_profile(cg)["delta"]


def delta_operands(cg, delta: float, *, base_ops: Optional[dict] = None,
                   width_multiple: int = 8) -> dict:
    """Stage a CsrGraph for the Δ-stepping engine.

    Extends :func:`csr_operands` (incoming src/dst/w, kept for the O(m)
    pred recovery — ``base_ops`` reuses an already-staged copy, the same
    no-double-staging contract as ``frontier_operands``) with the Δ-split
    views:

    * ``light_ell_idx`` / ``light_ell_w``: (n, K_light) padded light
      in-ELL, the pull operand (``CsrGraph.light_in_ell``);
    * ``out_indptr`` / ``out_dst`` / ``out_w``: heavy outgoing CSR
      (``CsrGraph.heavy_out_csr``), indptr staged with the trailing
      sentinel row — deliberately under the SAME keys as
      ``frontier_operands`` so ``relax_active`` + the flat sweep consume
      it unchanged;
    * ``m_light``: light arc count as a traced int32 scalar (the
      edges-relaxed charge per pull pass).

    The split is memoized on the graph per Δ, so repeat solves (and the
    serving registry) pay the O(m) partition once.
    """
    ops = dict(base_ops) if base_ops is not None else csr_operands(cg)
    l_idx, l_w = cg.light_in_ell(delta, width_multiple)
    ops["light_ell_idx"] = jnp.asarray(l_idx)
    ops["light_ell_w"] = jnp.asarray(l_w)
    hip, h_dst, h_w = cg.heavy_out_csr(delta)
    hip_s = np.concatenate([hip, hip[-1:]])              # (n + 2,)
    ops["out_indptr"] = jnp.asarray(hip_s, jnp.int32)
    ops["out_dst"] = jnp.asarray(h_dst)
    ops["out_w"] = jnp.asarray(h_w)
    ops["m_light"] = jnp.int32(cg.nnz - h_dst.shape[0])
    return ops


@functools.lru_cache(maxsize=None)
def make_light_pull_fn() -> Callable:
    """Default light-phase pull: one fused XLA pass.  Memoized so the
    closure identity is stable (static jit argument of the engine, same
    contract as make_flat_sweep_fn).

    The pull contract (shared with kernels/bucket_relax/ops.py):
    ``pull(dist, ops, hi) -> (new_dist, go)`` computing, for every vertex
    at once, ``new = min(dist, min_k(dist[light_ell_idx[:, k]] +
    light_ell_w[:, k]))`` plus the inner-loop control bit ``go =
    any((new < dist) & (new < hi))`` — the fused kernel produces both in
    one pass.  Padding slots are (0, INF) so they never win; min and the
    comparisons are exact in f32, so any pull implementation with this
    contract is bitwise-interchangeable.
    """
    def pull(dist, ops, hi):
        cand = jnp.min(dist[ops["light_ell_idx"]] + ops["light_ell_w"],
                       axis=1)
        new = jnp.minimum(dist, cand)
        return new, jnp.any((new < dist) & (new < hi))
    return pull


def delta_fixpoint(ops: dict, dist0, hpend0, delta, *, n: int,
                   pull: Callable, sweep: Callable, cap_outer, edges0=0):
    """The Δ-stepping phase loop on an arbitrary initial state — the
    bucketed twin of ``frontier_fixpoint``, same factoring contract (must
    be called inside jit; warm starts need ``dist0`` pointwise >= the
    fixpoint with real path labels, ``hpend0`` covering every vertex whose
    heavy out-arcs haven't seen its final label).

    Returns ``(dist, phases, edges_relaxed, inner_passes, converged)``.
    """
    m_light = ops["m_light"]

    def outer_cond(c):
        _, hpend, it, _, _ = c
        return (it < cap_outer) & jnp.any(hpend)

    def outer_body(c):
        dist, hpend, it, edges, itot = c
        dmin = jnp.min(jnp.where(hpend, dist, INF))
        # fp-robust bucket window around the minimum pending label: lo is
        # its bucket's floor but never above dmin, hi is one Δ up but
        # always strictly above dmin — guarantees the min pending vertex
        # is in-window, so the phase settles at least one vertex and the
        # outer loop cannot stall on f32 rounding.
        lo = jnp.minimum(jnp.floor(dmin / delta) * delta, dmin)
        hi = jnp.maximum(lo + delta, jnp.nextafter(dmin, jnp.float32(np.inf)))

        def inner_cond(ci):
            _, _, go, j = ci
            return go & (j <= n)

        def inner_body(ci):
            d, hp, go, j = ci
            # keep pulling only while improvements land inside the bucket
            # (the pull's fused go bit); global improvements above hi
            # belong to later phases and are kept — relaxation is monotone
            # and idempotent — without extending this fixpoint.
            new, go = pull(d, ops, hi)
            hp = hp | (new < d)               # improved labels owe a push
            return new, hp, go, j + 1

        # each improving pass strictly lowers some label along a shortest
        # path (<= n-1 hops), plus one closing non-improving pass: j <= n.
        dist, hpend, _, jin = lax.while_loop(
            inner_cond, inner_body,
            (dist, hpend, jnp.bool_(True), jnp.int32(0)))
        # the bucket below hi is now settled (its light fixpoint reached,
        # and no lighter pending label exists): push its heavy out-arcs
        # once through the shared frontier compaction machinery.
        settled = hpend & (dist < hi)
        new, E = relax_active(ops, dist, settled, n=n, sweep=sweep)
        hpend = (hpend & ~settled) | (new < dist)
        return new, hpend, it + 1, edges + E + jin * m_light, itot + jin

    dist, hpend, phases, edges, itot = lax.while_loop(
        outer_cond, outer_body,
        (dist0, hpend0, jnp.int32(0), jnp.int32(edges0), jnp.int32(0)))
    return dist, phases, edges, itot, ~jnp.any(hpend)


@functools.partial(
    jax.jit, static_argnames=("n", "pull_fn", "sweep_fn", "max_sweeps",
                              "chunk")
)
def sssp_delta_stepping(
    ops: dict,
    source: jax.Array,
    delta: jax.Array,
    *,
    n: int,
    pull_fn: Optional[Callable] = None,
    sweep_fn: Optional[Callable] = None,
    max_sweeps: int | None = None,
    chunk: int = 1024,
):
    """Δ-stepping fixpoint SSSP on :func:`delta_operands`.

    ``delta`` is a TRACED f32 scalar (one compile covers every Δ for a
    given graph size — the light/heavy split baked into ``ops`` is what
    actually depends on Δ; callers must pass the same Δ to both, which
    the api facade enforces).  Returns ``(dist, pred, phases,
    edges_relaxed, converged)`` — ``phases`` counts outer bucket phases
    (the engine's ``sweeps`` unit), ``converged`` False iff the phase cap
    stopped the loop early (serve/errors.NotConverged guardrail, as for
    every other fixpoint engine).

    The phase cap comes from :func:`repro.core.frontier.sweep_cap` fed
    with the in-graph distance bound (n-1)·w_max — the derived form, not
    the legacy 4·n guess (that constant survives as the floor).
    """
    mark_trace("delta_stepping")
    pull = pull_fn or make_light_pull_fn()
    sweep = sweep_fn or make_flat_sweep_fn(chunk)
    delta = jnp.asarray(delta, jnp.float32)
    # upper bound on any finite label, from the staged weights: a shortest
    # path has <= n-1 arcs of weight <= w_max each (empty graphs: 0).
    wmax = jnp.max(ops["w"], initial=jnp.float32(0.0))
    max_dist_ub = jnp.float32(max(n - 1, 1)) * wmax
    cap = sweep_cap(n, delta, max_sweeps, max_dist=max_dist_ub)
    dist0 = jnp.full((n,), INF, jnp.float32).at[source].set(0.0)
    hpend0 = dist0 < INF
    dist, phases, edges, _, converged = delta_fixpoint(
        ops, dist0, hpend0, delta, n=n, pull=pull, sweep=sweep,
        cap_outer=cap,
    )
    pred = predecessors_from_dist_csr(dist, ops, source)
    return dist, pred, phases, edges, converged
