"""Relax-to-fixpoint SSSP over sparse CSR edges — O(m) per sweep.

Same fixpoint iteration as core/bellman.py (the paper's Algorithm 3/4), but
the relax sweep is a **segment-min over the edge list** instead of a dense
min-plus matvec:

    via[e]  = dist[src[e]] + w[e]                 (one add per edge)
    cand[v] = segment_min(via, dst)               (associative min per vertex)
    new[v]  = min(dist[v], cand[v])

This touches each of the m stored arcs exactly once per sweep — O(m) work —
where the dense sweep reads the full n² matrix however sparse the graph is.
That is precisely the paper's §V complaint about its adjacency-matrix data
structure, and the reason Table II's 40k-vertex/120k-edge graph is the dense
formulation's ceiling.  The segment-min is the TPU-legal stand-in for the
CUDA kernel's ``atomicMin`` over incoming edges: an associative reduction
with deterministic result, the same argument as bellman.py's matvec.

The kernel path (api engine ``bellman_csr_kernel``) swaps ``sweep_fn`` for
the Pallas padded-ELL kernel in kernels/csr_relax — fixed-width rows so the
block shapes are static, mirroring the paper's padding trick.

Frontier-restricted relaxation lives in core/frontier.py (api engines
``frontier`` / ``frontier_kernel``): it compacts the improved vertices and
touches only their out-edges, O(frontier out-degree) per sweep instead of
this engine's O(m).  ``sssp_multisource_csr`` below is the batched twin:
S sources share one (S, m) gather of the edge arrays per sweep — the
sparse analogue of core/multisource.py's min-plus matmul.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.multisource import init_dist
from repro.obs.metrics import mark_trace

INF = jnp.inf


def csr_operands(cg, *, with_ell: bool = False) -> dict:
    """Stage a core.csr.CsrGraph's arrays onto the device as the pytree the
    engine threads through jit.  ``with_ell`` adds the padded-ELL view the
    Pallas kernel consumes (skipped for the pure segment-min path).

    Deliberately NOT memoized on the CsrGraph (unlike its host-side
    views): caching jax buffers on a long-lived host container would pin
    device memory for the graph's lifetime, and the host numpy views are
    already cached so repeat staging is a plain O(n + m) copy.
    """
    ops = {
        "src": jnp.asarray(cg.indices),
        "dst": jnp.asarray(cg.dst_ids()),
        "w": jnp.asarray(cg.weights),
    }
    if with_ell:
        ell_idx, ell_w = cg.ell()
        ops["ell_idx"] = jnp.asarray(ell_idx)
        ops["ell_w"] = jnp.asarray(ell_w)
    return ops


def segment_relax_sweep(dist: jax.Array, csr: dict) -> jax.Array:
    """One O(m) relax sweep: per-vertex min over incoming-edge candidates,
    folded with the self-distance — matches kernels/csr_relax/ref.py's
    ``segment_relax_ref`` and the sweep-fn contract of every other engine
    sweep (the fold also erases the segment identity on vertices with no
    incoming arcs)."""
    via = dist[csr["src"]] + csr["w"]
    cand = jax.ops.segment_min(
        via, csr["dst"], num_segments=dist.shape[0], indices_are_sorted=True
    )
    return jnp.minimum(dist, cand)


@functools.partial(
    jax.jit, static_argnames=("n", "sweep_fn", "max_sweeps")
)
def sssp_bellman_csr(
    csr: dict,
    source: jax.Array,
    *,
    n: int,
    sweep_fn: Optional[Callable] = None,
    max_sweeps: int | None = None,
):
    """Fixpoint SSSP on CSR operands.  Returns
    ``(dist, pred, num_sweeps, converged)``.

    csr: the pytree from :func:`csr_operands`.  ``sweep_fn(dist, csr) ->
    new_dist`` (self-distance folded in, like bellman.py's sweep_fn) lets
    callers swap in the Pallas ELL kernel
    (kernels/csr_relax/ops.make_csr_sweep_fn) for the segment-min path;
    both satisfy the same oracle (kernels/csr_relax/ref.py).

    ``converged`` is the solver guardrail (serve/errors.py's
    ``NotConverged`` consumes it): True iff the loop exited because the
    last sweep changed nothing — under a tight ``max_sweeps=`` cap the
    flag goes False instead of silently returning labels above their
    fixpoint.  The hop-diameter default cap (n) always converges on
    nonnegative weights, so the flag is only ever False when a caller
    caps the loop (or, later, when Johnson's reweighting meets a
    negative cycle).

    Every sweep relaxes all m stored arcs; for frontier-restricted O(active
    out-degree) sweeps use core.frontier.sssp_frontier instead (the old
    dead-defaulted ``use_frontier`` flag here was removed in its favor).
    """
    # Python body => trace time only; counts (re)traces, free when cached
    mark_trace("bellman_csr")
    cap = n if max_sweeps is None else max_sweeps
    sweep = sweep_fn or segment_relax_sweep
    dist0 = jnp.full((n,), INF, csr["w"].dtype).at[source].set(0.0)

    def cond(carry):
        dist, prev, it = carry
        return (it < cap) & jnp.any(dist != prev)

    def body(carry):
        dist, _, it = carry
        new = jnp.minimum(sweep(dist, csr), dist)
        return new, dist, it + 1

    # prev sentinel differs from dist0 so the loop runs at least once.
    prev0 = jnp.full_like(dist0, -1.0)
    dist, prev, sweeps = lax.while_loop(
        cond, body, (dist0, prev0, jnp.int32(0))
    )
    converged = ~jnp.any(dist != prev)
    pred = predecessors_from_dist_csr(dist, csr, source)
    return dist, pred, sweeps, converged


def segment_relax_sweep_multi(D: jax.Array, csr: dict) -> jax.Array:
    """Batched O(S·m) relax sweep over a (S, n) distance matrix: the sparse
    twin of multisource.relax_sweep_multi_ref.  One gather of the edge
    index arrays serves all S sources (vmap hoists the shared ``src``/
    ``dst`` loads), so arithmetic intensity rises S× exactly as in the
    dense batched engine — per-row results are bitwise identical to S
    independent ``segment_relax_sweep`` calls by construction."""
    return jax.vmap(lambda d: segment_relax_sweep(d, csr))(D)


@functools.partial(jax.jit, static_argnames=("n", "sweep_fn", "max_sweeps"))
def sssp_multisource_csr(
    csr: dict,
    sources: jax.Array,
    *,
    n: int,
    sweep_fn: Optional[Callable] = None,
    max_sweeps: int | None = None,
):
    """Batched fixpoint SSSP from S sources on CSR operands.  Returns
    ``(D (S, n), sweeps, converged)``; per-source rows equal S
    single-source solves run to their joint fixpoint (the sweep count is
    the max over sources).  ``converged`` is the joint flag — False means
    at least one row may sit above its fixpoint (same guardrail contract
    as :func:`sssp_bellman_csr`).  pred is recovered on demand —
    api.recover_pred reuses the O(m) recovery per row."""
    mark_trace("multisource_csr")
    cap = n if max_sweeps is None else max_sweeps
    sweep = sweep_fn or segment_relax_sweep_multi
    D0 = init_dist(n, sources, csr["w"].dtype)

    def cond(carry):
        D, prev, it = carry
        return (it < cap) & jnp.any(D != prev)

    def body(carry):
        D, _, it = carry
        new = jnp.minimum(sweep(D, csr), D)
        return new, D, it + 1

    prev0 = jnp.full_like(D0, -1.0)
    D, prev, sweeps = lax.while_loop(cond, body, (D0, prev0, jnp.int32(0)))
    return D, sweeps, ~jnp.any(D != prev)


def predecessors_from_dist_csr(dist: jax.Array, csr: dict, source) -> jax.Array:
    """Recover pred[] at the fixpoint from the edge list.

    At the fixpoint every reachable v != source has an incoming arc (u, w)
    with dist[v] == dist[u] + w; among those we take the lowest u — the same
    deterministic tie-break as the dense argmin (bellman.py), at O(m) cost
    instead of materializing the (n, n) ``via`` matrix.

    Valid tree whenever weights are strictly positive (pred edges strictly
    decrease dist).  Same known limitation as the dense recovery: explicit
    zero-weight edges between equal-dist vertices can form pred 2-cycles.
    """
    n = dist.shape[0]
    via = dist[csr["src"]] + csr["w"]
    best = jax.ops.segment_min(
        via, csr["dst"], num_segments=n, indices_are_sorted=True
    )
    attains = via <= best[csr["dst"]]
    u_cand = jnp.where(attains, csr["src"].astype(jnp.int32), jnp.int32(n))
    u_best = jax.ops.segment_min(
        u_cand, csr["dst"], num_segments=n, indices_are_sorted=True
    )
    reached = jnp.isfinite(dist) & (u_best < n)
    pred = jnp.where(reached, u_best, -1)
    return pred.at[source].set(-1)
