"""Graph containers and generators for the SSSP engine.

The paper (§III) takes edge lists as input, materializes them into an
adjacency matrix (undirected by default, directed with ``-w``), and pads the
matrix so the vertex count is a multiple of the number of processes
(§III-B.2, "Calculate Padded Vertices Number").  This module reproduces all
of that, plus the dense/sparse generators behind the paper's Tables I/II.

Unreachable entries are ``INF`` (the paper's ∞); diagonal is 0.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Adjacency-matrix graph, the paper's data structure of record.

    adj:      (n, n) float32, INF where no edge, 0 diagonal.
    n:        true vertex count (before any padding).
    directed: the paper's ``-w`` flag.
    """

    adj: np.ndarray
    n: int
    directed: bool = False

    @property
    def num_edges(self) -> int:
        finite = np.isfinite(self.adj) & (self.adj > 0)
        cnt = int(finite.sum())
        return cnt if self.directed else cnt // 2

    def padded(self, multiple: int) -> "Graph":
        """Pad to the next multiple of ``multiple`` with INF rows/cols.

        Mirrors the paper's padding algorithm: if ``multiple > n`` the padded
        size is ``multiple``; otherwise round n up to a multiple.  Padding
        vertices are unreachable (INF everywhere incl. their diagonal-offs),
        so they never win the argmin and never relax anything.
        """
        pn = padded_size(self.n, multiple)
        if pn == self.n:
            return self
        out = np.full((pn, pn), INF, dtype=np.float32)
        out[: self.n, : self.n] = self.adj
        # keep a 0 diagonal for padding vertices: harmless (self-distance),
        # and keeps the matrix a valid min-plus identity-compatible operand.
        for i in range(self.n, pn):
            out[i, i] = 0.0
        return Graph(adj=out, n=self.n, directed=self.directed)


def padded_size(n: int, multiple: int) -> int:
    """The paper's "Calculate Padded Vertices Number" (verbatim logic)."""
    if multiple > n:
        return multiple
    rem = n % multiple
    return n if rem == 0 else n + (multiple - rem)


def from_edge_list(
    n: int,
    edges: np.ndarray,
    weights: np.ndarray,
    directed: bool = False,
) -> Graph:
    """Build the adjacency matrix from an edge list (paper §III).

    edges: (m, 2) int array of (u, v); weights: (m,) float array.
    Duplicate edges keep the minimum weight (a well-defined choice; the
    paper does not specify).
    """
    adj = np.full((n, n), INF, dtype=np.float32)
    np.fill_diagonal(adj, 0.0)
    u, v = edges[:, 0], edges[:, 1]
    w = weights.astype(np.float32)
    # np.minimum.at handles duplicates deterministically.
    np.minimum.at(adj, (u, v), w)
    if not directed:
        np.minimum.at(adj, (v, u), w)
    return Graph(adj=adj, n=n, directed=directed)


def random_graph(
    n: int,
    m: int,
    *,
    seed: int = 0,
    directed: bool = False,
    max_weight: float = 100.0,
    connected: bool = True,
) -> Graph:
    """Random weighted graph with ~m edges (paper's test corpus shape).

    ``connected=True`` first threads a random spanning path so every vertex
    is reachable (the paper's graphs are connected; a disconnected graph
    would make the Table III timings incomparable).
    """
    rng = np.random.default_rng(seed)
    edges = []
    if connected and n > 1:
        perm = rng.permutation(n)
        path = np.stack([perm[:-1], perm[1:]], axis=1)
        edges.append(path)
        m = max(m - (n - 1), 0)
    if m > 0:
        u = rng.integers(0, n, size=2 * m + 16)
        v = rng.integers(0, n, size=2 * m + 16)
        keep = u != v
        extra = np.stack([u[keep], v[keep]], axis=1)[:m]
        edges.append(extra)
    e = np.concatenate(edges, axis=0) if edges else np.zeros((0, 2), np.int64)
    w = rng.uniform(1.0, max_weight, size=len(e))
    return from_edge_list(n, e, w, directed=directed)


def dense_graph(n: int, *, seed: int = 0) -> Graph:
    """Paper Table I: complete-ish graph, m = n(n-1)/2."""
    return random_graph(n, n * (n - 1) // 2, seed=seed)


def sparse_graph(n: int, *, seed: int = 0) -> Graph:
    """Paper Table II: m = 3n (paper's 1:3 node:edge ratio)."""
    return random_graph(n, 3 * n, seed=seed)


# The paper's exact evaluation corpus (Tables I and II).
PAPER_DENSE = [(10, 45), (100, 4950), (1000, 499500), (2000, 1899500)]
PAPER_SPARSE = [
    (10, 30), (100, 300), (1000, 3000), (2000, 6000),
    (10000, 30000), (20000, 60000), (40000, 120000),
]


def paper_graph(n: int, m: int, *, seed: int = 0) -> Graph:
    return random_graph(n, m, seed=seed)
