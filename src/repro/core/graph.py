"""Graph containers and generators for the SSSP engine.

The paper (§III) takes edge lists as input, materializes them into an
adjacency matrix (undirected by default, directed with ``-w``), and pads the
matrix so the vertex count is a multiple of the number of processes
(§III-B.2, "Calculate Padded Vertices Number").  This module reproduces all
of that, plus the dense/sparse generators behind the paper's Tables I/II.

Unreachable entries are ``INF`` (the paper's ∞); diagonal is 0.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class Graph:
    """Adjacency-matrix graph, the paper's data structure of record.

    adj:      (n, n) float32, INF where no edge, 0 diagonal.
    n:        true vertex count (before any padding).
    directed: the paper's ``-w`` flag.

    Treat instances as immutable: the dataclass is frozen and derived
    views (``to_csr()`` here, ``ell()``/``to_dense()`` on CsrGraph) are
    memoized per instance, so mutating ``adj`` in place after use would
    leave engines reading stale caches.  Build a new Graph instead.
    """

    adj: np.ndarray
    n: int
    directed: bool = False

    @property
    def num_edges(self) -> int:
        finite = np.isfinite(self.adj) & (self.adj > 0)
        cnt = int(finite.sum())
        return cnt if self.directed else cnt // 2

    def to_csr(self) -> "CsrGraph":
        """Convert to the sparse CSR container (core/csr.py).

        Captures every finite off-diagonal entry of ``adj`` as an arc; the
        0 diagonal is implicit in CSR relaxation (``min(dist[v], ·)``), so
        round-tripping through ``CsrGraph.to_dense()`` reproduces ``adj``
        exactly for any matrix built by ``from_edge_list``.

        Memoized per instance (the O(n²) scan would otherwise repeat on
        every CSR-engine solve of a dense Graph); writes through __dict__
        to sidestep the frozen-dataclass __setattr__, like CsrGraph's
        derived-view caches.
        """
        if "_csr" not in self.__dict__:
            from repro.core import csr as _csr

            self.__dict__["_csr"] = _csr.CsrGraph.from_dense(self)
        return self.__dict__["_csr"]

    def padded(self, multiple: int) -> "Graph":
        """Pad to the next multiple of ``multiple`` with INF rows/cols.

        Mirrors the paper's padding algorithm: if ``multiple > n`` the padded
        size is ``multiple``; otherwise round n up to a multiple.  Padding
        vertices are unreachable (INF everywhere incl. their diagonal-offs),
        so they never win the argmin and never relax anything.
        """
        pn = padded_size(self.n, multiple)
        if pn == self.n:
            return self
        out = np.full((pn, pn), INF, dtype=np.float32)
        out[: self.n, : self.n] = self.adj
        # keep a 0 diagonal for padding vertices: harmless (self-distance),
        # and keeps the matrix a valid min-plus identity-compatible operand.
        for i in range(self.n, pn):
            out[i, i] = 0.0
        return Graph(adj=out, n=self.n, directed=self.directed)


def padded_size(n: int, multiple: int) -> int:
    """The paper's "Calculate Padded Vertices Number" (verbatim logic)."""
    if multiple > n:
        return multiple
    rem = n % multiple
    return n if rem == 0 else n + (multiple - rem)


def from_edge_list(
    n: int,
    edges: np.ndarray,
    weights: np.ndarray,
    directed: bool = False,
) -> Graph:
    """Build the adjacency matrix from an edge list (paper §III).

    edges: (m, 2) int array of (u, v); weights: (m,) float array.
    Duplicate edges keep the minimum weight (a well-defined choice; the
    paper does not specify).  Out-of-range vertex ids (including negative
    ones, which numpy indexing would silently wrap) fail fast.
    """
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    if edges.size and (edges.min() < 0 or edges.max() >= n):
        raise IndexError(
            f"edge endpoints must be in [0, {n}); got "
            f"[{edges.min()}, {edges.max()}]"
        )
    adj = np.full((n, n), INF, dtype=np.float32)
    np.fill_diagonal(adj, 0.0)
    u, v = edges[:, 0], edges[:, 1]
    w = weights.astype(np.float32)
    # np.minimum.at handles duplicates deterministically.
    np.minimum.at(adj, (u, v), w)
    if not directed:
        np.minimum.at(adj, (v, u), w)
    return Graph(adj=adj, n=n, directed=directed)


def random_edge_list(
    n: int,
    m: int,
    *,
    seed: int = 0,
    max_weight: float = 100.0,
    connected: bool = True,
) -> tuple[np.ndarray, np.ndarray]:
    """Random edge list with ~m edges (paper's test corpus shape).

    ``connected=True`` first threads a random spanning path so every vertex
    is reachable (the paper's graphs are connected; a disconnected graph
    would make the Table III timings incomparable).  Shared by the dense
    (``random_graph``) and sparse (``csr.random_csr_graph``) generators so
    the same seed yields the same graph in either representation.
    """
    rng = np.random.default_rng(seed)
    edges = []
    if connected and n > 1:
        perm = rng.permutation(n)
        path = np.stack([perm[:-1], perm[1:]], axis=1)
        edges.append(path)
        m = max(m - (n - 1), 0)
    if m > 0:
        u = rng.integers(0, n, size=2 * m + 16)
        v = rng.integers(0, n, size=2 * m + 16)
        keep = u != v
        extra = np.stack([u[keep], v[keep]], axis=1)[:m]
        edges.append(extra)
    e = np.concatenate(edges, axis=0) if edges else np.zeros((0, 2), np.int64)
    w = rng.uniform(1.0, max_weight, size=len(e))
    return e, w


def road_like_edge_list(
    n: int,
    *,
    seed: int = 0,
    max_weight: float = 100.0,
) -> tuple[int, np.ndarray, np.ndarray]:
    """Road-network-like corpus: a ``side × side`` 4-neighbour grid
    (side = isqrt(n)) with uniform(1, max_weight) weights.  Returns
    ``(n_actual, edges, weights)`` — n is rounded DOWN to side² so the
    grid is exact.

    This is the long-diameter shape the frontier engine's docstring
    promises it wins on, and the Δ-stepping gate corpus
    (benchmarks/run_bench.py ``gate_delta``): shortest paths are
    O(side) hops deep, so the per-sweep frontier compaction overhead is
    paid O(side) times while the Δ engine's dense pull touches the whole
    light ELL in a handful of fused passes.
    """
    side = math.isqrt(n)
    rng = np.random.default_rng(seed)
    idx = np.arange(side * side).reshape(side, side)
    u = np.concatenate([idx[:, :-1].ravel(), idx[:-1, :].ravel()])
    v = np.concatenate([idx[:, 1:].ravel(), idx[1:, :].ravel()])
    e = np.stack([u, v], axis=1)
    w = rng.uniform(1.0, max_weight, size=len(e))
    return side * side, e, w


def skewed_hub_edge_list(
    n: int,
    *,
    seed: int = 0,
    hubs: int = 16,
    spokes: int = 512,
    max_weight: float = 100.0,
    heavy_lo: float = 150.0,
    heavy_hi: float = 1500.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Skewed-hub corpus: a connected light base (spanning path + 2n
    random edges, weights uniform(1, max_weight)) plus ``hubs`` vertices
    that each fan out ``spokes`` HEAVY edges (weights uniform(heavy_lo,
    heavy_hi)).  The heavy-tailed weight mix is the Δ-stepping showcase:
    with Δ between the light and heavy ranges the hub fan-outs are
    relaxed once per bucket instead of rippling through every sweep,
    while the plain frontier engine re-touches the hub windows every
    time any spoke endpoint improves.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    pe = np.stack([perm[:-1], perm[1:]], axis=1)
    m_base = 2 * n
    bu = rng.integers(0, n, size=m_base + 32)
    bv = rng.integers(0, n, size=m_base + 32)
    keep = bu != bv
    be = np.stack([bu[keep], bv[keep]], axis=1)[:m_base]
    e = np.concatenate([pe, be])
    w = rng.uniform(1.0, max_weight, size=len(e))
    hub_ids = rng.choice(n, size=min(hubs, n), replace=False)
    hu = np.repeat(hub_ids, spokes)
    hv = rng.integers(0, n, size=len(hub_ids) * spokes)
    keep = hu != hv
    he = np.stack([hu[keep], hv[keep]], axis=1)
    hw = rng.uniform(heavy_lo, heavy_hi, size=len(he))
    return np.concatenate([e, he]), np.concatenate([w, hw])


def random_graph(
    n: int,
    m: int,
    *,
    seed: int = 0,
    directed: bool = False,
    max_weight: float = 100.0,
    connected: bool = True,
) -> Graph:
    """Random weighted dense-adjacency graph with ~m edges."""
    e, w = random_edge_list(
        n, m, seed=seed, max_weight=max_weight, connected=connected
    )
    return from_edge_list(n, e, w, directed=directed)


def csr_from_edge_list(
    n: int,
    edges: np.ndarray,
    weights: np.ndarray,
    directed: bool = False,
) -> "CsrGraph":
    """Sparse sibling of :func:`from_edge_list` — same edge semantics
    (undirected mirroring, duplicate edges keep the minimum weight) into a
    ``CsrGraph`` without ever materializing the O(n²) matrix."""
    from repro.core import csr as _csr

    return _csr.csr_from_edge_list(n, edges, weights, directed=directed)


def dense_graph(n: int, *, seed: int = 0) -> Graph:
    """Paper Table I: complete-ish graph, m = n(n-1)/2."""
    return random_graph(n, n * (n - 1) // 2, seed=seed)


def sparse_graph(n: int, *, seed: int = 0) -> Graph:
    """Paper Table II: m = 3n (paper's 1:3 node:edge ratio)."""
    return random_graph(n, 3 * n, seed=seed)


# The paper's exact evaluation corpus (Tables I and II).
PAPER_DENSE = [(10, 45), (100, 4950), (1000, 499500), (2000, 1899500)]
PAPER_SPARSE = [
    (10, 30), (100, 300), (1000, 3000), (2000, 6000),
    (10000, 30000), (20000, 60000), (40000, 120000),
]


def paper_graph(n: int, m: int, *, seed: int = 0) -> Graph:
    return random_graph(n, m, seed=seed)
