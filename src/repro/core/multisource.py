"""Batched multi-source SSSP — beyond-paper extension (DESIGN.md §2).

The paper runs one source at a time.  The min-plus sweep generalizes to a
min-plus *matmul* over a (S, n) distance matrix: S sources amortize every
adjacency-tile load, raising arithmetic intensity S× — the adjacency matrix
is the memory traffic (see EXPERIMENTS.md §Roofline for the term-by-term
account).  Fixpoint and per-source results are identical to running the
paper's Alg. 3 S times.

``sssp_multisource_sharded`` distributes the sweep over a mesh axis with one
all-gather of the (S, loc_n) block per sweep — the batched version of the
one-collective-per-sweep fix for the paper's §V.2 synchronization diagnosis.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core._axes import axis_size, axis_tuple
from repro.core._compat import pvary, shard_map

INF = jnp.inf


def relax_sweep_multi_ref(D: jax.Array, adj: jax.Array) -> jax.Array:
    return jnp.minimum(D, jnp.min(D[:, :, None] + adj[None, :, :], axis=1))


def init_dist(n: int, sources: jax.Array, dtype=jnp.float32) -> jax.Array:
    """(S, n) initial distance matrix: 0 at (s, sources[s]), INF elsewhere."""
    s = sources.shape[0]
    cols = jnp.arange(n, dtype=sources.dtype)[None, :]
    return jnp.where(cols == sources[:, None], 0.0, INF).astype(dtype)


@functools.partial(jax.jit, static_argnames=("sweep_fn", "max_sweeps"))
def sssp_multisource(
    adj: jax.Array,
    sources: jax.Array,
    *,
    sweep_fn: Optional[Callable] = None,
    max_sweeps: int | None = None,
):
    """Fixpoint SSSP from S sources at once.  Returns (D (S, n), sweeps)."""
    n = adj.shape[0]
    cap = n if max_sweeps is None else max_sweeps
    sweep = sweep_fn or relax_sweep_multi_ref
    D0 = init_dist(n, sources, adj.dtype)

    def cond(c):
        D, prev, it = c
        return (it < cap) & jnp.any(D != prev)

    def body(c):
        D, _, it = c
        new = jnp.minimum(sweep(D, adj), D)
        return new, D, it + 1

    prev0 = jnp.full_like(D0, -1.0)
    D, _, sweeps = lax.while_loop(cond, body, (D0, prev0, jnp.int32(0)))
    return D, sweeps


def sssp_multisource_sharded(
    adj_padded: jax.Array,
    sources: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    axis: str = "data",
    max_sweeps: int | None = None,
):
    """Distributed batched fixpoint: columns sharded, D replicated.

    One ``all_gather`` of (S, loc_n) per sweep.  Returns (D (S, n_pad), sweeps).
    """
    nprocs = axis_size(mesh, axis)
    n_pad = adj_padded.shape[0]
    assert n_pad % nprocs == 0
    loc_n = n_pad // nprocs
    s = sources.shape[0]
    cap = int(max_sweeps if max_sweeps is not None else n_pad)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(None, axis), P()),
        out_specs=(P(None, axis), P()),
    )
    def run(adj_loc, srcs):
        my_p = lax.axis_index(axis)
        v_base = my_p * loc_n
        D0 = pvary(init_dist(n_pad, srcs, adj_loc.dtype), axis_tuple(axis))
        prev0 = pvary(jnp.full((s, n_pad), -1.0, adj_loc.dtype), axis_tuple(axis))

        def cond(c):
            D, prev, it = c
            return (it < cap) & jnp.any(D != prev)

        def body(c):
            D, _, it = c
            # (s, n_pad) x (n_pad, loc_n) min-plus -> (s, loc_n)
            loc_new = jnp.min(D[:, :, None] + adj_loc[None, :, :], axis=1)
            mine = lax.dynamic_slice_in_dim(D, v_base, loc_n, axis=1)
            loc_new = jnp.minimum(mine, loc_new)
            new = lax.all_gather(loc_new, axis, axis=1, tiled=True)
            return new, D, it + 1

        it0 = pvary(jnp.int32(0), axis_tuple(axis))
        D, _, sweeps = lax.while_loop(cond, body, (D0, prev0, it0))
        mine = lax.dynamic_slice_in_dim(D, v_base, loc_n, axis=1)
        return mine, lax.psum(sweeps, axis) // nprocs

    D, sweeps = run(adj_padded, jnp.asarray(sources, jnp.int32))
    return D, sweeps
