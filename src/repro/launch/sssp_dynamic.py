"""Dynamic-serving driver: replay open-loop CHURN traces (mixed edge
mutations + queries) against the serve subsystem over mutable graphs.

    PYTHONPATH=src python -m repro.launch.sssp_dynamic --smoke

Mirrors launch/sssp_serve.py, but the registered graphs are
:class:`~repro.dynamic.DynamicGraph` overlays and the trace interleaves
``add``/``update``/``delete`` edge edits with the query stream
(serve/workload.make_churn_trace).  Each scheduler tick commits the
pending edits as one mutation batch BEFORE answering the tick's queries;
the registry's mutate hook then keeps, incrementally repairs, or
invalidates the affected distance-cache rows and lazily re-solves staled
landmarks (see serve/scheduler.py and dynamic/repair.py).

Two replay modes:

* default — wall-clock open loop (arrivals vs a real clock, latency
  includes queueing): reports p50/p99/qps plus the dynamic accounting
  (versions committed, rows kept/repaired/invalidated, repair edge work,
  landmark refreshes, overlay occupancy / compactions).
* ``--verify`` (default under ``--smoke``) — deterministic event-order
  replay: after EVERY event the queue is drained and each served answer
  is checked **bitwise** against a fresh ``serial`` solve on the mutated
  snapshot of the answer-time version — the end-to-end form of the
  dynamic exactness guarantee (tests/test_dynamic.py holds the
  per-component forms).  This is the CI ``dynamic-smoke`` entry point.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.dynamic import DynamicGraph
from repro.serve import (DistanceCache, GraphRegistry, LatencyRecorder,
                         MicroBatchScheduler, MutationEvent, make_churn_trace)


def _submit(sched: MicroBatchScheduler, e) -> None:
    if isinstance(e, MutationEvent):
        sched.submit_mutation(e.graph, e.op, e.u, e.v, e.w,
                              arrival=e.arrival)
    else:
        sched.submit(e.graph, e.source, e.target, arrival=e.arrival)


def replay_wallclock(sched: MicroBatchScheduler, events) -> list:
    """Open-loop wall-clock replay (launch/sssp_serve.py's shape, with
    mutation events submitted into the same clock)."""
    events = sorted(events, key=lambda e: e.arrival)
    t0 = time.perf_counter()
    i, answers = 0, []
    while i < len(events) or sched.pending:
        now = time.perf_counter() - t0
        while i < len(events) and events[i].arrival <= now:
            _submit(sched, events[i])
            i += 1
        if sched.pending:
            out = sched.tick(now)   # now= stamps Answer.service_start
            done = time.perf_counter() - t0
            for a in out:
                a.done_at = done
            answers.extend(out)
        elif i < len(events):
            time.sleep(min(events[i].arrival - now, 1e-3))
    return answers


def replay_verified(sched: MicroBatchScheduler, events,
                    dyns: dict) -> tuple:
    """Deterministic event-order replay with bitwise verification: every
    answer is compared against a fresh ``serial`` solve on the snapshot
    of the graph version the answer was computed for (rows memoized per
    (graph, version, source) — versions are immutable once committed).
    Returns (answers, distinct rows checked)."""
    rows: dict = {}

    def serial_row(graph: str, source: int) -> np.ndarray:
        key = (graph, dyns[graph].version, source)
        if key not in rows:
            rows[key] = shortest_paths(
                dyns[graph].snapshot(), source, engine="serial").dist
        return rows[key]

    answers = []
    for e in events:
        _submit(sched, e)
        for a in sched.drain(e.arrival):
            answers.append(a)
            if a.via == "mutate":
                continue
            q = a.query
            if a.via == "error":
                raise SystemExit(
                    f"scheduler returned an error answer for {q} "
                    f"(last mutation error: {sched.last_mutation_error})")
            ref = serial_row(q.graph, q.source)
            if q.target is None:
                if not np.array_equal(a.value, ref):
                    raise SystemExit(
                        f"row mismatch vs serial: {q} (via {a.via}, "
                        f"version {dyns[q.graph].version})")
            else:
                got, want = np.float32(a.value), ref[q.target]
                if not (got == want or (np.isinf(got) and np.isinf(want))):
                    raise SystemExit(
                        f"dist mismatch vs serial: {q} (via {a.via}): "
                        f"served {got!r}, serial {want!r}")
    return answers, len(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graphs, short traces, verify on (CI-sized)")
    ap.add_argument("--n", type=int, default=None,
                    help="vertices per graph (default 10000; smoke 256)")
    ap.add_argument("--graphs", type=int, default=2)
    ap.add_argument("--events", type=int, default=None,
                    help="trace events incl. mutations "
                         "(default 400; smoke 120)")
    ap.add_argument("--rate", type=float, default=None,
                    help="open-loop arrival rate, events/s "
                         "(default 500; smoke 2000)")
    ap.add_argument("--mutate-frac", type=float, default=0.15)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--landmarks", type=int, default=8)
    ap.add_argument("--cache-rows", type=int, default=256)
    ap.add_argument("--repair-rows", type=int, default=8,
                    help="max cache rows repaired in place per "
                         "mutation batch (rest invalidated)")
    ap.add_argument("--overlay-capacity", type=int, default=256)
    ap.add_argument("--compact-threshold", type=int, default=None,
                    help="live overlay arcs that trigger compaction "
                         "(default: half the overlay capacity)")
    ap.add_argument("--verify", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="deterministic bitwise replay vs serial "
                         "(default: on under --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="capture observability: Chrome trace JSON to "
                         "PATH, per-solve cost records to "
                         "PATH-with-.cost.jsonl; both are schema-"
                         "validated at exit (repro/obs)")
    args = ap.parse_args(argv)

    capture = None
    if args.trace_out:
        from repro.obs import install_capture
        capture = install_capture()

    n = args.n or (256 if args.smoke else 10000)
    events_n = args.events or (120 if args.smoke else 400)
    rate = args.rate or (2000.0 if args.smoke else 500.0)
    verify = args.verify if args.verify is not None else args.smoke
    threshold = (args.compact_threshold if args.compact_threshold is not None
                 else "auto")

    dyns = {}
    for i in range(args.graphs):
        cg = C.random_csr_graph(n, 3 * n, seed=args.seed + i)
        dyns[f"g{i}"] = DynamicGraph(
            cg, overlay_capacity=args.overlay_capacity,
            compact_threshold=threshold)

    registry = GraphRegistry()
    cache = DistanceCache(capacity=args.cache_rows)
    sched = MicroBatchScheduler(registry, cache, max_batch=args.batch,
                                repair_rows=args.repair_rows)
    t0 = time.perf_counter()
    for name, dyn in dyns.items():
        registry.register(name, dyn, landmarks=args.landmarks,
                          landmark_seed=args.seed)
    prep_s = time.perf_counter() - t0

    events = make_churn_trace(
        [(name, dyn.base) for name, dyn in dyns.items()],
        num_events=events_n, rate=rate, mutate_frac=args.mutate_frac,
        seed=args.seed, hot_seed=args.seed + 101)
    n_mut = sum(isinstance(e, MutationEvent) for e in events)

    if verify:
        answers, checked = replay_verified(sched, events, dyns)
        print(f"[sssp_dynamic] verified bitwise vs serial: "
              f"{len(answers)} answers ({n_mut} mutations) against "
              f"{checked} distinct (graph, version, source) rows",
              flush=True)
    else:
        answers = replay_wallclock(sched, events)
        rec = LatencyRecorder()
        for a in answers:
            rec.observe(a, a.done_at)
        lat = rec.summary()
        print(f"[sssp_dynamic] churn: {lat['queries']} answers "
              f"({n_mut} mutations, {args.graphs} graphs, n={n}, "
              f"prep {prep_s:.2f}s) | p50 {lat['p50_ms']:.1f} ms, "
              f"p99 {lat['p99_ms']:.1f} ms, {lat['qps']:.0f} ev/s",
              flush=True)
        if "queue_p50_ms" in lat:
            print(f"[sssp_dynamic] churn: queue wait "
                  f"p50 {lat['queue_p50_ms']:.1f} ms / "
                  f"p99 {lat['queue_p99_ms']:.1f} ms | service "
                  f"p50 {lat['service_p50_ms']:.1f} ms / "
                  f"p99 {lat['service_p99_ms']:.1f} ms", flush=True)

    s = sched.stats()
    versions = {name: dyn.version for name, dyn in dyns.items()}
    overlays = {name: f"{dyn.overlay_used}/{dyn.overlay_capacity}"
                f"(+{dyn.compactions} compactions)"
                for name, dyn in dyns.items()}
    print(f"[sssp_dynamic] via {s['answered_via']}", flush=True)
    print(f"[sssp_dynamic] mutation batches {s['registry']['mutations']} "
          f"({s['registry']['edges_mutated']} edge deltas) -> versions "
          f"{versions} | cache rows kept {s['rows_kept']}, repaired "
          f"{s['rows_repaired']} ({s['repair_edges']} edges relaxed), "
          f"invalidated {s['rows_invalidated']} | landmark refreshes "
          f"{s['registry']['landmark_refreshes']} | overlay {overlays}",
          flush=True)
    c = s["cache"]
    print(f"[sssp_dynamic] cache: {c['hits']} hits / {c['misses']} misses "
          f"(rate {c['hit_rate']:.2f}), {c['evictions']} evictions, "
          f"{c['rows']}/{c['capacity']} rows", flush=True)
    if capture is not None:
        from repro.obs import cost_path_for, finalize_capture
        tr, cl = capture
        errs = finalize_capture(tr, cl, args.trace_out)
        print(f"[sssp_dynamic] trace: {len(tr.spans)} spans, "
              f"{len(tr.instants)} instants -> {args.trace_out} | "
              f"{len(cl.records)} cost records -> "
              f"{cost_path_for(args.trace_out)}", flush=True)
        if errs:
            for e in errs[:20]:
                print(f"[sssp_dynamic] trace INVALID: {e}", flush=True)
            raise SystemExit(f"observability capture invalid "
                             f"({len(errs)} errors)")
        print("[sssp_dynamic] trace: schema + answer chains valid",
              flush=True)
    print("[sssp_dynamic] done", flush=True)


if __name__ == "__main__":
    main()
