"""Batched serving driver: continuous prefill + decode over a request queue.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 8 --batch 4 --prompt-len 32 --gen 16

A minimal but real serving loop: requests arrive with prompts, are grouped
into fixed-size batches, prefilled once (filling KV/state caches sized to
prompt+gen), then decoded step-by-step with greedy sampling.  Per-request
latency and aggregate tokens/s are reported.  The same prefill/decode steps
are what the decode_32k / long_500k dry-run cells lower at production shape.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    generated: list


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config, make_smoke
    from repro.models import transformer as T

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(args.seed)
    queue = [Request(i, rng.integers(0, cfg.vocab_size,
                                     size=args.prompt_len).astype(np.int32),
                     []) for i in range(args.requests)]

    extras = {}
    if cfg.num_image_tokens:
        extras["image_embeds"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.num_image_tokens,
                                 cfg.d_model)).astype(np.float32) * 0.02)
    if cfg.encoder_segments:
        extras["encoder_frames"] = jnp.asarray(
            rng.standard_normal(
                (args.batch, max(args.prompt_len // cfg.audio_downsample, 1),
                 cfg.d_model)).astype(np.float32) * 0.02)

    prefill = jax.jit(lambda p, t, **ex: T.prefill(
        p, t, cfg, max_len=max_len, **ex))
    decode = jax.jit(lambda p, tok, pos, c, **ex: T.decode_step(
        p, tok, pos, c, cfg, **ex))
    dec_extras = ({"image_embeds": extras["image_embeds"]}
                  if "image_embeds" in extras else {})

    t_start = time.time()
    total_tokens = 0
    lat = []
    while queue:
        batch_reqs = queue[:args.batch]
        queue = queue[args.batch:]
        while len(batch_reqs) < args.batch:           # pad the last batch
            batch_reqs.append(batch_reqs[0])
        t0 = time.time()
        toks = jnp.stack([jnp.asarray(r.prompt) for r in batch_reqs])
        logits, caches, pos = prefill(params, toks, **extras)
        nxt = jnp.argmax(logits, axis=-1)[:, None]
        for _ in range(args.gen):
            for i, r in enumerate(batch_reqs):
                r.generated.append(int(nxt[i, 0]))
            logits, caches, pos = decode(params, nxt, pos, caches,
                                         **dec_extras)
            nxt = jnp.argmax(logits, axis=-1)[:, None]
        dt = time.time() - t0
        lat.append(dt)
        total_tokens += args.gen * len(batch_reqs)
        print(f"[serve] batch of {len(batch_reqs)}: {dt*1e3:.0f} ms "
              f"({args.gen} tokens/req)", flush=True)

    wall = time.time() - t_start
    print(f"[serve] {total_tokens} tokens in {wall:.2f}s = "
          f"{total_tokens/wall:.1f} tok/s; "
          f"p50 batch latency {np.median(lat)*1e3:.0f} ms", flush=True)


if __name__ == "__main__":
    main()
