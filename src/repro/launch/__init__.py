"""Launch drivers: SSSP runs (sssp_run, sssp_serve), serving (serve),
training (train), dry-run/roofline analysis (dryrun, hlo_analysis,
memory_model).  Modules are imported on demand — several force XLA flags
at import time, so nothing is re-exported here.
"""
