import os
import sys

# Device count must be fixed before jax imports; parse --procs by hand.
if "--procs" in sys.argv:
    _n = sys.argv[sys.argv.index("--procs") + 1]
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_n} "
        + os.environ.get("XLA_FLAGS", ""))
"""Paper-reproduction driver: run any SSSP engine on any graph.

    PYTHONPATH=src python -m repro.launch.sssp_run \
        --engine bellman_kernel --nodes 2000 --edges 6000
    PYTHONPATH=src python -m repro.launch.sssp_run \
        --engine dijkstra_sharded --procs 8 --nodes 4000 --edges 12000
    PYTHONPATH=src python -m repro.launch.sssp_run \
        --engine delta_stepping --corpus road --nodes 10000 --delta auto

Timing follows the paper's §III cost envelope: graph construction (edge
list -> adjacency matrix) is excluded; device transfer + algorithm + result
gather are included.
"""
import argparse
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="serial",
                    choices=["serial", "dijkstra_sharded", "bellman",
                             "bellman_kernel", "bellman_sharded",
                             "multisource", "bellman_csr",
                             "bellman_csr_kernel", "frontier",
                             "frontier_kernel", "delta_stepping",
                             "delta_stepping_kernel", "multisource_csr",
                             "bellman_csr_sharded", "frontier_sharded"])
    ap.add_argument("--nodes", type=int, default=1000)
    ap.add_argument("--edges", type=int, default=3000)
    ap.add_argument("--delta", default=None,
                    help="Δ bucket width: a positive float or 'auto' "
                         "(per-graph width from the weight profile).  "
                         "Consumed by the frontier and delta_stepping "
                         "engines; the Δ engines default to auto.")
    ap.add_argument("--corpus", default="random",
                    choices=["random", "road", "hub"],
                    help="graph shape: 'road' (4-neighbour grid, --nodes "
                         "rounded down to a square) and 'hub' (heavy-"
                         "tailed hub fan-outs) are the Δ-stepping gate "
                         "corpora; CSR-native engines only")
    ap.add_argument("--procs", type=int, default=1)
    ap.add_argument("--source", type=int, default=0)
    ap.add_argument("--sources", type=int, default=8,
                    help="batch size for the multisource engines")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--directed", action="store_true",
                    help="the paper's -w flag")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--verify", action="store_true")
    args = ap.parse_args(argv)

    from repro.core import csr as C
    from repro.core import graph as G
    from repro.core._compat import make_mesh
    from repro.core.api import (DELTA_ENGINES, SHARDED_CSR_ENGINES,
                                shortest_paths)
    from repro.core.serial import dijkstra_serial_np

    csr_native = args.engine in SHARDED_CSR_ENGINES + DELTA_ENGINES
    if args.corpus != "random":
        if not (csr_native or args.engine in
                ("bellman_csr", "bellman_csr_kernel", "frontier",
                 "frontier_kernel", "multisource_csr")):
            ap.error(f"--corpus {args.corpus} builds a CsrGraph; "
                     f"engine {args.engine!r} needs the dense corpus")
        make = (C.road_like_csr_graph if args.corpus == "road"
                else C.skewed_hub_csr_graph)
        g = make(args.nodes, seed=args.seed)
        csr_native = True
    elif csr_native:
        # --procs for the CSR engines: same flag, sparse partition — no
        # dense matrix is ever built, so n can go far beyond the dense cap.
        g = C.random_csr_graph(args.nodes, args.edges, seed=args.seed,
                               directed=args.directed)
    else:
        g = G.random_graph(args.nodes, args.edges, seed=args.seed,
                           directed=args.directed)
    delta = args.delta
    if delta is not None and delta != "auto":
        delta = float(delta)   # api re-validates (positive, finite)
    mesh = None
    if args.engine in ("dijkstra_sharded", "bellman_sharded",
                       "multisource") + SHARDED_CSR_ENGINES:
        mesh = make_mesh((max(args.procs, 1),), ("data",))

    source = (np.arange(args.sources) % args.nodes
              if args.engine in ("multisource", "multisource_csr")
              else args.source)

    kw = {} if delta is None else {"delta": delta}
    times = []
    res = None
    for rep in range(args.repeats):
        t0 = time.perf_counter()
        res = shortest_paths(g, source, engine=args.engine, mesh=mesh, **kw)
        times.append(time.perf_counter() - t0)
    best = min(times)
    n, m = g.n, (g.nnz if csr_native else args.edges)
    print(f"engine={args.engine} corpus={args.corpus} n={n} m={m} "
          f"procs={args.procs} time={best:.6f}s"
          + (f" sweeps={res.sweeps}" if res.sweeps is not None else "")
          + (f" edges_relaxed={res.edges_relaxed}"
             if res.edges_relaxed is not None else ""))

    if args.verify:
        adj = g.to_dense().adj if csr_native else g.adj   # O(n²): verify only
        ref, _ = dijkstra_serial_np(adj, args.source)
        got = res.dist[0] if res.dist.ndim == 2 else res.dist
        ok = np.allclose(np.where(np.isfinite(ref), ref, 1e30),
                         np.where(np.isfinite(got), got, 1e30), rtol=1e-5)
        print("verify:", "OK" if ok else "MISMATCH")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
