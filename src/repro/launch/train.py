"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 10

Production behaviors exercised end-to-end (and covered by tests):
  * checkpoint/restart: atomic async checkpoints; on start, the driver
    resumes from the newest checkpoint and replays the data pipeline from
    the restored step (deterministic, restart-safe);
  * failure injection: ``--simulate-failure-at N`` raises mid-run; rerun
    the same command and training continues from the last checkpoint —
    the integration test asserts bit-identical losses vs an uninterrupted
    run;
  * preemption: SIGTERM triggers a final synchronous checkpoint before
    exit (the TPU-pod eviction pattern);
  * straggler watchdog: per-step wall time is tracked against an EWMA;
    steps slower than ``--straggler-factor``× the moving average are
    logged with their step index (on real pods this feeds re-dispatch);
  * elastic restore: checkpoints store logical arrays; restoring onto a
    different mesh/device count just works (reshard-on-load).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--data-axis", type=int, default=None)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--ddp-compress", action="store_true",
                    help="use the shard_map DP trainer with int8 EF "
                         "gradient compression")
    args = ap.parse_args(argv)

    from repro.core._compat import set_mesh
    from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
    from repro.configs import get_config, make_smoke
    from repro.data.pipeline import DataConfig, SyntheticPipeline
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import rules
    from repro.train.optimizer import OptConfig
    from repro.train.state import init_train_state, train_state_shape
    from repro.train.step import make_ddp_train_step, make_train_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = make_smoke(cfg)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                        total_steps=args.steps)
    mesh = make_host_mesh(data=args.data_axis, model=args.model_axis)

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch, seed=args.seed,
                    image_tokens=cfg.num_image_tokens,
                    frame_len=(args.seq // cfg.audio_downsample
                               if cfg.encoder_segments else 0),
                    d_model=cfg.d_model)
    pipe = SyntheticPipeline(dc)

    # ---- init or restore -------------------------------------------------
    start_step = 0
    state_shape = train_state_shape(cfg, opt_cfg)
    with set_mesh(mesh):
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            shardings = jax.tree.map(
                lambda l: rules.replicated(mesh), state_shape,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            state, extra = restore_checkpoint(args.ckpt_dir, state_shape,
                                              shardings=shardings)
            start_step = int(extra.get("step", int(state.step)))
            print(f"[train] restored step {start_step} from {args.ckpt_dir}",
                  flush=True)
        else:
            state = init_train_state(jax.random.PRNGKey(args.seed), cfg,
                                     opt_cfg)

        step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                          grad_accum=args.grad_accum),
                          donate_argnums=0)

        ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

        # ---- SIGTERM preemption hook ----------------------------------
        preempted = {"flag": False}

        def _on_sigterm(signum, frame):
            preempted["flag"] = True
        signal.signal(signal.SIGTERM, _on_sigterm)

        # ---- loop -------------------------------------------------------
        ewma = None
        losses = []
        for step_idx in range(start_step, args.steps):
            if (args.simulate_failure_at is not None
                    and step_idx == args.simulate_failure_at):
                # save nothing NEW: the point is recovering from the last
                # periodic checkpoint.  Do drain the in-flight async write
                # first — the injection tests restart determinism, not
                # mid-write interruption (test_tmp_dirs_never_visible covers
                # that separately), and otherwise whether the periodic save
                # landed depends on a disk-vs-step-time race.
                if ckpt:
                    ckpt.wait()
                raise RuntimeError(
                    f"[train] simulated node failure at step {step_idx}")
            batch = {k: jnp.asarray(v) for k, v in
                     pipe.batch_at(step_idx).items()}
            t0 = time.time()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if dt > args.straggler_factor * ewma and step_idx > start_step + 3:
                print(f"[watchdog] straggler step {step_idx}: "
                      f"{dt:.3f}s vs ewma {ewma:.3f}s", flush=True)
            losses.append(loss)
            if step_idx % args.log_every == 0:
                print(f"[train] step {step_idx} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)", flush=True)
            if ckpt and (step_idx + 1) % args.ckpt_every == 0:
                ckpt.save(state, step_idx + 1, {"step": step_idx + 1})
            if preempted["flag"]:
                print("[train] SIGTERM: checkpointing and exiting", flush=True)
                if ckpt:
                    ckpt.save(state, step_idx + 1, {"step": step_idx + 1},
                              block=True)
                sys.exit(143)

        if ckpt:
            ckpt.save(state, args.steps, {"step": args.steps}, block=True)
        print(f"[train] done: final loss {losses[-1]:.4f} "
              f"(first {losses[0]:.4f})", flush=True)
        if os.environ.get("REPRO_EMIT_LOSSES"):
            print("LOSSES " + json.dumps(losses), flush=True)


if __name__ == "__main__":
    main()
