"""Analytic per-device memory model for every cell — the "does it fit"
complement to XLA:CPU's pessimistic buffer assignment (DESIGN.md §6).

Everything except activation working set is *exact*: parameter, optimizer
and cache bytes are computed from the real pytrees via ``jax.eval_shape``
and divided by each leaf's actual shard count from the rules engine (so
replicated-on-model leaves, padded experts, fsdp fallbacks are all
accounted exactly).  Activation carries use the block-remat formula
(L × microbatch × S × d × 2 B bf16 + f32 working set of one layer).

    PYTHONPATH=src python -m repro.launch.memory_model [--mesh pod]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import math

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, LONG_CONTEXT_ARCHS, SHAPES, get_config
from repro.core._compat import set_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import default_grad_accum, default_opt_config
from repro.models import transformer as T
from repro.sharding import rules
from repro.train.state import train_state_shape

HBM_PER_CHIP = 16e9      # v5e


def _sharded_bytes(shape_tree, shardings) -> float:
    """Σ per-device shard bytes, using each leaf's actual NamedSharding
    (replicated-on-model leaves, expert padding, fsdp fallbacks exact)."""
    leaves = jax.tree.leaves(shape_tree)
    shards = jax.tree.leaves(shardings,
                             is_leaf=lambda x: hasattr(x, "num_devices"))
    total = 0.0
    for l, s in zip(leaves, shards):
        shard_shape = s.shard_shape(l.shape)
        total += math.prod(shard_shape) * l.dtype.itemsize
    return total


def cell_memory(arch: str, shape_name: str, mesh) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    out = {"arch": arch, "shape": shape_name}

    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = rules.param_shardings(params_shape, mesh)
    out["params_gb"] = _sharded_bytes(params_shape, p_sh) / 1e9

    if shape.kind == "train":
        opt = default_opt_config(cfg)
        st = train_state_shape(cfg, opt)
        mu_sh = rules.param_shardings(st.opt_state["mu"], mesh)
        out["moments_gb"] = 2 * _sharded_bytes(st.opt_state["mu"], mu_sh) / 1e9
        out["grads_gb"] = out["params_gb"] * 2   # f32 grads vs bf16 params
        accum = default_grad_accum(cfg, B)
        dp = max(rules._axis_size(mesh, rules.logical_map(mesh)["dp"]), 1)
        mb_tokens = B * S // accum // dp
        # block-remat carries (bf16) + one layer f32 working set
        carries = cfg.num_layers * mb_tokens * cfg.d_model * 2
        work = 6 * mb_tokens * max(cfg.d_model, cfg.moe_d_ff or 0,
                                   cfg.d_ff or 0) * 4
        out["activations_gb"] = (carries + work) / 1e9
        out["total_gb"] = sum(out[k] for k in
                              ("params_gb", "moments_gb", "grads_gb",
                               "activations_gb"))
    else:
        caches = jax.eval_shape(lambda: T.init_cache(cfg, B, S, jnp.bfloat16))
        c_sh = rules.cache_shardings(caches, mesh)
        out["cache_gb"] = _sharded_bytes(caches, c_sh) / 1e9
        dp = max(rules._axis_size(mesh, rules.logical_map(mesh)["dp"]), 1)
        tok = (B * S if shape.kind == "prefill" else B) // dp
        out["activations_gb"] = 8 * tok * cfg.d_model * 2 / 1e9
        out["total_gb"] = (out["params_gb"] + out["cache_gb"]
                           + out["activations_gb"])
    out["fits_16gb"] = out["total_gb"] <= HBM_PER_CHIP / 1e9
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    print(f"analytic per-device memory, {args.mesh} "
          f"({mesh.devices.size} chips), v5e 16 GB HBM\n")
    hdr = (f"{'arch':24s} {'shape':12s} {'params':>8s} {'opt+grad':>9s} "
           f"{'cache':>7s} {'activ':>7s} {'total':>7s}  fits")
    print(hdr)
    with set_mesh(mesh):
        for arch in ARCHS:
            for sh in SHAPES:
                if sh == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                    continue
                m = cell_memory(arch, sh, mesh)
                og = m.get("moments_gb", 0) + m.get("grads_gb", 0)
                print(f"{arch:24s} {sh:12s} {m['params_gb']:8.2f} "
                      f"{og:9.2f} {m.get('cache_gb', 0):7.2f} "
                      f"{m['activations_gb']:7.2f} {m['total_gb']:7.2f}  "
                      f"{'YES' if m['fits_16gb'] else 'NO'}")


if __name__ == "__main__":
    main()
