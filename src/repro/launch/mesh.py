"""Mesh construction.  Functions, not module-level constants, so importing
this module never touches jax device state (the dry-run must set
XLA_FLAGS before the first jax device query)."""
from __future__ import annotations

import jax

from repro.core._compat import make_mesh


def _mk(shape, axes):
    return make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi-pod adds a leading pod=2 axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Mesh over whatever devices exist (tests / smoke runs)."""
    n = jax.device_count()
    if data is None:
        data = n // model
    assert data * model <= n, (data, model, n)
    return _mk((data, model), ("data", "model"))
