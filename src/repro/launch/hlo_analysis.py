"""Loop-weighted HLO analysis: FLOPs, HBM traffic, collective payloads.

Why not ``compiled.cost_analysis()`` alone: XLA's HloCostAnalysis counts
each while-loop *body once*, but this framework deliberately keeps HLO
small by scanning over layers / q-chunks / microbatches — so an unweighted
count under-reports a 61-layer model by ~61×.  The compiled HLO annotates
every while op with ``backend_config={"known_trip_count":{"n":...}}``; this
module parses the module into computations, builds the call graph, and
propagates costs with while bodies multiplied by their trip counts.

Per-op cost model (applied in the weighted walk):
  dot                       2 · prod(out dims) · prod(contracting dims) FLOPs
                            (MXU-eligible)
  elementwise / compare     prod(out dims) FLOPs (VPU)
  reduce / reduce-window    prod(input dims) FLOPs (VPU)
  traffic                   out bytes + Σ operand bytes for every
                            non-bookkeeping top-level op (fusion internals
                            excluded — they live in registers/VMEM)
  collectives               payload = output bytes (all-reduce counted 2×:
                            ring reduce+broadcast halves)

The VPU/MXU split matters for the paper's SSSP engines: min-plus relaxation
is *not* an MXU workload (adds+mins, no multiply-accumulate), so its compute
roofline is the VPU term — a TPU-adaptation insight recorded in DESIGN.md.

Roofline terms (TPU v5e, per chip): 197 TFLOP/s bf16 MXU; 3.9 TFLOP/s f32
VPU (8×128 lanes × 2 ops × ~940 MHz — derived, not assignment-given);
819 GB/s HBM; 50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 MXU per chip (assignment constant)
VPU_FLOPS = 3.9e12           # f32 VPU per chip (derived; see module doc)
HBM_BW = 819e9               # bytes/s per chip (assignment constant)
ICI_BW = 50e9                # bytes/s per link (assignment constant)
COLL_LATENCY = 1e-6          # s per collective launch (ICI hop + dispatch);
                             # captures the paper's n-tiny-allreduce regime
                             # where payload bytes are negligible but each
                             # round is a synchronization barrier

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "compare", "select", "and", "or", "not", "xor", "clamp", "floor",
    "ceil", "round-nearest-afz", "cosine", "sine", "atan2", "expm1",
    "log-plus-one", "logistic",
}

_BOOKKEEPING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota",
}

_SHAPE_TOK = re.compile(r"(\w[\w-]*)\[([\d,]*)\](?:\{[^}]*\})?")
# computation headers sit at column 0 and may contain nested parens:
#   %region_0.2 (arg_tuple.1: (s32[], f32[8,512])) -> (s32[], ...) {
_COMP_START = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_CALLED_COMP = re.compile(r"(?:body|calls|to_apply)=%?([\w.-]+)")
_COND_COMP = re.compile(r"condition=%?([\w.-]+)")
_TRIP = re.compile(r'known_trip_count[\\"{:n]+(\d+)')
_OPERAND = re.compile(r"%([\w.-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_SCALAR_SHAPE = re.compile(r"([\w-]+\[[\d,]*\](?:\{[^}]*\})?)")
_OPCODE = re.compile(r"([\w-]+)\((.*)$")


def _matched_paren(s: str) -> int:
    """Index just past the close paren matching s[0] == '('."""
    depth = 0
    for i, ch in enumerate(s):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(s)


def _parse_op_line(line: str):
    """'%name = TYPE opcode(args), attrs' -> (name, shape, opcode, args, rest).

    Handles tuple-typed outputs containing /*index=N*/ comments and nested
    layout braces by explicit paren matching instead of a single regex."""
    ls = line.strip()
    if ls.startswith("ROOT "):
        ls = ls[5:]
    if not ls.startswith("%"):
        return None
    name, eq, rest = ls.partition(" = ")
    if not eq:
        return None
    name = name.lstrip("%").strip()
    rest = rest.lstrip()
    if rest.startswith("("):                 # tuple-shaped output
        end = _matched_paren(rest)
        out_shape, rem = rest[:end], rest[end:].lstrip()
    else:
        m = _SCALAR_SHAPE.match(rest)
        if not m:
            return None
        out_shape, rem = m.group(1), rest[m.end():].lstrip()
    mo = _OPCODE.match(rem)
    if not mo:
        return None
    opcode, tail = mo.group(1), "(" + mo.group(2)
    end = _matched_paren(tail)
    args, attrs = tail[1:end - 1], tail[end:]
    return name, out_shape, opcode, args, attrs


def _shape_bytes(shape_str: str) -> int:
    return sum(
        (math.prod(int(d) for d in m.group(2).split(",") if d)
         if m.group(2) else 1) * _DTYPE_BYTES.get(m.group(1), 0)
        for m in _SHAPE_TOK.finditer(shape_str))


def _shape_elems(shape_str: str) -> int:
    m = _SHAPE_TOK.search(shape_str)
    if not m:
        return 0
    return (math.prod(int(d) for d in m.group(2).split(",") if d)
            if m.group(2) else 1)


def _shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_TOK.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    out_shape: str
    operands: list[str]
    rest: str


def parse_computations(hlo_text: str) -> dict[str, list[_Op]]:
    comps: dict[str, list[_Op]] = {}
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        mc = _COMP_START.match(line)
        if mc:
            cur = mc.group(1)
            comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if parsed is None:
            continue
        name, out_shape, opcode, args, attrs = parsed
        operands = _OPERAND.findall(args)
        comps[cur].append(_Op(name, opcode, out_shape, operands,
                              args + " " + attrs))
    return comps


@dataclasses.dataclass
class WeightedStats:
    dot_flops: float = 0.0
    vector_flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    collective_count: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in COLLECTIVES})

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def add(self, other: "WeightedStats", w: float):
        self.dot_flops += w * other.dot_flops
        self.vector_flops += w * other.vector_flops
        self.traffic_bytes += w * other.traffic_bytes
        for k in COLLECTIVES:
            self.collective_bytes[k] += w * other.collective_bytes[k]
            self.collective_count[k] += int(w * other.collective_count[k])

    def to_dict(self):
        return {
            "dot_flops": self.dot_flops,
            "vector_flops": self.vector_flops,
            "traffic_bytes": self.traffic_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_count": dict(self.collective_count),
            "total_collective_bytes": self.total_collective_bytes,
        }


def _dot_flops(op: _Op, symtab: dict[str, str]) -> float:
    out_elems = _shape_elems(op.out_shape)
    mc = _CONTRACT.search(op.rest)
    k = 1
    if mc and op.operands:
        lhs_shape = symtab.get(op.operands[0], "")
        dims = _shape_dims(lhs_shape)
        for idx in (int(i) for i in mc.group(1).split(",") if i):
            if idx < len(dims):
                k *= dims[idx]
    return 2.0 * out_elems * k


def weighted_stats(hlo_text: str) -> WeightedStats:
    comps = parse_computations(hlo_text)
    memo: dict[str, WeightedStats] = {}

    def comp_stats(cname: str, *, top_level: bool) -> WeightedStats:
        key = cname + ("#t" if top_level else "#f")
        if key in memo:
            return memo[key]
        st = WeightedStats()
        memo[key] = st      # guard (acyclic in valid HLO)
        ops = comps.get(cname, [])
        symtab = {o.name: o.out_shape for o in ops}
        defop = {o.name: o.opcode for o in ops}
        for op in ops:
            oc = op.opcode
            if oc in _BOOKKEEPING:
                continue
            is_coll = next((c for c in COLLECTIVES
                            if oc == c or oc == c + "-start"), None)
            if oc.endswith("-done"):
                continue
            if is_coll:
                payload = _shape_bytes(op.out_shape)
                if is_coll == "all-reduce":
                    payload *= 2           # ring: reduce + broadcast halves
                st.collective_bytes[is_coll] += payload
                st.collective_count[is_coll] += 1
                st.traffic_bytes += _shape_bytes(op.out_shape)
                continue
            # flops
            if oc == "dot":
                st.dot_flops += _dot_flops(op, symtab)
            elif oc in _ELEMENTWISE:
                st.vector_flops += _shape_elems(op.out_shape)
            elif oc in ("reduce", "reduce-window"):
                ins = sum(_shape_elems(symtab.get(o, ""))
                          for o in op.operands[:1])
                st.vector_flops += ins
            # traffic (top-level ops only; fusion internals live in VMEM).
            # Fusion-discounted buffer model (XLA:CPU fuses far more finely
            # than a TPU compiler would, so naive operand+output counting
            # inflates HBM traffic ~5-10x):
            #   anchors (dot / reduce / sort / top-k and fusions containing
            #   them): 2×out (producer write + consumer read) + reads of
            #   parameter/loop-carried operands (weights inside scan bodies)
            #   + for reductions the large input read;
            #   elementwise/convert/copy fusions: 1×out (roughly half of
            #   these materializations fuse into a neighbor on TPU);
            #   dynamic-slice/gather: 2×sliced bytes only;
            #   dynamic-update-slice/scatter: 2×update bytes only (in-place
            #   KV-cache writes, scan residual stacking).
            if top_level:
                out_b = _shape_bytes(op.out_shape)
                is_dus = (oc in ("dynamic-update-slice", "scatter")
                          or (oc == "fusion"
                              and "dynamic-update-slice" in op.name))
                is_ds = (oc in ("dynamic-slice", "gather", "slice")
                         or (oc == "fusion" and not is_dus
                             and ("dynamic-slice" in op.name
                                  or "gather" in op.name)))
                is_reduce = (oc in ("reduce", "reduce-window", "sort")
                             or (oc == "fusion"
                                 and ("reduce" in op.name
                                      or "sort" in op.name)))
                is_anchor = (oc in ("dot", "convolution", "topk",
                                    "custom-call", "while", "conditional")
                             or is_reduce
                             or (oc == "fusion" and "dot" in op.name))
                if is_ds:
                    st.traffic_bytes += 2 * out_b
                elif is_dus:
                    op_bytes = [_shape_bytes(symtab.get(o, ""))
                                for o in op.operands]
                    upd = (sum(op_bytes) - max(op_bytes)
                           if len(op_bytes) > 1 else out_b)
                    st.traffic_bytes += 2 * min(max(upd, 1), out_b)
                elif is_anchor:
                    param_reads = sum(
                        _shape_bytes(symtab.get(o, ""))
                        for o in op.operands
                        if defop.get(o) in ("parameter",
                                            "get-tuple-element", "constant"))
                    big_in = (max((_shape_bytes(symtab.get(o, ""))
                                   for o in op.operands), default=0)
                              if is_reduce else 0)
                    st.traffic_bytes += 2 * out_b + param_reads + big_in
                else:
                    st.traffic_bytes += out_b
            # recurse
            if oc == "while":
                body = _CALLED_COMP.search(op.rest)
                cond = _COND_COMP.search(op.rest)
                trip = _TRIP.search(op.rest)
                n = int(trip.group(1)) if trip else 1
                if body:
                    st.add(comp_stats(body.group(1), top_level=True), n)
                if cond:
                    st.add(comp_stats(cond.group(1), top_level=True), n)
            elif oc in ("fusion", "call", "conditional"):
                m = _CALLED_COMP.search(op.rest)
                if m:
                    # fusion internals: flops recursed, traffic suppressed
                    st.add(comp_stats(m.group(1), top_level=False), 1)
            # reduce/scatter `to_apply` scalar computations: negligible.
        memo[key] = st
        return st

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        raise ValueError("no ENTRY computation found")
    total = WeightedStats()
    total.add(comp_stats(entry, top_level=True), 1.0)
    return total


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    compute_s: float
    vpu_s: float
    memory_s: float
    collective_s: float
    latency_s: float                # collective count × COLL_LATENCY
    dot_flops: float
    vector_flops: float
    traffic_bytes: float
    collective_bytes: float
    collective_count: int
    model_flops: Optional[float]
    useful_ratio: Optional[float]   # model_flops / (dot_flops × chips)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "vpu": self.vpu_s,
                 "memory": self.memory_s, "collective": self.collective_s,
                 "latency": self.latency_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.vpu_s, self.memory_s,
                   self.collective_s, self.latency_s)

    @property
    def roofline_fraction(self) -> Optional[float]:
        """Fraction of ideal compute-bound time: how close the bound time is
        to the pure model-FLOPs MXU time (the MFU-like score)."""
        if not self.model_flops:
            return None
        ideal = self.model_flops
        return ideal / max(self.bound_time_s, 1e-30)

    def to_dict(self):
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["bound_time_s"] = self.bound_time_s
        return d


def roofline(ws: WeightedStats, *, chips: int,
             model_flops: Optional[float] = None) -> Roofline:
    """ws: weighted per-device stats.  model_flops: whole-model analytic
    FLOPs for the step (6·N·D train / 2·N per token decode)."""
    mf_per_chip = (model_flops / chips) if model_flops else None
    n_coll = int(sum(ws.collective_count.values()))
    return Roofline(
        compute_s=ws.dot_flops / PEAK_FLOPS,
        vpu_s=ws.vector_flops / VPU_FLOPS,
        memory_s=ws.traffic_bytes / HBM_BW,
        collective_s=ws.total_collective_bytes / ICI_BW,
        latency_s=n_coll * COLL_LATENCY,
        dot_flops=ws.dot_flops,
        vector_flops=ws.vector_flops,
        traffic_bytes=ws.traffic_bytes,
        collective_bytes=ws.total_collective_bytes,
        collective_count=n_coll,
        model_flops=model_flops,
        useful_ratio=(mf_per_chip / ws.dot_flops
                      if model_flops and ws.dot_flops else None),
    )


def mfu_fraction(r: Roofline, chips: int) -> Optional[float]:
    """model_flops / (chips × peak × bound_time): the §Perf score."""
    if not r.model_flops:
        return None
    t = r.bound_time_s
    if t <= 0:
        return None
    return r.model_flops / (chips * PEAK_FLOPS * t)


# ---------------------------------------------------------------------------
# legacy simple interface (kept for tests / quick greps)
# ---------------------------------------------------------------------------

def collective_stats(hlo_text: str) -> dict:
    """Unweighted single-pass scan (counts loop bodies once)."""
    ws = WeightedStats()
    comps = parse_computations(hlo_text)
    for ops in comps.values():
        symtab = {o.name: o.out_shape for o in ops}
        for op in ops:
            is_coll = next((c for c in COLLECTIVES
                            if op.opcode == c or op.opcode == c + "-start"),
                           None)
            if is_coll:
                ws.collective_bytes[is_coll] += _shape_bytes(op.out_shape)
                ws.collective_count[is_coll] += 1
    return {"bytes_by_kind": ws.collective_bytes,
            "count_by_kind": ws.collective_count,
            "total_bytes": ws.total_collective_bytes}


def analytic_train_flops(cfg, tokens: int) -> float:
    """6·N_active·D (the assignment's MODEL_FLOPS definition)."""
    return 6.0 * cfg.active_param_count() * tokens


def analytic_decode_flops(cfg, tokens: int) -> float:
    """2·N_active per processed token (fwd only: prefill and decode)."""
    return 2.0 * cfg.active_param_count() * tokens
