import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count at first init.
#   setdefault lets tests/smoke runs override with their own XLA_FLAGS.
"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell against the production mesh, with ShapeDtypeStruct stand-ins
(no allocation), and record memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun

Each cell writes ``<out>/<arch>__<shape>__<mesh>.json`` containing:
  memory_analysis   bytes-per-device breakdown (proves the cell fits)
  cost_analysis     HLO FLOPs / bytes accessed (per-device program)
  collectives       payload bytes by kind, parsed from compiled HLO
  roofline          the three terms in seconds + dominant bottleneck

SSSP cells (the paper's engine at production scale) are included alongside
the 40 LM cells: --arch sssp --shape bellman_512k | dijkstra_128k |
multisource_128k.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import LONG_CONTEXT_ARCHS, SHAPES, ARCHS, get_config
from repro.launch import hlo_analysis as H
from repro.core._compat import set_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

SSSP_SHAPES = ("bellman_512k", "dijkstra_128k", "multisource_128k")


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:            # backend without memory analysis
        return {"error": repr(e)}
    out = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes", "host_generated_code_size_in_bytes",
              "host_argument_size_in_bytes", "host_output_size_in_bytes",
              "host_temp_size_in_bytes", "host_alias_size_in_bytes"):
        v = getattr(ma, f, None)
        if v is not None:
            out[f] = int(v)
    if not out:
        out["repr"] = str(ma)
    if "argument_size_in_bytes" in out and "temp_size_in_bytes" in out:
        out["live_bytes_per_device"] = (
            out["argument_size_in_bytes"] + out["output_size_in_bytes"]
            + out["temp_size_in_bytes"] - out.get("alias_size_in_bytes", 0))
    return out


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": repr(e)}


def build_sssp_cell(shape_name: str, mesh, overrides=None):
    """SSSP engines as dry-run cells (adjacency as ShapeDtypeStruct).
    overrides: {"minloc": "pmin"} etc. for §Perf variants."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.bellman import sssp_bellman_sharded
    from repro.core.multisource import sssp_multisource_sharded
    from repro.core.sharded import dijkstra_sharded

    ov = overrides or {}
    axis = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    nproc = 1
    for a in axis:
        nproc *= mesh.shape[a]

    if shape_name == "bellman_512k":
        n = 524_288
        fn = lambda adj, src: sssp_bellman_sharded(
            adj, src, mesh, axis=axis, max_sweeps=64)
        meta = {"n": n, "engine": "bellman_sharded", "sweep_cap": 64}
    elif shape_name == "dijkstra_128k":
        n = 131_072
        minloc = ov.get("minloc", "allgather")
        fn = lambda adj, src: dijkstra_sharded(
            adj, src, mesh, axis=axis, n_true=n, minloc=minloc)
        meta = {"n": n, "engine": "dijkstra_sharded (paper Alg.2)",
                "minloc": minloc}
    elif shape_name == "multisource_128k":
        n, s = 131_072, 64
        fn = lambda adj, srcs: sssp_multisource_sharded(
            adj, srcs, mesh, axis=axis, max_sweeps=64)
        meta = {"n": n, "sources": s, "engine": "multisource_sharded"}
    else:
        raise KeyError(shape_name)

    adj = jax.ShapeDtypeStruct((n, n), jnp.float32)
    adj_sh = NamedSharding(mesh, P(None, axis))
    if shape_name == "multisource_128k":
        src = jax.ShapeDtypeStruct((64,), jnp.int32)
    else:
        src = jax.ShapeDtypeStruct((), jnp.int32)
    src_sh = NamedSharding(mesh, P())

    class _C:                          # duck-typed Cell
        arch, shape, kind = "sssp", shape_name, "sssp"
        step_fn = staticmethod(fn)
        args = (adj, src)
        in_shardings = (adj_sh, src_sh)
        out_shardings = None
        cfg = None
        meta_ = meta
    _C.meta = dict(meta, tokens_per_step=0)
    return _C


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, save_hlo: bool = False, overrides: dict | None = None,
             tag: str = "") -> dict:
    multi = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    t0 = time.time()
    if arch == "sssp":
        cell = build_sssp_cell(shape_name, mesh, overrides)
        model_flops = None
    else:
        ga = (overrides or {}).pop("grad_accum", None) if overrides else None
        cell = build_cell(arch, shape_name, mesh, cfg_overrides=overrides,
                          grad_accum=ga)
        cfg = cell.cfg
        toks = cell.meta["tokens_per_step"]
        if cell.kind == "train":
            model_flops = H.analytic_train_flops(cfg, toks)
        elif cell.kind == "prefill":
            model_flops = H.analytic_decode_flops(cfg, toks)
        else:
            model_flops = H.analytic_decode_flops(cfg, toks)

    # set_mesh (not just `with mesh:`) so in-model with_sharding_constraint
    # activation rules see the ambient abstract mesh during tracing.
    with set_mesh(mesh):
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    hlo = compiled.as_text()
    ws = H.weighted_stats(hlo)          # loop-weighted per-device stats
    cost = _cost_dict(compiled)         # raw XLA numbers (loop bodies × 1)
    mem = _memory_dict(compiled)
    rf = H.roofline(ws, chips=chips, model_flops=model_flops)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": int(chips), "kind": cell.kind, "meta": cell.meta,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "cost_analysis_unweighted": {
            k: cost.get(k) for k in ("flops", "bytes accessed")},
        "weighted": ws.to_dict(),
        "roofline": rf.to_dict(),
        "mfu_fraction": H.mfu_fraction(rf, chips),
    }
    rec["overrides"] = overrides or {}
    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_kind}{tag}".replace("/", "_")
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, name + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def cells_for(mesh_kind: str):
    for arch in ARCHS:
        for sh in SHAPES:
            if sh == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                continue
            yield arch, sh
    for sh in SSSP_SHAPES:
        yield "sssp", sh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (e.g. moe_impl=ep); "
                         "values parsed as python literals when possible")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, _, v = kv.partition("=")
        try:
            import ast
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    todo = []
    for mk in meshes:
        if args.all:
            todo += [(a, s, mk) for a, s in cells_for(mk)]
        else:
            assert args.arch and args.shape, "--arch/--shape or --all"
            todo.append((args.arch, args.shape, mk))

    failures = 0
    for arch, sh, mk in todo:
        try:
            rec = run_cell(arch, sh, mk, args.out, save_hlo=args.save_hlo,
                           overrides=overrides or None, tag=args.tag)
            rf = rec["roofline"]
            mfu = rec["mfu_fraction"]
            mfu_s = f" mfu={mfu:.3f}" if mfu is not None else ""
            temp = rec["memory_analysis"].get("temp_size_in_bytes", 0)
            print(f"[ok] {arch:24s} {sh:16s} {mk:8s} "
                  f"compile={rec['compile_s']:.1f}s "
                  f"dominant={rf['dominant']:10s} "
                  f"bound={rf['bound_time_s']:.4f}s "
                  f"temp={temp/1e9:.1f}GB{mfu_s}", flush=True)
        except Exception:
            failures += 1
            print(f"[FAIL] {arch} {sh} {mk}\n{traceback.format_exc()}",
                  flush=True)
    print(f"done: {len(todo) - failures}/{len(todo)} cells passed")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
