"""ShapeDtypeStruct input stands-ins + shardings for every dry-run cell.

``build_cell(arch, shape_name, mesh)`` returns everything
``jax.jit(...).lower(...)`` needs for one (architecture × input shape)
cell: the step callable, argument ShapeDtypeStructs, and in/out shardings
from the rules engine — with zero device allocation (weak-type-correct
stand-ins only).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config
from repro.models import transformer as T
from repro.sharding import rules
from repro.train.optimizer import OptConfig
from repro.train.state import train_state_shape
from repro.train.step import make_train_step

S32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
BF16 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.bfloat16)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                 # train | prefill | decode
    step_fn: Callable
    args: tuple               # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    cfg: Any
    meta: dict


def _modality_specs(cfg, B, S):
    extras = {}
    if cfg.num_image_tokens:
        extras["image_embeds"] = BF16((B, cfg.num_image_tokens, cfg.d_model))
    if cfg.encoder_segments:
        extras["encoder_frames"] = BF16(
            (B, S // cfg.audio_downsample, cfg.d_model))
    return extras


def default_opt_config(cfg) -> OptConfig:
    # bf16 moments for 1T-class models (see train/optimizer.py docstring)
    big = cfg.param_count() > 50e9
    return OptConfig(moment_dtype="bfloat16" if big else "float32")


def default_grad_accum(cfg, B: int) -> int:
    """Microbatching keeps per-device activation memory inside the HBM
    budget at train_4k's global batch 256 (recorded per cell in §Dry-run)."""
    if cfg.d_model >= 4096:
        return 4
    if cfg.d_model >= 1152:
        return 2
    return 1


def build_cell(arch: str, shape_name: str, mesh, *,
               opt_cfg: OptConfig | None = None,
               grad_accum: int | None = None,
               cfg_overrides: dict | None = None) -> Cell:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt_cfg = opt_cfg or default_opt_config(cfg)
        accum = grad_accum or default_grad_accum(cfg, B)
        state_shape = train_state_shape(cfg, opt_cfg)
        batch = {"tokens": S32((B, S)), "labels": S32((B, S)),
                 **_modality_specs(cfg, B, S)}
        from repro.train.state import TrainState
        state_sh = TrainState(
            params=rules.param_shardings(state_shape.params, mesh),
            opt_state={
                "mu": rules.param_shardings(state_shape.opt_state["mu"], mesh),
                "nu": rules.param_shardings(state_shape.opt_state["nu"], mesh),
                "count": rules.replicated(mesh),
            },
            step=rules.replicated(mesh),
        )
        batch_sh = rules.batch_shardings(batch, mesh)
        step = make_train_step(cfg, opt_cfg, grad_accum=accum)
        return Cell(arch, shape_name, "train", step,
                    (state_shape, batch), (state_sh, batch_sh),
                    (state_sh, None), cfg,
                    {"tokens_per_step": B * S, "grad_accum": accum})

    if shape.kind == "prefill":
        params_shape = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(0), cfg))
        params_sh = rules.param_shardings(params_shape, mesh)
        tokens = S32((B, S))
        extras = _modality_specs(cfg, B, S)

        def prefill_step(params, tokens, **ex):
            return T.prefill(params, tokens, cfg, max_len=S, **ex)

        args = (params_shape, tokens)
        in_sh = (params_sh, rules.batch_shardings(tokens, mesh))
        if extras:
            # fold extras into a positional dict arg for lowering
            def prefill_step(params, tokens, extras):  # noqa: F811
                return T.prefill(params, tokens, cfg, max_len=S, **extras)
            args = (params_shape, tokens, extras)
            in_sh = (params_sh, rules.batch_shardings(tokens, mesh),
                     rules.batch_shardings(extras, mesh))
        return Cell(arch, shape_name, "prefill", prefill_step, args,
                    in_sh, None, cfg, {"tokens_per_step": B * S})

    # ---- decode ----
    params_shape = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    params_sh = rules.param_shardings(params_shape, mesh)
    caches_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, B, S, jnp.bfloat16))
    caches_sh = rules.cache_shardings(caches_shape, mesh)
    token, pos = S32((B, 1)), S32((B,))
    extras = _modality_specs(cfg, B, S)
    img = extras.get("image_embeds")

    def decode_step(params, token, pos, caches, image_embeds=None):
        return T.decode_step(params, token, pos, caches, cfg,
                             image_embeds=image_embeds)

    args = (params_shape, token, pos, caches_shape)
    in_sh = (params_sh, rules.batch_shardings(token, mesh),
             rules.batch_shardings(pos, mesh), caches_sh)
    if img is not None:
        args = args + (img,)
        in_sh = in_sh + (rules.batch_shardings(img, mesh),)
    out_sh = (None, caches_sh, None)
    return Cell(arch, shape_name, "decode", decode_step, args, in_sh,
                out_sh, cfg, {"tokens_per_step": B})
