"""SSSP serving driver: replay open-loop query traces against the serve
subsystem and report latency/throughput/cache metrics.

    PYTHONPATH=src python -m repro.launch.sssp_serve --smoke

Mirrors launch/serve.py's shape (queue -> batcher -> engine, per-request
latency + aggregate throughput), but for shortest-path queries: per
scenario (uniform / zipf / p2p, see repro/serve/workload.py) the driver
registers the graphs (with ALT landmarks), generates an open-loop arrival
trace, and replays it in wall-clock time — events are submitted when
their arrival time passes, the scheduler ticks whenever work is queued,
and latency = completion - arrival (queueing included, the open-loop
penalty for falling behind).

Reported per scenario: p50/p99/max latency, queries/s, mean batch
occupancy, dedup savings, answers-by-path, cache hit rate.

``--verify`` (default under ``--smoke``) re-solves every distinct
(graph, source) with the ``serial`` engine and asserts each served answer
is bitwise-equal — the end-to-end form of the serving exactness
guarantee (tests/test_serve.py holds the per-component forms).

``--devices P`` emulates a P-device mesh (forced host devices, fixed
before jax initializes — the MPI-procs analogue) and ``--shard-threshold
N`` routes graphs with >= N vertices through the vertex-partitioned
sharded engines (serve/dispatch.py); ``--verify`` covers the sharded
answers identically, which is how CI's ``--smoke --devices 4`` leg pins
the sharded route to the bitwise guarantee.

``--chaos`` replays a **seeded fault schedule** (serve/faults.py)
through a deterministic closed-loop replay instead of the wall-clock
one: a mixed static + dynamic (churn) trace is submitted in fixed-size
chunks with the event clock as ``tick(now=)``, while the fault plan
fires injected solve/staging failures, mid-tick evictions, poisoned
mutation batches, and sweep clips at the scheduler's seams.  The
verifier then asserts (1) every answer carries a typed status, (2)
every ``exact=True`` answer is bitwise-equal to a fresh ``serial``
solve on the answer-time graph version, (3) degraded p2p answers
bracket the true distance, and (4) every fired fault site surfaced
through its expected status (or the retry counters) — see
README.md §Robustness.  ``--chaos --smoke`` is CI's chaos-smoke entry
point.
"""
from __future__ import annotations

import os
import sys

# Device count must be fixed before jax initializes; parse --devices by
# hand (same pattern as benchmarks/run_bench.py).
if __name__ == "__main__" and "--help" not in sys.argv and "-h" not in sys.argv:
    _n = 1
    for _i, _a in enumerate(sys.argv):
        try:
            if _a == "--devices":
                _n = int(sys.argv[_i + 1])
            elif _a.startswith("--devices="):
                _n = int(_a.split("=", 1)[1])
        except (IndexError, ValueError):
            break
    if _n > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n} "
            + os.environ.get("XLA_FLAGS", ""))

import argparse
import time

import numpy as np

from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.serve import (STATUS_OK, STATUSES, DispatchPolicy, DistanceCache,
                         GraphRegistry, LatencyRecorder, MicroBatchScheduler,
                         MutationEvent, QueryRejected, SCENARIOS,
                         make_churn_trace, make_trace, set_default_policy)
from repro.serve.dispatch import DEFAULT_SHARD_THRESHOLD


def replay(sched: MicroBatchScheduler, events) -> list:
    """Wall-clock open-loop replay; returns Answers with done_at stamped.
    A submit rejected by bounded-queue backpressure is dropped (counted
    in the scheduler's ``submissions_rejected``)."""
    events = sorted(events, key=lambda e: e.arrival)
    t0 = time.perf_counter()
    i, answers = 0, []
    while i < len(events) or sched.pending:
        now = time.perf_counter() - t0
        while i < len(events) and events[i].arrival <= now:
            e = events[i]
            try:
                sched.submit(e.graph, e.source, e.target, arrival=e.arrival,
                             deadline=getattr(e, "deadline", None))
            except QueryRejected:
                pass
            i += 1
        if sched.pending:
            out = sched.tick(now)
            done = time.perf_counter() - t0
            for a in out:
                a.done_at = done
            answers.extend(out)
        elif i < len(events):
            time.sleep(min(events[i].arrival - now, 1e-3))
    return answers


def verify_answers(answers, graphs_by_name, *, allow=()) -> int:
    """Assert every ``exact=True`` answer is bitwise-equal to a fresh
    serial solve (degraded p2p answers are instead checked to BRACKET
    the serial distance); returns the number of distinct (graph, source)
    rows checked.  Non-ok statuses listed in ``allow`` are skipped; any
    other failure answer aborts — in a fault-free replay every answer
    must be exact."""
    rows = {}

    def serial_row(graph: str, source: int) -> np.ndarray:
        key = (graph, source)
        if key not in rows:
            rows[key] = shortest_paths(
                graphs_by_name[graph], source, engine="serial").dist
        return rows[key]

    for a in answers:
        q = a.query
        if a.status not in STATUSES:
            raise SystemExit(f"unknown answer status {a.status!r} for {q}")
        if a.status != STATUS_OK:
            if a.status in allow:
                continue
            raise SystemExit(
                f"scheduler returned a {a.status} answer for {q}: "
                f"{a.error}")
        if not a.exact:
            # degraded answers are approximate by contract; a p2p bound
            # pair must still bracket the true distance (admissibility).
            if q.target is not None and a.bounds is not None:
                lb, ub = a.bounds
                want = float(serial_row(q.graph, q.source)[q.target])
                if not (lb <= want * (1 + 1e-4) + 1e-3
                        and want <= ub * (1 + 1e-4) + 1e-3):
                    raise SystemExit(
                        f"degraded bounds ({lb}, {ub}) do not bracket "
                        f"serial {want} for {q}")
            continue
        ref = serial_row(q.graph, q.source)
        if q.target is None:
            if not np.array_equal(a.value, ref):
                raise SystemExit(
                    f"row mismatch vs serial: {q} (via {a.via})")
        else:
            got, want = np.float32(a.value), ref[q.target]
            ok = got == want or (np.isinf(got) and np.isinf(want))
            if not ok:
                raise SystemExit(
                    f"dist mismatch vs serial: {q} (via {a.via}): "
                    f"served {got!r}, serial {want!r}")
    return len(rows)


def run_chaos(args, dispatch) -> None:
    """Seeded chaos replay (see module docstring).  Deterministic closed
    loop: events are submitted in fixed-size chunks with the event clock
    as ``tick(now=)``, so a given (seed, chaos-seed, rates) triple
    replays the exact same fault schedule and answer stream every run."""
    from collections import Counter

    from repro.dynamic import DynamicGraph
    from repro.serve import FaultPlan

    n = args.n or (256 if args.smoke else 2000)
    queries = args.queries or (80 if args.smoke else 400)
    scale = args.fault_rate
    # per-site probe volumes differ by orders of magnitude (solve/clip
    # probe every engine call, mutate only per drained batch), so the
    # multipliers are tuned so every site fires a few times per smoke
    # replay — the reconciliation below is vacuous for a silent site.
    plan = FaultPlan(seed=args.chaos_seed, rates={
        "solve": 0.8 * scale, "stage": 0.4 * scale, "evict": 0.6 * scale,
        "mutate": min(1.0, 4.0 * scale), "clip": 0.5 * scale})

    statics = [(f"g{i}", C.random_csr_graph(n, 3 * n, seed=args.seed + i))
               for i in range(args.graphs)]
    graphs_by_name = dict(statics)
    dyn = DynamicGraph(C.random_csr_graph(n, 3 * n, seed=args.seed + 77))
    registry = GraphRegistry()
    cache = DistanceCache(capacity=args.cache_rows)
    sched = MicroBatchScheduler(
        registry, cache, max_batch=args.batch, dispatch=dispatch,
        faults=plan, retry_budget=2, max_queue=args.max_queue)
    for name, cg in statics:
        registry.register(name, cg, landmarks=args.landmarks,
                          landmark_seed=args.seed)
    registry.register("dyn0", dyn, landmarks=args.landmarks,
                      landmark_seed=args.seed)

    events = make_trace(
        "p2p", [(name, cg.n) for name, cg in statics], num_queries=queries,
        rate=1000.0, seed=args.seed, deadline=args.deadline)
    events += make_churn_trace(
        [("dyn0", dyn.base)], num_events=queries // 2, rate=1000.0,
        mutate_frac=0.25, p2p_frac=0.3, seed=args.seed + 1,
        hot_seed=args.seed + 101)
    events.sort(key=lambda e: e.arrival)

    # serial reference rows, memoized per (graph, version, source);
    # dynamic versions are immutable once committed, so verifying each
    # tick's answers at the then-current version is exact.
    rows: dict = {}

    def serial_row(graph: str, source: int) -> np.ndarray:
        if graph == "dyn0":
            key = (graph, dyn.version, source)
            g = dyn.snapshot() if key not in rows else None
        else:
            key = (graph, 0, source)
            g = graphs_by_name[graph]
        if key not in rows:
            rows[key] = shortest_paths(g, source, engine="serial").dist
        return rows[key]

    def check_tick(out) -> None:
        for a in out:
            q = a.query
            if a.status not in STATUSES:
                raise SystemExit(f"unknown status {a.status!r} for {q}")
            if a.status != STATUS_OK or a.via == "mutate" or not a.exact:
                continue
            ref = serial_row(q.graph, q.source)
            if q.target is None:
                if not np.array_equal(a.value, ref):
                    raise SystemExit(f"row mismatch vs serial: {q} "
                                     f"(via {a.via})")
            else:
                got, want = np.float32(a.value), ref[q.target]
                if not (got == want or (np.isinf(got) and np.isinf(want))):
                    raise SystemExit(
                        f"dist mismatch vs serial: {q} (via {a.via}): "
                        f"served {got!r}, serial {want!r}")

    answers, rejected, i = [], 0, 0
    submitted = 0
    max_iters = 8 * len(events) + 256   # progress backstop (backoff ticks)
    iters = 0
    while i < len(events) or sched.pending:
        iters += 1
        if iters > max_iters:
            raise SystemExit(
                f"chaos replay made no progress: {sched.pending} pending "
                f"after {iters} ticks")
        now = events[i].arrival if i < len(events) else events[-1].arrival
        chunk = 0
        while i < len(events) and chunk < 8:
            e = events[i]
            now = e.arrival
            try:
                if isinstance(e, MutationEvent):
                    sched.submit_mutation(e.graph, e.op, e.u, e.v, e.w,
                                          arrival=e.arrival)
                else:
                    sched.submit(e.graph, e.source, e.target,
                                 arrival=e.arrival, deadline=e.deadline)
                submitted += 1
            except QueryRejected:
                rejected += 1
            i += 1
            chunk += 1
        out = sched.tick(now)
        for a in out:
            a.done_at = now
        check_tick(out)     # verify at the tick's graph version
        answers.extend(out)

    # every accepted submission must be answered exactly once — the
    # scheduler made progress through every injected fault.
    if len(answers) != submitted:
        raise SystemExit(f"progress violation: {submitted} accepted "
                         f"submissions but {len(answers)} answers")
    statuses = Counter(a.status for a in answers)
    fired = plan.counts()
    print(f"[sssp_serve] chaos: {len(answers)} answers "
          f"({rejected} rejected at submit) | statuses {dict(statuses)} | "
          f"faults fired {fired} (probes {plan.summary()['probes']})",
          flush=True)

    # reconcile: every fired fault site must have surfaced through its
    # typed status (or, for retried transients, the exception counter).
    recon = []
    if fired["evict"] and not statuses["graph_gone"]:
        recon.append("evict fired but no graph_gone answers")
    if fired["mutate"] and not statuses["rejected"]:
        recon.append("mutate fired but no rejected mutation acks")
    if fired["clip"] and not statuses["not_converged"]:
        recon.append("clip fired but no not_converged answers")
    if sched.solve_exceptions < fired["solve"] + fired["stage"]:
        recon.append(
            f"{fired['solve']}+{fired['stage']} solve/stage faults fired "
            f"but only {sched.solve_exceptions} exceptions were caught")
    if recon:
        raise SystemExit("chaos reconciliation failed: " + "; ".join(recon))
    print(f"[sssp_serve] chaos: verified {len(rows)} distinct serial rows "
          f"bitwise; retries {sched.retries}, solve exceptions "
          f"{sched.solve_exceptions}, deadline expired "
          f"{sched.deadline_expired}; all fired sites reconciled",
          flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graphs, short traces, verify on (CI-sized)")
    ap.add_argument("--scenario", default="all",
                    choices=("all",) + SCENARIOS)
    ap.add_argument("--n", type=int, default=None,
                    help="vertices per graph (default 10000; smoke 256)")
    ap.add_argument("--graphs", type=int, default=2,
                    help="number of registered graphs")
    ap.add_argument("--queries", type=int, default=None,
                    help="queries per scenario (default 400; smoke 60)")
    ap.add_argument("--rate", type=float, default=None,
                    help="open-loop arrival rate, queries/s "
                         "(default 500; smoke 2000)")
    ap.add_argument("--batch", type=int, default=16,
                    help="max distinct sources per tick per graph")
    ap.add_argument("--landmarks", type=int, default=8,
                    help="ALT landmarks per graph (0 disables)")
    ap.add_argument("--cache-rows", type=int, default=256)
    ap.add_argument("--devices", type=int, default=1,
                    help="mesh size for the sharded route (host devices "
                         "are forced before jax init; 1 = never shard)")
    ap.add_argument("--shard-threshold", type=int,
                    default=DEFAULT_SHARD_THRESHOLD,
                    help="route graphs with >= this many vertices through "
                         "the sharded engines (needs --devices > 1)")
    ap.add_argument("--verify", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="bitwise-check every answer vs serial "
                         "(default: on under --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-query deadline in seconds after arrival "
                         "(None = queries never expire)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded-queue admission: reject/shed submits "
                         "past this many pending queries")
    ap.add_argument("--chaos", action="store_true",
                    help="deterministic seeded fault-injection replay "
                         "(serve/faults.py); verifies every exact answer "
                         "bitwise and reconciles fired faults vs statuses")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="fault-plan seed (independent of --seed)")
    ap.add_argument("--fault-rate", type=float, default=0.1,
                    help="chaos fault-rate scale factor across sites")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="capture observability: Chrome trace JSON to "
                         "PATH, per-solve cost records to "
                         "PATH-with-.cost.jsonl; both are schema-"
                         "validated at exit (repro/obs)")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="serve through the measured cost model fitted "
                         "from this CALIBRATION.json (repro/tune) "
                         "instead of the hard-coded thresholds; "
                         "out-of-support queries still fall back to "
                         "them")
    args = ap.parse_args(argv)

    capture = None
    if args.trace_out:
        from repro.obs import install_capture
        capture = install_capture()

    n = args.n or (256 if args.smoke else 10000)
    queries = args.queries or (60 if args.smoke else 400)
    rate = args.rate or (2000.0 if args.smoke else 500.0)
    verify = args.verify if args.verify is not None else args.smoke
    scenarios = SCENARIOS if args.scenario == "all" else (args.scenario,)
    if args.calibration:
        from repro.tune.model import load_model
        from repro.tune.select import TunedPolicy
        dispatch = TunedPolicy(load_model(args.calibration),
                               shard_threshold=args.shard_threshold,
                               nprocs=args.devices)
        print(f"[sssp_serve] tuned dispatch from {args.calibration}: "
              f"{dispatch.model.coverage()['engines']}", flush=True)
    else:
        dispatch = DispatchPolicy(shard_threshold=args.shard_threshold,
                                  nprocs=args.devices)
    set_default_policy(dispatch)    # engine="auto" callers agree with us
    if dispatch.nprocs > 1:
        print(f"[sssp_serve] sharded route: {dispatch.nprocs} devices, "
              f"threshold n>={args.shard_threshold}", flush=True)

    if args.chaos:
        run_chaos(args, dispatch)
        if capture is not None:
            _finalize_capture(capture, args.trace_out)
        print("[sssp_serve] done", flush=True)
        return

    graphs = [(f"g{i}", C.random_csr_graph(n, 3 * n, seed=args.seed + i))
              for i in range(args.graphs)]
    graphs_by_name = dict(graphs)
    sizes = [(name, cg.n) for name, cg in graphs]

    for scen in scenarios:
        # fresh serving state per scenario so metrics don't bleed across
        registry = GraphRegistry()
        cache = DistanceCache(capacity=args.cache_rows)
        sched = MicroBatchScheduler(registry, cache, max_batch=args.batch,
                                    dispatch=dispatch,
                                    max_queue=args.max_queue)
        t0 = time.perf_counter()
        for name, cg in graphs:
            registry.register(name, cg, landmarks=args.landmarks,
                              landmark_seed=args.seed)
        prep_s = time.perf_counter() - t0

        events = make_trace(scen, sizes, num_queries=queries, rate=rate,
                            seed=args.seed, deadline=args.deadline)
        answers = replay(sched, events)
        rec = LatencyRecorder()
        for a in answers:
            rec.observe(a, a.done_at)
        s, lat = sched.stats(), rec.summary()
        print(f"[sssp_serve] {scen}: {lat['queries']} queries "
              f"({args.graphs} graphs, n={n}, prep {prep_s:.2f}s) | "
              f"p50 {lat['p50_ms']:.1f} ms, p99 {lat['p99_ms']:.1f} ms, "
              f"{lat['qps']:.0f} q/s | "
              f"occupancy {s['mean_occupancy']:.2f}, "
              f"dedup saved {s['dedup_saved']}, "
              f"cache hit rate {s['cache']['hit_rate']:.2f} | "
              f"via {s['answered_via']}", flush=True)
        if "queue_p50_ms" in lat:
            # end-to-end latency split: time queued before the serving
            # tick vs time inside it (LatencyRecorder's two components)
            print(f"[sssp_serve] {scen}: queue wait "
                  f"p50 {lat['queue_p50_ms']:.1f} ms / "
                  f"p99 {lat['queue_p99_ms']:.1f} ms | service "
                  f"p50 {lat['service_p50_ms']:.1f} ms / "
                  f"p99 {lat['service_p99_ms']:.1f} ms", flush=True)
        if s["sharded_batches"] or s["sharded_p2p"]:
            print(f"[sssp_serve] {scen}: sharded route "
                  f"{s['sharded_batches']} batches + {s['sharded_p2p']} "
                  f"p2p ({s['sharded_sources']} sources, "
                  f"{s['sharded_edges']} edges relaxed) on "
                  f"{dispatch.nprocs} devices", flush=True)
        # end-of-run accounting: the cache and registry counters the
        # scheduler aggregates but the per-scenario line above elides
        c, r = s["cache"], s["registry"]
        print(f"[sssp_serve] {scen}: cache {c['hits']} hits / "
              f"{c['misses']} misses / {c['evictions']} evictions "
              f"({c['rows']}/{c['capacity']} rows) | registry "
              f"{r['graphs']} graphs, {r['bytes_in_use'] / 1e6:.1f} MB "
              f"in use (budget "
              f"{'none' if r['byte_budget'] is None else r['byte_budget']}"
              f"{', OVER' if r['over_budget'] else ''}), "
              f"{r['registered']} registered / {r['evicted']} evicted",
              flush=True)
        if (s["shed"] or s["deadline_expired"] or s["submissions_rejected"]
                or s["degraded_p2p"] or s["degraded_batch"]):
            print(f"[sssp_serve] {scen}: robustness: "
                  f"{s['submissions_rejected']} rejected at submit, "
                  f"{s['shed']} shed, {s['deadline_expired']} expired, "
                  f"{s['degraded_p2p']}+{s['degraded_batch']} degraded | "
                  f"statuses {s['answered_status']}", flush=True)
        if verify:
            # deadline / bounded-queue runs legitimately produce typed
            # failures; every exact answer must still match serial.
            allow = (("deadline_exceeded", "rejected")
                     if (args.deadline is not None
                         or args.max_queue is not None) else ())
            checked = verify_answers(answers, graphs_by_name, allow=allow)
            print(f"[sssp_serve] {scen}: verified bitwise vs serial "
                  f"({checked} distinct rows)", flush=True)

    if capture is not None:
        _finalize_capture(capture, args.trace_out)
    print("[sssp_serve] done", flush=True)


def _finalize_capture(capture, path: str) -> None:
    """Write + validate the observability artifacts; abort on schema or
    answer-chain violations so CI's obs-smoke job fails loudly."""
    from repro.obs import cost_path_for, finalize_capture

    tr, cl = capture
    errs = finalize_capture(tr, cl, path)
    print(f"[sssp_serve] trace: {len(tr.spans)} spans, "
          f"{len(tr.instants)} instants -> {path} | "
          f"{len(cl.records)} cost records -> {cost_path_for(path)}",
          flush=True)
    if errs:
        for e in errs[:20]:
            print(f"[sssp_serve] trace INVALID: {e}", flush=True)
        raise SystemExit(f"observability capture invalid "
                         f"({len(errs)} errors)")
    print("[sssp_serve] trace: schema + answer chains valid", flush=True)


if __name__ == "__main__":
    main()
