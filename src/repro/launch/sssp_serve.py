"""SSSP serving driver: replay open-loop query traces against the serve
subsystem and report latency/throughput/cache metrics.

    PYTHONPATH=src python -m repro.launch.sssp_serve --smoke

Mirrors launch/serve.py's shape (queue -> batcher -> engine, per-request
latency + aggregate throughput), but for shortest-path queries: per
scenario (uniform / zipf / p2p, see repro/serve/workload.py) the driver
registers the graphs (with ALT landmarks), generates an open-loop arrival
trace, and replays it in wall-clock time — events are submitted when
their arrival time passes, the scheduler ticks whenever work is queued,
and latency = completion - arrival (queueing included, the open-loop
penalty for falling behind).

Reported per scenario: p50/p99/max latency, queries/s, mean batch
occupancy, dedup savings, answers-by-path, cache hit rate.

``--verify`` (default under ``--smoke``) re-solves every distinct
(graph, source) with the ``serial`` engine and asserts each served answer
is bitwise-equal — the end-to-end form of the serving exactness
guarantee (tests/test_serve.py holds the per-component forms).

``--devices P`` emulates a P-device mesh (forced host devices, fixed
before jax initializes — the MPI-procs analogue) and ``--shard-threshold
N`` routes graphs with >= N vertices through the vertex-partitioned
sharded engines (serve/dispatch.py); ``--verify`` covers the sharded
answers identically, which is how CI's ``--smoke --devices 4`` leg pins
the sharded route to the bitwise guarantee.
"""
from __future__ import annotations

import os
import sys

# Device count must be fixed before jax initializes; parse --devices by
# hand (same pattern as benchmarks/run_bench.py).
if __name__ == "__main__" and "--help" not in sys.argv and "-h" not in sys.argv:
    _n = 1
    for _i, _a in enumerate(sys.argv):
        try:
            if _a == "--devices":
                _n = int(sys.argv[_i + 1])
            elif _a.startswith("--devices="):
                _n = int(_a.split("=", 1)[1])
        except (IndexError, ValueError):
            break
    if _n > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n} "
            + os.environ.get("XLA_FLAGS", ""))

import argparse
import time

import numpy as np

from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.serve import (DispatchPolicy, DistanceCache, GraphRegistry,
                         LatencyRecorder, MicroBatchScheduler, SCENARIOS,
                         make_trace, set_default_policy)
from repro.serve.dispatch import DEFAULT_SHARD_THRESHOLD


def replay(sched: MicroBatchScheduler, events) -> list:
    """Wall-clock open-loop replay; returns Answers with done_at stamped."""
    events = sorted(events, key=lambda e: e.arrival)
    t0 = time.perf_counter()
    i, answers = 0, []
    while i < len(events) or sched.pending:
        now = time.perf_counter() - t0
        while i < len(events) and events[i].arrival <= now:
            e = events[i]
            sched.submit(e.graph, e.source, e.target, arrival=e.arrival)
            i += 1
        if sched.pending:
            out = sched.tick()
            done = time.perf_counter() - t0
            for a in out:
                a.done_at = done
            answers.extend(out)
        elif i < len(events):
            time.sleep(min(events[i].arrival - now, 1e-3))
    return answers


def verify_answers(answers, graphs_by_name) -> int:
    """Assert every served answer is bitwise-equal to a fresh serial
    solve; returns the number of distinct (graph, source) rows checked."""
    rows = {}
    for a in answers:
        q = a.query
        if a.via == "error":
            raise SystemExit(f"scheduler returned an error answer for {q}")
        key = (q.graph, q.source)
        if key not in rows:
            rows[key] = shortest_paths(
                graphs_by_name[q.graph], q.source, engine="serial").dist
        ref = rows[key]
        if q.target is None:
            if not np.array_equal(a.value, ref):
                raise SystemExit(
                    f"row mismatch vs serial: {q} (via {a.via})")
        else:
            got, want = np.float32(a.value), ref[q.target]
            ok = got == want or (np.isinf(got) and np.isinf(want))
            if not ok:
                raise SystemExit(
                    f"dist mismatch vs serial: {q} (via {a.via}): "
                    f"served {got!r}, serial {want!r}")
    return len(rows)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graphs, short traces, verify on (CI-sized)")
    ap.add_argument("--scenario", default="all",
                    choices=("all",) + SCENARIOS)
    ap.add_argument("--n", type=int, default=None,
                    help="vertices per graph (default 10000; smoke 256)")
    ap.add_argument("--graphs", type=int, default=2,
                    help="number of registered graphs")
    ap.add_argument("--queries", type=int, default=None,
                    help="queries per scenario (default 400; smoke 60)")
    ap.add_argument("--rate", type=float, default=None,
                    help="open-loop arrival rate, queries/s "
                         "(default 500; smoke 2000)")
    ap.add_argument("--batch", type=int, default=16,
                    help="max distinct sources per tick per graph")
    ap.add_argument("--landmarks", type=int, default=8,
                    help="ALT landmarks per graph (0 disables)")
    ap.add_argument("--cache-rows", type=int, default=256)
    ap.add_argument("--devices", type=int, default=1,
                    help="mesh size for the sharded route (host devices "
                         "are forced before jax init; 1 = never shard)")
    ap.add_argument("--shard-threshold", type=int,
                    default=DEFAULT_SHARD_THRESHOLD,
                    help="route graphs with >= this many vertices through "
                         "the sharded engines (needs --devices > 1)")
    ap.add_argument("--verify", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="bitwise-check every answer vs serial "
                         "(default: on under --smoke)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    n = args.n or (256 if args.smoke else 10000)
    queries = args.queries or (60 if args.smoke else 400)
    rate = args.rate or (2000.0 if args.smoke else 500.0)
    verify = args.verify if args.verify is not None else args.smoke
    scenarios = SCENARIOS if args.scenario == "all" else (args.scenario,)
    dispatch = DispatchPolicy(shard_threshold=args.shard_threshold,
                              nprocs=args.devices)
    set_default_policy(dispatch)    # engine="auto" callers agree with us
    if dispatch.nprocs > 1:
        print(f"[sssp_serve] sharded route: {dispatch.nprocs} devices, "
              f"threshold n>={args.shard_threshold}", flush=True)

    graphs = [(f"g{i}", C.random_csr_graph(n, 3 * n, seed=args.seed + i))
              for i in range(args.graphs)]
    graphs_by_name = dict(graphs)
    sizes = [(name, cg.n) for name, cg in graphs]

    for scen in scenarios:
        # fresh serving state per scenario so metrics don't bleed across
        registry = GraphRegistry()
        cache = DistanceCache(capacity=args.cache_rows)
        sched = MicroBatchScheduler(registry, cache, max_batch=args.batch,
                                    dispatch=dispatch)
        t0 = time.perf_counter()
        for name, cg in graphs:
            registry.register(name, cg, landmarks=args.landmarks,
                              landmark_seed=args.seed)
        prep_s = time.perf_counter() - t0

        events = make_trace(scen, sizes, num_queries=queries, rate=rate,
                            seed=args.seed)
        answers = replay(sched, events)
        rec = LatencyRecorder()
        for a in answers:
            rec.observe(a, a.done_at)
        s, lat = sched.stats(), rec.summary()
        print(f"[sssp_serve] {scen}: {lat['queries']} queries "
              f"({args.graphs} graphs, n={n}, prep {prep_s:.2f}s) | "
              f"p50 {lat['p50_ms']:.1f} ms, p99 {lat['p99_ms']:.1f} ms, "
              f"{lat['qps']:.0f} q/s | "
              f"occupancy {s['mean_occupancy']:.2f}, "
              f"dedup saved {s['dedup_saved']}, "
              f"cache hit rate {s['cache']['hit_rate']:.2f} | "
              f"via {s['answered_via']}", flush=True)
        if s["sharded_batches"] or s["sharded_p2p"]:
            print(f"[sssp_serve] {scen}: sharded route "
                  f"{s['sharded_batches']} batches + {s['sharded_p2p']} "
                  f"p2p ({s['sharded_sources']} sources, "
                  f"{s['sharded_edges']} edges relaxed) on "
                  f"{dispatch.nprocs} devices", flush=True)
        # end-of-run accounting: the cache and registry counters the
        # scheduler aggregates but the per-scenario line above elides
        c, r = s["cache"], s["registry"]
        print(f"[sssp_serve] {scen}: cache {c['hits']} hits / "
              f"{c['misses']} misses / {c['evictions']} evictions "
              f"({c['rows']}/{c['capacity']} rows) | registry "
              f"{r['graphs']} graphs, {r['bytes_in_use'] / 1e6:.1f} MB "
              f"in use (budget "
              f"{'none' if r['byte_budget'] is None else r['byte_budget']}"
              f"{', OVER' if r['over_budget'] else ''}), "
              f"{r['registered']} registered / {r['evicted']} evicted",
              flush=True)
        if verify:
            checked = verify_answers(answers, graphs_by_name)
            print(f"[sssp_serve] {scen}: verified bitwise vs serial "
                  f"({checked} distinct rows)", flush=True)

    print("[sssp_serve] done", flush=True)


if __name__ == "__main__":
    main()
