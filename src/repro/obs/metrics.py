"""Process-local metrics registry: counters, gauges, histograms.

One uniform namespace for every counter the serving stack keeps —
`DistanceCache`, `GraphRegistry`, and `MicroBatchScheduler` all hang
their counters off a `MetricsRegistry` and derive their legacy
``stats()`` dicts from `snapshot()`.

Design constraints (see ISSUE 9):

- **Process-local, not global-only.**  Each component owns (or is
  handed) a registry instance, so two schedulers in one process never
  alias each other's counters.  A module-level `default_registry()`
  exists for process-wide series — the jit-retrace counter lives
  there, because jitted engine functions are module-level objects.
- **Deterministic snapshots.**  `snapshot()` returns a flat
  ``{qualified_name: number}`` dict in sorted-key order containing
  only event counts and set gauges — no wall-clock values — so two
  same-seed replays produce byte-identical snapshots (the chaos
  determinism test relies on this).
- **Cheap increments.**  `Counter.inc` is one int add; the serving hot
  path calls it unconditionally, so it must stay trivial.

Series are keyed on ``(name, sorted(labels))``; the qualified name
renders as ``name{k=v,...}``.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterator, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "mark_trace",
    "count_traces",
]


def _qualify(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonically increasing event count."""

    __slots__ = ("name", "labels", "_value")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._value = 0

    def inc(self, k: int = 1) -> None:
        self._value += k

    @property
    def value(self) -> int:
        return self._value

    def reset(self) -> None:
        self._value = 0


class Gauge:
    """Point-in-time value: either set explicitly or computed at
    snapshot time via a callback (``fn=``)."""

    __slots__ = ("name", "labels", "_value", "_fn")

    def __init__(
        self,
        name: str,
        labels: Tuple[Tuple[str, str], ...] = (),
        fn: Optional[Callable[[], float]] = None,
    ):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        self._value = float(v)

    def add(self, v: float) -> None:
        self._value += float(v)

    @property
    def value(self) -> float:
        if self._fn is not None:
            return float(self._fn())
        return self._value


class Histogram:
    """Streaming distribution summary.

    Keeps every observation (serving runs are bounded, and the latency
    recorder needs exact p50/p99), exposing count/sum/min/max and
    percentile helpers.  `snapshot()` reports only the count — the
    observed values themselves are typically wall-times and would break
    snapshot determinism.
    """

    __slots__ = ("name", "labels", "_values")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self._values: list = []

    def observe(self, v: float) -> None:
        self._values.append(float(v))

    @property
    def count(self) -> int:
        return len(self._values)

    @property
    def sum(self) -> float:
        return float(sum(self._values))

    @property
    def min(self) -> float:
        return min(self._values) if self._values else 0.0

    @property
    def max(self) -> float:
        return max(self._values) if self._values else 0.0

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over everything observed so far."""
        if not self._values:
            return 0.0
        xs = sorted(self._values)
        idx = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
        return xs[idx]

    def summary(self) -> Dict[str, float]:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50.0),
            "p99": self.percentile(99.0),
        }


class MetricsRegistry:
    """Get-or-create store of named, labeled series."""

    def __init__(self) -> None:
        self._series: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], object] = {}

    def _key(self, name: str, labels: Dict[str, str]) -> Tuple[str, Tuple[Tuple[str, str], ...]]:
        return (name, tuple(sorted((str(k), str(v)) for k, v in labels.items())))

    def counter(self, name: str, **labels: str) -> Counter:
        key = self._key(name, labels)
        s = self._series.get(key)
        if s is None:
            s = Counter(name, key[1])
            self._series[key] = s
        elif not isinstance(s, Counter):
            raise TypeError(f"series {name!r} already registered as {type(s).__name__}")
        return s

    def gauge(self, name: str, fn: Optional[Callable[[], float]] = None, **labels: str) -> Gauge:
        key = self._key(name, labels)
        s = self._series.get(key)
        if s is None:
            s = Gauge(name, key[1], fn=fn)
            self._series[key] = s
        elif not isinstance(s, Gauge):
            raise TypeError(f"series {name!r} already registered as {type(s).__name__}")
        return s

    def histogram(self, name: str, **labels: str) -> Histogram:
        key = self._key(name, labels)
        s = self._series.get(key)
        if s is None:
            s = Histogram(name, key[1])
            self._series[key] = s
        elif not isinstance(s, Histogram):
            raise TypeError(f"series {name!r} already registered as {type(s).__name__}")
        return s

    def series(self) -> Iterator[object]:
        return iter(self._series.values())

    def find(self, name: str) -> list:
        """Every series registered under ``name`` (any label set)."""
        return [s for (n, _), s in self._series.items() if n == name]

    def snapshot(self) -> Dict[str, float]:
        """Flat, sorted, deterministic view of every series.

        Counters report their count, gauges their current value,
        histograms their observation count (values may be wall-times
        and are deliberately excluded — see class docstring).
        """
        out: Dict[str, float] = {}
        for (name, labels), s in self._series.items():
            q = _qualify(name, labels)
            if isinstance(s, Counter):
                out[q] = s.value
            elif isinstance(s, Gauge):
                out[q] = s.value
            elif isinstance(s, Histogram):
                out[q + ".count"] = s.count
        return dict(sorted(out.items()))

    def reset(self) -> None:
        for s in self._series.values():
            if isinstance(s, Counter):
                s.reset()
            elif isinstance(s, Gauge):
                if s._fn is None:
                    s.set(0.0)
            elif isinstance(s, Histogram):
                s._values.clear()


_DEFAULT: MetricsRegistry = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry.

    Holds series whose natural scope is the process, not a component
    instance — most importantly ``jit.retrace{fn=...}``, because the
    jitted engine functions it instruments are module-level objects.
    """
    return _DEFAULT


def mark_trace(fn_name: str) -> None:
    """Record one jit trace of ``fn_name``.

    Called from *inside* jitted function bodies: the Python body only
    executes while jax is tracing, so each call marks exactly one
    (re)trace and costs nothing on cached executions.
    """
    _DEFAULT.counter("jit.retrace", fn=fn_name).inc()


def trace_count(fn_name: str) -> int:
    """How many times ``fn_name`` has been traced so far."""
    return _DEFAULT.counter("jit.retrace", fn=fn_name).value


def count_traces(fn_name: str) -> Callable:
    """Wrap a to-be-jitted callable so each trace of it is counted.

    Used on the sweep functions returned by the memoized kernel
    factories: the factory's ``lru_cache`` keeps the wrapper's identity
    stable, so wrapping does not itself cause retraces.
    """

    def deco(fn: Callable) -> Callable:
        def wrapper(*args, **kwargs):
            mark_trace(fn_name)
            return fn(*args, **kwargs)

        wrapper.__name__ = getattr(fn, "__name__", fn_name)
        wrapper.__qualname__ = wrapper.__name__
        wrapper.__wrapped__ = fn
        return wrapper

    return deco
