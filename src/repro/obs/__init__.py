"""Observability layer: unified metrics, solve-level tracing, cost records.

- `repro.obs.metrics` — process-local counter/gauge/histogram registry
  with labeled series and a deterministic `snapshot()` contract; the
  serving components' `stats()` dicts are views over it.
- `repro.obs.trace` — hierarchical spans (tick → batch_solve/p2p_solve/
  repair/stage/mutate) with Chrome-trace + JSONL export, an injected
  clock, and a no-op singleton when disabled.
- `repro.obs.profile` — per-solve cost records
  ``(engine, statics, shape) → wall_ms, sweeps, edges``, the training
  data for ROADMAP item 4's measured cost model.
- `repro.obs.validate` — schema + answer-chain validation for the
  exported artifacts (also a CLI for CI).
- `repro.obs.capture` — install/finalize helpers shared by the launch
  drivers' ``--trace-out`` paths.
"""
from .capture import cost_path_for, finalize_capture, install_capture
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    count_traces,
    default_registry,
    mark_trace,
    trace_count,
)
from .profile import (CostLog, CostRecord, NULL_COST_LOG, backend_info,
                      get_cost_log, set_cost_log)
from .trace import NULL_TRACER, NullTracer, Span, Tracer, get_tracer, set_tracer

__all__ = [
    "cost_path_for",
    "finalize_capture",
    "install_capture",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "count_traces",
    "default_registry",
    "mark_trace",
    "trace_count",
    "backend_info",
    "CostLog",
    "CostRecord",
    "NULL_COST_LOG",
    "get_cost_log",
    "set_cost_log",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "get_tracer",
    "set_tracer",
]
