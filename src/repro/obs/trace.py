"""Hierarchical solve-level tracing with Chrome-trace export.

Span taxonomy (parent → child):

    tick ─┬─ mutate        graph mutation applied ahead of solves
          ├─ repair        incremental distance repair after a mutation
          ├─ stage         registry staging of device operands
          ├─ batch_solve   one multisource engine solve (args.qids)
          └─ p2p_solve     one target= early-exit solve (args.qids)

plus instant events ``submit`` (query admitted) and ``answer`` (answer
emitted), so an exact answer's chain submit → tick → solve → answer is
reconstructible from timestamps + qids alone (`obs.validate`).

Two hard requirements drive the shape:

- **Near-zero overhead when disabled.**  The default tracer is a
  module-level no-op singleton; hot-path call sites guard payload
  construction behind ``if tracer.enabled:`` and the no-op ``span()``
  returns one shared reusable context manager — no allocation, no
  clock read.
- **Deterministic under test.**  The clock is injected
  (``Tracer(clock=...)``), fault-plan style, so span ordering and
  durations are exact in tests.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "Span",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "get_tracer",
    "set_tracer",
]


class Span:
    """One closed or in-flight duration event."""

    __slots__ = ("name", "t0", "t1", "depth", "args")

    def __init__(self, name: str, t0: float, depth: int):
        self.name = name
        self.t0 = t0
        self.t1: Optional[float] = None
        self.depth = depth
        self.args: Dict[str, Any] = {}

    def set(self, **kwargs: Any) -> "Span":
        """Attach payload fields (engine, sweeps, edges_relaxed, ...)."""
        self.args.update(kwargs)
        return self

    @property
    def duration(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        # closed by the owning Tracer via _SpanCtx; nothing to do here
        return None


class _SpanCtx:
    """Context manager that closes its span on exit."""

    __slots__ = ("_tracer", "span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self.span = span

    def set(self, **kwargs: Any) -> "_SpanCtx":
        self.span.set(**kwargs)
        return self

    def __enter__(self) -> "_SpanCtx":
        return self

    def __exit__(self, *exc) -> None:
        self._tracer._close(self.span)


class _NullSpanCtx:
    """Shared, reusable, allocation-free stand-in for a span."""

    __slots__ = ()
    span = None

    def set(self, **kwargs: Any) -> "_NullSpanCtx":
        return self

    def __enter__(self) -> "_NullSpanCtx":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_SPAN = _NullSpanCtx()


class Tracer:
    """Collects spans + instant events on an injected monotonic clock."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._stack: List[Span] = []
        self.spans: List[Span] = []
        self.instants: List[Dict[str, Any]] = []

    # -- recording ---------------------------------------------------------
    def span(self, name: str, **args: Any) -> _SpanCtx:
        s = Span(name, self._clock(), depth=len(self._stack))
        if args:
            s.args.update(args)
        self._stack.append(s)
        return _SpanCtx(self, s)

    def _close(self, span: Span) -> None:
        span.t1 = self._clock()
        # tolerate out-of-order exits rather than corrupt the stack
        if self._stack and self._stack[-1] is span:
            self._stack.pop()
        elif span in self._stack:
            self._stack.remove(span)
        self.spans.append(span)

    def instant(self, name: str, **args: Any) -> None:
        self.instants.append({"name": name, "ts": self._clock(), "args": args})

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON (open in chrome://tracing or Perfetto)."""
        events: List[Dict[str, Any]] = []
        for s in self.spans:
            events.append(
                {
                    "ph": "X",
                    "name": s.name,
                    "ts": s.t0 * 1e6,
                    "dur": s.duration * 1e6,
                    "pid": 1,
                    "tid": 1,
                    "args": s.args,
                }
            )
        for ev in self.instants:
            events.append(
                {
                    "ph": "i",
                    "s": "t",
                    "name": ev["name"],
                    "ts": ev["ts"] * 1e6,
                    "pid": 1,
                    "tid": 1,
                    "args": ev["args"],
                }
            )
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def write_jsonl(self, path: str) -> None:
        """One span/instant per line, in timestamp order."""
        rows: List[Dict[str, Any]] = []
        for s in self.spans:
            rows.append(
                {
                    "kind": "span",
                    "name": s.name,
                    "t0": s.t0,
                    "t1": s.t1,
                    "depth": s.depth,
                    "args": s.args,
                }
            )
        for ev in self.instants:
            rows.append({"kind": "instant", "name": ev["name"], "t0": ev["ts"], "args": ev["args"]})
        rows.sort(key=lambda r: r["t0"])
        with open(path, "w") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")


class NullTracer:
    """Disabled tracer: every operation is a shared no-op."""

    enabled = False
    spans: List[Span] = []
    instants: List[Dict[str, Any]] = []

    def span(self, name: str, **args: Any) -> _NullSpanCtx:
        return _NULL_SPAN

    def instant(self, name: str, **args: Any) -> None:
        return None

    def to_chrome(self) -> Dict[str, Any]:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)

    def write_jsonl(self, path: str) -> None:
        open(path, "w").close()


NULL_TRACER = NullTracer()

_current: object = NULL_TRACER


def get_tracer():
    """The active tracer — NULL_TRACER unless a driver installed one."""
    return _current


def set_tracer(tracer) -> object:
    """Install ``tracer`` process-wide; returns the previous one."""
    global _current
    prev = _current
    _current = tracer if tracer is not None else NULL_TRACER
    return prev
