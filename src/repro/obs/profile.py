"""Per-solve cost records — the training data for a measured cost model.

Every engine solve (batched multisource, p2p early-exit, API-level
single source, dynamic repair) can emit one `CostRecord` mapping the
*decision inputs* an engine selector would see —
``(engine, n, m, batch, nprocs, delta)`` — to the *measured outcome*
``(sweeps, edges_relaxed, wall_ms, converged)``.  ROADMAP item 4's
self-tuning dispatch fits its cost model on exactly these rows.

Emission follows the tracer pattern: a module-level no-op `CostLog`
singleton, replaced by the launch drivers when ``--trace-out`` is
given.  `emit()` on the null log is a constant-time early return, so
instrumented call sites cost nothing in normal runs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

__all__ = [
    "CostRecord",
    "CostLog",
    "NULL_COST_LOG",
    "get_cost_log",
    "set_cost_log",
]

COST_RECORD_FIELDS = (
    "engine",
    "graph",
    "n",
    "m",
    "batch",
    "nprocs",
    "delta",
    "sweeps",
    "edges_relaxed",
    "wall_ms",
    "converged",
)


@dataclasses.dataclass(frozen=True)
class CostRecord:
    """One solve: decision inputs → measured outcome."""

    engine: str          # which engine ran (bellman_csr, frontier, ...)
    graph: str           # registry graph name, or "" outside serving
    n: int               # vertex count
    m: int               # edge count
    batch: int           # padded multisource bucket size (1 for p2p/single)
    nprocs: int          # mesh size for sharded solves, else 1
    delta: float         # Δ-stepping bucket width, 0.0 when not applicable
    sweeps: int          # relaxation sweeps / bucket phases executed
    edges_relaxed: int   # total edge relaxations performed
    wall_ms: float       # host wall-clock for the solve, ms
    converged: bool      # fixpoint reached within the sweep cap

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class CostLog:
    """Append-only in-memory cost-record sink with a JSONL exporter."""

    enabled = True

    def __init__(self) -> None:
        self.records: List[CostRecord] = []

    def emit(
        self,
        *,
        engine: str,
        n: int,
        m: int,
        sweeps: int,
        edges_relaxed: int,
        wall_ms: float,
        converged: bool,
        graph: str = "",
        batch: int = 1,
        nprocs: int = 1,
        delta: float = 0.0,
    ) -> None:
        self.records.append(
            CostRecord(
                engine=str(engine),
                graph=str(graph),
                n=int(n),
                m=int(m),
                batch=int(batch),
                nprocs=int(nprocs),
                delta=float(delta),
                sweeps=int(sweeps),
                edges_relaxed=int(edges_relaxed),
                wall_ms=float(wall_ms),
                converged=bool(converged),
            )
        )

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r.to_dict()) + "\n")

    def clear(self) -> None:
        self.records.clear()


class _NullCostLog(CostLog):
    """Disabled sink: emit() drops the record before building it."""

    enabled = False

    def __init__(self) -> None:
        self.records = []

    def emit(self, **kwargs: Any) -> None:  # noqa: D102 - no-op
        return None


NULL_COST_LOG = _NullCostLog()

_current: CostLog = NULL_COST_LOG


def get_cost_log() -> CostLog:
    return _current


def set_cost_log(log: Optional[CostLog]) -> CostLog:
    """Install ``log`` process-wide; returns the previous one."""
    global _current
    prev = _current
    _current = log if log is not None else NULL_COST_LOG
    return prev
