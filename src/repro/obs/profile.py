"""Per-solve cost records — the training data for a measured cost model.

Every engine solve (batched multisource, p2p early-exit, API-level
single source, dynamic repair) can emit one `CostRecord` mapping the
*decision inputs* an engine selector would see —
``(engine, n, m, batch, nprocs, delta)`` — to the *measured outcome*
``(sweeps, edges_relaxed, wall_ms, converged)``.  ROADMAP item 4's
self-tuning dispatch fits its cost model on exactly these rows.

Emission follows the tracer pattern: a module-level no-op `CostLog`
singleton, replaced by the launch drivers when ``--trace-out`` is
given.  `emit()` on the null log is a constant-time early return, so
instrumented call sites cost nothing in normal runs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

__all__ = [
    "CostRecord",
    "CostLog",
    "NULL_COST_LOG",
    "backend_info",
    "get_cost_log",
    "set_cost_log",
]

# schema v1: the decision/outcome fields every record must carry.
COST_RECORD_FIELDS_V1 = (
    "engine",
    "graph",
    "n",
    "m",
    "batch",
    "nprocs",
    "delta",
    "sweeps",
    "edges_relaxed",
    "wall_ms",
    "converged",
)

# schema v2 adds the hardware identity: a cost model fitted on one
# backend is meaningless on another (the paper's MPI/CUDA crossover
# moves with the hardware), so records name where they were measured.
# obs/validate.py accepts both versions; new emitters always write v2.
COST_RECORD_FIELDS_V2_EXTRA = ("backend", "device_kind")
COST_RECORD_FIELDS = COST_RECORD_FIELDS_V1 + COST_RECORD_FIELDS_V2_EXTRA
COST_RECORD_SCHEMA = 2

_backend_info: Optional[tuple] = None


def backend_info() -> tuple:
    """``(backend, device_kind)`` of the running process — e.g.
    ``("cpu", "cpu")`` or ``("gpu", "NVIDIA A100...")``.  Cached after
    the first call; jax is imported lazily so a pure log-reading process
    never initializes a backend."""
    global _backend_info
    if _backend_info is None:
        import jax

        devs = jax.devices()
        _backend_info = (str(jax.default_backend()),
                         str(devs[0].device_kind) if devs else "")
    return _backend_info


@dataclasses.dataclass(frozen=True)
class CostRecord:
    """One solve: decision inputs → measured outcome."""

    engine: str          # which engine ran (bellman_csr, frontier, ...)
    graph: str           # registry graph name, or "" outside serving
    n: int               # vertex count
    m: int               # edge count
    batch: int           # padded multisource bucket size (1 for p2p/single)
    nprocs: int          # mesh size for sharded solves, else 1
    delta: float         # Δ-stepping bucket width, 0.0 when not applicable
    sweeps: int          # relaxation sweeps / bucket phases executed
    edges_relaxed: int   # total edge relaxations performed
    wall_ms: float       # host wall-clock for the solve, ms
    converged: bool      # fixpoint reached within the sweep cap
    backend: str = ""    # jax.default_backend() at measurement (v2)
    device_kind: str = ""  # device_kind of device 0 at measurement (v2)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class CostLog:
    """Append-only in-memory cost-record sink with a JSONL exporter."""

    enabled = True

    def __init__(self) -> None:
        self.records: List[CostRecord] = []

    def emit(
        self,
        *,
        engine: str,
        n: int,
        m: int,
        sweeps: int,
        edges_relaxed: int,
        wall_ms: float,
        converged: bool,
        graph: str = "",
        batch: int = 1,
        nprocs: int = 1,
        delta: float = 0.0,
        backend: str = "",
        device_kind: str = "",
    ) -> None:
        if not backend or not device_kind:
            # v2: stamp the measuring hardware so fitted models can
            # refuse records from a different backend (tune/replay.py).
            be, dk = backend_info()
            backend = backend or be
            device_kind = device_kind or dk
        self.records.append(
            CostRecord(
                engine=str(engine),
                graph=str(graph),
                n=int(n),
                m=int(m),
                batch=int(batch),
                nprocs=int(nprocs),
                delta=float(delta),
                sweeps=int(sweeps),
                edges_relaxed=int(edges_relaxed),
                wall_ms=float(wall_ms),
                converged=bool(converged),
                backend=str(backend),
                device_kind=str(device_kind),
            )
        )

    def write_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for r in self.records:
                f.write(json.dumps(r.to_dict()) + "\n")

    def clear(self) -> None:
        self.records.clear()


class _NullCostLog(CostLog):
    """Disabled sink: emit() drops the record before building it."""

    enabled = False

    def __init__(self) -> None:
        self.records = []

    def emit(self, **kwargs: Any) -> None:  # noqa: D102 - no-op
        return None


NULL_COST_LOG = _NullCostLog()

_current: CostLog = NULL_COST_LOG


def get_cost_log() -> CostLog:
    return _current


def set_cost_log(log: Optional[CostLog]) -> CostLog:
    """Install ``log`` process-wide; returns the previous one."""
    global _current
    prev = _current
    _current = log if log is not None else NULL_COST_LOG
    return prev
