"""Schema + chain validation for trace and cost-record artifacts.

Used three ways:

- by `tests/test_obs.py` on in-memory tracer output,
- by the launch drivers right after writing ``--trace-out`` files,
- as a CLI in the CI ``obs-smoke`` job::

      python -m repro.obs.validate TRACE.json COSTS.jsonl

Validation is structural (required keys, types, timestamp sanity) plus
the acceptance-criteria chain check: every ``answer`` instant with
``exact=True`` that was answered by an engine solve must be enclosed by
a ``tick`` span, preceded by a ``submit`` instant for the same qid, and
matched by a solve span whose ``args.qids`` contains the qid.
"""
from __future__ import annotations

import json
import sys
from typing import Any, Dict, List

from .profile import COST_RECORD_FIELDS_V1, COST_RECORD_FIELDS_V2_EXTRA

__all__ = [
    "validate_chrome_trace",
    "validate_cost_records",
    "reconstruct_answer_chains",
]

SOLVE_SPANS = ("batch_solve", "p2p_solve")
# answers whose `via` names an engine solve (serve/scheduler.VIAS):
# "batch" = multisource engine, "target" = p2p early exit.  trivial/
# cache/landmark/degraded answers legitimately have no solve span.
ENGINE_VIAS = ("batch", "target")


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Return a list of schema violations (empty == valid)."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["missing top-level traceEvents"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["traceEvents is not a list"]
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i"):
            errs.append(f"event {i}: unsupported ph {ph!r}")
            continue
        for key in ("name", "ts", "pid", "tid"):
            if key not in ev:
                errs.append(f"event {i} ({ev.get('name')}): missing {key!r}")
        if not isinstance(ev.get("name"), str):
            errs.append(f"event {i}: name is not a string")
        if not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"event {i}: ts is not numeric")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)):
                errs.append(f"event {i} ({ev.get('name')}): missing numeric dur")
            elif dur < 0:
                errs.append(f"event {i} ({ev.get('name')}): negative dur")
        if "args" in ev and not isinstance(ev["args"], dict):
            errs.append(f"event {i} ({ev.get('name')}): args is not an object")
    return errs


def validate_cost_records(rows: List[Dict[str, Any]]) -> List[str]:
    """Return a list of cost-record schema violations (empty == valid).

    Accepts both schema versions: v1 records carry only the decision/
    outcome fields; v2 additionally stamps ``backend``/``device_kind``
    (non-empty strings when present) — a record may omit them (v1) but
    may not carry them malformed.
    """
    errs: List[str] = []
    for i, r in enumerate(rows):
        if not isinstance(r, dict):
            errs.append(f"record {i}: not an object")
            continue
        for key in COST_RECORD_FIELDS_V1:
            if key not in r:
                errs.append(f"record {i}: missing {key!r}")
        for key in COST_RECORD_FIELDS_V2_EXTRA:
            if key in r and (not isinstance(r[key], str) or not r[key]):
                errs.append(
                    f"record {i}: {key} must be a non-empty string "
                    f"when present (v2)")
        for key in ("n", "m", "batch", "nprocs", "sweeps", "edges_relaxed"):
            if key in r and (not isinstance(r[key], int) or r[key] < 0):
                errs.append(f"record {i}: {key} must be a non-negative int")
        if "wall_ms" in r and (not isinstance(r["wall_ms"], (int, float)) or r["wall_ms"] < 0):
            errs.append(f"record {i}: wall_ms must be non-negative")
        if "engine" in r and (not isinstance(r["engine"], str) or not r["engine"]):
            errs.append(f"record {i}: engine must be a non-empty string")
        if "converged" in r and not isinstance(r["converged"], bool):
            errs.append(f"record {i}: converged must be a bool")
    return errs


def reconstruct_answer_chains(doc: Dict[str, Any]) -> List[str]:
    """Check every exact engine-served answer has a full span chain.

    Chain: ``submit`` instant (same qid, earlier) → enclosing ``tick``
    span (ts containment) → solve span with qid in args.qids → the
    ``answer`` instant itself.
    """
    errs: List[str] = []
    events = doc.get("traceEvents", [])
    submits = {}
    for ev in events:
        if ev.get("ph") == "i" and ev.get("name") == "submit":
            qid = ev.get("args", {}).get("qid")
            if qid is not None and qid not in submits:
                submits[qid] = ev["ts"]
    ticks = [ev for ev in events if ev.get("ph") == "X" and ev.get("name") == "tick"]
    solves = [ev for ev in events if ev.get("ph") == "X" and ev.get("name") in SOLVE_SPANS]
    answers = [ev for ev in events if ev.get("ph") == "i" and ev.get("name") == "answer"]
    if not answers:
        errs.append("no answer instants found")
    for ev in answers:
        args = ev.get("args", {})
        qid = args.get("qid")
        if qid is None:
            errs.append("answer instant without qid")
            continue
        if not args.get("exact", False):
            continue
        if qid not in submits:
            errs.append(f"answer qid={qid}: no submit instant")
        elif submits[qid] > ev["ts"]:
            errs.append(f"answer qid={qid}: submit after answer")
        if args.get("via") not in ENGINE_VIAS:
            continue  # cache/landmark answers need no solve span
        tick = next(
            (t for t in ticks if t["ts"] <= ev["ts"] <= t["ts"] + t.get("dur", 0)),
            None,
        )
        if tick is None:
            errs.append(f"answer qid={qid}: no enclosing tick span")
        solve = next(
            (s for s in solves if qid in s.get("args", {}).get("qids", ())),
            None,
        )
        if solve is None:
            errs.append(f"answer qid={qid} via={args.get('via')}: no solve span lists it")
        elif tick is not None and not (
            tick["ts"] <= solve["ts"] and solve["ts"] + solve.get("dur", 0) <= tick["ts"] + tick.get("dur", 0) + 1e-3
        ):
            # the solve must have happened within *a* tick; it may be an
            # earlier tick than the answering one (cached rows), so only
            # require some tick to contain it
            if not any(
                t["ts"] <= solve["ts"] <= t["ts"] + t.get("dur", 0) for t in ticks
            ):
                errs.append(f"answer qid={qid}: solve span outside every tick")
    return errs


def main(argv: List[str]) -> int:
    if len(argv) < 1:
        print("usage: python -m repro.obs.validate TRACE.json [COSTS.jsonl]")
        return 2
    with open(argv[0]) as f:
        doc = json.load(f)
    errs = validate_chrome_trace(doc)
    errs += reconstruct_answer_chains(doc)
    n_events = len(doc.get("traceEvents", []))
    if len(argv) > 1:
        rows = []
        with open(argv[1]) as f:
            for line in f:
                line = line.strip()
                if line:
                    rows.append(json.loads(line))
        errs += validate_cost_records(rows)
        if not rows:
            errs.append("cost-record file is empty")
        print(f"cost records: {len(rows)}")
    print(f"trace events: {n_events}")
    if errs:
        for e in errs:
            print(f"INVALID: {e}", file=sys.stderr)
        return 1
    print("OK: trace + cost records schema-valid, answer chains complete")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
