"""Driver-side capture helpers: install the process-wide tracer +
cost log, and write/validate their outputs at end of run.

The launch drivers and benchmark harnesses all follow the same
``--trace-out PATH`` contract:

- the Chrome trace JSON is written to ``PATH``
  (open in chrome://tracing or Perfetto),
- the per-solve cost records go to ``splitext(PATH)[0] + ".cost.jsonl"``,
- both artifacts are schema-validated in-process (obs/validate) and the
  driver exits nonzero on an invalid capture — CI's obs-smoke job relies
  on this plus an independent ``python -m repro.obs.validate`` pass.
"""
from __future__ import annotations

import os.path
from typing import Callable, List, Optional, Tuple

from repro.obs.profile import CostLog, set_cost_log
from repro.obs.trace import Tracer, set_tracer

__all__ = ["cost_path_for", "install_capture", "finalize_capture"]


def cost_path_for(trace_path: str) -> str:
    """Cost-record JSONL path derived from the Chrome-trace path."""
    return os.path.splitext(trace_path)[0] + ".cost.jsonl"


def install_capture(
    clock: Optional[Callable[[], float]] = None,
) -> Tuple[Tracer, CostLog]:
    """Create and install a live Tracer + CostLog process-wide."""
    tr = Tracer() if clock is None else Tracer(clock=clock)
    cl = CostLog()
    set_tracer(tr)
    set_cost_log(cl)
    return tr, cl


def finalize_capture(
    tr: Tracer,
    cl: CostLog,
    trace_path: str,
    *,
    validate: bool = True,
    check_chains: bool = True,
) -> List[str]:
    """Write both artifacts; return validation errors (empty = valid).

    ``check_chains=False`` skips the answer-chain reconstruction for
    captures that never ran the serving scheduler (pure benchmark
    solves emit no submit/tick/answer events, which is not an error).
    """
    tr.write_chrome(trace_path)
    cl.write_jsonl(cost_path_for(trace_path))
    if not validate:
        return []
    from repro.obs.validate import (reconstruct_answer_chains,
                                    validate_chrome_trace,
                                    validate_cost_records)

    doc = tr.to_chrome()
    errs = validate_chrome_trace(doc)
    errs += validate_cost_records([r.to_dict() for r in cl.records])
    if check_chains:
        errs += reconstruct_answer_chains(doc)
    return errs
