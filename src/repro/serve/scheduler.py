"""Micro-batching query scheduler — queue -> dedup -> bucket-padded solve.

The serving loop that turns the batched ``multisource_csr`` engine (so far
only exercised by benchmarks) into a query server.  Each ``tick()``:

1. drains the request queue and groups queries by graph;
2. answers what it can **without an engine**: trivial ``dist(s, s)``,
   cached source rows (serve/cache.py), landmark source rows and
   landmark-proven disconnection (serve/landmarks.py) — always from the
   query's own source direction, see ``_try_fast``;
3. **deduplicates** the remaining sources — fifty queries against one hot
   source cost one solved row — and coalesces up to ``max_batch`` distinct
   sources into ONE ``multisource_csr`` solve, **padding** the source axis
   up to a memoized bucket size (powers of two) by repeating the first
   source, so repeat ticks present the same (S,) shape and hit the jit
   cache instead of retracing;
4. fans the solved rows back out to every waiting query and inserts them
   into the distance cache.

A tick whose residue is a single point-to-point query takes the
**target early-exit path** instead: one ``frontier`` solve with
``target=`` (core/frontier.py) sharpened by the landmark lower bound —
the solve stops once the target's label is provably final.  Its row is
partial by construction, so it is never cached.

Engine SELECTION routes through the dispatch seam (serve/dispatch.py):
graphs at or above the policy's shard threshold — when the runtime has
devices to shard across — solve on the vertex-partitioned engines
instead (core/sharded_csr.py) using the handle's staged ``CsrPartition``
operands on the policy's cached mesh.  Batched residues coalesce across
devices through the union-frontier ``multisource_csr_sharded`` engine
(one compacted exchange + one arc gather per sweep shared by all S
sources); the point-to-point residue runs ``frontier_sharded`` WITHOUT
early exit — the full fixpoint row is a superset of the partial solve
with identical ``dist[target]`` bytes, and being complete it IS cached,
so sharded p2p traffic warms the row cache where single-device p2p
cannot.  Sharded-served rows are cached under shard-aware keys
(``row_key(source, shards=P)``, derived from the policy's pure size
check so key shapes are deterministic from the first tick).  Either
route returns bitwise-identical bytes.

Every path returns bytes some engine solved (or a bound that *proves* the
value), so served answers stay bitwise-equal to per-query ``serial``
solves — the invariant tests/test_serve.py and the --smoke driver verify.

Graphs registered as :class:`~repro.dynamic.DynamicGraph` additionally
accept **mutation ticks**: ``submit_mutation`` queues edge edits that
``tick()`` applies BEFORE the tick's queries, one committed batch per
graph.  The registry's mutate hook then reconciles the distance cache
per row — rows no delta can touch are re-keyed to the new version
untouched, up to ``repair_rows`` hot rows are repaired incrementally
(dynamic/repair.py), the rest invalidated (or retained under their OLD
version key as degraded-serving candidates, see below) — and the
landmark set stales lazily.  Engine paths pick up each handle's dynamic
sweeps so solves run on the mutable overlay operands directly,
preserving the bitwise guarantee against the mutated snapshot.

**Fault tolerance** (serve/errors.py is the taxonomy):

* ``submit()`` validates eagerly (graph name, non-negative in-range
  integer endpoints, deadline sanity) and raises ``QueryRejected``
  instead of poisoning a later tick; with ``max_queue=`` set, a
  saturated queue rejects the newcomer or sheds the cheapest-to-
  recompute queued work (p2p before full rows, newest first) —
  reject-on-saturation backpressure.
* every post-admission failure becomes a per-query ``Answer`` with a
  typed ``status`` (``graph_gone``, ``deadline_exceeded``,
  ``solve_failed``, ``not_converged``) rather than an exception across
  the tick; transient solve/staging failures are retried with capped
  exponential backoff (``retry_budget`` attempts per query, backoff
  measured in ticks).
* ``tick(now=...)`` answers already-expired queries
  ``deadline_exceeded`` before solving; under deadline pressure
  (``deadline - now <= degrade_margin``, or admission overflow on a
  deadlined query) p2p queries may be served from ALT landmark
  lower/upper bounds and full-row queries from a stale-but-versioned
  cache row — always ``exact=False``, via="degraded": the bitwise
  exactness invariant binds only answers claiming ``exact=True``.
* a non-``converged`` engine result (``max_sweeps`` cap) is answered
  ``not_converged`` and its rows are never cached — no silent wrong
  answers.
* ``drain()`` has a progress guard: a tick that had eligible work but
  served zero and retired zero raises ``SchedulerStalled`` instead of
  looping forever.
* ``faults=`` accepts a serve/faults.FaultPlan whose seeded schedule is
  probed at the existing seams (solve, staging, mid-tick eviction,
  mutation rollback, sweep clipping) — the chaos harness
  launch/sssp_serve.py --chaos replays and verifies.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.bellman_csr import sssp_multisource_csr
from repro.core.frontier import sssp_frontier

from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import get_cost_log
from repro.obs.trace import get_tracer
from repro.serve.cache import DistanceCache
from repro.serve.dispatch import DispatchPolicy, default_policy
from repro.serve.errors import (STATUS_OK, DeadlineExceeded, GraphGone,
                                NotConverged, QueryRejected,
                                SchedulerStalled, ServeError, SolveFailed)
from repro.serve.registry import GraphRegistry

VIAS = ("trivial", "cache", "landmark", "batch", "target", "mutate",
        "degraded", "error")


@dataclasses.dataclass
class Query:
    """One request: ``target is None`` => full ``sssp(source)`` row,
    else a point-to-point ``dist(source, target)`` scalar.  ``deadline``
    (same clock as ``arrival``) makes the query droppable once passed;
    ``attempts``/``not_before`` are the retry-backoff state (a query
    whose solve failed is ineligible until tick ``not_before``)."""

    qid: int
    graph: str
    source: int
    target: Optional[int] = None
    arrival: float = 0.0
    deadline: Optional[float] = None
    attempts: int = 0
    not_before: int = 0


@dataclasses.dataclass
class Mutation:
    """One edge-edit request against a dynamic graph: ``edit`` is the
    registry wire tuple ``("add"|"update"|"delete", u, v[, w])``.  All of
    a graph's mutations drained in one tick commit as ONE version bump
    (the repair batch granularity)."""

    qid: int
    graph: str
    edit: tuple
    arrival: float = 0.0


@dataclasses.dataclass
class Answer:
    query: "Query | Mutation"
    value: "np.ndarray | float | int | None"  # (n,) row for sssp, float
                                        # for dist, new version int for
                                        # mutate; None iff via == "error"
    via: str                            # one of VIAS
    done_at: float = 0.0                # stamped by the driver (wall clock)
    status: str = STATUS_OK             # STATUS_OK or a ServeError code
    exact: bool = True                  # True => bitwise-equal-to-serial
                                        # guarantee applies to ``value``
    error: Optional[ServeError] = None  # the typed failure, iff not ok
    bounds: Optional[tuple] = None      # (lb, ub) for degraded p2p answers
    service_start: Optional[float] = None   # clock at which the answering
                                        # tick began (tick(now=...)); the
                                        # queue-wait / service-time pivot
                                        # for workload.LatencyRecorder

    @property
    def ok(self) -> bool:
        return self.status == STATUS_OK


class MicroBatchScheduler:
    """See module docstring.  ``max_batch`` caps distinct sources per
    tick per graph (overflow is requeued ahead of newer arrivals);
    ``p2p_solo=False`` disables the target early-exit path (everything
    residual goes through the batched engine).

    Robustness knobs (all optional; defaults preserve the permissive
    pre-fault-tolerance behavior except eager submit validation, which
    is always on):

    ``max_queue``
        Bounded-queue admission: a submit that would push the query
        queue past this raises :class:`QueryRejected` — unless a
        cheaper-to-recompute queued query (a p2p, newest first) can be
        shed in its favor, acked ``rejected`` on the next tick.
    ``retry_budget`` / ``backoff_cap``
        A query whose solve raised is requeued with capped exponential
        backoff (``2**(attempts-1)`` ticks, capped) up to
        ``retry_budget`` attempts, then answered ``solve_failed``.
    ``max_sweeps``
        Fixpoint-sweep cap passed to every engine solve; a capped
        non-converged result is answered ``not_converged`` and its rows
        are never cached.
    ``degrade`` / ``degrade_margin``
        Inexact fallbacks under deadline pressure: p2p from landmark
        bounds, full rows from a stale-version cache row (retained by
        the mutate hook when ``degrade`` is on).  ``degrade_margin`` is
        the seconds-to-deadline threshold below which an admitted query
        is degraded pre-solve (0.0 = only admission overflow degrades).
    ``faults``
        A serve/faults.FaultPlan probed at the solve / stage / evict /
        mutate / clip seams (chaos harness).

    All event counters live on a `MetricsRegistry` under the ``sched.*``
    namespace (``metrics=`` shares one across components; the default is
    a fresh instance per scheduler so two schedulers never alias).  The
    legacy plain-attribute reads (``sched.engine_batches`` ...) resolve
    through ``__getattr__`` onto the registry, ``stats()`` keeps its
    historical shape, and ``snapshot()`` is the uniform merged view of
    scheduler + cache + registry series.
    """

    # every legacy int counter, now one sched.* series each
    _COUNTER_NAMES = (
        "ticks", "engine_batches", "engine_sources", "sharded_batches",
        "sharded_p2p", "sharded_sources", "sharded_edges", "target_solves",
        "dedup_saved", "rows_kept", "rows_repaired", "rows_invalidated",
        "rows_staled", "repair_edges", "submissions_rejected", "shed",
        "deadline_expired", "degraded_p2p", "degraded_batch",
        "solve_exceptions", "retries", "not_converged",
    )

    def __init__(
        self,
        registry: GraphRegistry,
        cache: DistanceCache,
        *,
        max_batch: int = 16,
        p2p_solo: bool = True,
        repair_rows: int = 8,
        dispatch: Optional[DispatchPolicy] = None,
        max_queue: Optional[int] = None,
        retry_budget: int = 2,
        backoff_cap: int = 8,
        max_sweeps: Optional[int] = None,
        degrade: bool = True,
        degrade_margin: float = 0.0,
        faults=None,
        metrics: Optional[MetricsRegistry] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if retry_budget < 0:
            raise ValueError(f"retry_budget must be >= 0, got {retry_budget}")
        self.registry = registry
        self.cache = cache
        self.max_batch = max_batch
        self.p2p_solo = p2p_solo
        self.repair_rows = repair_rows
        self.dispatch = dispatch if dispatch is not None else default_policy()
        self.max_queue = max_queue
        self.retry_budget = retry_budget
        self.backoff_cap = backoff_cap
        self.max_sweeps = max_sweeps
        self.degrade = degrade
        self.degrade_margin = float(degrade_margin)
        self.faults = faults
        registry.add_evict_hook(cache.purge_graph)
        registry.add_mutate_hook(self._on_mutate)
        self._queue: "collections.deque[Query]" = collections.deque()
        self._mutations: "collections.deque[Mutation]" = collections.deque()
        self._next_qid = 0
        # one sched.* series per legacy counter; __getattr__ serves the
        # old plain-attribute reads from these.  The sharded slices feed
        # the serve_bench sharded gate (sharded_edges / sharded_sources
        # = edges-per-solved-source).
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._c = {name: self.metrics.counter(f"sched.{name}")
                   for name in self._COUNTER_NAMES}
        # running sum of per-batch occupancy (distinct/bucket) plus the
        # last observed value as the per-tick occupancy gauge
        self._occ_sum = self.metrics.gauge("sched.occupancy_sum")
        self._occ_last = self.metrics.gauge("sched.occupancy")
        self._via = {v: self.metrics.counter("sched.answered", via=v)
                     for v in VIAS}
        self.last_mutation_error: Optional[str] = None
        self._shed_acks: list = []          # delivered at next tick's start
        self._last_tick_stalled = False     # drain()'s progress-guard flag

    def __getattr__(self, name: str):
        # legacy counter attributes (sched.ticks, sched.engine_batches,
        # ...) read straight off the metrics registry
        c = self.__dict__.get("_c")
        if c is not None and name in c:
            return c[name].value
        raise AttributeError(
            f"{type(self).__name__!r} object has no attribute {name!r}")

    @property
    def occupancy_sum(self) -> float:
        return self._occ_sum.value

    @property
    def answered_via(self) -> dict:
        return {v: c.value for v, c in self._via.items()}

    @property
    def answered_status(self) -> "collections.Counter[str]":
        out: "collections.Counter[str]" = collections.Counter()
        for s in self.metrics.find("sched.answered_status"):
            labels = dict(s.labels)
            out[labels.get("status", "?")] = s.value
        return out

    # -- queue ------------------------------------------------------------

    @staticmethod
    def _check_vertex(value, what: str) -> int:
        """Eager endpoint validation: a non-negative integer (bool is an
        int subclass but never a vertex id)."""
        if isinstance(value, bool) or not isinstance(
                value, (int, np.integer)):
            raise QueryRejected(
                f"{what} must be an integer vertex id, got "
                f"{type(value).__name__} {value!r}")
        v = int(value)
        if v < 0:
            raise QueryRejected(f"{what} must be >= 0, got {v}")
        return v

    def submit(self, graph: str, source: int, target: Optional[int] = None,
               *, arrival: float = 0.0,
               deadline: Optional[float] = None) -> Query:
        """Enqueue one query, validating EAGERLY — a malformed request
        fails its caller with :class:`QueryRejected` here instead of
        poisoning the tick that would have drained it.  Range checks run
        against the graph's current handle when it is registered; an
        unregistered name is accepted (it may be registered before the
        serving tick) and answered ``graph_gone`` at tick time if not.

        ``deadline`` (same clock as ``arrival``) marks the query
        droppable: ``tick(now=...)`` answers it ``deadline_exceeded``
        once passed, and may serve it degraded under pressure.  With
        ``max_queue`` set, a full queue either sheds a cheaper queued
        query in this one's favor or rejects this one (backpressure).
        """
        try:
            if not isinstance(graph, str) or not graph:
                raise QueryRejected(
                    f"graph must be a non-empty name string, got {graph!r}")
            src = self._check_vertex(source, "source")
            tgt = (None if target is None
                   else self._check_vertex(target, "target"))
            if deadline is not None:
                deadline = float(deadline)
                if not np.isfinite(deadline):
                    raise QueryRejected(f"deadline must be finite, got "
                                        f"{deadline!r}")
            if graph in self.registry:
                n = self.registry.get(graph).n
                for what, v in (("source", src), ("target", tgt)):
                    if v is not None and v >= n:
                        raise QueryRejected(
                            f"{what} {v} out of range for graph {graph!r} "
                            f"(n={n})")
        except QueryRejected:
            self._c["submissions_rejected"].inc()
            raise
        q = Query(qid=self._next_qid, graph=graph, source=src, target=tgt,
                  arrival=arrival, deadline=deadline)
        self._next_qid += 1
        if (self.max_queue is not None
                and len(self._queue) >= self.max_queue):
            self._admit_saturated(q)
        else:
            self._queue.append(q)
        tr = get_tracer()
        if tr.enabled:
            tr.instant("submit", qid=q.qid, graph=graph, source=src,
                       target=tgt)
        return q

    def _admit_saturated(self, q: Query) -> None:
        """Bounded-queue admission: shed the cheapest-to-recompute queued
        work — a p2p query (bounded early-exit re-solve, its partial row
        is never cached), newest first (least queue investment) — in the
        newcomer's favor; if the newcomer is itself in the cheapest
        class, reject it instead (reject-on-saturation backpressure)."""
        victim_i = None
        if q.target is None:
            for i in range(len(self._queue) - 1, -1, -1):
                if self._queue[i].target is not None:
                    victim_i = i
                    break
        if victim_i is None:
            self._c["submissions_rejected"].inc()
            raise QueryRejected(
                f"queue saturated ({self.max_queue} pending); resubmit "
                "after a tick drains")
        victim = self._queue[victim_i]
        del self._queue[victim_i]
        self._c["shed"].inc()
        err = QueryRejected(
            f"shed under saturation in favor of query {q.qid}")
        self._shed_acks.append(Answer(victim, None, "error",
                                      status=err.code, exact=False,
                                      error=err))
        self._queue.append(q)

    def submit_mutation(self, graph: str, op: str, u: int, v: int,
                        w: Optional[float] = None, *,
                        arrival: float = 0.0) -> Mutation:
        """Queue one edge edit against a dynamic graph.  Edits are
        applied at the START of the next tick (before any query drained
        in the same tick is answered), all of a graph's pending edits
        committing as one mutation batch."""
        edit = (op, int(u), int(v)) if w is None else (op, int(u), int(v),
                                                       float(w))
        m = Mutation(qid=self._next_qid, graph=graph, edit=edit,
                     arrival=arrival)
        self._next_qid += 1
        self._mutations.append(m)
        tr = get_tracer()
        if tr.enabled:
            tr.instant("submit", qid=m.qid, graph=graph, op=op)
        return m

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._mutations)

    # -- mutation ticks ---------------------------------------------------

    def _apply_mutations(self) -> list:
        """Drain the mutation queue: one ``registry.mutate`` batch per
        graph (the registry fires :meth:`_on_mutate` to reconcile the
        cache), acked with via="mutate" answers whose value is the
        graph's new version."""
        if not self._mutations:
            return []
        drained, self._mutations = list(self._mutations), collections.deque()
        by_graph: "collections.OrderedDict[str, list]" = (
            collections.OrderedDict())
        for m in drained:
            by_graph.setdefault(m.graph, []).append(m)
        acks = []
        tr = get_tracer()
        for name, muts in by_graph.items():
            edits = [m.edit for m in muts]
            if self.faults is not None and self.faults.roll(
                    "mutate", graph=name, detail="poisoned edit"):
                # chaos seam: a poisoned edit forces the registry's
                # atomic-rollback path — the whole batch must roll back
                # and every mutation in it is acked rejected.
                edits = edits + [("update", -1, -1, 1.0)]
            try:
                with tr.span("mutate", graph=name, edits=len(edits)):
                    self.registry.mutate(name, edits)
                version = self.registry.get(name).version
                acks.extend(Answer(m, version, "mutate") for m in muts)
            except (KeyError, ValueError, IndexError) as e:
                # unknown/static graph or invalid edit: fail the whole
                # graph's batch — a half-applied batch would leave the
                # trace's edge-set bookkeeping unverifiable.
                err = QueryRejected(f"mutation batch rolled back: {e}")
                acks.extend(Answer(m, None, "error", status=err.code,
                                   exact=False, error=err) for m in muts)
                self.last_mutation_error = str(e)
        return acks

    def _on_mutate(self, name, handle, batch, old_ops) -> None:
        """Registry mutate hook: reconcile this graph's cached rows with
        the new version.  Per row (hottest first): if no delta can touch
        it (dynamic/repair.row_affected) it is RE-KEYED to the new
        version untouched; otherwise up to ``repair_rows`` rows are
        REPAIRED in place (pred recovered against the pre-commit
        operands, then one incremental repair on the new ones —
        dynamic/repair.py) and the rest are invalidated — or, when
        degraded serving is on, RETAINED under their old version key as
        stale-but-versioned fallbacks (never served exact: exact lookups
        only ever consult the current version's key)."""
        if not batch.records:
            return
        tr = get_tracer()
        if not tr.enabled:
            self._reconcile_rows(name, handle, batch, old_ops)
            return
        with tr.span("repair", graph=name, version=handle.version,
                     edits=len(batch.records)) as sp:
            kept0, rep0, inv0 = (self.rows_kept, self.rows_repaired,
                                 self.rows_invalidated)
            edges0 = self.repair_edges
            self._reconcile_rows(name, handle, batch, old_ops)
            sp.set(rows_kept=self.rows_kept - kept0,
                   rows_repaired=self.rows_repaired - rep0,
                   rows_invalidated=self.rows_invalidated - inv0,
                   repair_edges=self.repair_edges - edges0)

    def _reconcile_rows(self, name, handle, batch, old_ops) -> None:
        import jax.numpy as jnp

        from repro.core.api import SsspResult
        from repro.dynamic.repair import (predecessors_from_dist_dynamic,
                                          repair_sssp, row_affected)

        cl = get_cost_log()
        # walk LRU -> MRU so the re-puts (which append at the MRU end)
        # PRESERVE the graph's recency order; the repair budget still
        # goes to the hottest rows — the affected keys nearest the MRU
        # end — by slicing the affected list from its tail.  Only the
        # PRE-COMMIT version's keys are reconciled: older keys are stale
        # retainees from earlier batches (this delta says nothing about
        # their version) and are left for the LRU to age out.
        keys = self.cache.keys_for(name)
        rows = {k: self.cache.peek(k) for k in keys}
        prev_version = handle.version - 1
        current = [k for k in keys if len(k) == 3 and k[1] == prev_version]
        affected = {k for k in current
                    if row_affected(rows[k], batch, handle.dyn.directed)}
        budget = self.repair_rows if old_ops is not None else 0
        repair = set([k for k in current if k in affected][-budget:]
                     if budget else [])
        for key in current:
            source = key[-1]
            row = rows[key]
            if key not in affected:
                self.cache.pop(key)
                self.cache.put(handle.row_key(source), row)
                self._c["rows_kept"].inc()
            elif key in repair:
                self.cache.pop(key)
                t0 = time.perf_counter() if cl.enabled else 0.0
                pred = predecessors_from_dist_dynamic(
                    jnp.asarray(row), old_ops, jnp.int32(source))
                prev = SsspResult(
                    dist=row, pred=np.asarray(pred), sweeps=None,
                    engine="cache", sources=np.asarray([source], np.int32))
                res, _ = repair_sssp(handle.dyn, prev, batch)
                self.cache.put(handle.row_key(source), res.dist)
                self._c["rows_repaired"].inc()
                self._c["repair_edges"].inc(res.edges_relaxed or 0)
                if cl.enabled:
                    cl.emit(engine="repair", graph=name, n=handle.n,
                            m=handle.m, sweeps=int(res.sweeps or 0),
                            edges_relaxed=int(res.edges_relaxed or 0),
                            wall_ms=(time.perf_counter() - t0) * 1e3,
                            converged=True)
            else:
                self._c["rows_invalidated"].inc()
                if self.degrade:
                    # retained under its OLD version key: invisible to
                    # exact lookups, available to _try_degraded.
                    self._c["rows_staled"].inc()
                else:
                    self.cache.pop(key)

    # -- dispatch ---------------------------------------------------------

    def _shards(self, handle) -> int:
        """Shard arity of this graph's cache keys: the policy's PURE size
        check (no mesh, no staging), so lookups and inserts agree on the
        key shape from the first tick onward."""
        if self.dispatch.would_shard(handle.n,
                                     dynamic=handle.dyn is not None):
            return self.dispatch.nprocs
        return 1

    def _row_key(self, handle, source: int) -> tuple:
        return handle.row_key(source, shards=self._shards(handle))

    # -- answer-without-engine paths --------------------------------------

    def _try_fast(self, handle, q: Query) -> Optional[Answer]:
        """Trivial / cache / landmark answers; None if an engine is needed.

        Only SAME-DIRECTION rows are served: an undirected graph has
        d(s, t) == d(t, s) in exact arithmetic, but f32 path sums round
        differently when traversed from the other end, so answering
        ``dist(s, t)`` from a cached/landmark *t*-row would break the
        bitwise-equal-to-serial guarantee by an ulp.  Symmetry is still
        exploited where it is exact: the landmark disconnection proof.
        """
        if q.target is not None and q.target == q.source:
            return Answer(q, 0.0, "trivial")
        row = self.cache.get(self._row_key(handle, q.source))
        if row is not None:
            val = row if q.target is None else float(row[q.target])
            return Answer(q, val, "cache")
        ls = handle.landmarks_ready()
        if ls is not None:
            row = ls.row_of(q.source)
            if row is not None:
                val = row if q.target is None else float(row[q.target])
                return Answer(q, val, "landmark")
            if (q.target is not None
                    and not np.isfinite(ls.lower_bound(q.source, q.target))):
                # some landmark reaches exactly one endpoint: s and t are
                # provably disconnected (undirected graphs only — which
                # is the only kind landmarks are built for), so inf is
                # the exact answer, no solve needed; inf is ulp-proof.
                return Answer(q, float("inf"), "landmark")
        return None

    def _try_degraded(self, handle, q: Query) -> Optional[Answer]:
        """Inexact fallback under deadline pressure; None if no degraded
        source exists (the query then solves, or expires).

        p2p: the ALT landmark bracket — value is the UPPER bound (a real
        path length through the best landmark, so always achievable),
        with ``bounds=(lb, ub)`` attached.  Full row: the most recently
        used stale-version cache row for this source (dynamic graphs
        whose mutate hook retained it).  Both are ``exact=False`` with
        status "ok" — approximate, not failed."""
        if not self.degrade:
            return None
        if q.target is not None:
            ls = handle.landmarks_ready()
            if ls is None:
                return None
            ub = ls.upper_bound(q.source, q.target)
            if not np.isfinite(ub):
                return None
            lb = ls.lower_bound(q.source, q.target)
            self._c["degraded_p2p"].inc()
            return Answer(q, float(ub), "degraded", exact=False,
                          bounds=(float(lb), float(ub)))
        if handle.dyn is None:
            return None
        for key in reversed(self.cache.keys_for(handle.name)):  # MRU first
            if (len(key) == 3 and key[2] == q.source
                    and key[1] != handle.version):
                self._c["degraded_batch"].inc()
                return Answer(q, self.cache.peek(key), "degraded",
                              exact=False)
        return None

    # -- engine paths -----------------------------------------------------

    def _bucket(self, count: int, cap: Optional[int] = None) -> int:
        """Smallest power of two >= count, clamped to max_batch — the
        memoized source-axis sizes that keep repeat ticks on the same
        compiled multisource solve.  ``cap`` (a policy's calibrated
        ``EngineChoice.batch_cap``) tightens the clamp further, but never
        below ``count`` — every admitted distinct source must fit."""
        b = 1
        while b < count:
            b *= 2
        b = min(b, self.max_batch)
        if cap is not None:
            b = max(count, min(b, int(cap)))
        return b

    def _admission_limit(self, handle) -> int:
        """Distinct sources admitted per tick for ``handle``: the
        scheduler's ``max_batch`` tightened by the dispatch policy's
        calibrated per-graph bucket ceiling (``DispatchPolicy.batch_cap``
        — None from the threshold policy, the measured-best B from a
        tuned one)."""
        cap = self.dispatch.batch_cap(handle)
        if cap is None:
            return self.max_batch
        return max(1, min(self.max_batch, int(cap)))

    def _probe(self, site: str, name: str) -> None:
        """Fault-plan probe at a raising seam (solve / stage)."""
        if self.faults is not None:
            self.faults.maybe_raise(site, graph=name)

    def _sweep_cap(self, name: str) -> Optional[int]:
        """The effective ``max_sweeps`` for one engine solve: the
        configured cap, unless the fault plan's ``clip`` site fires and
        forces its (tighter) clip — the solver-guardrail seam.  Probed
        LAST, after the stage/solve fault seams, so a fired clip always
        governs a solve that actually runs (a same-attempt injected
        exception cannot mask it from the chaos reconciliation)."""
        if self.faults is not None and self.faults.roll("clip", graph=name):
            return self.faults.clip_sweeps
        return self.max_sweeps

    def _solve_target(self, handle, q: Query) -> Answer:
        """Point-to-point residue of a tick.

        Single-device route: one frontier solve that early-exits on the
        target (plus the landmark bound when one is admissibly
        available); the row is partial — never cached.  Sharded route:
        one ``frontier_sharded`` FULL fixpoint — no early exit exists
        across owners, but the complete row is cacheable, which the
        partial row never is (``dist[target]`` bytes identical either
        way).  Raises :class:`NotConverged` when a sweep cap stopped the
        engine short — capped labels are never served or cached."""
        tr = get_tracer()
        cl = get_cost_log()
        obs = tr.enabled or cl.enabled
        choice = self.dispatch.choose(handle, kind="p2p")
        if choice.sharded:
            from repro.core.sharded_csr import sssp_frontier_sharded

            with tr.span("p2p_solve", qids=(q.qid,)) as sp:
                with tr.span("stage", graph=handle.name):
                    self._probe("stage", handle.name)
                    parts = handle.partition(choice.nprocs)
                    pops = handle.partition_ops(choice.nprocs)
                    self.registry.touch_staged(handle.name)
                self._probe("solve", handle.name)
                ms = self._sweep_cap(handle.name)
                t0 = time.perf_counter() if obs else 0.0
                d, sw, e, conv = sssp_frontier_sharded(
                    parts, q.source, choice.mesh, axis=choice.axis,
                    ops=pops, max_sweeps=ms)
                conv = bool(int(conv))
                self._c["target_solves"].inc()
                self._c["sharded_p2p"].inc()
                self._c["sharded_sources"].inc()
                self._c["sharded_edges"].inc(int(e))
                if obs:
                    wall_ms = (time.perf_counter() - t0) * 1e3
                    if tr.enabled:
                        sp.set(engine="frontier_sharded", graph=handle.name,
                               n=handle.n, m=handle.m, B=1,
                               P=choice.nprocs, sweeps=int(sw),
                               edges_relaxed=int(e), converged=conv)
                    cl.emit(engine="frontier_sharded", graph=handle.name,
                            n=handle.n, m=handle.m, nprocs=choice.nprocs,
                            sweeps=int(sw), edges_relaxed=int(e),
                            wall_ms=wall_ms, converged=conv)
            if not conv:
                raise NotConverged(
                    f"sharded p2p solve on {handle.name!r} capped at "
                    f"max_sweeps={ms}")
            row = np.asarray(d)[:handle.n]
            self.cache.put(self._row_key(handle, q.source), row)
            return Answer(q, float(row[q.target]), "target")
        with tr.span("p2p_solve", qids=(q.qid,)) as sp:
            with tr.span("stage", graph=handle.name):
                self._probe("stage", handle.name)
                ops = handle.frontier_ops()
                self.registry.touch_staged(handle.name)
            lb = None
            ls = handle.landmarks_ready()
            if ls is not None:
                lb = ls.conservative_lb(q.source, q.target)
                lb = None if not np.isfinite(lb) else jnp.float32(lb)
            self._probe("solve", handle.name)
            ms = self._sweep_cap(handle.name)
            # model-chosen frontier statics ride the choice: Δ throttles
            # the bucket schedule, chunk the scatter width — both change
            # only the schedule, never the fixpoint bytes.
            skw = {}
            if choice.delta is not None:
                skw["delta"] = float(choice.delta)
            if choice.chunk is not None:
                skw["chunk"] = int(choice.chunk)
            t0 = time.perf_counter() if obs else 0.0
            d, _, sw, e, conv = sssp_frontier(
                ops, jnp.int32(q.source), n=handle.n,
                sweep_fn=handle.frontier_sweep_fn(), max_sweeps=ms,
                target=jnp.int32(q.target), target_lb=lb, **skw,
            )
            conv = bool(conv)
            self._c["target_solves"].inc()
            if obs:
                wall_ms = (time.perf_counter() - t0) * 1e3
                if tr.enabled:
                    sp.set(engine="frontier", graph=handle.name,
                           n=handle.n, m=handle.m, B=1, P=1,
                           sweeps=int(sw), edges_relaxed=int(e),
                           converged=conv)
                cl.emit(engine="frontier", graph=handle.name, n=handle.n,
                        m=handle.m, sweeps=int(sw), edges_relaxed=int(e),
                        wall_ms=wall_ms, converged=conv)
        if not conv:
            raise NotConverged(
                f"p2p solve on {handle.name!r} capped at max_sweeps={ms} "
                "before the target settled")
        return Answer(q, float(np.asarray(d)[q.target]), "target")

    def _solve_batch(self, handle, queries: list) -> list:
        """One bucket-padded multisource solve answering ``queries``
        (all on ``handle``'s graph, <= max_batch distinct sources).
        Raises :class:`NotConverged` on a capped solve BEFORE any row is
        cached — non-fixpoint labels never enter the cache."""
        distinct: list[int] = []
        seen: set[int] = set()
        for q in queries:
            if q.source not in seen:
                seen.add(q.source)
                distinct.append(q.source)
        choice = self.dispatch.choose(handle, kind="batch")
        bucket = self._bucket(len(distinct), choice.batch_cap)
        padded = distinct + [distinct[0]] * (bucket - len(distinct))
        tr = get_tracer()
        cl = get_cost_log()
        obs = tr.enabled or cl.enabled
        qids = tuple(q.qid for q in queries) if obs else ()
        with tr.span("batch_solve", qids=qids) as sp:
            if choice.sharded:
                from repro.core.sharded_csr import (
                    sssp_multisource_csr_sharded)

                engine = "multisource_csr_sharded"
                with tr.span("stage", graph=handle.name):
                    self._probe("stage", handle.name)
                    parts = handle.partition(choice.nprocs)
                    pops = handle.partition_ops(choice.nprocs)
                    self.registry.touch_staged(handle.name)
                self._probe("solve", handle.name)
                ms = self._sweep_cap(handle.name)
                t0 = time.perf_counter() if obs else 0.0
                D, sw, e, conv = sssp_multisource_csr_sharded(
                    parts, jnp.asarray(padded, jnp.int32), choice.mesh,
                    axis=choice.axis, ops=pops, max_sweeps=ms)
                rows = np.asarray(D)[:, :handle.n]
                converged = bool(int(conv))
                edges = int(e)
                self._c["sharded_batches"].inc()
                self._c["sharded_sources"].inc(len(distinct))
                self._c["sharded_edges"].inc(edges)
            else:
                engine = "multisource_csr"
                with tr.span("stage", graph=handle.name):
                    self._probe("stage", handle.name)
                    ops = handle.csr_ops()
                    self.registry.touch_staged(handle.name)
                self._probe("solve", handle.name)
                ms = self._sweep_cap(handle.name)
                t0 = time.perf_counter() if obs else 0.0
                D, sw, conv = sssp_multisource_csr(
                    ops, jnp.asarray(padded, jnp.int32),
                    n=handle.n, sweep_fn=handle.multisource_sweep_fn(),
                    max_sweeps=ms)
                rows = np.asarray(D)
                converged = bool(conv)
                # the segment engine relaxes every stored arc for every
                # bucket lane each sweep — exact, not sampled
                edges = int(sw) * handle.m * bucket if obs else 0
            self._c["engine_batches"].inc()
            self._c["engine_sources"].inc(len(distinct))
            self._c["dedup_saved"].inc(len(queries) - len(distinct))
            occupancy = len(distinct) / bucket
            self._occ_sum.add(occupancy)
            self._occ_last.set(occupancy)
            if obs:
                wall_ms = (time.perf_counter() - t0) * 1e3
                if tr.enabled:
                    sp.set(engine=engine, graph=handle.name, n=handle.n,
                           m=handle.m, B=bucket,
                           P=choice.nprocs if choice.sharded else 1,
                           sweeps=int(sw), edges_relaxed=edges,
                           occupancy=round(occupancy, 4),
                           converged=converged)
                cl.emit(engine=engine, graph=handle.name, n=handle.n,
                        m=handle.m, batch=bucket,
                        nprocs=choice.nprocs if choice.sharded else 1,
                        sweeps=int(sw), edges_relaxed=edges,
                        wall_ms=wall_ms, converged=converged)
        if not converged:
            raise NotConverged(
                f"batched solve on {handle.name!r} ({len(distinct)} "
                f"sources) capped at max_sweeps={ms}")
        by_source = {s: rows[i] for i, s in enumerate(distinct)}
        out = []
        for q in queries:
            row = by_source[q.source]
            self.cache.put(self._row_key(handle, q.source), row)
            val = row if q.target is None else float(row[q.target])
            out.append(Answer(q, val, "batch"))
        return out

    # -- the tick ---------------------------------------------------------

    def _fail(self, q, err: ServeError) -> Answer:
        """A typed per-query failure answer (never raised mid-tick)."""
        return Answer(q, None, "error", status=err.code, exact=False,
                      error=err)

    def _retry_or_fail(self, queries: list, exc: Exception,
                       requeue: list) -> list:
        """A solve raised: requeue each query with capped exponential
        backoff (ineligible for ``2**(attempts-1)`` ticks, capped at
        ``backoff_cap``) until its retry budget is spent, then answer it
        ``solve_failed``."""
        failed = []
        for q in queries:
            q.attempts += 1
            if q.attempts > self.retry_budget:
                failed.append(self._fail(q, SolveFailed(
                    f"solve raised on attempt {q.attempts} "
                    f"(budget {self.retry_budget} retries): {exc}")))
            else:
                q.not_before = self.ticks + min(
                    2 ** (q.attempts - 1), self.backoff_cap)
                self._c["retries"].inc()
                requeue.append(q)
        return failed

    def tick(self, now: Optional[float] = None) -> list:
        """Drain the queues once; returns the Answers produced this tick
        (overflow beyond max_batch distinct sources per graph is requeued
        ahead of newer arrivals).  Pending mutations are applied FIRST —
        one committed batch per graph — so every query drained in the
        same tick is answered against the post-mutation version (the
        interleaving contract launch/sssp_dynamic.py's verifier pins).

        ``now`` (the driver's clock, same units as arrival/deadline)
        activates deadline handling: expired queries are answered
        ``deadline_exceeded`` before any solve, and near-deadline ones
        (within ``degrade_margin``) may be served degraded.  A solve
        exception fails only ITS queries (retried under backoff first) —
        never the tick: every other graph's drained queries still serve.
        """
        self._last_tick_stalled = False
        if not self._queue and not self._mutations and not self._shed_acks:
            return []
        tr = get_tracer()
        if not tr.enabled:
            return self._tick(now)
        with tr.span("tick", tick=self.ticks + 1) as sp:
            answers = self._tick(now)
            sp.set(answers=len(answers), pending=self.pending)
            # emitted inside the span: an answer belongs to its tick,
            # which is what obs/validate's chain reconstruction pins
            for a in answers:
                tr.instant("answer", qid=a.query.qid, via=a.via,
                           status=a.status, exact=a.exact)
        return answers

    def _tick(self, now: Optional[float]) -> list:
        self._c["ticks"].inc()
        retries0 = self.retries
        answers: list = list(self._shed_acks)
        self._shed_acks = []
        answers.extend(self._apply_mutations())
        # backoff gate: queries parked by a failed solve sit out their
        # not_before ticks without blocking the rest of the queue.
        batch: list = []
        held: "collections.deque[Query]" = collections.deque()
        for q in self._queue:
            (batch if q.not_before <= self.ticks else held).append(q)
        self._queue = held
        if now is not None:
            live = []
            for q in batch:
                if q.deadline is not None and now > q.deadline:
                    self._c["deadline_expired"].inc()
                    answers.append(self._fail(q, DeadlineExceeded(
                        f"deadline {q.deadline:.6f} passed at "
                        f"now={now:.6f} before serving")))
                else:
                    live.append(q)
            batch = live
        by_graph: "collections.OrderedDict[str, list]" = (
            collections.OrderedDict())
        for q in batch:
            by_graph.setdefault(q.graph, []).append(q)
        requeue: list = []
        for name, queries in by_graph.items():
            if (self.faults is not None and name in self.registry
                    and self.faults.roll("evict", graph=name)):
                # chaos seam: the graph vanishes mid-tick, after
                # admission but before its solve — the evicted-graph
                # race the GraphGone path below must absorb.
                self.registry.evict(name)
            if name not in self.registry:
                # the graph was evicted (or never registered): fail these
                # queries with typed answers rather than crashing the
                # tick and losing every other graph's drained queries.
                err = GraphGone(f"graph {name!r} is not registered "
                                "(evicted or never admitted)")
                answers.extend(self._fail(q, err) for q in queries)
                continue
            handle = self.registry.get(name)
            need_engine = []
            for q in queries:
                ans = self._try_fast(handle, q)
                if ans is None:
                    need_engine.append(q)
                else:
                    answers.append(ans)
            if now is not None and self.degrade and need_engine:
                # deadline pressure: a query too close to its deadline to
                # risk an engine solve takes the degraded fallback when
                # one exists (else it still solves — it may make it).
                still = []
                for q in need_engine:
                    if (q.deadline is not None
                            and q.deadline - now <= self.degrade_margin):
                        d = self._try_degraded(handle, q)
                        if d is not None:
                            answers.append(d)
                            continue
                    still.append(q)
                need_engine = still
            if not need_engine:
                continue
            # cap distinct sources at max_batch; queries on uncovered
            # sources wait for the next tick.  Admission is O(1) per
            # query via the set; the list keeps admission order (and is
            # what _solve_batch's dedup re-derives per-query order from).
            allowed: list[int] = []
            allowed_set: set[int] = set()
            take, defer = [], []
            limit = self._admission_limit(handle)
            for q in need_engine:
                if q.source in allowed_set:
                    take.append(q)
                elif len(allowed) < limit:
                    allowed.append(q.source)
                    allowed_set.add(q.source)
                    take.append(q)
                else:
                    defer.append(q)
            for q in defer:
                # admission overflow on a deadlined query: a degraded
                # answer NOW beats an exact answer after the deadline.
                d = (self._try_degraded(handle, q)
                     if q.deadline is not None else None)
                if d is not None:
                    answers.append(d)
                else:
                    requeue.append(q)
            if not take:
                continue
            try:
                if (self.p2p_solo and len(take) == 1
                        and take[0].target is not None):
                    answers.append(self._solve_target(handle, take[0]))
                else:
                    answers.extend(self._solve_batch(handle, take))
            except NotConverged as e:
                # a capped solve is NOT transient — retrying under the
                # same cap re-runs the identical truncation, so answer
                # typed immediately (satisfying the guardrail contract).
                self._c["not_converged"].inc(len(take))
                answers.extend(self._fail(q, e) for q in take)
            except Exception as e:    # injected or real engine failure
                self._c["solve_exceptions"].inc()
                answers.extend(self._retry_or_fail(take, e, requeue))
        for q in reversed(requeue):
            self._queue.appendleft(q)
        # progress accounting for drain()'s guard: a tick progressed if
        # it answered anything, advanced some query's retry state, or
        # simply had no eligible work (backoff holds drain by design).
        self._last_tick_stalled = (bool(batch) and not answers
                                   and self.retries == retries0)
        for a in answers:
            if now is not None and a.service_start is None:
                a.service_start = now
            self._via[a.via].inc()
            self.metrics.counter("sched.answered_status",
                                 status=a.status).inc()
        return answers

    def drain(self, now: Optional[float] = None) -> list:
        """Tick until the queues are empty (closed-loop replay).

        Progress guard: a tick that had eligible work but served zero
        answers and retired zero queries (everything requeued unchanged)
        raises :class:`SchedulerStalled` instead of spinning forever —
        the failure mode a requeue-path bug would otherwise turn into a
        silent infinite loop."""
        out = []
        while self.pending:
            out.extend(self.tick(now))
            if self._last_tick_stalled:
                raise SchedulerStalled(
                    f"tick {self.ticks} had eligible work but served "
                    f"zero and retired zero ({self.pending} pending)")
        return out

    # -- metrics ----------------------------------------------------------

    @property
    def mean_occupancy(self) -> float:
        return (self.occupancy_sum / self.engine_batches
                if self.engine_batches else 0.0)

    def snapshot(self) -> dict:
        """The uniform metrics view: every scheduler, cache, and registry
        series merged into one flat sorted ``{name: value}`` dict (the
        components may share one registry or own separate ones — the
        ``sched.`` / ``cache.`` / ``registry.`` prefixes cannot collide).
        Deterministic under seeded replay: only event counts and set
        gauges, no wall-clock values."""
        merged = dict(self.metrics.snapshot())
        for reg in (self.cache.metrics, self.registry.metrics):
            if reg is not self.metrics:
                merged.update(reg.snapshot())
        return dict(sorted(merged.items()))

    def stats(self) -> dict:
        """Legacy nested view, unchanged shape; every count in it is
        derived from the same series :meth:`snapshot` reports."""
        return {
            "ticks": self.ticks,
            "engine_batches": self.engine_batches,
            "engine_sources": self.engine_sources,
            "sharded_batches": self.sharded_batches,
            "sharded_p2p": self.sharded_p2p,
            "sharded_sources": self.sharded_sources,
            "sharded_edges": self.sharded_edges,
            "target_solves": self.target_solves,
            "dedup_saved": self.dedup_saved,
            "mean_occupancy": round(self.mean_occupancy, 4),
            "rows_kept": self.rows_kept,
            "rows_repaired": self.rows_repaired,
            "rows_invalidated": self.rows_invalidated,
            "rows_staled": self.rows_staled,
            "repair_edges": self.repair_edges,
            "answered_via": dict(self.answered_via),
            "answered_status": dict(self.answered_status),
            "submissions_rejected": self.submissions_rejected,
            "shed": self.shed,
            "deadline_expired": self.deadline_expired,
            "degraded_p2p": self.degraded_p2p,
            "degraded_batch": self.degraded_batch,
            "solve_exceptions": self.solve_exceptions,
            "retries": self.retries,
            "not_converged": self.not_converged,
            "faults": (self.faults.summary()
                       if self.faults is not None else None),
            "cache": self.cache.stats(),
            "registry": self.registry.stats(),
        }
