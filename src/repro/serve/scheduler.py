"""Micro-batching query scheduler — queue -> dedup -> bucket-padded solve.

The serving loop that turns the batched ``multisource_csr`` engine (so far
only exercised by benchmarks) into a query server.  Each ``tick()``:

1. drains the request queue and groups queries by graph;
2. answers what it can **without an engine**: trivial ``dist(s, s)``,
   cached source rows (serve/cache.py), landmark source rows and
   landmark-proven disconnection (serve/landmarks.py) — always from the
   query's own source direction, see ``_try_fast``;
3. **deduplicates** the remaining sources — fifty queries against one hot
   source cost one solved row — and coalesces up to ``max_batch`` distinct
   sources into ONE ``multisource_csr`` solve, **padding** the source axis
   up to a memoized bucket size (powers of two) by repeating the first
   source, so repeat ticks present the same (S,) shape and hit the jit
   cache instead of retracing;
4. fans the solved rows back out to every waiting query and inserts them
   into the distance cache.

A tick whose residue is a single point-to-point query takes the
**target early-exit path** instead: one ``frontier`` solve with
``target=`` (core/frontier.py) sharpened by the landmark lower bound —
the solve stops once the target's label is provably final.  Its row is
partial by construction, so it is never cached.

Every path returns bytes some engine solved (or a bound that *proves* the
value), so served answers stay bitwise-equal to per-query ``serial``
solves — the invariant tests/test_serve.py and the --smoke driver verify.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.bellman_csr import sssp_multisource_csr
from repro.core.frontier import sssp_frontier

from repro.serve.cache import DistanceCache
from repro.serve.registry import GraphRegistry

VIAS = ("trivial", "cache", "landmark", "batch", "target", "error")


@dataclasses.dataclass
class Query:
    """One request: ``target is None`` => full ``sssp(source)`` row,
    else a point-to-point ``dist(source, target)`` scalar."""

    qid: int
    graph: str
    source: int
    target: Optional[int] = None
    arrival: float = 0.0


@dataclasses.dataclass
class Answer:
    query: Query
    value: "np.ndarray | float | None"  # (n,) row for sssp, float for
                                        # dist; None iff via == "error"
    via: str                            # one of VIAS
    done_at: float = 0.0                # stamped by the driver (wall clock)


class MicroBatchScheduler:
    """See module docstring.  ``max_batch`` caps distinct sources per
    tick per graph (overflow is requeued ahead of newer arrivals);
    ``p2p_solo=False`` disables the target early-exit path (everything
    residual goes through the batched engine)."""

    def __init__(
        self,
        registry: GraphRegistry,
        cache: DistanceCache,
        *,
        max_batch: int = 16,
        p2p_solo: bool = True,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.registry = registry
        self.cache = cache
        self.max_batch = max_batch
        self.p2p_solo = p2p_solo
        registry.add_evict_hook(cache.purge_graph)
        self._queue: "collections.deque[Query]" = collections.deque()
        self._next_qid = 0
        self.ticks = 0
        self.engine_batches = 0
        self.engine_sources = 0
        self.target_solves = 0
        self.dedup_saved = 0
        self.occupancy_sum = 0.0
        self.answered_via = {v: 0 for v in VIAS}

    # -- queue ------------------------------------------------------------

    def submit(self, graph: str, source: int, target: Optional[int] = None,
               *, arrival: float = 0.0) -> Query:
        q = Query(qid=self._next_qid, graph=graph, source=int(source),
                  target=None if target is None else int(target),
                  arrival=arrival)
        self._next_qid += 1
        self._queue.append(q)
        return q

    @property
    def pending(self) -> int:
        return len(self._queue)

    # -- answer-without-engine paths --------------------------------------

    def _try_fast(self, handle, q: Query) -> Optional[Answer]:
        """Trivial / cache / landmark answers; None if an engine is needed.

        Only SAME-DIRECTION rows are served: an undirected graph has
        d(s, t) == d(t, s) in exact arithmetic, but f32 path sums round
        differently when traversed from the other end, so answering
        ``dist(s, t)`` from a cached/landmark *t*-row would break the
        bitwise-equal-to-serial guarantee by an ulp.  Symmetry is still
        exploited where it is exact: the landmark disconnection proof.
        """
        if q.target is not None and q.target == q.source:
            return Answer(q, 0.0, "trivial")
        row = self.cache.get((q.graph, q.source))
        if row is not None:
            val = row if q.target is None else float(row[q.target])
            return Answer(q, val, "cache")
        ls = handle.landmarks
        if ls is not None:
            row = ls.row_of(q.source)
            if row is not None:
                val = row if q.target is None else float(row[q.target])
                return Answer(q, val, "landmark")
            if (q.target is not None
                    and not np.isfinite(ls.lower_bound(q.source, q.target))):
                # some landmark reaches exactly one endpoint: s and t are
                # provably disconnected (undirected graphs only — which
                # is the only kind landmarks are built for), so inf is
                # the exact answer, no solve needed; inf is ulp-proof.
                return Answer(q, float("inf"), "landmark")
        return None

    # -- engine paths -----------------------------------------------------

    def _bucket(self, count: int) -> int:
        """Smallest power of two >= count, clamped to max_batch — the
        memoized source-axis sizes that keep repeat ticks on the same
        compiled multisource solve."""
        b = 1
        while b < count:
            b *= 2
        return min(b, self.max_batch)

    def _solve_target(self, handle, q: Query) -> Answer:
        """Point-to-point residue of a tick: one frontier solve that
        early-exits on the target (plus the landmark bound when one is
        admissibly available).  The row is partial — never cached."""
        ops = handle.frontier_ops()
        self.registry.touch_staged(handle.name)
        lb = None
        if handle.landmarks is not None:
            lb = handle.landmarks.conservative_lb(q.source, q.target)
            lb = None if not np.isfinite(lb) else jnp.float32(lb)
        d, _, _, _ = sssp_frontier(
            ops, jnp.int32(q.source), n=handle.n,
            target=jnp.int32(q.target), target_lb=lb,
        )
        self.target_solves += 1
        return Answer(q, float(np.asarray(d)[q.target]), "target")

    def _solve_batch(self, handle, queries: list) -> list:
        """One bucket-padded multisource solve answering ``queries``
        (all on ``handle``'s graph, <= max_batch distinct sources)."""
        distinct: list[int] = []
        for q in queries:
            if q.source not in distinct:
                distinct.append(q.source)
        bucket = self._bucket(len(distinct))
        padded = distinct + [distinct[0]] * (bucket - len(distinct))
        D, _ = sssp_multisource_csr(
            handle.csr_ops(), jnp.asarray(padded, jnp.int32), n=handle.n)
        self.registry.touch_staged(handle.name)
        rows = np.asarray(D)
        self.engine_batches += 1
        self.engine_sources += len(distinct)
        self.dedup_saved += len(queries) - len(distinct)
        self.occupancy_sum += len(distinct) / bucket
        by_source = {s: rows[i] for i, s in enumerate(distinct)}
        out = []
        for q in queries:
            row = by_source[q.source]
            self.cache.put((q.graph, q.source), row)
            val = row if q.target is None else float(row[q.target])
            out.append(Answer(q, val, "batch"))
        return out

    # -- the tick ---------------------------------------------------------

    def tick(self) -> list:
        """Drain the queue once; returns the Answers produced this tick
        (overflow beyond max_batch distinct sources per graph is requeued
        ahead of newer arrivals)."""
        if not self._queue:
            return []
        self.ticks += 1
        batch, self._queue = list(self._queue), collections.deque()
        by_graph: "collections.OrderedDict[str, list]" = (
            collections.OrderedDict())
        for q in batch:
            by_graph.setdefault(q.graph, []).append(q)
        answers: list = []
        requeue: list = []
        for name, queries in by_graph.items():
            if name not in self.registry:
                # the graph was evicted (or never registered): fail these
                # queries with error answers rather than crashing the
                # tick and losing every other graph's drained queries.
                answers.extend(Answer(q, None, "error") for q in queries)
                continue
            handle = self.registry.get(name)
            need_engine = []
            for q in queries:
                ans = self._try_fast(handle, q)
                if ans is None:
                    need_engine.append(q)
                else:
                    answers.append(ans)
            if not need_engine:
                continue
            # cap distinct sources at max_batch; queries on uncovered
            # sources wait for the next tick.
            allowed: list[int] = []
            take, defer = [], []
            for q in need_engine:
                if q.source in allowed:
                    take.append(q)
                elif len(allowed) < self.max_batch:
                    allowed.append(q.source)
                    take.append(q)
                else:
                    defer.append(q)
            requeue.extend(defer)
            if (self.p2p_solo and len(take) == 1
                    and take[0].target is not None):
                answers.append(self._solve_target(handle, take[0]))
            else:
                answers.extend(self._solve_batch(handle, take))
        for q in reversed(requeue):
            self._queue.appendleft(q)
        for a in answers:
            self.answered_via[a.via] += 1
        return answers

    def drain(self) -> list:
        """Tick until the queue is empty (closed-loop replay)."""
        out = []
        while self._queue:
            out.extend(self.tick())
        return out

    # -- metrics ----------------------------------------------------------

    @property
    def mean_occupancy(self) -> float:
        return (self.occupancy_sum / self.engine_batches
                if self.engine_batches else 0.0)

    def stats(self) -> dict:
        return {
            "ticks": self.ticks,
            "engine_batches": self.engine_batches,
            "engine_sources": self.engine_sources,
            "target_solves": self.target_solves,
            "dedup_saved": self.dedup_saved,
            "mean_occupancy": round(self.mean_occupancy, 4),
            "answered_via": dict(self.answered_via),
            "cache": self.cache.stats(),
            "registry": self.registry.stats(),
        }
