"""Micro-batching query scheduler — queue -> dedup -> bucket-padded solve.

The serving loop that turns the batched ``multisource_csr`` engine (so far
only exercised by benchmarks) into a query server.  Each ``tick()``:

1. drains the request queue and groups queries by graph;
2. answers what it can **without an engine**: trivial ``dist(s, s)``,
   cached source rows (serve/cache.py), landmark source rows and
   landmark-proven disconnection (serve/landmarks.py) — always from the
   query's own source direction, see ``_try_fast``;
3. **deduplicates** the remaining sources — fifty queries against one hot
   source cost one solved row — and coalesces up to ``max_batch`` distinct
   sources into ONE ``multisource_csr`` solve, **padding** the source axis
   up to a memoized bucket size (powers of two) by repeating the first
   source, so repeat ticks present the same (S,) shape and hit the jit
   cache instead of retracing;
4. fans the solved rows back out to every waiting query and inserts them
   into the distance cache.

A tick whose residue is a single point-to-point query takes the
**target early-exit path** instead: one ``frontier`` solve with
``target=`` (core/frontier.py) sharpened by the landmark lower bound —
the solve stops once the target's label is provably final.  Its row is
partial by construction, so it is never cached.

Engine SELECTION routes through the dispatch seam (serve/dispatch.py):
graphs at or above the policy's shard threshold — when the runtime has
devices to shard across — solve on the vertex-partitioned engines
instead (core/sharded_csr.py) using the handle's staged ``CsrPartition``
operands on the policy's cached mesh.  Batched residues coalesce across
devices through the union-frontier ``multisource_csr_sharded`` engine
(one compacted exchange + one arc gather per sweep shared by all S
sources); the point-to-point residue runs ``frontier_sharded`` WITHOUT
early exit — the full fixpoint row is a superset of the partial solve
with identical ``dist[target]`` bytes, and being complete it IS cached,
so sharded p2p traffic warms the row cache where single-device p2p
cannot.  Sharded-served rows are cached under shard-aware keys
(``row_key(source, shards=P)``, derived from the policy's pure size
check so key shapes are deterministic from the first tick).  Either
route returns bitwise-identical bytes.

Every path returns bytes some engine solved (or a bound that *proves* the
value), so served answers stay bitwise-equal to per-query ``serial``
solves — the invariant tests/test_serve.py and the --smoke driver verify.

Graphs registered as :class:`~repro.dynamic.DynamicGraph` additionally
accept **mutation ticks**: ``submit_mutation`` queues edge edits that
``tick()`` applies BEFORE the tick's queries, one committed batch per
graph.  The registry's mutate hook then reconciles the distance cache
per row — rows no delta can touch are re-keyed to the new version
untouched, up to ``repair_rows`` hot rows are repaired incrementally
(dynamic/repair.py), the rest invalidated — and the landmark set stales
lazily.  Engine paths pick up each handle's dynamic sweeps so solves run
on the mutable overlay operands directly, preserving the bitwise
guarantee against the mutated snapshot.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.bellman_csr import sssp_multisource_csr
from repro.core.frontier import sssp_frontier

from repro.serve.cache import DistanceCache
from repro.serve.dispatch import DispatchPolicy, default_policy
from repro.serve.registry import GraphRegistry

VIAS = ("trivial", "cache", "landmark", "batch", "target", "mutate",
        "error")


@dataclasses.dataclass
class Query:
    """One request: ``target is None`` => full ``sssp(source)`` row,
    else a point-to-point ``dist(source, target)`` scalar."""

    qid: int
    graph: str
    source: int
    target: Optional[int] = None
    arrival: float = 0.0


@dataclasses.dataclass
class Mutation:
    """One edge-edit request against a dynamic graph: ``edit`` is the
    registry wire tuple ``("add"|"update"|"delete", u, v[, w])``.  All of
    a graph's mutations drained in one tick commit as ONE version bump
    (the repair batch granularity)."""

    qid: int
    graph: str
    edit: tuple
    arrival: float = 0.0


@dataclasses.dataclass
class Answer:
    query: "Query | Mutation"
    value: "np.ndarray | float | int | None"  # (n,) row for sssp, float
                                        # for dist, new version int for
                                        # mutate; None iff via == "error"
    via: str                            # one of VIAS
    done_at: float = 0.0                # stamped by the driver (wall clock)


class MicroBatchScheduler:
    """See module docstring.  ``max_batch`` caps distinct sources per
    tick per graph (overflow is requeued ahead of newer arrivals);
    ``p2p_solo=False`` disables the target early-exit path (everything
    residual goes through the batched engine)."""

    def __init__(
        self,
        registry: GraphRegistry,
        cache: DistanceCache,
        *,
        max_batch: int = 16,
        p2p_solo: bool = True,
        repair_rows: int = 8,
        dispatch: Optional[DispatchPolicy] = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.registry = registry
        self.cache = cache
        self.max_batch = max_batch
        self.p2p_solo = p2p_solo
        self.repair_rows = repair_rows
        self.dispatch = dispatch if dispatch is not None else default_policy()
        registry.add_evict_hook(cache.purge_graph)
        registry.add_mutate_hook(self._on_mutate)
        self._queue: "collections.deque[Query]" = collections.deque()
        self._mutations: "collections.deque[Mutation]" = collections.deque()
        self._next_qid = 0
        self.ticks = 0
        self.engine_batches = 0
        self.engine_sources = 0
        # sharded-route slices of the above plus the engines' measured
        # relaxation counters (the serve_bench sharded gate divides
        # sharded_edges by sharded_sources for edges-per-solved-source).
        self.sharded_batches = 0
        self.sharded_p2p = 0
        self.sharded_sources = 0
        self.sharded_edges = 0
        self.target_solves = 0
        self.dedup_saved = 0
        self.occupancy_sum = 0.0
        self.rows_kept = 0
        self.rows_repaired = 0
        self.rows_invalidated = 0
        self.repair_edges = 0
        self.last_mutation_error: Optional[str] = None
        self.answered_via = {v: 0 for v in VIAS}

    # -- queue ------------------------------------------------------------

    def submit(self, graph: str, source: int, target: Optional[int] = None,
               *, arrival: float = 0.0) -> Query:
        q = Query(qid=self._next_qid, graph=graph, source=int(source),
                  target=None if target is None else int(target),
                  arrival=arrival)
        self._next_qid += 1
        self._queue.append(q)
        return q

    def submit_mutation(self, graph: str, op: str, u: int, v: int,
                        w: Optional[float] = None, *,
                        arrival: float = 0.0) -> Mutation:
        """Queue one edge edit against a dynamic graph.  Edits are
        applied at the START of the next tick (before any query drained
        in the same tick is answered), all of a graph's pending edits
        committing as one mutation batch."""
        edit = (op, int(u), int(v)) if w is None else (op, int(u), int(v),
                                                       float(w))
        m = Mutation(qid=self._next_qid, graph=graph, edit=edit,
                     arrival=arrival)
        self._next_qid += 1
        self._mutations.append(m)
        return m

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._mutations)

    # -- mutation ticks ---------------------------------------------------

    def _apply_mutations(self) -> list:
        """Drain the mutation queue: one ``registry.mutate`` batch per
        graph (the registry fires :meth:`_on_mutate` to reconcile the
        cache), acked with via="mutate" answers whose value is the
        graph's new version."""
        if not self._mutations:
            return []
        drained, self._mutations = list(self._mutations), collections.deque()
        by_graph: "collections.OrderedDict[str, list]" = (
            collections.OrderedDict())
        for m in drained:
            by_graph.setdefault(m.graph, []).append(m)
        acks = []
        for name, muts in by_graph.items():
            try:
                self.registry.mutate(name, [m.edit for m in muts])
                version = self.registry.get(name).version
                acks.extend(Answer(m, version, "mutate") for m in muts)
            except (KeyError, ValueError, IndexError) as e:
                # unknown/static graph or invalid edit: fail the whole
                # graph's batch — a half-applied batch would leave the
                # trace's edge-set bookkeeping unverifiable.
                acks.extend(Answer(m, None, "error") for m in muts)
                self.last_mutation_error = str(e)
        return acks

    def _on_mutate(self, name, handle, batch, old_ops) -> None:
        """Registry mutate hook: reconcile this graph's cached rows with
        the new version.  Per row (hottest first): if no delta can touch
        it (dynamic/repair.row_affected) it is RE-KEYED to the new
        version untouched; otherwise up to ``repair_rows`` rows are
        REPAIRED in place (pred recovered against the pre-commit
        operands, then one incremental repair on the new ones —
        dynamic/repair.py) and the rest are invalidated."""
        import jax.numpy as jnp

        from repro.core.api import SsspResult
        from repro.dynamic.repair import (predecessors_from_dist_dynamic,
                                          repair_sssp, row_affected)

        if not batch.records:
            return
        # walk LRU -> MRU so the re-puts (which append at the MRU end)
        # PRESERVE the graph's recency order; the repair budget still
        # goes to the hottest rows — the affected keys nearest the MRU
        # end — by slicing the affected list from its tail.
        keys = self.cache.keys_for(name)
        rows = {k: self.cache.peek(k) for k in keys}
        affected = {k for k in keys
                    if row_affected(rows[k], batch, handle.dyn.directed)}
        budget = self.repair_rows if old_ops is not None else 0
        repair = set([k for k in keys if k in affected][-budget:]
                     if budget else [])
        for key in keys:
            source = key[-1]
            row = rows[key]
            self.cache.pop(key)
            if key not in affected:
                self.cache.put(handle.row_key(source), row)
                self.rows_kept += 1
            elif key in repair:
                pred = predecessors_from_dist_dynamic(
                    jnp.asarray(row), old_ops, jnp.int32(source))
                prev = SsspResult(
                    dist=row, pred=np.asarray(pred), sweeps=None,
                    engine="cache", sources=np.asarray([source], np.int32))
                res, _ = repair_sssp(handle.dyn, prev, batch)
                self.cache.put(handle.row_key(source), res.dist)
                self.rows_repaired += 1
                self.repair_edges += res.edges_relaxed or 0
            else:
                self.rows_invalidated += 1

    # -- dispatch ---------------------------------------------------------

    def _shards(self, handle) -> int:
        """Shard arity of this graph's cache keys: the policy's PURE size
        check (no mesh, no staging), so lookups and inserts agree on the
        key shape from the first tick onward."""
        if self.dispatch.would_shard(handle.n,
                                     dynamic=handle.dyn is not None):
            return self.dispatch.nprocs
        return 1

    def _row_key(self, handle, source: int) -> tuple:
        return handle.row_key(source, shards=self._shards(handle))

    # -- answer-without-engine paths --------------------------------------

    def _try_fast(self, handle, q: Query) -> Optional[Answer]:
        """Trivial / cache / landmark answers; None if an engine is needed.

        Only SAME-DIRECTION rows are served: an undirected graph has
        d(s, t) == d(t, s) in exact arithmetic, but f32 path sums round
        differently when traversed from the other end, so answering
        ``dist(s, t)`` from a cached/landmark *t*-row would break the
        bitwise-equal-to-serial guarantee by an ulp.  Symmetry is still
        exploited where it is exact: the landmark disconnection proof.
        """
        if q.target is not None and q.target == q.source:
            return Answer(q, 0.0, "trivial")
        row = self.cache.get(self._row_key(handle, q.source))
        if row is not None:
            val = row if q.target is None else float(row[q.target])
            return Answer(q, val, "cache")
        ls = handle.landmarks_ready()
        if ls is not None:
            row = ls.row_of(q.source)
            if row is not None:
                val = row if q.target is None else float(row[q.target])
                return Answer(q, val, "landmark")
            if (q.target is not None
                    and not np.isfinite(ls.lower_bound(q.source, q.target))):
                # some landmark reaches exactly one endpoint: s and t are
                # provably disconnected (undirected graphs only — which
                # is the only kind landmarks are built for), so inf is
                # the exact answer, no solve needed; inf is ulp-proof.
                return Answer(q, float("inf"), "landmark")
        return None

    # -- engine paths -----------------------------------------------------

    def _bucket(self, count: int) -> int:
        """Smallest power of two >= count, clamped to max_batch — the
        memoized source-axis sizes that keep repeat ticks on the same
        compiled multisource solve."""
        b = 1
        while b < count:
            b *= 2
        return min(b, self.max_batch)

    def _solve_target(self, handle, q: Query) -> Answer:
        """Point-to-point residue of a tick.

        Single-device route: one frontier solve that early-exits on the
        target (plus the landmark bound when one is admissibly
        available); the row is partial — never cached.  Sharded route:
        one ``frontier_sharded`` FULL fixpoint — no early exit exists
        across owners, but the complete row is cacheable, which the
        partial row never is (``dist[target]`` bytes identical either
        way)."""
        choice = self.dispatch.choose(handle, kind="p2p")
        if choice.sharded:
            from repro.core.sharded_csr import sssp_frontier_sharded

            parts = handle.partition(choice.nprocs)
            pops = handle.partition_ops(choice.nprocs)
            self.registry.touch_staged(handle.name)
            d, _, e = sssp_frontier_sharded(
                parts, q.source, choice.mesh, axis=choice.axis, ops=pops)
            row = np.asarray(d)[:handle.n]
            self.cache.put(self._row_key(handle, q.source), row)
            self.target_solves += 1
            self.sharded_p2p += 1
            self.sharded_sources += 1
            self.sharded_edges += int(e)
            return Answer(q, float(row[q.target]), "target")
        ops = handle.frontier_ops()
        self.registry.touch_staged(handle.name)
        lb = None
        ls = handle.landmarks_ready()
        if ls is not None:
            lb = ls.conservative_lb(q.source, q.target)
            lb = None if not np.isfinite(lb) else jnp.float32(lb)
        d, _, _, _ = sssp_frontier(
            ops, jnp.int32(q.source), n=handle.n,
            sweep_fn=handle.frontier_sweep_fn(),
            target=jnp.int32(q.target), target_lb=lb,
        )
        self.target_solves += 1
        return Answer(q, float(np.asarray(d)[q.target]), "target")

    def _solve_batch(self, handle, queries: list) -> list:
        """One bucket-padded multisource solve answering ``queries``
        (all on ``handle``'s graph, <= max_batch distinct sources)."""
        distinct: list[int] = []
        seen: set[int] = set()
        for q in queries:
            if q.source not in seen:
                seen.add(q.source)
                distinct.append(q.source)
        bucket = self._bucket(len(distinct))
        padded = distinct + [distinct[0]] * (bucket - len(distinct))
        choice = self.dispatch.choose(handle, kind="batch")
        if choice.sharded:
            from repro.core.sharded_csr import sssp_multisource_csr_sharded

            parts = handle.partition(choice.nprocs)
            pops = handle.partition_ops(choice.nprocs)
            self.registry.touch_staged(handle.name)
            D, _, e = sssp_multisource_csr_sharded(
                parts, jnp.asarray(padded, jnp.int32), choice.mesh,
                axis=choice.axis, ops=pops)
            rows = np.asarray(D)[:, :handle.n]
            self.sharded_batches += 1
            self.sharded_sources += len(distinct)
            self.sharded_edges += int(e)
        else:
            D, _ = sssp_multisource_csr(
                handle.csr_ops(), jnp.asarray(padded, jnp.int32),
                n=handle.n, sweep_fn=handle.multisource_sweep_fn())
            self.registry.touch_staged(handle.name)
            rows = np.asarray(D)
        self.engine_batches += 1
        self.engine_sources += len(distinct)
        self.dedup_saved += len(queries) - len(distinct)
        self.occupancy_sum += len(distinct) / bucket
        by_source = {s: rows[i] for i, s in enumerate(distinct)}
        out = []
        for q in queries:
            row = by_source[q.source]
            self.cache.put(self._row_key(handle, q.source), row)
            val = row if q.target is None else float(row[q.target])
            out.append(Answer(q, val, "batch"))
        return out

    # -- the tick ---------------------------------------------------------

    def tick(self) -> list:
        """Drain the queues once; returns the Answers produced this tick
        (overflow beyond max_batch distinct sources per graph is requeued
        ahead of newer arrivals).  Pending mutations are applied FIRST —
        one committed batch per graph — so every query drained in the
        same tick is answered against the post-mutation version (the
        interleaving contract launch/sssp_dynamic.py's verifier pins)."""
        if not self._queue and not self._mutations:
            return []
        self.ticks += 1
        mut_acks = self._apply_mutations()
        if not self._queue:
            for a in mut_acks:
                self.answered_via[a.via] += 1
            return mut_acks
        batch, self._queue = list(self._queue), collections.deque()
        by_graph: "collections.OrderedDict[str, list]" = (
            collections.OrderedDict())
        for q in batch:
            by_graph.setdefault(q.graph, []).append(q)
        answers: list = []
        requeue: list = []
        for name, queries in by_graph.items():
            if name not in self.registry:
                # the graph was evicted (or never registered): fail these
                # queries with error answers rather than crashing the
                # tick and losing every other graph's drained queries.
                answers.extend(Answer(q, None, "error") for q in queries)
                continue
            handle = self.registry.get(name)
            need_engine = []
            for q in queries:
                ans = self._try_fast(handle, q)
                if ans is None:
                    need_engine.append(q)
                else:
                    answers.append(ans)
            if not need_engine:
                continue
            # cap distinct sources at max_batch; queries on uncovered
            # sources wait for the next tick.  Admission is O(1) per
            # query via the set; the list keeps admission order (and is
            # what _solve_batch's dedup re-derives per-query order from).
            allowed: list[int] = []
            allowed_set: set[int] = set()
            take, defer = [], []
            for q in need_engine:
                if q.source in allowed_set:
                    take.append(q)
                elif len(allowed) < self.max_batch:
                    allowed.append(q.source)
                    allowed_set.add(q.source)
                    take.append(q)
                else:
                    defer.append(q)
            requeue.extend(defer)
            if (self.p2p_solo and len(take) == 1
                    and take[0].target is not None):
                answers.append(self._solve_target(handle, take[0]))
            else:
                answers.extend(self._solve_batch(handle, take))
        for q in reversed(requeue):
            self._queue.appendleft(q)
        answers = mut_acks + answers
        for a in answers:
            self.answered_via[a.via] += 1
        return answers

    def drain(self) -> list:
        """Tick until the queues are empty (closed-loop replay)."""
        out = []
        while self.pending:
            out.extend(self.tick())
        return out

    # -- metrics ----------------------------------------------------------

    @property
    def mean_occupancy(self) -> float:
        return (self.occupancy_sum / self.engine_batches
                if self.engine_batches else 0.0)

    def stats(self) -> dict:
        return {
            "ticks": self.ticks,
            "engine_batches": self.engine_batches,
            "engine_sources": self.engine_sources,
            "sharded_batches": self.sharded_batches,
            "sharded_p2p": self.sharded_p2p,
            "sharded_sources": self.sharded_sources,
            "sharded_edges": self.sharded_edges,
            "target_solves": self.target_solves,
            "dedup_saved": self.dedup_saved,
            "mean_occupancy": round(self.mean_occupancy, 4),
            "rows_kept": self.rows_kept,
            "rows_repaired": self.rows_repaired,
            "rows_invalidated": self.rows_invalidated,
            "repair_edges": self.repair_edges,
            "answered_via": dict(self.answered_via),
            "cache": self.cache.stats(),
            "registry": self.registry.stats(),
        }
