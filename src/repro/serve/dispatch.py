"""Engine-selection seam: one place that decides single-device vs
vertex-partitioned sharded solves.

Both entry points into the engine stack route through here instead of
hard-coding engine names: ``core.api.shortest_paths(engine="auto")`` for
one-shot callers, and ``MicroBatchScheduler`` for every served batch /
point-to-point solve (serve/scheduler.py takes a ``dispatch=`` policy).
Centralizing the choice keeps the two paths answering identically and
gives operators a single knob set.

The policy mirrors the paper's own crossover: the MPI arm wins only once
the per-rank block is big enough to hide the exchange (its Table III
speedups start at the largest graphs), so small graphs stay on the
single-device engines and only graphs with ``n >= shard_threshold``
route to the partitioned ones — and only when the runtime actually has
multiple devices to partition across.  Below the shard crossover, large
single-source solves on static CSR graphs route to the Δ-stepping
engine (core/delta_stepping.py) when the graph's weight profile keeps
its light in-ELL narrow — ``delta_threshold`` / ``would_delta`` gate
this, and the answers stay bitwise-identical either way.  Dynamic graphs (PR 5 overlays)
never shard: their serving path relies on overlay-native operands and
incremental repair, both of which are built on the single-device staged
views (a frozen CsrPartition would go stale at the first mutation).

The mesh is built once per (nprocs, axis) and cached module-wide —
serving solves hundreds of queries per second and mesh construction is
not free.  ``EngineChoice.nprocs`` doubles as the DistanceCache shard
arity: row keys of sharded-served rows carry the source's owner shard
(``registry.GraphHandle.row_key(..., shards=nprocs)``), the
cache-locality layout of "Optimizing Dijkstra for real-world
performance" (arXiv 1505.05033) — rows live with their owner.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import Optional

import jax
import numpy as np

# crossover below which partitioning loses to a single device on the
# emulated host mesh (benchmarks/serve_bench.py gates the >= side at 4
# devices); operators override per deployment via DispatchPolicy.
DEFAULT_SHARD_THRESHOLD = 20000

# vertex count from which single-device single-source solves try the
# Δ-stepping engine: below it the frontier engine's per-sweep compaction
# is cheap enough that the Δ split/staging isn't worth it (the
# benchmarks/run_bench.py gate_delta corpora sit well above).  Routing
# additionally requires the graph's delta_profile to be routable (narrow
# light in-ELL) — see DispatchPolicy.would_delta.
DEFAULT_DELTA_THRESHOLD = 4096

# query kinds the scheduler distinguishes (scheduler.tick's two solve
# paths plus api's one-shot single-source case).
KINDS = ("single", "batch", "p2p")


@functools.lru_cache(maxsize=None)
def serving_mesh(nprocs: int, axis: str = "data") -> jax.sharding.Mesh:
    """The serving layer's cached 1-D mesh over the first ``nprocs``
    devices (forced host devices in CI/benchmarks, real ones on metal)."""
    from repro.core._compat import make_mesh

    return make_mesh((nprocs,), (axis,), devices=jax.devices()[:nprocs])


@dataclasses.dataclass(frozen=True)
class EngineChoice:
    """One routing decision: which engine, on which mesh (None for the
    single-device engines), and the shard arity cache keys must carry.

    The optional statics fields let a policy return not just the engine
    but its tuning parameters, so every caller's magic numbers route
    through this one seam (ROADMAP item 4): ``delta`` is the Δ-bucket
    width for the engines that consume one, ``chunk`` the frontier
    engines' scatter chunk, ``batch_cap`` the padded multisource bucket
    ceiling the scheduler should admit per tick.  ``None`` (the
    threshold policy's value) means "caller keeps its default" — the
    measured-model policy (repro/tune/select.py) fills them from
    calibrated data.  ``via`` names which arm decided: ``"threshold"``
    for the hard-coded size rules, ``"model"`` for a fitted cost model.
    """
    engine: str
    mesh: Optional[jax.sharding.Mesh]
    axis: str = "data"
    nprocs: int = 1
    delta: Optional[float] = None
    chunk: Optional[int] = None
    batch_cap: Optional[int] = None
    via: str = "threshold"

    @property
    def sharded(self) -> bool:
        return self.nprocs > 1


class DispatchPolicy:
    """Size-threshold routing between the single-device and sharded CSR
    engine families.

    shard_threshold: vertex count at which graphs route sharded
        (inclusive).  ``None`` disables sharding outright.
    nprocs: devices to partition across; default = every visible device.
        Clamped to the visible count; 1 also disables sharding.
    axis: mesh axis name (matches the sharded engines' default).
    delta_threshold: vertex count at which non-sharded single-source
        solves on static CsrGraphs route to the Δ-stepping engine
        (inclusive), when the graph's weight profile supports it.
        ``None`` disables Δ routing.
    """

    def __init__(self, *, shard_threshold: int | None = DEFAULT_SHARD_THRESHOLD,
                 nprocs: int | None = None, axis: str = "data",
                 delta_threshold: int | None = DEFAULT_DELTA_THRESHOLD):
        avail = len(jax.devices())
        self.nprocs = avail if nprocs is None else min(int(nprocs), avail)
        self.shard_threshold = shard_threshold
        self.delta_threshold = delta_threshold
        self.axis = axis

    # engine per (family, kind); p2p stays on frontier single-device for
    # the target= early exit — sharded p2p runs the full fixpoint instead
    # (superset row, same dist[target] bytes) which the scheduler then
    # caches as a COMPLETE row, unlike the partial target= rows.
    _SINGLE = {"single": "frontier", "batch": "multisource_csr",
               "p2p": "frontier"}
    _SHARDED = {"single": "frontier_sharded",
                "batch": "multisource_csr_sharded",
                "p2p": "frontier_sharded"}

    def would_shard(self, n: int, *, dynamic: bool = False) -> bool:
        """Pure size check — no mesh/staging side effects, so callers
        (scheduler, registry) can compute deterministic cache-key shapes
        before anything is staged."""
        return (not dynamic
                and self.shard_threshold is not None
                and self.nprocs > 1
                and n >= self.shard_threshold)

    def would_delta(self, g, n: int, *, dynamic: bool = False) -> bool:
        """Whether a non-sharded single-source solve of ``g`` should use
        the Δ-stepping engine: a static (non-dynamic) CsrGraph at or
        above ``delta_threshold`` whose weight distribution yields a
        narrow light in-ELL (``delta_profile(g)["routable"]`` — dense or
        hub-in-degree-skewed graphs stay on the frontier engine, whose
        compacted push doesn't pay the pull's O(n·K_light) pass).  The
        profile is memoized on the graph, so repeat routing of a pinned
        handle is a dict lookup.  Only graphs that actually carry CSR
        arrays qualify — dense arrays / Graph inputs keep the frontier
        engine rather than paying a host-side conversion just to route.
        """
        if (dynamic or self.delta_threshold is None
                or n < self.delta_threshold):
            return False
        if getattr(g, "indptr", None) is None:      # not CSR-backed
            return False
        from repro.core.delta_stepping import delta_profile

        return bool(delta_profile(g)["routable"])

    def batch_cap(self, g) -> Optional[int]:
        """Per-tick distinct-source admission ceiling for batched solves
        of ``g``, or ``None`` for "scheduler keeps its ``max_batch``".
        Pure (no mesh/staging), called at admission time — the threshold
        policy has no opinion; the measured-model policy returns the
        calibrated bucket size (tune/select.py)."""
        return None

    def choose(self, g, *, kind: str = "single") -> EngineChoice:
        """Route one solve.  ``g`` is anything with an ``n`` (CsrGraph,
        Graph, DynamicGraph, GraphHandle-like) or a dense square array;
        dynamic graphs are detected and pinned to the single-device
        family (see module docstring)."""
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r}; choose from {KINDS}")
        from repro.dynamic.overlay import DynamicGraph  # local: serve<->dyn

        dynamic = isinstance(g, DynamicGraph) or getattr(g, "dyn", None) is not None
        n = getattr(g, "n", None)
        if n is None:
            n = int(np.asarray(g).shape[0])
        if self.would_shard(int(n), dynamic=dynamic):
            return EngineChoice(self._SHARDED[kind],
                                serving_mesh(self.nprocs, self.axis),
                                self.axis, self.nprocs)
        # kind="single" only (batch wants the shared-gather multisource
        # engine, p2p the target= early exit the Δ engine doesn't have).
        if kind == "single" and self.would_delta(g, int(n), dynamic=dynamic):
            return EngineChoice("delta_stepping", None, self.axis, 1)
        return EngineChoice(self._SINGLE[kind], None, self.axis, 1)


_DEFAULT: Optional[DispatchPolicy] = None


def default_policy() -> DispatchPolicy:
    """Process-wide policy used by ``shortest_paths(engine="auto")`` and
    by schedulers constructed without an explicit ``dispatch=``."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = DispatchPolicy()
    return _DEFAULT


def set_default_policy(
        policy: Optional[DispatchPolicy]) -> Optional[DispatchPolicy]:
    """Install (or with ``None`` reset) the process-wide policy — the
    launcher wires its ``--shard-threshold`` / ``--devices`` flags here.
    Returns the PREVIOUS policy (``None`` if it was still the lazy
    default) so callers can restore it; prefer :func:`policy_override`
    for scoped swaps."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = policy
    return prev


@contextlib.contextmanager
def policy_override(policy: Optional[DispatchPolicy]):
    """Scoped :func:`set_default_policy`: installs ``policy`` for the
    ``with`` body and restores the previous one on exit (exception
    included) — how tests and the tuner race two policies without
    leaking global state.  Yields the installed policy."""
    prev = set_default_policy(policy)
    try:
        yield policy
    finally:
        set_default_policy(prev)
