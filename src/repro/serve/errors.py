"""Typed serving failures — the error taxonomy every Answer.status draws
from.

A query server must never let one bad request poison its tick: a
malformed submit, an evicted graph, a blown deadline, a flaky engine, or
a capped solver each get a DISTINCT exception class carrying a stable
wire ``code``, and the scheduler (serve/scheduler.py) converts them into
per-query ``Answer(status=<code>, error=<instance>)`` records instead of
raising across the batch.  Only :class:`QueryRejected` is ever raised to
the submitting caller (fail-fast validation and queue saturation — the
backpressure signal); everything after admission surfaces as an Answer.

The taxonomy:

=================  ===================  ====================================
class              code                 raised / answered when
=================  ===================  ====================================
QueryRejected      rejected             submit-time validation failure, or
                                        the bounded queue is saturated
                                        (reject-on-saturation backpressure /
                                        load shedding)
GraphGone          graph_gone           the graph was evicted (or never
                                        registered) between submit and the
                                        serving tick
DeadlineExceeded   deadline_exceeded    the query's deadline passed before
                                        an engine could serve it
SolveFailed        solve_failed         an engine solve (or operand staging)
                                        raised and the per-query retry
                                        budget is exhausted
NotConverged       not_converged        the fixpoint engine hit its
                                        ``max_sweeps`` cap before
                                        convergence (SsspResult.converged
                                        False) — the labels may sit above
                                        their fixpoint and are never served
                                        as exact
SchedulerStalled   stalled              drain()'s progress guard: a tick
                                        served zero queries and retired
                                        zero (everything requeued), so the
                                        loop would spin forever
=================  ===================  ====================================

``STATUS_OK`` ("ok") is the non-error status; degraded answers (landmark
bounds, stale cache rows) keep status "ok" but carry ``exact=False`` —
the taxonomy separates *failed* from *approximate*, and the bitwise
exactness invariant binds only answers claiming ``exact=True``.
"""
from __future__ import annotations

STATUS_OK = "ok"


class ServeError(Exception):
    """Base of the serving error taxonomy; ``code`` is the stable status
    string the scheduler stamps onto failed Answers."""

    code = "error"


class QueryRejected(ServeError):
    """Refused at submit time: malformed (source/target out of range,
    non-integer, negative) or shed by the bounded queue's backpressure."""

    code = "rejected"


class GraphGone(ServeError):
    """The query's graph is not registered at serving time — evicted
    between submit and tick, or never admitted."""

    code = "graph_gone"


class DeadlineExceeded(ServeError):
    """The query's deadline passed before an engine served it."""

    code = "deadline_exceeded"


class SolveFailed(ServeError):
    """An engine solve or operand staging raised, and retries (capped
    exponential backoff, per-query budget) did not recover it."""

    code = "solve_failed"


class NotConverged(ServeError):
    """The fixpoint engine stopped at its ``max_sweeps`` cap with work
    remaining (``SsspResult.converged`` False): the distances may sit
    above their fixpoint, so they are reported as a typed failure rather
    than silently served.  Also the hook Johnson-style negative-cycle
    detection will raise through once negative weights land."""

    code = "not_converged"


class SchedulerStalled(ServeError):
    """drain()'s progress guard tripped: a tick had eligible work but
    served zero queries and retired zero — without the guard the drain
    loop would spin forever."""

    code = "stalled"


#: every status value an Answer can carry: "ok" plus the taxonomy codes.
STATUSES = (STATUS_OK,) + tuple(
    cls.code for cls in (QueryRejected, GraphGone, DeadlineExceeded,
                         SolveFailed, NotConverged, SchedulerStalled))
