"""Source-row distance cache — LRU over solved ``(graph, source)`` rows.

The serving workloads (arXiv:1505.05033's observation, reproduced by the
Zipf scenario in serve/workload.py) repeat sources heavily: a handful of
hub sources account for most queries.  Once any engine has solved a source
to its fixpoint, its (n,) distance row answers every later ``sssp(s)`` and
``dist(s, t)`` query against the same graph without touching an engine.

Rows are exact fixpoints, so cache hits preserve the bitwise-equal-to-
serial guarantee trivially: the bytes returned are the bytes solved.  Two
things must never be served from here: partial rows (a ``target=``
early-exit solve) are not inserted at all, and a *t*-row is never used to
answer ``dist(s, t)`` — undirected symmetry holds in exact arithmetic,
but f32 path sums traversed from the other end can differ by an ulp,
which would break bitwise equality with a fresh s-sourced solve.

Eviction is plain LRU by row count (each row is n * 4 bytes, so a row
budget is a byte budget per graph size); hit/miss/eviction counters feed
the serve metrics and the BENCH_serve.json cache-hit gate.
"""
from __future__ import annotations

import collections
from typing import Hashable, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry


class DistanceCache:
    """LRU cache of solved distance rows keyed by ``(graph, source)``.

    ``capacity`` bounds the number of rows held; 0 disables caching (every
    ``get`` is a miss, ``put`` is a no-op) so the sequential baseline in
    benchmarks/serve_bench.py can run the same scheduler cache-less.

    Counters live on a `MetricsRegistry` (own instance by default, or a
    shared one via ``metrics=``) under the ``cache.*`` namespace; the
    legacy ``hits``/``misses``/``evictions`` attributes and ``stats()``
    dict are views over it.
    """

    def __init__(self, capacity: int = 256,
                 metrics: Optional[MetricsRegistry] = None):
        if capacity < 0:
            raise ValueError(f"capacity must be >= 0, got {capacity}")
        self.capacity = capacity
        self._rows: "collections.OrderedDict[Hashable, np.ndarray]" = (
            collections.OrderedDict())
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._hits = self.metrics.counter("cache.hits")
        self._misses = self.metrics.counter("cache.misses")
        self._evictions = self.metrics.counter("cache.evictions")
        self.metrics.gauge("cache.rows", fn=lambda: len(self._rows))

    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    def __len__(self) -> int:
        return len(self._rows)

    def get(self, key: Hashable) -> Optional[np.ndarray]:
        """Return the cached row (refreshing its recency) or None."""
        row = self._rows.get(key)
        if row is None:
            self._misses.inc()
            return None
        self._rows.move_to_end(key)
        self._hits.inc()
        return row

    def peek(self, key: Hashable) -> Optional[np.ndarray]:
        """Like get but touches neither counters nor recency (for tests
        and for probing both endpoint rows before committing to one)."""
        return self._rows.get(key)

    def put(self, key: tuple, row: np.ndarray) -> None:
        """Insert a COMPLETE fixpoint row under a tuple key.

        Key contract: every key is a tuple whose first element is the
        graph name — ``(name, source)``, ``(name, version, source)`` for
        dynamic graphs, ``(name, shard, source)`` for sharded-routed ones
        (``GraphHandle.row_key`` builds all three).  ``keys_for`` /
        ``purge_graph`` index ``k[0]`` on every key, so a non-tuple key
        would crash the next eviction purge (or, for a str key equal to a
        graph name, be silently over-purged); reject it at insert time
        where the caller is on the stack.

        The row is FROZEN on insert: served bytes alias the stored array,
        so a caller that keeps mutating its buffer after ``put`` (e.g. a
        repair loop patching rows in place) would silently corrupt every
        later hit — the same aliasing class the overlay staging fixed in
        dynamic/overlay.py.  Borrowed/externally-owned buffers (views,
        jax exports) are copied before freezing; owned buffers are frozen
        in place, making post-insert writes through the caller's handle
        raise instead of corrupt.
        """
        if not isinstance(key, tuple):
            raise TypeError(
                f"cache keys must be (graph, ...) tuples (see "
                f"GraphHandle.row_key); got {type(key).__name__}: {key!r}")
        if self.capacity == 0:
            return
        row = np.asarray(row)
        if not row.flags.owndata:
            row = row.copy()
        row.setflags(write=False)
        if key in self._rows:
            self._rows.move_to_end(key)
        self._rows[key] = row
        while len(self._rows) > self.capacity:
            self._rows.popitem(last=False)
            self._evictions.inc()

    def pop(self, key: Hashable) -> Optional[np.ndarray]:
        """Remove and return one row without touching the hit/miss
        counters (the mutation path's selective invalidation and
        re-keying are bookkeeping, not query traffic)."""
        return self._rows.pop(key, None)

    def keys_for(self, graph: Hashable) -> list:
        """All keys belonging to ``graph``, LRU-first (keys start with
        the graph name whatever their arity — versioned dynamic keys are
        ``(graph, version, source)``, sharded ``(graph, shard, source)``,
        static ``(graph, source)``; ``put`` enforces tuple keys so the
        ``k[0]`` probe here is always the name)."""
        return [k for k in self._rows if k[0] == graph]

    def purge_graph(self, graph: Hashable) -> int:
        """Drop every row belonging to ``graph`` — every VERSION of it,
        since all keys lead with the name — wired to registry eviction so
        a re-registered name can never serve rows of the evicted graph."""
        stale = self.keys_for(graph)
        for k in stale:
            del self._rows[k]
        return len(stale)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        """Legacy flat view; the same counts appear in
        ``metrics.snapshot()`` under the ``cache.*`` namespace."""
        return {
            "rows": len(self._rows),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": round(self.hit_rate, 4),
        }
