"""Graph registry — named graph handles with staged views and a byte budget.

A query server holds a few registered graphs and answers many queries per
graph, so per-graph state that core deliberately re-derives per solve is
worth pinning here:

* the CSR container itself (``Graph`` inputs are converted once);
* the **staged device operands** — ``csr_operands`` is deliberately not
  memoized on ``CsrGraph`` (core/bellman_csr.py) because a long-lived host
  container shouldn't pin device memory; a registry entry is exactly the
  long-lived *server* object that should, so both the segment-min and the
  frontier operand pytrees are staged lazily and cached on the handle;
* the **landmark set** (serve/landmarks.py), built at registration with
  one batched multisource solve;
* the **vertex-partitioned view** (``CsrGraph.partitioned``) and its
  staged per-owner device arrays, for graphs the dispatch policy routes
  to the sharded engines (serve/dispatch.py) — built lazily on first
  sharded solve and accounted/evicted like every other staged view.

Memory is accounted with the containers' own byte counters (``CsrGraph.
nbytes``, ``LandmarkSet.nbytes``, device ``.nbytes`` of every staged
array) and bounded by an LRU **byte budget**: registering or staging past
the budget evicts the least-recently-used other graphs, fires the
``on_evict`` hooks (the scheduler purges the evicted graph's cache rows),
and drops the handle so its device buffers can be freed.  The most
recently touched graph is never evicted — a single graph over budget is
admitted (and flagged in ``stats()``) rather than leaving the server
empty.

Graphs registered as :class:`~repro.dynamic.DynamicGraph` get
**versioned handles**: ``mutate()`` edits edges in place, commits them
as one batch, stales the landmark set only when a landmark row is
actually touched (lazy re-solve on next use), and fires the mutate
hooks through which the scheduler keeps, repairs, or invalidates the
graph's cached distance rows — see serve/scheduler.py and
dynamic/repair.py.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Iterable, Optional

from repro.core import csr as csr_mod
from repro.core import graph as graph_mod
from repro.core.bellman_csr import csr_operands
from repro.core.frontier import frontier_operands

from repro.obs.metrics import MetricsRegistry
from repro.serve.landmarks import LandmarkSet, build_landmarks


def _tree_bytes(ops: Optional[dict]) -> int:
    return sum(int(a.nbytes) for a in ops.values()) if ops else 0


@dataclasses.dataclass
class GraphHandle:
    """One registered graph: the CSR container plus lazily staged views.

    A graph registered as a :class:`~repro.dynamic.DynamicGraph` makes
    the handle **versioned**: ``version`` tracks the overlay's committed
    mutation batches, both operand views resolve to the overlay's
    static-shape device arrays, the ``*_sweep_fn`` accessors return the
    dynamic sweeps the engines need on those operands, and ``row_key``
    scopes cache rows to ``(name, version, source)`` so a stale version's
    row can never answer a query against a newer graph.
    """

    name: str
    # static container; None for dynamic handles (whose container is
    # dyn.base and is REBOUND by compaction — pinning it here would both
    # retain the pre-compaction base forever and hide it from nbytes)
    cg: Optional[csr_mod.CsrGraph] = None
    landmarks: Optional[LandmarkSet] = None
    dyn: Optional[object] = None               # repro.dynamic.DynamicGraph
    landmarks_stale: bool = False
    landmark_refreshes: int = 0
    landmark_seed: int = 0
    _csr_ops: Optional[dict] = dataclasses.field(default=None, repr=False)
    _frontier_ops: Optional[dict] = dataclasses.field(default=None,
                                                      repr=False)
    # vertex-partitioned view + its staged device arrays (sharded serving
    # path, serve/dispatch.py); keyed by nprocs — a policy change restages.
    _partition: Optional[csr_mod.CsrPartition] = dataclasses.field(
        default=None, repr=False)
    _partition_ops: Optional[dict] = dataclasses.field(default=None,
                                                       repr=False)
    _partition_nprocs: int = 0

    @property
    def n(self) -> int:
        return self.dyn.n if self.dyn is not None else self.cg.n

    @property
    def m(self) -> int:
        """Stored arc count at the current version (live arcs for
        dynamic overlays) — the edge-size axis of a solve's cost record."""
        return (self.dyn.nnz_live if self.dyn is not None
                else self.cg.nnz)

    @property
    def version(self) -> int:
        """Committed mutation-batch count (0 for static graphs)."""
        return self.dyn.version if self.dyn is not None else 0

    def owner_shard(self, source: int, nprocs: int) -> int:
        """Owner block of ``source`` under the contiguous 1-D vertex
        partition (``CsrGraph.partitioned``): source // ceil(n/P)."""
        return int(source) // -(-self.n // int(nprocs))

    def row_key(self, source: int, *, shards: int = 1) -> tuple:
        """Cache key for this graph's ``source`` row at the CURRENT
        version.  Static graphs keep the plain ``(name, source)`` form;
        dynamic graphs interpose the version so every mutation batch
        implicitly retires the old keys (survivors are re-keyed by the
        scheduler's selective-invalidation hook).

        ``shards>1`` (sharded-routed graphs) interposes the source's
        OWNER SHARD instead — ``(name, shard, source)`` — so cache scans
        and future tiering can group a graph's rows by the device block
        that produced them (arXiv 1505.05033's rows-live-with-their-owner
        locality).  The scheduler derives ``shards`` from the dispatch
        policy's pure size check, never from staged state, so the key
        shape is deterministic from the first tick.  Dynamic graphs never
        shard (serve/dispatch.py), so the two extended forms don't
        collide."""
        if self.dyn is None:
            if shards > 1:
                return (self.name, self.owner_shard(source, shards), source)
            return (self.name, source)
        return (self.name, self.dyn.version, source)

    def csr_ops(self) -> dict:
        """Staged segment-min operands (multisource / bellman_csr path).
        Dynamic handles resolve to the overlay operand dict, a superset
        of the static pytree with effective weights."""
        if self.dyn is not None:
            return self.dyn.dyn_ops()
        if self._csr_ops is None:
            self._csr_ops = csr_operands(self.cg)
        return self._csr_ops

    def frontier_ops(self) -> dict:
        """Staged frontier operands (the ``target=`` point-to-point path).
        Supersets csr_ops, whose staged arrays are reused — only the
        outgoing views are uploaded on top."""
        if self.dyn is not None:
            return self.dyn.dyn_ops()
        if self._frontier_ops is None:
            self._frontier_ops = frontier_operands(
                self.cg, base_ops=self.csr_ops())
        return self._frontier_ops

    def partition(self, nprocs: int) -> csr_mod.CsrPartition:
        """The handle's vertex-partitioned view for ``nprocs`` owners,
        built once and pinned (the sharded serving path's analogue of the
        staged operand pytrees).  Dynamic graphs refuse: a CsrPartition
        freezes the arc set, so the overlay's in-place mutations would
        silently stop reaching sharded answers."""
        if self.dyn is not None:
            raise ValueError(
                f"graph {self.name!r} is dynamic; the sharded engines "
                "run on a frozen CsrPartition and never serve dynamic "
                "graphs (serve/dispatch.py pins them single-device)")
        nprocs = int(nprocs)
        if self._partition is None or self._partition_nprocs != nprocs:
            self._partition = self.cg.partitioned(nprocs)
            self._partition_ops = None
            self._partition_nprocs = nprocs
        return self._partition

    def partition_ops(self, nprocs: int) -> dict:
        """Staged per-owner device arrays over :meth:`partition` —
        memoized like the other operand pytrees so every sharded solve
        after the first skips the host->device upload."""
        parts = self.partition(nprocs)
        if self._partition_ops is None:
            from repro.core.sharded_csr import partition_operands

            self._partition_ops = partition_operands(parts)
        return self._partition_ops

    def multisource_sweep_fn(self):
        """``sweep_fn`` the batched engine needs on this handle's operands
        (None = the engine's static default)."""
        if self.dyn is None:
            return None
        from repro.dynamic.repair import dynamic_segment_sweep_multi

        return dynamic_segment_sweep_multi

    def frontier_sweep_fn(self):
        """``sweep_fn`` the frontier engine needs on this handle's
        operands (None = the engine's static default)."""
        if self.dyn is None:
            return None
        from repro.dynamic.repair import make_dynamic_flat_sweep_fn

        return make_dynamic_flat_sweep_fn()

    def landmarks_ready(self) -> Optional[LandmarkSet]:
        """The landmark set, lazily re-solved if a mutation staled it —
        the deferred half of the mutate() contract: staling is O(K) host
        tests at mutation time, the K-source re-solve only happens when a
        query actually consults the bounds (same ids, new version)."""
        if self.landmarks is not None and self.landmarks_stale:
            self.landmarks = build_landmarks(
                self.dyn if self.dyn is not None else self.cg,
                self.landmarks.k, csr_ops=self.csr_ops(),
                ids=self.landmarks.ids,
                sweep_fn=self.multisource_sweep_fn())
            self.landmarks_stale = False
            self.landmark_refreshes += 1
        return self.landmarks

    @property
    def nbytes(self) -> int:
        """Host container + landmark rows + every distinct staged device
        array (frontier_ops shares csr_ops' arrays; count each buffer
        once).  Dynamic handles account the overlay's host mirrors and
        staged buffers through the overlay's own counters."""
        if self.dyn is not None:
            total = self.dyn.nbytes + self.dyn.staged_nbytes
        else:
            total = self.cg.nbytes
        if self.landmarks is not None:
            total += self.landmarks.nbytes
        if self._partition is not None:
            total += self._partition.nbytes      # host view (all owners)
        seen = {}
        for ops in (self._csr_ops, self._frontier_ops,
                    self._partition_ops):
            if ops:
                for a in ops.values():
                    seen[id(a)] = int(a.nbytes)
        return total + sum(seen.values())


class GraphRegistry:
    """LRU-evicting map of name -> :class:`GraphHandle`.

    ``byte_budget=None`` disables eviction (the registry still accounts
    bytes).  ``on_evict(name)`` callbacks run for every evicted graph.

    Counters live on a `MetricsRegistry` (own instance by default, or a
    shared one via ``metrics=``) under the ``registry.*`` namespace; the
    legacy attributes and ``stats()`` dict are views over it.
    """

    def __init__(self, byte_budget: Optional[int] = None,
                 metrics: Optional[MetricsRegistry] = None):
        self.byte_budget = byte_budget
        self._graphs: "collections.OrderedDict[str, GraphHandle]" = (
            collections.OrderedDict())
        self._on_evict: list[Callable[[str], None]] = []
        self._on_mutate: list[Callable] = []
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._registered = self.metrics.counter("registry.registered")
        self._evicted = self.metrics.counter("registry.evicted")
        self._mutations = self.metrics.counter("registry.mutations")
        self._edges_mutated = self.metrics.counter("registry.edges_mutated")
        self.metrics.gauge("registry.graphs", fn=lambda: len(self._graphs))

    @property
    def registered(self) -> int:
        return self._registered.value

    @property
    def evicted(self) -> int:
        return self._evicted.value

    @property
    def mutations(self) -> int:
        return self._mutations.value

    @property
    def edges_mutated(self) -> int:
        return self._edges_mutated.value

    def __len__(self) -> int:
        return len(self._graphs)

    def __contains__(self, name: str) -> bool:
        return name in self._graphs

    @property
    def names(self) -> tuple:
        return tuple(self._graphs)

    @property
    def bytes_in_use(self) -> int:
        return sum(h.nbytes for h in self._graphs.values())

    def add_evict_hook(self, fn: Callable[[str], None]) -> None:
        self._on_evict.append(fn)

    def add_mutate_hook(self, fn: Callable) -> None:
        """``fn(name, handle, batch, old_ops)`` runs after every committed
        mutation batch: ``batch`` is the overlay's MutationBatch and
        ``old_ops`` the PRE-commit staged operands (None if the graph was
        never staged) — jax buffers are immutable, so holding the old
        dict long enough to recover predecessor trees against the
        previous version is free.  The scheduler's selective cache
        invalidation/repair lives here."""
        self._on_mutate.append(fn)

    def register(
        self,
        name: str,
        g: "graph_mod.Graph | csr_mod.CsrGraph | object",
        *,
        landmarks: int = 0,
        landmark_seed: int = 0,
    ) -> GraphHandle:
        """Admit a graph under ``name`` (replacing any previous holder of
        the name, which counts as an eviction).  ``landmarks=K`` runs the
        one-time ALT precompute (serve/landmarks.py) before admission.
        A :class:`~repro.dynamic.DynamicGraph` is admitted as a versioned
        mutable handle (see GraphHandle) whose edges ``mutate()`` can
        edit in place."""
        from repro.dynamic.overlay import DynamicGraph

        if isinstance(g, DynamicGraph):
            handle = GraphHandle(name=name, dyn=g)
        else:
            cg = g if isinstance(g, csr_mod.CsrGraph) else g.to_csr()
            handle = GraphHandle(name=name, cg=cg)
        handle.landmark_seed = landmark_seed
        if landmarks:
            handle.landmarks = build_landmarks(
                handle.dyn if handle.dyn is not None else handle.cg,
                landmarks, seed=landmark_seed, csr_ops=handle.csr_ops(),
                sweep_fn=handle.multisource_sweep_fn())
        if name in self._graphs:
            self._evict(name)
        self._graphs[name] = handle
        self._registered.inc()
        self._maybe_evict()
        return handle

    def mutate(self, name: str, edits: Iterable[tuple]) -> "object":
        """Apply one batch of edge edits to a dynamic graph and publish
        the new version.

        ``edits`` is an iterable of ``("add"|"update"|"delete", u, v[,
        w])`` tuples, applied in order and committed as ONE batch (the
        repair granularity).  On commit: the landmark set is staled only
        if some landmark row is actually affected (the O(K·batch) host
        tightness test of dynamic/repair.row_affected) and re-solved
        lazily on next use; the mutate hooks then run with the pre-commit
        operands so the scheduler can keep/repair/invalidate cache rows
        per source (see add_mutate_hook).  Returns the MutationBatch.
        """
        from repro.dynamic.repair import row_affected

        if name not in self._graphs:
            raise KeyError(f"graph {name!r} is not registered")
        handle = self._graphs[name]
        self._graphs.move_to_end(name)
        if handle.dyn is None:
            raise ValueError(
                f"graph {name!r} is static; register a DynamicGraph to "
                "mutate it")
        # pre-commit staged view (or None): commit swaps buffers into the
        # live operand dict in place, and the mutate hooks need the
        # previous version's buffers to recover pred trees for repair.
        old_ops = handle.dyn.staged_ops()
        try:
            for edit in edits:
                handle.dyn.apply(edit)
        except Exception:
            # a bad edit mid-batch must not leak the earlier edits into
            # the next commit: the batch applies atomically or not at all
            handle.dyn.rollback()
            raise
        batch = handle.dyn.commit()
        if batch.records:
            self._mutations.inc()
            self._edges_mutated.inc(len(batch.records))
            ls = handle.landmarks
            if ls is not None and not handle.landmarks_stale:
                handle.landmarks_stale = any(
                    row_affected(ls.D[k], batch, handle.dyn.directed)
                    for k in range(ls.k))
            for fn in self._on_mutate:
                fn(name, handle, batch, old_ops)
            self._maybe_evict()             # restaged buffers may have grown
        return batch

    def get(self, name: str) -> GraphHandle:
        """Fetch a handle, refreshing its LRU recency."""
        if name not in self._graphs:
            raise KeyError(
                f"graph {name!r} is not registered (evicted or never "
                f"admitted); registered: {list(self._graphs)}")
        self._graphs.move_to_end(name)
        return self._graphs[name]

    def touch_staged(self, name: str) -> None:
        """Re-run the budget check after a handle staged new device views
        (scheduler calls this after csr_ops()/frontier_ops() grow)."""
        if name in self._graphs:
            self._maybe_evict()

    def evict(self, name: str) -> None:
        """Force-evict one graph by name (administrative / chaos-harness
        seam; LRU budget eviction happens automatically).  Fires the
        evict hooks like any budget eviction; unknown names are a no-op
        so a racing double-evict stays idempotent."""
        if name in self._graphs:
            self._evict(name)

    def _evict(self, name: str) -> None:
        del self._graphs[name]
        self._evicted.inc()
        for fn in self._on_evict:
            fn(name)

    def _maybe_evict(self) -> None:
        if self.byte_budget is None:
            return
        # never evict the most recently touched graph: a lone over-budget
        # graph is admitted (visible via stats()['over_budget']).
        while len(self._graphs) > 1 and self.bytes_in_use > self.byte_budget:
            lru = next(iter(self._graphs))
            self._evict(lru)

    def stats(self) -> dict:
        """Legacy flat view; the event counts also appear in
        ``metrics.snapshot()`` under the ``registry.*`` namespace."""
        return {
            "graphs": len(self._graphs),
            "bytes_in_use": self.bytes_in_use,
            "byte_budget": self.byte_budget,
            "over_budget": (self.byte_budget is not None
                            and self.bytes_in_use > self.byte_budget),
            "registered": self.registered,
            "evicted": self.evicted,
            "mutations": self.mutations,
            "edges_mutated": self.edges_mutated,
            "landmark_refreshes": sum(h.landmark_refreshes
                                      for h in self._graphs.values()),
        }
