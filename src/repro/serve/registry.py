"""Graph registry — named graph handles with staged views and a byte budget.

A query server holds a few registered graphs and answers many queries per
graph, so per-graph state that core deliberately re-derives per solve is
worth pinning here:

* the CSR container itself (``Graph`` inputs are converted once);
* the **staged device operands** — ``csr_operands`` is deliberately not
  memoized on ``CsrGraph`` (core/bellman_csr.py) because a long-lived host
  container shouldn't pin device memory; a registry entry is exactly the
  long-lived *server* object that should, so both the segment-min and the
  frontier operand pytrees are staged lazily and cached on the handle;
* the **landmark set** (serve/landmarks.py), built at registration with
  one batched multisource solve.

Memory is accounted with the containers' own byte counters (``CsrGraph.
nbytes``, ``LandmarkSet.nbytes``, device ``.nbytes`` of every staged
array) and bounded by an LRU **byte budget**: registering or staging past
the budget evicts the least-recently-used other graphs, fires the
``on_evict`` hooks (the scheduler purges the evicted graph's cache rows),
and drops the handle so its device buffers can be freed.  The most
recently touched graph is never evicted — a single graph over budget is
admitted (and flagged in ``stats()``) rather than leaving the server
empty.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Optional

from repro.core import csr as csr_mod
from repro.core import graph as graph_mod
from repro.core.bellman_csr import csr_operands
from repro.core.frontier import frontier_operands

from repro.serve.landmarks import LandmarkSet, build_landmarks


def _tree_bytes(ops: Optional[dict]) -> int:
    return sum(int(a.nbytes) for a in ops.values()) if ops else 0


@dataclasses.dataclass
class GraphHandle:
    """One registered graph: the CSR container plus lazily staged views."""

    name: str
    cg: csr_mod.CsrGraph
    landmarks: Optional[LandmarkSet] = None
    _csr_ops: Optional[dict] = dataclasses.field(default=None, repr=False)
    _frontier_ops: Optional[dict] = dataclasses.field(default=None,
                                                      repr=False)

    @property
    def n(self) -> int:
        return self.cg.n

    def csr_ops(self) -> dict:
        """Staged segment-min operands (multisource / bellman_csr path)."""
        if self._csr_ops is None:
            self._csr_ops = csr_operands(self.cg)
        return self._csr_ops

    def frontier_ops(self) -> dict:
        """Staged frontier operands (the ``target=`` point-to-point path).
        Supersets csr_ops, whose staged arrays are reused — only the
        outgoing views are uploaded on top."""
        if self._frontier_ops is None:
            self._frontier_ops = frontier_operands(
                self.cg, base_ops=self.csr_ops())
        return self._frontier_ops

    @property
    def nbytes(self) -> int:
        """Host CSR + landmark rows + every distinct staged device array
        (frontier_ops shares csr_ops' arrays; count each buffer once)."""
        total = self.cg.nbytes
        if self.landmarks is not None:
            total += self.landmarks.nbytes
        seen = {}
        for ops in (self._csr_ops, self._frontier_ops):
            if ops:
                for a in ops.values():
                    seen[id(a)] = int(a.nbytes)
        return total + sum(seen.values())


class GraphRegistry:
    """LRU-evicting map of name -> :class:`GraphHandle`.

    ``byte_budget=None`` disables eviction (the registry still accounts
    bytes).  ``on_evict(name)`` callbacks run for every evicted graph.
    """

    def __init__(self, byte_budget: Optional[int] = None):
        self.byte_budget = byte_budget
        self._graphs: "collections.OrderedDict[str, GraphHandle]" = (
            collections.OrderedDict())
        self._on_evict: list[Callable[[str], None]] = []
        self.registered = 0
        self.evicted = 0

    def __len__(self) -> int:
        return len(self._graphs)

    def __contains__(self, name: str) -> bool:
        return name in self._graphs

    @property
    def names(self) -> tuple:
        return tuple(self._graphs)

    @property
    def bytes_in_use(self) -> int:
        return sum(h.nbytes for h in self._graphs.values())

    def add_evict_hook(self, fn: Callable[[str], None]) -> None:
        self._on_evict.append(fn)

    def register(
        self,
        name: str,
        g: "graph_mod.Graph | csr_mod.CsrGraph",
        *,
        landmarks: int = 0,
        landmark_seed: int = 0,
    ) -> GraphHandle:
        """Admit a graph under ``name`` (replacing any previous holder of
        the name, which counts as an eviction).  ``landmarks=K`` runs the
        one-time ALT precompute (serve/landmarks.py) before admission."""
        cg = g if isinstance(g, csr_mod.CsrGraph) else g.to_csr()
        handle = GraphHandle(name=name, cg=cg)
        if landmarks:
            handle.landmarks = build_landmarks(
                cg, landmarks, seed=landmark_seed, csr_ops=handle.csr_ops())
        if name in self._graphs:
            self._evict(name)
        self._graphs[name] = handle
        self.registered += 1
        self._maybe_evict()
        return handle

    def get(self, name: str) -> GraphHandle:
        """Fetch a handle, refreshing its LRU recency."""
        if name not in self._graphs:
            raise KeyError(
                f"graph {name!r} is not registered (evicted or never "
                f"admitted); registered: {list(self._graphs)}")
        self._graphs.move_to_end(name)
        return self._graphs[name]

    def touch_staged(self, name: str) -> None:
        """Re-run the budget check after a handle staged new device views
        (scheduler calls this after csr_ops()/frontier_ops() grow)."""
        if name in self._graphs:
            self._maybe_evict()

    def _evict(self, name: str) -> None:
        del self._graphs[name]
        self.evicted += 1
        for fn in self._on_evict:
            fn(name)

    def _maybe_evict(self) -> None:
        if self.byte_budget is None:
            return
        # never evict the most recently touched graph: a lone over-budget
        # graph is admitted (visible via stats()['over_budget']).
        while len(self._graphs) > 1 and self.bytes_in_use > self.byte_budget:
            lru = next(iter(self._graphs))
            self._evict(lru)

    def stats(self) -> dict:
        return {
            "graphs": len(self._graphs),
            "bytes_in_use": self.bytes_in_use,
            "byte_budget": self.byte_budget,
            "over_budget": (self.byte_budget is not None
                            and self.bytes_in_use > self.byte_budget),
            "registered": self.registered,
            "evicted": self.evicted,
        }
