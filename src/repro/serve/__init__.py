"""SSSP query-serving subsystem: registry -> scheduler -> engines -> cache.

The serving layer over the core engine stack (see README.md §Serving):
``GraphRegistry`` admits named graphs under a byte budget and pins their
staged views; ``MicroBatchScheduler`` coalesces deduplicated sources into
bucket-padded ``multisource_csr`` solves and point-to-point residues into
``target=`` frontier solves; ``DistanceCache`` answers hot sources from
solved rows; ``dispatch`` is the engine-selection seam routing
large-graph solves to the vertex-partitioned sharded engines on a
cached mesh; ``landmarks`` precomputes ALT bounds per graph;
``workload`` generates the synthetic open-loop traces the driver
(repro/launch/sssp_serve.py) replays; ``errors`` is the typed failure
taxonomy every ``Answer.status`` draws from and ``faults`` the seeded
chaos-injection plans the scheduler probes (README.md §Robustness).
"""
from repro.serve.cache import DistanceCache
from repro.serve.dispatch import (DispatchPolicy, EngineChoice,
                                  default_policy, policy_override,
                                  serving_mesh, set_default_policy)
from repro.serve.errors import (STATUS_OK, STATUSES, DeadlineExceeded,
                                GraphGone, NotConverged, QueryRejected,
                                SchedulerStalled, ServeError, SolveFailed)
from repro.serve.faults import FaultPlan, FaultRecord, InjectedFault, SITES
from repro.serve.landmarks import LandmarkSet, build_landmarks
from repro.serve.registry import GraphHandle, GraphRegistry
from repro.serve.scheduler import (Answer, MicroBatchScheduler, Mutation,
                                   Query)
from repro.serve.workload import (LatencyRecorder, MutationEvent, SCENARIOS,
                                  TraceEvent, make_churn_trace, make_trace)

__all__ = [
    "Answer",
    "DeadlineExceeded",
    "DispatchPolicy",
    "DistanceCache",
    "EngineChoice",
    "FaultPlan",
    "FaultRecord",
    "GraphGone",
    "GraphHandle",
    "GraphRegistry",
    "InjectedFault",
    "LandmarkSet",
    "LatencyRecorder",
    "MicroBatchScheduler",
    "Mutation",
    "MutationEvent",
    "NotConverged",
    "Query",
    "QueryRejected",
    "SCENARIOS",
    "SITES",
    "STATUSES",
    "STATUS_OK",
    "SchedulerStalled",
    "ServeError",
    "SolveFailed",
    "TraceEvent",
    "build_landmarks",
    "default_policy",
    "policy_override",
    "make_churn_trace",
    "make_trace",
    "serving_mesh",
    "set_default_policy",
]
