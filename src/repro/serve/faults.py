"""Seeded fault injection for the serving stack — the chaos harness.

A :class:`FaultPlan` is a deterministic schedule of failures injected at
the serving seams that already exist (nothing is monkeypatched; the
scheduler probes the plan at each seam), so a chaos replay is exactly
reproducible from ``(workload seed, fault seed, rates)`` and the
driver's verifier can cross-check every injected fault against the typed
``Answer.status`` (or retry counter) that surfaced it.

Sites — each maps to one seam in serve/scheduler.py / serve/registry.py:

``solve``
    The engine call of a batch/p2p solve raises :class:`InjectedFault`
    *before* the solve runs — the transient-failure path.  The scheduler
    catches it, requeues the tick's queries with capped exponential
    backoff, and answers ``solve_failed`` only once the per-query retry
    budget is spent.
``stage``
    Operand staging (``handle.csr_ops()`` / ``frontier_ops()`` /
    ``partition_ops()``) raises before the engine sees the operands —
    same surfaced behavior as ``solve``, different seam.
``evict``
    The query's graph is force-evicted from the registry *mid-tick*,
    after admission but before its solve — the evicted-graph race: the
    scheduler must answer that graph's drained queries ``graph_gone``
    (and purge its cache rows via the evict hook) while the same tick's
    other graphs still serve.
``mutate``
    A poisoned edit is appended to a drained mutation batch, forcing the
    registry's atomic-rollback seam: the whole batch must roll back
    (``DynamicGraph.rollback``) and every mutation in it is acked
    ``rejected`` — no half-applied version may ever be published.
``clip``
    The solve runs with ``max_sweeps=1``: the engine returns
    ``converged=False`` and the scheduler must answer ``not_converged``
    instead of serving the capped labels — the solver-guardrail path.

Probes draw from independent per-site generators seeded ``(seed,
site)``, so adding probes at one site never shifts another site's
schedule.  Every fired fault is logged as a :class:`FaultRecord`;
``counts()`` is what launch/sssp_serve.py's ``--chaos`` verifier
reconciles against the replay's answers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

SITES = ("solve", "stage", "evict", "mutate", "clip")


class InjectedFault(RuntimeError):
    """The synthetic transient failure a FaultPlan raises at the solve /
    stage seams.  Deliberately NOT a ServeError: the scheduler's retry
    path must treat it exactly like any unexpected engine exception."""


@dataclasses.dataclass(frozen=True)
class FaultRecord:
    """One fired fault: where, the per-site firing index, and the graph
    being served when it fired (None where no graph is in scope)."""

    site: str
    seq: int
    graph: Optional[str] = None
    detail: str = ""


class FaultPlan:
    """Deterministic seeded fault schedule over the sites above.

    ``rates`` maps site -> firing probability per probe (unlisted sites
    never fire); ``max_per_site`` caps how often each site fires so a
    high rate cannot starve a replay of successful answers entirely.
    ``clip_sweeps`` is the ``max_sweeps`` value the ``clip`` site forces
    on a solve (1 = maximally capped).
    """

    def __init__(self, *, seed: int = 0, rates: Optional[dict] = None,
                 max_per_site: Optional[int] = None, clip_sweeps: int = 1):
        rates = dict(rates or {})
        unknown = set(rates) - set(SITES)
        if unknown:
            raise ValueError(f"unknown fault sites {sorted(unknown)}; "
                             f"choose from {SITES}")
        self.seed = seed
        self.rates = {s: float(rates.get(s, 0.0)) for s in SITES}
        self.max_per_site = max_per_site
        self.clip_sweeps = int(clip_sweeps)
        self._rngs = {s: np.random.default_rng((seed, i))
                      for i, s in enumerate(SITES)}
        self.injected: list[FaultRecord] = []
        self._fired = {s: 0 for s in SITES}
        self.probes = {s: 0 for s in SITES}

    def roll(self, site: str, *, graph: Optional[str] = None,
             detail: str = "") -> bool:
        """One probe at ``site``: True iff the fault fires (and is then
        logged).  Each probe consumes one draw from the site's own
        stream even when capped, so the schedule is a pure function of
        the probe sequence."""
        if site not in self._rngs:
            raise ValueError(f"unknown fault site {site!r}")
        self.probes[site] += 1
        fired = bool(self._rngs[site].random() < self.rates[site])
        if fired and (self.max_per_site is not None
                      and self._fired[site] >= self.max_per_site):
            fired = False
        if fired:
            self.injected.append(FaultRecord(
                site=site, seq=self._fired[site], graph=graph,
                detail=detail))
            self._fired[site] += 1
        return fired

    def maybe_raise(self, site: str, *, graph: Optional[str] = None,
                    detail: str = "") -> None:
        """Probe and raise :class:`InjectedFault` when the fault fires
        (the solve / stage seams)."""
        if self.roll(site, graph=graph, detail=detail):
            raise InjectedFault(
                f"injected {site} fault"
                + (f" on graph {graph!r}" if graph else ""))

    def counts(self) -> dict:
        """Fired-fault count per site (zeros included)."""
        return dict(self._fired)

    def summary(self) -> dict:
        return {
            "seed": self.seed,
            "rates": {s: r for s, r in self.rates.items() if r},
            "probes": dict(self.probes),
            "fired": self.counts(),
            "total_fired": len(self.injected),
        }
