"""Synthetic serving workloads: open-loop arrival traces + latency metrics.

Three scenarios, matching the workload taxonomy of arXiv:1505.05033 (real
query streams are repeat-heavy) scaled down to a reproducible generator:

* ``uniform`` — full ``sssp(s)`` queries, sources uniform over the graph:
  the cache-hostile baseline where batching + dedup must carry throughput.
* ``zipf`` — ``sssp(s)`` queries with Zipf-skewed sources (rank
  probability 1/rank^a over a seeded permutation): a few hub sources
  dominate, so the distance cache and dedup absorb most of the load.
* ``p2p`` — point-to-point heavy: mostly ``dist(s, t)`` queries with
  Zipf-skewed endpoints, a sprinkle of full-row queries; exercises the
  landmark answers and the ``target=`` early-exit path.

Arrivals are **open loop**: exponential inter-arrival times at ``rate``
queries/s, independent of service progress — the server falls behind when
a tick is slower than the arrivals it spans, and latency includes that
queueing delay.  Multi-graph traces interleave queries across graphs
uniformly.

``LatencyRecorder`` folds per-answer latencies into p50/p99, queries/s and
per-path counts; scenario summaries land in BENCH_serve.json
(benchmarks/serve_bench.py) and the driver printout
(launch/sssp_serve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

SCENARIOS = ("uniform", "zipf", "p2p")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    arrival: float              # seconds since trace start
    graph: str
    source: int
    target: Optional[int]       # None => full sssp row


def zipf_vertices(rng: np.random.Generator, n: int, size: int,
                  a: float = 1.1,
                  perm: Optional[np.ndarray] = None) -> np.ndarray:
    """Zipf-skewed vertex ids: probability 1/rank^a over a permutation of
    [0, n), so the hot set is scattered over the id space (not just the
    low ids).  Pass ``perm`` to pin the rank->vertex assignment — two
    traces sharing a perm share their hot vertices, which is what makes a
    steady-state cache measurement meaningful."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** -a
    p /= p.sum()
    if perm is None:
        perm = rng.permutation(n)
    return perm[rng.choice(n, size=size, p=p)].astype(np.int64)


def make_trace(
    scenario: str,
    graphs: Sequence[tuple],        # (name, n) pairs
    *,
    num_queries: int,
    rate: float,
    seed: int = 0,
    zipf_a: float = 1.1,
    p2p_frac: float = 0.85,
    hot_seed: Optional[int] = None,
) -> list:
    """Generate one open-loop trace (see module docstring).  ``rate`` is
    the mean arrival rate in queries/s; ``p2p_frac`` only applies to the
    p2p scenario (the rest of its queries are full rows).  ``hot_seed``
    pins the Zipf rank->vertex permutation independently of ``seed``, so
    differently-seeded traces target the same hot set (the steady-state
    serving shape benchmarks/serve_bench.py measures)."""
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; "
                         f"choose from {SCENARIOS}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=num_queries))
    which = rng.integers(0, len(graphs), size=num_queries)
    # two skewed draws per event covers every scenario's worst case; the
    # per-graph pools are drawn up front so the Zipf setup (perm + rank
    # probabilities, O(n)) runs once per graph, not per event.
    pools = {}
    for gi, (name, n) in enumerate(graphs):
        if scenario == "uniform":
            pools[gi] = rng.integers(0, n, size=2 * num_queries)
        else:
            perm = None
            if hot_seed is not None:
                perm = np.random.default_rng(
                    (hot_seed, gi)).permutation(n)
            pools[gi] = zipf_vertices(rng, n, 2 * num_queries, zipf_a,
                                      perm=perm)
    p2p_draw = rng.random(num_queries)
    events = []
    for i in range(num_queries):
        gi = int(which[i])
        name, n = graphs[gi]
        src = int(pools[gi][2 * i])
        tgt = None
        if scenario == "p2p" and p2p_draw[i] < p2p_frac:
            tgt = int(pools[gi][2 * i + 1])
        events.append(TraceEvent(float(arrivals[i]), name, src, tgt))
    return events


class LatencyRecorder:
    """Accumulates per-answer latencies and renders the serving summary."""

    def __init__(self):
        self.latencies: list[float] = []
        self.first_arrival: Optional[float] = None
        self.last_done: float = 0.0

    def observe(self, answer, now: float) -> None:
        """Record one Answer completed at wall-clock offset ``now``
        (latency = completion - arrival, i.e. queueing + service)."""
        self.latencies.append(now - answer.query.arrival)
        a = answer.query.arrival
        if self.first_arrival is None or a < self.first_arrival:
            self.first_arrival = a
        self.last_done = max(self.last_done, now)

    def summary(self) -> dict:
        lat = np.asarray(self.latencies, np.float64)
        if lat.size == 0:
            return {"queries": 0}
        span = max(self.last_done - (self.first_arrival or 0.0), 1e-9)
        return {
            "queries": int(lat.size),
            "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "max_ms": round(float(lat.max()) * 1e3, 3),
            "qps": round(lat.size / span, 2),
        }
