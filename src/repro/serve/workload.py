"""Synthetic serving workloads: open-loop arrival traces + latency metrics.

Three scenarios, matching the workload taxonomy of arXiv:1505.05033 (real
query streams are repeat-heavy) scaled down to a reproducible generator:

* ``uniform`` — full ``sssp(s)`` queries, sources uniform over the graph:
  the cache-hostile baseline where batching + dedup must carry throughput.
* ``zipf`` — ``sssp(s)`` queries with Zipf-skewed sources (rank
  probability 1/rank^a over a seeded permutation): a few hub sources
  dominate, so the distance cache and dedup absorb most of the load.
* ``p2p`` — point-to-point heavy: mostly ``dist(s, t)`` queries with
  Zipf-skewed endpoints, a sprinkle of full-row queries; exercises the
  landmark answers and the ``target=`` early-exit path.

Arrivals are **open loop**: exponential inter-arrival times at ``rate``
queries/s, independent of service progress — the server falls behind when
a tick is slower than the arrivals it spans, and latency includes that
queueing delay.  Multi-graph traces interleave queries across graphs
uniformly.

``LatencyRecorder`` folds per-answer latencies into p50/p99, queries/s and
per-path counts; scenario summaries land in BENCH_serve.json
(benchmarks/serve_bench.py) and the driver printout
(launch/sssp_serve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

SCENARIOS = ("uniform", "zipf", "p2p")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    arrival: float              # seconds since trace start
    graph: str
    source: int
    target: Optional[int]       # None => full sssp row
    deadline: Optional[float] = None    # absolute (trace clock); None =
                                        # the query never expires


@dataclasses.dataclass(frozen=True)
class MutationEvent:
    """One edge edit in a churn trace (see :func:`make_churn_trace`).
    ``op`` is the registry wire verb; ``w`` is None for deletes."""

    arrival: float
    graph: str
    op: str                     # "add" | "update" | "delete"
    u: int
    v: int
    w: Optional[float]


def zipf_vertices(rng: np.random.Generator, n: int, size: int,
                  a: float = 1.1,
                  perm: Optional[np.ndarray] = None) -> np.ndarray:
    """Zipf-skewed vertex ids: probability 1/rank^a over a permutation of
    [0, n), so the hot set is scattered over the id space (not just the
    low ids).  Pass ``perm`` to pin the rank->vertex assignment — two
    traces sharing a perm share their hot vertices, which is what makes a
    steady-state cache measurement meaningful."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** -a
    p /= p.sum()
    if perm is None:
        perm = rng.permutation(n)
    return perm[rng.choice(n, size=size, p=p)].astype(np.int64)


def make_trace(
    scenario: str,
    graphs: Sequence[tuple],        # (name, n) pairs
    *,
    num_queries: int,
    rate: float,
    seed: int = 0,
    zipf_a: float = 1.1,
    p2p_frac: float = 0.85,
    hot_seed: Optional[int] = None,
    deadline: Optional[float] = None,
) -> list:
    """Generate one open-loop trace (see module docstring).  ``rate`` is
    the mean arrival rate in queries/s; ``p2p_frac`` only applies to the
    p2p scenario (the rest of its queries are full rows).  ``hot_seed``
    pins the Zipf rank->vertex permutation independently of ``seed``, so
    differently-seeded traces target the same hot set (the steady-state
    serving shape benchmarks/serve_bench.py measures).  ``deadline``
    stamps every event with ``arrival + deadline`` seconds (the
    per-query latency SLO the overload benchmark and chaos driver feed
    to ``submit(deadline=...)``); None leaves queries unexpirable."""
    if scenario not in SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; "
                         f"choose from {SCENARIOS}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=num_queries))
    which = rng.integers(0, len(graphs), size=num_queries)
    # two skewed draws per event covers every scenario's worst case; the
    # per-graph pools are drawn up front so the Zipf setup (perm + rank
    # probabilities, O(n)) runs once per graph, not per event.
    pools = {}
    for gi, (name, n) in enumerate(graphs):
        if scenario == "uniform":
            pools[gi] = rng.integers(0, n, size=2 * num_queries)
        else:
            perm = None
            if hot_seed is not None:
                perm = np.random.default_rng(
                    (hot_seed, gi)).permutation(n)
            pools[gi] = zipf_vertices(rng, n, 2 * num_queries, zipf_a,
                                      perm=perm)
    p2p_draw = rng.random(num_queries)
    events = []
    for i in range(num_queries):
        gi = int(which[i])
        name, n = graphs[gi]
        src = int(pools[gi][2 * i])
        tgt = None
        if scenario == "p2p" and p2p_draw[i] < p2p_frac:
            tgt = int(pools[gi][2 * i + 1])
        t = float(arrivals[i])
        events.append(TraceEvent(
            t, name, src, tgt,
            deadline=None if deadline is None else t + deadline))
    return events


class EdgeChurn:
    """Seeded edge-mutation sampler over an evolving undirected edge set —
    the single source of churn sampling, shared by :func:`make_churn_trace`
    (which emits :class:`MutationEvent`\\ s) and benchmarks/dynamic_bench.py
    (which applies the edits directly to a DynamicGraph).

    Deletes and updates pick a uniformly random LIVE edge (swap-pop
    list); adds rejection-sample an absent pair; op is uniform over
    add/update/delete.  The internal mirror evolves with every sample,
    so any sampled sequence is valid when applied in order.
    """

    def __init__(self, cg, rng: np.random.Generator, *,
                 max_weight: float = 100.0):
        if getattr(cg, "directed", False):
            raise ValueError("churn traces assume undirected graphs "
                             "(the serve landmark path's contract)")
        self.n = int(cg.n)
        self.rng = rng
        self.max_weight = max_weight
        u = np.asarray(cg.indices, np.int64)
        v = cg.dst_ids().astype(np.int64)
        keep = u < v
        self.live = list(map(tuple, np.stack([u[keep], v[keep]], 1)))
        self.edge_set = set(self.live)

    def _weight(self) -> float:
        return float(np.float32(self.rng.uniform(0.5, self.max_weight)))

    def sample(self) -> tuple:
        """One ``(op, u, v, w)`` edit (w is None for deletes)."""
        op = ("add", "update", "delete")[int(self.rng.integers(3))]
        if op == "add" or not self.live:
            while True:
                a = int(self.rng.integers(self.n))
                b = int(self.rng.integers(self.n))
                key = (min(a, b), max(a, b))
                if a != b and key not in self.edge_set:
                    break
            self.edge_set.add(key)
            self.live.append(key)
            return ("add", key[0], key[1], self._weight())
        j = int(self.rng.integers(len(self.live)))
        key = self.live[j]
        if op == "delete":
            self.live[j] = self.live[-1]
            self.live.pop()
            self.edge_set.discard(key)
            return ("delete", key[0], key[1], None)
        return ("update", key[0], key[1], self._weight())


def make_churn_trace(
    graphs: Sequence[tuple],        # (name, CsrGraph-like) pairs
    *,
    num_events: int,
    rate: float,
    mutate_frac: float = 0.15,
    p2p_frac: float = 0.3,
    seed: int = 0,
    zipf_a: float = 1.1,
    hot_seed: Optional[int] = None,
    max_weight: float = 100.0,
) -> list:
    """Open-loop **churn** trace: a mixed stream of mutations and queries
    over slowly-changing graphs — the dynamic-serving shape of
    arXiv:1505.05033's repeat-heavy workloads.

    Each event is a mutation with probability ``mutate_frac``, sampled by
    a per-graph :class:`EdgeChurn` (deletes/updates pick a live edge,
    adds an absent pair — updates may raise or lower the weight, so both
    repair directions occur), else a Zipf-sourced query (a point-to-point
    pair with probability ``p2p_frac``).  The sampler's evolving edge-set
    mirror keeps the trace self-consistent: replayed in arrival order
    against a :class:`~repro.dynamic.DynamicGraph` every edit is valid by
    construction.  ``graphs`` carries the actual containers (unlike
    :func:`make_trace`'s (name, n) pairs) because the generator must see
    the edge sets.  ``hot_seed`` pins the query hot set as in
    ``make_trace``.
    """
    if not 0 <= mutate_frac <= 1:
        raise ValueError(f"mutate_frac must be in [0, 1], got {mutate_frac}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=num_events))
    which = rng.integers(0, len(graphs), size=num_events)
    churn, pools = {}, {}
    for gi, (name, cg) in enumerate(graphs):
        churn[gi] = EdgeChurn(cg, rng, max_weight=max_weight)
        perm = None
        if hot_seed is not None:
            perm = np.random.default_rng((hot_seed, gi)).permutation(cg.n)
        pools[gi] = zipf_vertices(rng, cg.n, 2 * num_events, zipf_a,
                                  perm=perm)
    events = []
    for i in range(num_events):
        gi = int(which[i])
        name = graphs[gi][0]
        t = float(arrivals[i])
        if rng.random() < mutate_frac:
            op, u, v, w = churn[gi].sample()
            events.append(MutationEvent(t, name, op, u, v, w))
        else:
            src = int(pools[gi][2 * i])
            tgt = None
            if rng.random() < p2p_frac:
                tgt = int(pools[gi][2 * i + 1])
            events.append(TraceEvent(t, name, src, tgt))
    return events


class LatencyRecorder:
    """Accumulates per-answer latencies and renders the serving summary.

    End-to-end latency (completion - arrival) is split into its two
    components when the scheduler stamps ``Answer.service_start`` (the
    tick clock at which the answering tick began):

    * **queue wait** = service_start - arrival — time spent waiting for
      a tick to pick the query up (batching + backlog delay), and
    * **service time** = completion - service_start — time inside the
      answering tick (staging + solve + cache work).

    The split is the attribution the paper's "synchronization overhead"
    claim needs: a fat queue_p99 with thin service_p99 is a scheduling/
    arrival-rate problem, the reverse is an engine problem.  Answers
    without a stamp (service_start None) count only toward end-to-end.
    """

    def __init__(self):
        self.latencies: list[float] = []
        self.queue_waits: list[float] = []
        self.service_times: list[float] = []
        self.first_arrival: Optional[float] = None
        self.last_done: float = 0.0

    def observe(self, answer, now: float) -> None:
        """Record one Answer completed at wall-clock offset ``now``
        (latency = completion - arrival, i.e. queueing + service)."""
        self.latencies.append(now - answer.query.arrival)
        start = getattr(answer, "service_start", None)
        if start is not None:
            self.queue_waits.append(max(0.0, start - answer.query.arrival))
            self.service_times.append(max(0.0, now - start))
        a = answer.query.arrival
        if self.first_arrival is None or a < self.first_arrival:
            self.first_arrival = a
        self.last_done = max(self.last_done, now)

    @staticmethod
    def _pcts(values: list, prefix: str) -> dict:
        xs = np.asarray(values, np.float64)
        if xs.size == 0:
            return {}
        return {
            f"{prefix}_p50_ms": round(float(np.percentile(xs, 50)) * 1e3, 3),
            f"{prefix}_p99_ms": round(float(np.percentile(xs, 99)) * 1e3, 3),
        }

    def summary(self) -> dict:
        lat = np.asarray(self.latencies, np.float64)
        if lat.size == 0:
            return {"queries": 0}
        span = max(self.last_done - (self.first_arrival or 0.0), 1e-9)
        out = {
            "queries": int(lat.size),
            "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 3),
            "max_ms": round(float(lat.max()) * 1e3, 3),
            "qps": round(lat.size / span, 2),
        }
        out.update(self._pcts(self.queue_waits, "queue"))
        out.update(self._pcts(self.service_times, "service"))
        return out
