"""ALT landmark preprocessing — admissible bounds from batched solves.

"Optimizing Dijkstra for real-world performance" (arXiv:1505.05033) and the
heuristic-search framing of arXiv:2506.19349 both pay a one-time
precomputation to make repeated point-to-point queries cheap.  This module
is the ALT (A*, Landmarks, Triangle inequality) half of that trade: per
registered graph we run ONE batched ``multisource_csr`` solve over K
sampled landmark vertices — the same engine call a scheduler tick makes,
so the precompute is exactly as fast as serving K sources — and keep the
(K, n) distance matrix.

For an undirected graph the triangle inequality gives, for every landmark
L, the admissible lower bound

    d(s, t) >= |d(L, s) - d(L, t)|

(and the upper bound ``d(L, s) + d(L, t)``).  Three uses downstream:

* **exact answers**: a query *sourced* at a landmark (s in ``ids``) reads
  its solved row — bitwise-identical to any engine, it IS an engine row.
  (A query *targeting* a landmark is deliberately not answered from the
  reversed row: undirected symmetry is exact in real arithmetic but f32
  path sums traversed from the other end can differ by an ulp.)
* **exact unreachability**: if some landmark reaches s but not t, the two
  are in different components and ``d(s, t) = inf`` exactly.
* **pruning**: the lower bound feeds the frontier engines' ``target_lb=``
  early exit (core/frontier.py).  Exactness there demands admissibility,
  and the engine distances are f32 path sums whose rounding can nudge
  ``|a - b|`` a few ulps above the true f32 distance — so
  :meth:`LandmarkSet.conservative_lb` shrinks the bound by a relative +
  absolute margin before it is used as a stopping rule.  A shrunken bound
  can only fire later (never wrongly), so serving stays oracle-exact.

Directed graphs would need backward landmark distances for admissibility;
the registry refuses to build landmarks for them rather than serve an
inadmissible bound.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bellman_csr import csr_operands, sssp_multisource_csr

# conservative_lb margins: engine distances are f32 path sums, so the
# subtraction below can exceed the true f32 distance by O(eps) relative
# rounding; shrink well past one ulp before using the bound as a stop rule.
_REL_MARGIN = 1e-5
_ABS_MARGIN = 1e-4


@dataclasses.dataclass(frozen=True)
class LandmarkSet:
    """K solved landmark rows for one graph.

    ids: (K,) int32 landmark vertex ids.
    D:   (K, n) float32 — row k is the exact SSSP row of ``ids[k]``, the
         output of one batched multisource solve (inf = unreachable).
    """

    ids: np.ndarray
    D: np.ndarray

    @property
    def k(self) -> int:
        return int(self.ids.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.ids.nbytes + self.D.nbytes)

    def row_of(self, vertex: int):
        """The solved distance row if ``vertex`` is a landmark, else None
        — the 'cache-adjacent' exact answer path."""
        hit = np.nonzero(self.ids == vertex)[0]
        return self.D[int(hit[0])] if hit.size else None

    def lower_bound(self, s: int, t: int) -> float:
        """Admissible (in exact arithmetic) lower bound on d(s, t):
        ``max_L |d(L,s) - d(L,t)|``, computed in float64 over the f32
        rows.  Returns inf when some landmark reaches exactly one of the
        endpoints (a proof of disconnection on an undirected graph), 0.0
        when no landmark gives information."""
        a = self.D[:, s].astype(np.float64)
        b = self.D[:, t].astype(np.float64)
        fa, fb = np.isfinite(a), np.isfinite(b)
        if bool(np.any(fa != fb)):
            return float("inf")
        both = fa & fb
        if not bool(np.any(both)):
            return 0.0
        return float(np.max(np.abs(a[both] - b[both])))

    def upper_bound(self, s: int, t: int) -> float:
        """``min_L d(L,s) + d(L,t)`` — a real path bound through the best
        landmark (inf if no landmark reaches both endpoints)."""
        a = self.D[:, s].astype(np.float64)
        b = self.D[:, t].astype(np.float64)
        both = np.isfinite(a) & np.isfinite(b)
        if not bool(np.any(both)):
            return float("inf")
        return float(np.min(a[both] + b[both]))

    def conservative_lb(self, s: int, t: int) -> float:
        """The lower bound shrunk by the f32-rounding margins — safe to
        pass as ``target_lb=`` (see module docstring).  inf (proven
        disconnection) passes through untouched: it is exact, not a
        rounding-sensitive magnitude."""
        lb = self.lower_bound(s, t)
        if not np.isfinite(lb):
            return lb
        return max(lb * (1.0 - _REL_MARGIN) - _ABS_MARGIN, 0.0)


def sample_landmark_ids(n: int, k: int, *, seed: int = 0) -> np.ndarray:
    """K distinct landmark ids, uniform without replacement.  Uniform
    sampling is the standard ALT baseline (farthest-point selection is a
    quality refinement, not a correctness one — any vertex set yields
    admissible bounds)."""
    if not 0 < k <= n:
        raise ValueError(f"need 0 < k <= n, got k={k}, n={n}")
    rng = np.random.default_rng(seed)
    return rng.choice(n, size=k, replace=False).astype(np.int32)


def build_landmarks(cg, k: int, *, seed: int = 0,
                    csr_ops: dict | None = None,
                    ids: np.ndarray | None = None,
                    sweep_fn=None) -> LandmarkSet:
    """One batched multisource solve over K sampled landmarks.

    ``csr_ops`` lets the registry reuse its staged device operands; by
    default the arrays are staged ad hoc (same cost as one scheduler
    tick's staging).  Directed graphs are refused — see module docstring.

    ``ids`` pins the landmark set instead of sampling — the lazy refresh
    after a graph mutation re-solves the SAME landmarks on the new
    version, so bound quality doesn't jitter with churn.  ``sweep_fn``
    threads a custom relax sweep to the engine (the dynamic-overlay sweep
    of dynamic/repair.py, for graphs registered as ``DynamicGraph``).
    """
    if getattr(cg, "directed", False):
        raise ValueError(
            "landmark bounds need symmetric distances; refusing to build "
            "an inadmissible bound for a directed graph")
    if ids is None:
        ids = sample_landmark_ids(cg.n, k, seed=seed)
    ops = csr_ops if csr_ops is not None else csr_operands(cg)
    D, _, _ = sssp_multisource_csr(ops, np.asarray(ids, np.int32), n=cg.n,
                                   sweep_fn=sweep_fn)
    return LandmarkSet(ids=np.asarray(ids, np.int32), D=np.asarray(D))
