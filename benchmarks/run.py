"""Benchmark orchestrator: one module per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only table3,...]

Outputs CSVs under experiments/bench/ and prints a summary.  Roofline rows
come from the dry-run JSONs (run ``python -m repro.launch.dryrun --all``
to regenerate them).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (fig23_size_sweep, roofline, table3_density,
                        table4_scaling, weak_scaling)

BENCHES = {
    "table3": table3_density.run,
    "table4": table4_scaling.run,
    "fig23": fig23_size_sweep.run,
    "weak": weak_scaling.run,       # the experiment the paper couldn't run
    "roofline": roofline.run,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(BENCHES))
    failures = 0
    for name in names:
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            BENCHES[name](args.quick)
            print(f"=== {name} done in {time.time() - t0:.1f}s ===",
                  flush=True)
        except Exception as e:
            failures += 1
            import traceback
            print(f"=== {name} FAILED: {e} ===")
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
