"""Shared benchmark utilities: timing envelope per the paper's §III."""
from __future__ import annotations

import csv
import os
import subprocess
import sys
import time
from typing import Callable

OUT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "bench")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def time_engine(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Best-of-N wall time (the paper reports single-run chrono timings;
    best-of-N with warmup removes jit compilation like the paper excludes
    graph construction)."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def run_with_devices(module: str, args: list[str], devices: int,
                     timeout: int = 900) -> str:
    """Run a repro module in a subprocess with a forced device count
    (the MPI-procs analogue for scaling benchmarks)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    r = subprocess.run([sys.executable, "-m", module, *args],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout
