"""Tracked serving benchmark gate — batched serving vs per-query solving.

Replays the three synthetic workload scenarios (repro/serve/workload.py)
through the serving subsystem in closed loop (submit everything, drain)
and measures queries/s, then replays the SAME trace sequentially — one
fresh ``frontier`` engine solve per query, no dedup, no cache, no
batching, which is what the repo could do before the serve layer existed
— and writes the comparison to ``BENCH_serve.json``.

The ``gate`` section asserts, on the largest Zipf point:

* batched-serving queries/s >= ``min_ratio`` x sequential per-query
  solving (1.5x at the full n=10000 scale; 1.0x for smoke-sized corpora
  where fixed overheads dominate), and
* the distance cache actually hits on the skewed scenario (hit rate > 0)
  — the workload property the whole cache exists for.

Correctness rides along like run_bench.py: every served answer on the
verified points is checked bitwise against a fresh ``serial`` solve.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
                                                    [--out PATH]

Spliced into EXPERIMENTS.md by benchmarks/make_experiments_md.py.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import time

import numpy as np

import jax

from benchmarks.common import REPO
from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.serve import (DistanceCache, GraphRegistry, MicroBatchScheduler,
                         SCENARIOS, make_trace)

DEFAULT_OUT = os.path.join(REPO, "BENCH_serve.json")

# scenario trace parameters (rate only shapes arrival stamps; both sides
# replay closed-loop so the comparison is pure service throughput)
RATE = 1000.0
LANDMARKS = 8
MAX_BATCH = 16
CACHE_ROWS = 256


def _make_scheduler(cg):
    """Serving stack for one graph with the jit cache pre-warmed (one
    compile per source-bucket size a drain can hit, plus the target
    early-exit path with and without a landmark bound) — compiles stay
    outside the timed windows, as run_bench.py does."""
    import jax.numpy as jnp

    from repro.core.bellman_csr import sssp_multisource_csr
    from repro.core.frontier import sssp_frontier

    registry = GraphRegistry()
    cache = DistanceCache(capacity=CACHE_ROWS)
    sched = MicroBatchScheduler(registry, cache, max_batch=MAX_BATCH)
    handle = registry.register("g", cg, landmarks=LANDMARKS)
    b = 1
    while True:
        sssp_multisource_csr(handle.csr_ops(),
                             jnp.zeros((b,), jnp.int32), n=cg.n)
        if b >= MAX_BATCH:
            break
        b *= 2
    sssp_frontier(handle.frontier_ops(), jnp.int32(0), n=cg.n,
                  target=jnp.int32(1), target_lb=jnp.float32(0.0))
    sssp_frontier(handle.frontier_ops(), jnp.int32(0), n=cg.n,
                  target=jnp.int32(1))
    return sched


def _drain_timed(sched, events, cg, *, verify: bool):
    """Submit + drain one trace closed-loop; returns (qps, hit_rate over
    this drain only)."""
    h0, m0 = sched.cache.hits, sched.cache.misses
    t0 = time.perf_counter()
    for e in events:
        sched.submit("g", e.source, e.target, arrival=e.arrival)
    answers = sched.drain()
    dt = time.perf_counter() - t0
    if verify:
        _verify(cg, answers)
    probes = (sched.cache.hits - h0) + (sched.cache.misses - m0)
    hit_rate = (sched.cache.hits - h0) / probes if probes else 0.0
    return len(events) / dt, hit_rate


def _replay_sequential(cg, events):
    """The pre-serve baseline: one fresh frontier solve per query, in
    trace order — no dedup, no cache, no batching.  Point-to-point
    queries index the solved row (no target early exit — that
    optimization belongs to the serving layer under test)."""
    shortest_paths(cg, 0, engine="frontier")               # warm jit
    t0 = time.perf_counter()
    for e in events:
        res = shortest_paths(cg, e.source, engine="frontier")
        _ = res.dist if e.target is None else float(res.dist[e.target])
    return len(events) / (time.perf_counter() - t0)


def _verify(cg, answers):
    rows = {}
    for a in answers:
        q = a.query
        if q.source not in rows:
            rows[q.source] = shortest_paths(cg, q.source,
                                            engine="serial").dist
        ref = rows[q.source]
        if q.target is None:
            ok = np.array_equal(a.value, ref)
        else:
            got, want = np.float32(a.value), ref[q.target]
            ok = got == want or (np.isinf(got) and np.isinf(want))
        if not ok:
            raise SystemExit(
                f"served answer mismatch vs serial: {q} via {a.via}")


def run(smoke: bool = False, out: str = DEFAULT_OUT) -> str:
    n = 1000 if smoke else 10000
    queries = 120 if smoke else 400
    verify = smoke or n <= 2000       # serial verify is O(n^2)/row: cap it
    cg = C.random_csr_graph(n, 3 * n, seed=n)
    records = []
    for scen in SCENARIOS:
        # two traces per scenario, different event seeds but a SHARED
        # Zipf hot set (hot_seed): the first drain is the cold start, the
        # second measures the steady serving state where the hot rows are
        # already cached — the repeat-query regime of arXiv:1505.05033.
        cold_trace = make_trace(scen, [("g", n)], num_queries=queries,
                                rate=RATE, seed=7, hot_seed=13)
        steady_trace = make_trace(scen, [("g", n)], num_queries=queries,
                                  rate=RATE, seed=8, hot_seed=13)
        sched = _make_scheduler(cg)
        qps_cold, _ = _drain_timed(sched, cold_trace, cg, verify=verify)
        qps_steady, hit_steady = _drain_timed(sched, steady_trace, cg,
                                              verify=verify)
        qps_s = _replay_sequential(cg, steady_trace)
        stats = sched.stats()
        rec = {
            "scenario": scen, "n": n, "m": 3 * n,
            "queries_per_trace": queries,
            "batched_cold_qps": round(qps_cold, 2),
            "batched_steady_qps": round(qps_steady, 2),
            "sequential_qps": round(qps_s, 2),
            "speedup_steady": round(qps_steady / qps_s, 3),
            "speedup_cold": round(qps_cold / qps_s, 3),
            "steady_cache_hit_rate": round(hit_steady, 4),
            "mean_occupancy": stats["mean_occupancy"],
            "dedup_saved": stats["dedup_saved"],
            "answered_via": stats["answered_via"],
            "verified_bitwise": verify,
        }
        records.append(rec)
        print(f"  {scen:8s} n={n}: batched cold {qps_cold:8.1f} / steady "
              f"{qps_steady:8.1f} q/s, sequential {qps_s:7.1f} q/s "
              f"({rec['speedup_steady']:.2f}x steady), steady hit rate "
              f"{hit_steady:.2f}", flush=True)

    zipf = next(r for r in records if r["scenario"] == "zipf")
    min_ratio = 1.5 if n >= 10000 else 1.0
    gate = {
        "rule": (f"steady-state batched serving >= {min_ratio}x sequential "
                 f"per-query frontier solves on the Zipf trace at n={n}, "
                 f"and the distance cache hits on the skewed scenario"),
        "zipf_speedup_steady": zipf["speedup_steady"],
        "min_ratio": min_ratio,
        "zipf_steady_cache_hit_rate": zipf["steady_cache_hit_rate"],
        "pass": bool(zipf["speedup_steady"] >= min_ratio
                     and zipf["steady_cache_hit_rate"] > 0),
    }
    doc = {
        "schema": 1,
        "meta": {
            "created_unix": int(time.time()),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "smoke": smoke,
            "rate": RATE, "landmarks": LANDMARKS,
            "max_batch": MAX_BATCH, "cache_rows": CACHE_ROWS,
        },
        "results": records,
        "gate": gate,
    }
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"\nwrote {len(records)} scenario records to {out}")
    print(f"gate[{gate['rule']}]: {'PASS' if gate['pass'] else 'FAIL'}")
    if not gate["pass"]:
        raise SystemExit("serving throughput gate failed")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized corpus (n=1000, short traces)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    run(args.smoke, out=args.out)
