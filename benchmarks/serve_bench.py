"""Tracked serving benchmark gate — batched serving vs per-query solving.

Replays the three synthetic workload scenarios (repro/serve/workload.py)
through the serving subsystem in closed loop (submit everything, drain)
and measures queries/s, then replays the SAME trace sequentially — one
fresh ``frontier`` engine solve per query, no dedup, no cache, no
batching, which is what the repo could do before the serve layer existed
— and writes the comparison to ``BENCH_serve.json``.

The ``gate`` section asserts, on the largest Zipf point:

* batched-serving queries/s >= ``min_ratio`` x sequential per-query
  solving (1.5x at the full n=10000 scale; 1.0x for smoke-sized corpora
  where fixed overheads dominate), and
* the distance cache actually hits on the skewed scenario (hit rate > 0)
  — the workload property the whole cache exists for.

Correctness rides along like run_bench.py: every served answer on the
verified points is checked bitwise against a fresh ``serial`` solve.

``--devices P`` (default 1) adds the SHARDED serving leg: the same Zipf
replay on a larger graph routed through the vertex-partitioned engines
(serve/dispatch.py) on a P-device mesh — forced host devices on CPU, the
MPI-procs analogue — against the single-device serve stack on the same
graph.  Its ``gate_sharded`` asserts the union-frontier engine relaxes
STRICTLY fewer edges per solved source than per-query single-device
``frontier`` solves (the coalescing win of arXiv:1903.12085, measured),
and at n >= 20000 additionally that sharded steady-state throughput
>= 1.0x the single-device route (the crossover DEFAULT_SHARD_THRESHOLD
encodes); smoke corpora record the ratio without enforcing it, since
below the crossover the exchange overhead is expected to dominate.

``--overload`` adds the DEGRADED-MODE leg (README.md §Robustness): the
sustainable p2p service rate is measured closed-loop, then the same
workload is offered OPEN-LOOP at 2x that rate against (a) an
unprotected scheduler — unbounded queue, no deadlines, queueing delay
compounds without limit — and (b) a protected one (bounded queue +
per-query deadlines + landmark/stale degradation).  Its
``gate_overload`` asserts the protected scheduler SHEDS OR DEGRADES
rather than collapses: every accepted query is answered, the overload
protection actually engages (load rejected/shed/expired, or answered
degraded from landmark bounds), and the p99 latency of served (ok)
answers stays <= 2x the deadline — while the unprotected p99 is
recorded for contrast.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke]
                                                    [--out PATH]
                                                    [--devices P]
                                                    [--overload]

Spliced into EXPERIMENTS.md by benchmarks/make_experiments_md.py.
"""
from __future__ import annotations

import os
import sys

# Device count must be fixed before jax initializes; parse --devices by
# hand (same pattern as run_bench.py).
if __name__ == "__main__" and "--help" not in sys.argv and "-h" not in sys.argv:
    _n = 1
    for _i, _a in enumerate(sys.argv):
        try:
            if _a == "--devices":
                _n = int(sys.argv[_i + 1])
            elif _a.startswith("--devices="):
                _n = int(_a.split("=", 1)[1])
        except (IndexError, ValueError):
            break
    if _n > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n} "
            + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import platform
import time

import numpy as np

import jax

from benchmarks.common import REPO
from repro.core import csr as C
from repro.core.api import shortest_paths
from repro.serve import (DispatchPolicy, DistanceCache, GraphRegistry,
                         MicroBatchScheduler, QueryRejected, SCENARIOS,
                         make_trace)

DEFAULT_OUT = os.path.join(REPO, "BENCH_serve.json")

# scenario trace parameters (rate only shapes arrival stamps; both sides
# replay closed-loop so the comparison is pure service throughput)
RATE = 1000.0
LANDMARKS = 8
MAX_BATCH = 16
CACHE_ROWS = 256


def _make_scheduler(cg, dispatch=None, **sched_kwargs):
    """Serving stack for one graph with the jit cache pre-warmed (one
    compile per source-bucket size a drain can hit, plus the p2p path)
    — compiles stay outside the timed windows, as run_bench.py does.
    Prewarms whichever engine family ``dispatch`` will route this graph
    to; default is an explicit never-shard policy so the single-device
    section measures the same stack at any ``--devices``.  Extra kwargs
    reach the scheduler (the overload leg's max_queue/degrade knobs)."""
    import jax.numpy as jnp

    from repro.core.bellman_csr import sssp_multisource_csr
    from repro.core.frontier import sssp_frontier

    if dispatch is None:
        dispatch = DispatchPolicy(shard_threshold=None)
    registry = GraphRegistry()
    cache = DistanceCache(capacity=CACHE_ROWS)
    sched = MicroBatchScheduler(registry, cache, max_batch=MAX_BATCH,
                                dispatch=dispatch, **sched_kwargs)
    handle = registry.register("g", cg, landmarks=LANDMARKS)
    if dispatch.would_shard(cg.n):
        from repro.core.sharded_csr import (sssp_frontier_sharded,
                                            sssp_multisource_csr_sharded)

        ch = dispatch.choose(handle, kind="batch")
        parts = handle.partition(ch.nprocs)
        pops = handle.partition_ops(ch.nprocs)
        b = 1
        while True:
            sssp_multisource_csr_sharded(
                parts, jnp.zeros((b,), jnp.int32), ch.mesh, axis=ch.axis,
                ops=pops)
            if b >= MAX_BATCH:
                break
            b *= 2
        sssp_frontier_sharded(parts, 0, ch.mesh, axis=ch.axis, ops=pops)
        return sched
    b = 1
    while True:
        sssp_multisource_csr(handle.csr_ops(),
                             jnp.zeros((b,), jnp.int32), n=cg.n)
        if b >= MAX_BATCH:
            break
        b *= 2
    sssp_frontier(handle.frontier_ops(), jnp.int32(0), n=cg.n,
                  target=jnp.int32(1), target_lb=jnp.float32(0.0))
    sssp_frontier(handle.frontier_ops(), jnp.int32(0), n=cg.n,
                  target=jnp.int32(1))
    return sched


def _drain_timed(sched, events, cg, *, verify: bool):
    """Submit + drain one trace closed-loop; returns (qps, hit_rate over
    this drain only)."""
    h0, m0 = sched.cache.hits, sched.cache.misses
    t0 = time.perf_counter()
    for e in events:
        sched.submit("g", e.source, e.target, arrival=e.arrival)
    answers = sched.drain()
    dt = time.perf_counter() - t0
    if verify:
        _verify(cg, answers)
    probes = (sched.cache.hits - h0) + (sched.cache.misses - m0)
    hit_rate = (sched.cache.hits - h0) / probes if probes else 0.0
    return len(events) / dt, hit_rate


def _replay_sequential(cg, events):
    """The pre-serve baseline: one fresh frontier solve per query, in
    trace order — no dedup, no cache, no batching.  Point-to-point
    queries index the solved row (no target early exit — that
    optimization belongs to the serving layer under test)."""
    shortest_paths(cg, 0, engine="frontier")               # warm jit
    t0 = time.perf_counter()
    for e in events:
        res = shortest_paths(cg, e.source, engine="frontier")
        _ = res.dist if e.target is None else float(res.dist[e.target])
    return len(events) / (time.perf_counter() - t0)


def _verify(cg, answers):
    rows = {}
    for a in answers:
        q = a.query
        if q.source not in rows:
            rows[q.source] = shortest_paths(cg, q.source,
                                            engine="serial").dist
        ref = rows[q.source]
        if q.target is None:
            ok = np.array_equal(a.value, ref)
        else:
            got, want = np.float32(a.value), ref[q.target]
            ok = got == want or (np.isinf(got) and np.isinf(want))
        if not ok:
            raise SystemExit(
                f"served answer mismatch vs serial: {q} via {a.via}")


def _run_sharded(smoke: bool, devices: int):
    """The --devices P leg: one Zipf cold+steady replay through the
    sharded route vs the single-device route on the same (larger) graph,
    plus the per-solve edge-work comparison against fresh per-query
    ``frontier`` solves.  Returns (record, gate_sharded)."""
    n = 1000 if smoke else 20000
    queries = 120 if smoke else 400
    verify = smoke or n <= 2000
    cg = C.random_csr_graph(n, 3 * n, seed=n)
    cold = make_trace("zipf", [("g", n)], num_queries=queries,
                      rate=RATE, seed=7, hot_seed=13)
    steady = make_trace("zipf", [("g", n)], num_queries=queries,
                        rate=RATE, seed=8, hot_seed=13)

    sched1 = _make_scheduler(cg)            # never-shard policy
    _drain_timed(sched1, cold, cg, verify=False)
    qps1, _ = _drain_timed(sched1, steady, cg, verify=False)

    shard_pol = DispatchPolicy(shard_threshold=n, nprocs=devices)
    schedP = _make_scheduler(cg, dispatch=shard_pol)
    qpsP_cold, _ = _drain_timed(schedP, cold, cg, verify=verify)
    qpsP, hitP = _drain_timed(schedP, steady, cg, verify=verify)
    s = schedP.stats()
    assert s["sharded_sources"] > 0, "sharded route never engaged"

    # edge-work baseline: fresh single-device frontier solves, one per
    # distinct trace source (what serving each query unbatched costs).
    srcs = sorted({e.source for e in cold + steady})
    base = [shortest_paths(cg, src, engine="frontier").edges_relaxed
            for src in srcs]
    frontier_per_solve = sum(base) / len(base)
    sharded_per_solve = s["sharded_edges"] / s["sharded_sources"]

    rec = {
        "scenario": "zipf-sharded", "n": n, "m": 3 * n,
        "devices": shard_pol.nprocs, "queries_per_trace": queries,
        "sharded_cold_qps": round(qpsP_cold, 2),
        "sharded_steady_qps": round(qpsP, 2),
        "single_steady_qps": round(qps1, 2),
        "speedup_vs_single_steady": round(qpsP / qps1, 3),
        "steady_cache_hit_rate": round(hitP, 4),
        "sharded_batches": s["sharded_batches"],
        "sharded_p2p": s["sharded_p2p"],
        "sharded_sources": s["sharded_sources"],
        "sharded_edges_per_solve": round(sharded_per_solve, 1),
        "frontier_edges_per_solve": round(frontier_per_solve, 1),
        "verified_bitwise": verify,
    }
    print(f"  sharded  n={n} P={shard_pol.nprocs}: cold {qpsP_cold:8.1f} / "
          f"steady {qpsP:8.1f} q/s, single-device steady {qps1:7.1f} q/s "
          f"({rec['speedup_vs_single_steady']:.2f}x) | edges/solve "
          f"{sharded_per_solve:.0f} vs frontier {frontier_per_solve:.0f}",
          flush=True)
    enforce_ratio = n >= 20000
    gate = {
        "rule": ("sharded union-frontier serving relaxes strictly fewer "
                 "edges per solved source than per-query frontier solves"
                 + (f", and sharded steady-state Zipf throughput >= 1.0x "
                    f"the single-device route at n={n}" if enforce_ratio
                    else f" (throughput ratio recorded, not enforced below "
                         f"the n=20000 crossover; n={n})")),
        "speedup_vs_single_steady": rec["speedup_vs_single_steady"],
        "min_ratio": 1.0,
        "ratio_enforced": enforce_ratio,
        "edges_ratio": round(sharded_per_solve / frontier_per_solve, 4),
        "pass": bool(sharded_per_solve < frontier_per_solve
                     and (not enforce_ratio or qpsP / qps1 >= 1.0)),
    }
    return rec, gate


def _replay_open_loop(sched, events):
    """Wall-clock open-loop replay with deadlines: submits when arrivals
    pass (dropping backpressure-rejected ones), ticks with the live
    clock so expiry/degradation engage.  Returns (answers, rejected)."""
    events = sorted(events, key=lambda e: e.arrival)
    t0 = time.perf_counter()
    i, answers, rejected = 0, [], 0
    while i < len(events) or sched.pending:
        now = time.perf_counter() - t0
        while i < len(events) and events[i].arrival <= now:
            e = events[i]
            try:
                sched.submit("g", e.source, e.target, arrival=e.arrival,
                             deadline=e.deadline)
            except QueryRejected:
                rejected += 1
            i += 1
        if sched.pending:
            out = sched.tick(now)
            done = time.perf_counter() - t0
            for a in out:
                a.done_at = done
            answers.extend(out)
        elif i < len(events):
            time.sleep(min(events[i].arrival - now, 1e-3))
    return answers, rejected


def _p99(latencies) -> float:
    lat = np.asarray(sorted(latencies), np.float64)
    return float(np.percentile(lat, 99)) if lat.size else 0.0


def _run_overload(smoke: bool):
    """The --overload leg (see module docstring): 2x-sustainable offered
    load against the unprotected vs the protected scheduler.  Returns
    (record, gate_overload)."""
    n = 1000 if smoke else 10000
    span = 0.5 if smoke else 1.0          # seconds of offered arrivals
    cg = C.random_csr_graph(n, 3 * n, seed=n)

    # Both schedulers under test are warmed IN PLACE (distance cache +
    # staged operands, on top of _make_scheduler's jit prewarm) before
    # the overload arrives: the leg measures a steady-state server hit
    # with 2x load, not a cold start whose first tick alone outlives
    # every deadline.
    warm = make_trace("p2p", [("g", n)], num_queries=160, rate=RATE,
                      seed=7, hot_seed=13)
    steady = make_trace("p2p", [("g", n)], num_queries=160, rate=RATE,
                        seed=8, hot_seed=13)
    schedU = _make_scheduler(cg)
    _drain_timed(schedU, warm, cg, verify=False)
    # sustainable service rate: closed-loop steady drain, warm cache
    capacity, _ = _drain_timed(schedU, steady, cg, verify=False)
    # service-time-aware deadline: a full batch costs ~MAX_BATCH/capacity
    # seconds of solve time on THIS host at THIS graph size, so each query
    # gets a few batch-times of budget.  A fixed wall-clock deadline is
    # either unservable (one n=10000 tick outlives it — served p99 can
    # never meet the gate no matter how well the scheduler sheds) or
    # trivially loose at smoke size.
    deadline = float(min(max(6.0 * MAX_BATCH / capacity, 0.1), 1.0))
    # protected: bounded queue + deadlines + degraded fallbacks.
    # margin = deadline/2: a query that has burned half its budget in the
    # queue is answered from landmark bounds instead of gambling on an
    # exact solve it may not get — the knob that makes degraded answers
    # actually appear under 2x load rather than only expiries.
    schedP = _make_scheduler(cg, max_queue=16 * MAX_BATCH,
                             degrade_margin=deadline / 2)
    _drain_timed(schedP, warm, cg, verify=False)
    _drain_timed(schedP, steady, cg, verify=False)
    offered = 2.0 * capacity
    # enough arrivals to span many ticks at the offered rate — an
    # open-loop trace shorter than one tick is just a burst, not load.
    queries = int(min(max(offered * span, 240), 4000))
    trace = make_trace("p2p", [("g", n)], num_queries=queries,
                       rate=offered, seed=9, hot_seed=13,
                       deadline=deadline)

    # unprotected: unbounded queue, no deadlines — queueing compounds
    ansU, _ = _replay_open_loop(
        schedU, [dataclasses.replace(e, deadline=None) for e in trace])
    p99_unprotected = _p99(a.done_at - a.query.arrival for a in ansU)

    ansP, rejected = _replay_open_loop(schedP, trace)
    served = [a for a in ansP if a.status == "ok"]
    _verify(cg, [a for a in served if a.exact])
    p99_served = _p99(a.done_at - a.query.arrival for a in served)
    sP = schedP.stats()
    shed_total = rejected + sP["shed"] + sP["deadline_expired"]
    accepted = queries - rejected

    rec = {
        "scenario": "p2p-overload", "n": n, "m": 3 * n,
        "queries": queries, "deadline_s": round(deadline, 3),
        "sustainable_qps": round(capacity, 2),
        "offered_qps": round(offered, 2),
        "unprotected_p99_s": round(p99_unprotected, 4),
        "protected_p99_served_s": round(p99_served, 4),
        "accepted": accepted,
        "answered": len(ansP),
        "served_ok": len(served),
        "served_degraded": sP["degraded_p2p"] + sP["degraded_batch"],
        "rejected_at_submit": rejected,
        "shed": sP["shed"],
        "deadline_expired": sP["deadline_expired"],
        "statuses": sP["answered_status"],
    }
    degraded = rec["served_degraded"]
    print(f"  overload n={n}: offered {offered:7.1f} q/s (2x sustainable "
          f"{capacity:.1f}) | protected p99 {p99_served * 1e3:.1f} ms "
          f"({len(served)} served, {degraded} degraded, "
          f"{shed_total} shed/rejected/expired) vs unprotected p99 "
          f"{p99_unprotected * 1e3:.1f} ms", flush=True)
    gate = {
        "rule": (f"at 2x sustainable offered load the protected scheduler "
                 f"sheds or degrades instead of collapsing: every accepted "
                 f"query is answered, overload protection actually engages "
                 f"(rejected/shed/expired or degraded answers > 0), and "
                 f"served-answer p99 stays <= 2x the {deadline:.3f}s "
                 f"service-time-scaled deadline "
                 f"(unprotected p99 recorded for contrast)"),
        "protected_p99_served_s": rec["protected_p99_served_s"],
        "p99_bound_s": 2 * deadline,
        "shed_total": shed_total,
        "degraded": degraded,
        "all_accepted_answered": bool(len(ansP) == accepted),
        "pass": bool(len(ansP) == accepted and shed_total + degraded > 0
                     and p99_served <= 2 * deadline),
    }
    return rec, gate


def _run_obs(smoke: bool, trace_out=None):
    """The --obs leg: TWO identically-warmed serving stacks drain the
    same fresh-seeded Zipf steady traces — one with tracing disabled,
    one with a live Tracer + CostLog installed — so both sides see the
    identical steady mix of cache hits and engine solves.  The gate
    pins the enabled/disabled throughput ratio >= 0.9 (best of 3 paired
    drains) — tracing must stay out of the solve hot path.  With
    ``trace_out`` the enabled side's artifacts are written + validated
    (chains included: the drains go through submit/tick/solve/answer).
    Returns (record, gate_obs)."""
    from repro.obs import (CostLog, Tracer, cost_path_for, finalize_capture,
                           set_cost_log, set_tracer)

    n = 1000 if smoke else 10000
    queries = 120 if smoke else 400
    # smoke drains finish in ~30 ms, where run-to-run jitter swamps any
    # real tracing cost — take best-of-more there; full-size drains run
    # for seconds and settle with 3.
    reps = 7 if smoke else 3
    cg = C.random_csr_graph(n, 3 * n, seed=n)
    cold = make_trace("zipf", [("g", n)], num_queries=queries,
                      rate=RATE, seed=7, hot_seed=13)
    sched_off = _make_scheduler(cg)
    sched_on = _make_scheduler(cg)
    _drain_timed(sched_off, cold, cg, verify=False)
    _drain_timed(sched_on, cold, cg, verify=False)
    tr, cl = Tracer(), CostLog()
    off_qps, on_qps = [], []
    for rep in range(reps):
        # fresh event seed per rep, shared hot set: every rep is a
        # steady-state drain (hot rows cached, cold tail solved), both
        # sides replay the identical trace, and the side order flips
        # each rep so clock/cache drift cannot bias one leg.
        steady = make_trace("zipf", [("g", n)], num_queries=queries,
                            rate=RATE, seed=8 + rep, hot_seed=13)

        def _off():
            off_qps.append(_drain_timed(sched_off, steady, cg,
                                        verify=False)[0])

        def _on():
            prev_tr, prev_cl = set_tracer(tr), set_cost_log(cl)
            try:
                on_qps.append(_drain_timed(sched_on, steady, cg,
                                           verify=False)[0])
            finally:
                set_tracer(prev_tr)
                set_cost_log(prev_cl)

        first, second = (_off, _on) if rep % 2 == 0 else (_on, _off)
        first()
        second()
    qps_off, qps_on = max(off_qps), max(on_qps)
    ratio = qps_on / qps_off
    if trace_out:
        errs = finalize_capture(tr, cl, trace_out)
        print(f"  obs      trace: {len(tr.spans)} spans -> {trace_out} | "
              f"{len(cl.records)} cost records -> {cost_path_for(trace_out)}",
              flush=True)
        if errs:
            for e in errs[:20]:
                print(f"  obs      trace INVALID: {e}", flush=True)
            raise SystemExit("observability capture invalid")
    rec = {
        "scenario": "zipf-obs", "n": n, "m": 3 * n,
        "queries_per_trace": queries, "reps": reps,
        "tracing_off_qps": round(qps_off, 2),
        "tracing_on_qps": round(qps_on, 2),
        "tracing_ratio": round(ratio, 4),
        "spans": len(tr.spans),
        "cost_records": len(cl.records),
    }
    print(f"  obs      n={n}: tracing off {qps_off:8.1f} / on "
          f"{qps_on:8.1f} q/s ({ratio:.3f}x, best of {reps}), "
          f"{len(tr.spans)} spans, {len(cl.records)} cost records",
          flush=True)
    gate = {
        "rule": (f"tracing-enabled steady Zipf serving throughput >= 0.9x "
                 f"tracing-disabled on the same warm trace at n={n} "
                 f"(best of {reps} drains each)"),
        "tracing_ratio": rec["tracing_ratio"],
        "min_ratio": 0.9,
        "pass": bool(ratio >= 0.9),
    }
    return rec, gate


def run(smoke: bool = False, out: str = DEFAULT_OUT, devices: int = 1,
        overload: bool = False, obs: bool = False,
        trace_out=None) -> str:
    n = 1000 if smoke else 10000
    queries = 120 if smoke else 400
    verify = smoke or n <= 2000       # serial verify is O(n^2)/row: cap it
    cg = C.random_csr_graph(n, 3 * n, seed=n)
    records = []
    for scen in SCENARIOS:
        # two traces per scenario, different event seeds but a SHARED
        # Zipf hot set (hot_seed): the first drain is the cold start, the
        # second measures the steady serving state where the hot rows are
        # already cached — the repeat-query regime of arXiv:1505.05033.
        cold_trace = make_trace(scen, [("g", n)], num_queries=queries,
                                rate=RATE, seed=7, hot_seed=13)
        steady_trace = make_trace(scen, [("g", n)], num_queries=queries,
                                  rate=RATE, seed=8, hot_seed=13)
        sched = _make_scheduler(cg)
        qps_cold, _ = _drain_timed(sched, cold_trace, cg, verify=verify)
        qps_steady, hit_steady = _drain_timed(sched, steady_trace, cg,
                                              verify=verify)
        qps_s = _replay_sequential(cg, steady_trace)
        stats = sched.stats()
        rec = {
            "scenario": scen, "n": n, "m": 3 * n,
            "queries_per_trace": queries,
            "batched_cold_qps": round(qps_cold, 2),
            "batched_steady_qps": round(qps_steady, 2),
            "sequential_qps": round(qps_s, 2),
            "speedup_steady": round(qps_steady / qps_s, 3),
            "speedup_cold": round(qps_cold / qps_s, 3),
            "steady_cache_hit_rate": round(hit_steady, 4),
            "mean_occupancy": stats["mean_occupancy"],
            "dedup_saved": stats["dedup_saved"],
            "answered_via": stats["answered_via"],
            "verified_bitwise": verify,
        }
        records.append(rec)
        print(f"  {scen:8s} n={n}: batched cold {qps_cold:8.1f} / steady "
              f"{qps_steady:8.1f} q/s, sequential {qps_s:7.1f} q/s "
              f"({rec['speedup_steady']:.2f}x steady), steady hit rate "
              f"{hit_steady:.2f}", flush=True)

    zipf = next(r for r in records if r["scenario"] == "zipf")
    min_ratio = 1.5 if n >= 10000 else 1.0
    gate = {
        "rule": (f"steady-state batched serving >= {min_ratio}x sequential "
                 f"per-query frontier solves on the Zipf trace at n={n}, "
                 f"and the distance cache hits on the skewed scenario"),
        "zipf_speedup_steady": zipf["speedup_steady"],
        "min_ratio": min_ratio,
        "zipf_steady_cache_hit_rate": zipf["steady_cache_hit_rate"],
        "pass": bool(zipf["speedup_steady"] >= min_ratio
                     and zipf["steady_cache_hit_rate"] > 0),
    }
    doc = {
        "schema": 2,
        "meta": {
            "created_unix": int(time.time()),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
            "smoke": smoke,
            "devices": devices,
            "rate": RATE, "landmarks": LANDMARKS,
            "max_batch": MAX_BATCH, "cache_rows": CACHE_ROWS,
        },
        "results": records,
        "gate": gate,
    }
    if devices > 1:
        srec, sgate = _run_sharded(smoke, devices)
        doc["sharded_results"] = [srec]
        doc["gate_sharded"] = sgate
    if overload:
        orec, ogate = _run_overload(smoke)
        doc["overload_results"] = [orec]
        doc["gate_overload"] = ogate
    if obs:
        brec, bgate = _run_obs(smoke, trace_out=trace_out)
        doc["obs_results"] = [brec]
        doc["gate_obs"] = bgate
    with open(out, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"\nwrote {len(records)} scenario records to {out}")
    from benchmarks.gates import enforce
    enforce(doc)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized corpus (n=1000, short traces)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--devices", type=int, default=1,
                    help="mesh size for the sharded leg (host devices are "
                         "forced before jax init; 1 = skip the leg)")
    ap.add_argument("--overload", action="store_true",
                    help="add the 2x-offered-load degraded-mode leg and "
                         "its shed-don't-collapse gate")
    ap.add_argument("--obs", action="store_true",
                    help="add the observability-overhead leg: tracing on "
                         "vs off on the same warm Zipf trace, gated at "
                         ">= 0.9x")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="with --obs: write + validate the enabled leg's "
                         "Chrome trace (and .cost.jsonl) here")
    args = ap.parse_args()
    run(args.smoke, out=args.out, devices=args.devices,
        overload=args.overload, obs=args.obs, trace_out=args.trace_out)
