"""Roofline table: aggregates the dry-run JSON records into the
EXPERIMENTS.md §Roofline markdown table (all three terms per cell, dominant
bottleneck, MODEL_FLOPS ratio, per-device memory)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import OUT_DIR, REPO

DRYRUN_DIR = os.path.join(REPO, "experiments", "dryrun")


def load_records(mesh: str | None = None, include_tagged: bool = False):
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        tagged = len(parts) < 3 or parts[2] not in ("pod", "multipod")
        if tagged and not include_tagged:
            continue                      # §Perf variants, not baselines
        with open(f) as fh:
            r = json.load(fh)
        if mesh is None or r["mesh"] == mesh:
            recs.append(r)
    return recs


def fmt_row(r) -> str:
    rf = r["roofline"]
    mem = r["memory_analysis"]
    temp = mem.get("temp_size_in_bytes", 0)
    args = mem.get("argument_size_in_bytes", 0)
    mfu = r.get("mfu_fraction")
    ur = rf.get("useful_ratio")
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['vpu_s']:.4f} "
            f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| {rf.get('latency_s', 0):.4f} "
            f"| {rf['dominant']} "
            f"| {(args + temp) / 1e9:.1f} "
            f"| {'' if ur is None else f'{ur:.2f}'} "
            f"| {'' if mfu is None else f'{mfu:.4f}'} |")


HEADER = ("| arch | shape | mesh | mxu_s | vpu_s | memory_s "
          "| collective_s | latency_s | dominant | GB/dev | useful | mfu |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|---|")


def run(quick: bool = False):
    recs = load_records()
    if not recs:
        print("no dry-run records found; run "
              "`python -m repro.launch.dryrun --all` first")
        return None
    lines = [HEADER] + [fmt_row(r) for r in recs]
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "roofline_table.md")
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print("\n".join(lines))
    # summary: worst cells
    scored = [(r.get("mfu_fraction"), r) for r in recs
              if r.get("mfu_fraction")]
    if scored:
        scored.sort(key=lambda t: t[0])
        print("\nworst roofline fractions:")
        for v, r in scored[:3]:
            print(f"  {r['arch']} {r['shape']} {r['mesh']}: mfu={v:.4f} "
                  f"dominant={r['roofline']['dominant']}")
    return out


if __name__ == "__main__":
    run()
