"""Paper Table III: dense vs sparse graphs at equal node counts.

The paper's claim: with an adjacency matrix, processing time depends on n,
not edge count.  We time the three implementations (serial = Alg.1;
bellman = the CUDA analogue's algorithm; dijkstra_sharded = the MPI
analogue, run across forced host devices in a subprocess) on the paper's
graph corpus.

CPU caveat recorded in EXPERIMENTS.md: absolute times are CPU times of the
TPU-targeted program (the kernel path runs in interpret mode); the
*density invariance* claim is what this table reproduces.
"""
from __future__ import annotations

import re

import numpy as np

import jax.numpy as jnp

from benchmarks.common import run_with_devices, time_engine, write_csv
from repro.core import graph as G
from repro.core.api import shortest_paths

PAIRS = [
    (10, 30), (10, 45),
    (100, 300), (100, 4950),
    (1000, 3000), (1000, 499500),
    (2000, 6000), (2000, 1899500),
]


def run(quick: bool = False):
    pairs = PAIRS[:6] if quick else PAIRS
    rows = []
    for n, m in pairs:
        g = G.random_graph(n, m, seed=n + m)
        adj = jnp.asarray(g.adj)
        t_serial = time_engine(
            lambda: shortest_paths(g, 0, engine="serial"))
        t_bell = time_engine(
            lambda: shortest_paths(g, 0, engine="bellman"))
        out = run_with_devices(
            "repro.launch.sssp_run",
            ["--engine", "dijkstra_sharded", "--procs", "8",
             "--nodes", str(n), "--edges", str(m), "--repeats", "2"], 8)
        t_mpi = float(re.search(r"time=([\d.e+-]+)s", out).group(1))
        rows.append([n, m, f"{t_serial:.6f}", f"{t_mpi:.6f}",
                     f"{t_bell:.6f}"])
        print(f"n={n:6d} m={m:8d} serial={t_serial:.6f}s "
              f"dijkstra_sharded(8)={t_mpi:.6f}s bellman={t_bell:.6f}s",
              flush=True)
    path = write_csv("table3_density.csv",
                     ["nodes", "edges", "serial_s", "mpi8_s", "bellman_s"],
                     rows)
    # density-invariance check (the paper's Table III conclusion)
    by_n = {}
    for n, m, ts, tm, tb in rows:
        by_n.setdefault(n, []).append(float(tb))
    for n, ts in by_n.items():
        if len(ts) == 2 and min(ts) > 0:
            ratio = max(ts) / min(ts)
            print(f"  density ratio n={n}: sparse/dense bellman "
                  f"time ratio {ratio:.2f} (paper: ~1)")
    return path


if __name__ == "__main__":
    import sys
    run("--quick" in sys.argv)
