"""Assemble EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
JSON records (single source of truth), leaving hand-written sections
(§Paper, §Perf) intact via marker comments.

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import REPO

DRYRUN_DIR = os.path.join(REPO, "experiments", "dryrun")
MD = os.path.join(REPO, "EXPERIMENTS.md")

BEGIN = "<!-- BEGIN GENERATED:{} -->"
END = "<!-- END GENERATED:{} -->"


def load(tagged: bool):
    """baseline records have filenames <arch>__<shape>__{pod|multipod};
    anything with a --tag suffix is a §Perf variant."""
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        is_tagged = len(parts) < 3 or parts[2] not in ("pod", "multipod")
        with open(f) as fh:
            r = json.load(fh)
        r["_file"] = base
        if is_tagged == tagged:
            recs.append(r)
    return recs


def dryrun_table(recs) -> str:
    rows = ["| arch | shape | mesh | chips | compile_s | params+temp GB/dev "
            "| all-gather GB | all-reduce GB | a2a GB | cperm GB |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m = r["memory_analysis"]
        w = r["weighted"]["collective_bytes"]
        gbdev = (m.get("argument_size_in_bytes", 0)
                 + m.get("temp_size_in_bytes", 0)) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} "
            f"| {r['compile_s']:.1f} | {gbdev:.1f} "
            f"| {w['all-gather']/1e9:.2f} | {w['all-reduce']/1e9:.2f} "
            f"| {w['all-to-all']/1e9:.2f} "
            f"| {w['collective-permute']/1e9:.2f} |")
    return "\n".join(rows)


def roofline_table(recs) -> str:
    rows = ["| arch | shape | mesh | mxu_s | vpu_s | mem_s | coll_s "
            "| lat_s | dominant | useful | mfu |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        rf = r["roofline"]
        ur = rf.get("useful_ratio")
        mfu = r.get("mfu_fraction")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['vpu_s']:.4f} "
            f"| {rf['memory_s']:.4f} | {rf['collective_s']:.4f} "
            f"| {rf.get('latency_s', 0):.4f} "
            f"| {rf['dominant']} "
            f"| {'' if ur is None else f'{ur:.2f}'} "
            f"| {'' if mfu is None else f'{mfu:.4f}'} |")
    return "\n".join(rows)


def splice(text: str, name: str, content: str) -> str:
    b, e = BEGIN.format(name), END.format(name)
    if b in text:
        pre, rest = text.split(b, 1)
        _, post = rest.split(e, 1)
        return pre + b + "\n" + content + "\n" + e + post
    return text + f"\n{b}\n{content}\n{e}\n"


def main():
    recs = load(tagged=False)
    text = open(MD).read() if os.path.exists(MD) else "# EXPERIMENTS\n"
    text = splice(text, "dryrun", dryrun_table(recs))
    text = splice(text, "roofline", roofline_table(recs))
    with open(MD, "w") as f:
        f.write(text)
    print(f"wrote tables for {len(recs)} records into {MD}")


if __name__ == "__main__":
    main()
